//! Multi-process integration tests for dist (ISSUE 10): a real
//! [`ShardPool`] spawning real `hpxmp worker` child processes, driven
//! through the real wire front-end.
//!
//! What must hold:
//!
//! * **Bitwise oracle** — every `Ok` reply routed through the shard
//!   fleet equals `expected_reply` bit-for-bit, and the distributed
//!   `dmatdmatmult` equals the single-process packed kernel bit-for-bit
//!   (sharding is a placement decision, never a numerics decision).
//! * **Death ≠ hang** — killing a worker mid-flight resolves every
//!   in-flight remote future (`Error` at worst), re-routes later
//!   traffic to survivors, and leaves both the front-end pending gauge
//!   and the remote registry at zero.
//! * **Supervision** — a killed worker is respawned and the fleet
//!   returns to full strength.
//!
//! Worker children inherit `HPXMP_FAULT` from the test environment, so
//! under the CI chaos rerun injected panics can kill whole worker
//! processes; strict status assertions relax while the no-hang/no-leak
//! assertions stay hard — that *is* the failure mode under test.

use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use hpxmp::blaze::{kernel, DynVector};
use hpxmp::dist::{dist_matmul, Router, ShardCfg, ShardPool};
use hpxmp::net::frame::Request;
use hpxmp::net::{
    expected_reply, Status, WireAddr, WireClient, WireOp, WireServer, WireStats,
};

static HARNESS: Mutex<()> = Mutex::new(());

fn harness() -> MutexGuard<'static, ()> {
    HARNESS.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Under the CI chaos rerun injected panics can kill worker processes
/// outright; correctness assertions relax to "every request resolved,
/// nothing hung, nothing leaked".
fn tolerate_faults() -> bool {
    std::env::var("HPXMP_FAULT").is_ok()
}

/// Pool config spawning the real `hpxmp` binary (the test binary's
/// `current_exe` would be the test harness itself).
fn pool_cfg(shards: usize, respawn: bool, stall_us: u64) -> ShardCfg {
    ShardCfg {
        shards,
        threads_per: 2,
        program: PathBuf::from(env!("CARGO_BIN_EXE_hpxmp")),
        respawn,
        stall_us,
    }
}

/// Wire front-end over the pool: the exact `hpxmp serve --shards` stack.
fn front(pool: &ShardPool) -> (Arc<WireStats>, WireServer, WireAddr) {
    let stats = Arc::new(WireStats::default());
    let router = Router::new(pool, stats.clone(), 1024);
    let server = WireServer::start_with(router, stats.clone(), &[WireAddr::Tcp("127.0.0.1:0".into())])
        .expect("bind dist front-end");
    let addr = WireAddr::Tcp(server.local_addr().expect("tcp addr").to_string());
    (stats, server, addr)
}

/// Requests keyed like the load generator: `conn << 32 | seq`, so `key`
/// picks the home shard (`key % shards`).
fn keyed_req(key: u64, seq: u64, op: WireOp, n: u32, payload: Vec<f64>) -> Request {
    Request {
        req_id: (key << 32) | seq,
        op,
        deadline_us: 0,
        n,
        payload,
    }
}

fn dim_for(op: WireOp) -> u32 {
    match op {
        WireOp::Daxpy | WireOp::VAdd => 64,
        WireOp::MatVec => 32,
        WireOp::MMult => 16,
    }
}

/// Request payload, same convention as the load generator: `MMult`
/// carries its A-seed as one double, everything else a seeded random x.
fn payload_for(op: WireOp, n: u32, seed: u64) -> Vec<f64> {
    if op == WireOp::MMult {
        vec![f64::from_bits(seed)]
    } else {
        DynVector::random(op.payload_len(n), seed).as_slice().to_vec()
    }
}

fn assert_bitwise(got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len(), "reply length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "element {i}: got {g}, want {w}");
    }
}

/// Remote futures settle on reader threads slightly after the last
/// reply is written; poll the registry to zero instead of racing it.
fn assert_remote_drains(pool: &ShardPool) {
    let t0 = Instant::now();
    while pool.pending_remote() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "remote registry leaked: {} futures still pending",
            pool.pending_remote()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// All four kernels through the full dist stack — client socket, Router,
/// worker process, Coalescer, completion frame, reply — with keys
/// landing on both shards; every `Ok` reply checked bit-for-bit against
/// the client-side oracle.
#[test]
fn router_roundtrip_bitwise_across_shards_and_ops() {
    let _g = harness();
    let mut pool = ShardPool::start(pool_cfg(2, true, 0)).expect("start pool");
    assert!(pool.wait_ready(Duration::from_secs(10)), "fleet never came up");
    let (_stats, server, addr) = front(&pool);
    for op in WireOp::ALL {
        for key in 0..2u64 {
            let mut cl = WireClient::connect(&addr).expect("connect");
            for seq in 0..3u64 {
                let n = dim_for(op);
                let payload = payload_for(op, n, 0xD15 ^ (key << 8) ^ seq);
                cl.send(&keyed_req(key, seq, op, n, payload.clone())).expect("send");
                let resp = match cl.recv() {
                    Ok(r) => r,
                    Err(_) if tolerate_faults() => continue,
                    Err(e) => panic!("{} round-trip failed (key {key}): {e}", op.name()),
                };
                assert_eq!(resp.req_id, (key << 32) | seq, "client id must be restored");
                match resp.status {
                    Status::Ok => {
                        assert_bitwise(&resp.payload, &expected_reply(op, n, &payload));
                    }
                    _ if tolerate_faults() => {}
                    s => panic!("{} (key {key}): unexpected status {s:?}", op.name()),
                }
            }
        }
    }
    if !tolerate_faults() {
        let routed = pool.routed_per_shard();
        assert!(
            routed.iter().all(|&c| c > 0),
            "both shards must carry traffic, got {routed:?}"
        );
    }
    assert!(server.drain(Duration::from_secs(10)), "front-end pending stuck");
    assert_eq!(server.pending(), 0);
    assert_remote_drains(&pool);
    drop(server);
    pool.shutdown();
}

/// Kill a worker with requests in flight (workers stalled so the kill
/// lands mid-pipeline): every admitted request must still get a reply —
/// `Ok` from a survivor, `Error` from `fail_tag` — never silence; later
/// traffic keyed to the dead shard re-routes to the survivor; pending
/// gauges drain to zero.  Respawn is off to pin down the re-route path.
#[test]
fn worker_death_mid_flight_resolves_and_reroutes() {
    let _g = harness();
    let before = hpxmp::dist::stats();
    let mut pool = ShardPool::start(pool_cfg(2, false, 50_000)).expect("start pool");
    assert!(pool.wait_ready(Duration::from_secs(10)), "fleet never came up");
    let (_stats, server, addr) = front(&pool);
    let n = 64u32;
    let per_key = 6u64;
    let mut clients = Vec::new();
    for key in 0..2u64 {
        let mut cl = WireClient::connect(&addr).expect("connect");
        for seq in 0..per_key {
            let payload = payload_for(WireOp::Daxpy, n, (key << 8) | seq);
            cl.send(&keyed_req(key, seq, WireOp::Daxpy, n, payload)).expect("send");
        }
        clients.push(cl);
    }
    // Let a couple of stalled submits land, then kill shard 0 dead.
    std::thread::sleep(Duration::from_millis(120));
    pool.kill_worker(0);
    for (key, cl) in clients.iter_mut().enumerate() {
        for got in 0..per_key {
            let resp = match cl.recv() {
                Ok(r) => r,
                Err(e) => panic!(
                    "key {key}: reply {got}/{per_key} missing after worker death: {e}"
                ),
            };
            match resp.status {
                Status::Ok | Status::Error | Status::Shed | Status::Expired => {}
                s => panic!("key {key}: unexpected status {s:?}"),
            }
        }
    }
    // Give the reader thread a beat to observe EOF and unlink slot 0,
    // then traffic homed there must probe on to the survivor.
    std::thread::sleep(Duration::from_millis(400));
    let mut cl = WireClient::connect(&addr).expect("connect");
    let payload = payload_for(WireOp::VAdd, 32, 7);
    cl.send(&keyed_req(0, 99, WireOp::VAdd, 32, payload.clone())).expect("send");
    let resp = cl.recv().expect("rerouted reply");
    match resp.status {
        Status::Ok => assert_bitwise(&resp.payload, &expected_reply(WireOp::VAdd, 32, &payload)),
        _ if tolerate_faults() => {}
        s => panic!("reroute to survivor failed: {s:?}"),
    }
    if !tolerate_faults() {
        let after = hpxmp::dist::stats();
        assert!(
            after.reroutes > before.reroutes,
            "a dead home shard must count a reroute"
        );
    }
    assert!(server.drain(Duration::from_secs(10)), "front-end pending stuck");
    assert_eq!(server.pending(), 0);
    assert_remote_drains(&pool);
    drop(server);
    pool.shutdown();
    assert_eq!(pool.pending_remote(), 0, "shutdown must cancel every leftover");
}

/// A killed worker is respawned (fresh process, fresh link generation)
/// and the fleet returns to full strength and full service.
#[test]
fn killed_worker_is_respawned() {
    let _g = harness();
    let before = hpxmp::dist::stats();
    let mut pool = ShardPool::start(pool_cfg(2, true, 0)).expect("start pool");
    assert!(pool.wait_ready(Duration::from_secs(10)), "fleet never came up");
    pool.kill_worker(0);
    let ready_again = pool.wait_ready(Duration::from_secs(10));
    if !tolerate_faults() {
        assert!(ready_again, "respawned worker never dialed back in");
    }
    let after = hpxmp::dist::stats();
    assert!(
        after.reconnects > before.reconnects,
        "a killed worker must count a respawn"
    );
    // Both slots serve again, bitwise.
    let (_stats, server, addr) = front(&pool);
    for key in 0..2u64 {
        let mut cl = WireClient::connect(&addr).expect("connect");
        let payload = payload_for(WireOp::MatVec, 32, 3 + key);
        cl.send(&keyed_req(key, 0, WireOp::MatVec, 32, payload.clone())).expect("send");
        let resp = match cl.recv() {
            Ok(r) => r,
            Err(_) if tolerate_faults() => continue,
            Err(e) => panic!("key {key}: round-trip failed after respawn: {e}"),
        };
        match resp.status {
            Status::Ok => assert_bitwise(&resp.payload, &expected_reply(WireOp::MatVec, 32, &payload)),
            _ if tolerate_faults() => {}
            s => panic!("key {key}: unexpected status {s:?} after respawn"),
        }
    }
    assert!(server.drain(Duration::from_secs(10)));
    assert_remote_drains(&pool);
    drop(server);
    pool.shutdown();
}

/// Distributed `dmatdmatmult` — broadcast B, scatter A row bands over
/// two worker processes, gather C — must be bitwise identical to the
/// single-process packed kernel (the ISSUE 10 numerics acceptance).
#[test]
fn dist_mmult_bitwise_vs_single_process() {
    let _g = harness();
    let mut pool = ShardPool::start(pool_cfg(2, true, 0)).expect("start pool");
    assert!(pool.wait_ready(Duration::from_secs(10)), "fleet never came up");
    // n = 160 splits into three 64-rounded bands over two workers, so
    // the gather really does interleave shards.
    let n = 160usize;
    let a = DynVector::random(n * n, 0xA11CE).as_slice().to_vec();
    let b = DynVector::random(n * n, 0xB0B).as_slice().to_vec();
    match dist_matmul(&pool, &a, &b, n) {
        Ok(c) => {
            let mut want = vec![0.0f64; n * n];
            kernel::packed_matmul(&a, &b, n, n, n, &mut want);
            assert_bitwise(&c, &want);
        }
        Err(_) if tolerate_faults() => {}
        Err(e) => panic!("dist mmult failed: {e}"),
    }
    assert_remote_drains(&pool);
    pool.shutdown();
}

/// Kill a worker while bands are in flight (stall holds them): the
/// gather must neither hang nor corrupt — lost bands are re-scattered
/// to the survivor/respawn and the result is still bitwise exact.
#[test]
fn dist_mmult_survives_worker_kill_mid_run() {
    let _g = harness();
    let mut pool = ShardPool::start(pool_cfg(2, true, 40_000)).expect("start pool");
    assert!(pool.wait_ready(Duration::from_secs(10)), "fleet never came up");
    let n = 192usize;
    let a = DynVector::random(n * n, 0xDEAD).as_slice().to_vec();
    let b = DynVector::random(n * n, 0xBEEF).as_slice().to_vec();
    let result = std::thread::scope(|s| {
        let h = s.spawn(|| dist_matmul(&pool, &a, &b, n));
        std::thread::sleep(Duration::from_millis(60));
        pool.kill_worker(0);
        h.join().expect("dist mmult thread panicked")
    });
    match result {
        Ok(c) => {
            let mut want = vec![0.0f64; n * n];
            kernel::packed_matmul(&a, &b, n, n, n, &mut want);
            assert_bitwise(&c, &want);
        }
        Err(_) if tolerate_faults() => {}
        Err(e) => panic!("dist mmult must survive a worker kill via retries: {e}"),
    }
    assert_remote_drains(&pool);
    pool.shutdown();
    assert_eq!(pool.pending_remote(), 0, "registry leaked after kill + shutdown");
}
