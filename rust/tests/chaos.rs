//! Chaos smoke (ISSUE 6): run real fork/task/serve traffic with the
//! fault-injection harness armed and assert the only acceptable outcome —
//! everything completes (no hangs, no poisoned-lock aborts), budgets
//! read zero, and the harness provably fired.
//!
//! Each test installs its own deterministic `FaultCfg` (fixed seed) and
//! clears it on the way out; Rust runs tests in this file in one process,
//! so installs are serialized through a mutex to keep the global harness
//! state per-test.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use hpxmp::coordinator::serve::{serve_shared, KernelMix, ServeCfg};
use hpxmp::omp::{current_ctx, fork_call, OmpRuntime};
use hpxmp::util::fault::{self, FaultCfg};

static HARNESS: Mutex<()> = Mutex::new(());

/// Run `body` with `spec` installed (fixed seed), restoring the disabled
/// state afterwards even if `body` panics.
fn with_faults(spec: &str, body: impl FnOnce()) {
    let _g = HARNESS.lock().unwrap_or_else(PoisonError::into_inner);
    fault::install(FaultCfg::parse(spec, 42));
    let r = catch_unwind(AssertUnwindSafe(body));
    fault::install(None);
    if let Err(p) = r {
        std::panic::resume_unwind(p);
    }
}

/// Fork/join storm under panic + delay injection: every region must
/// join, every contained panic must release its budget, and the suite
/// must terminate (the absence of a hang *is* the assertion).
#[test]
fn fork_storm_survives_panic_and_delay_injection() {
    with_faults("panic:0.05,delay:0.05:50", || {
        let rt = OmpRuntime::for_tests(4);
        let fired_before = fault::injections_fired();
        for _ in 0..60 {
            let _ = catch_unwind(AssertUnwindSafe(|| {
                fork_call(&rt, Some(4), |_ctx| {
                    // The injection point sits in the implicit-task body;
                    // a tiny payload keeps rounds fast.
                    std::hint::spin_loop();
                });
            }));
            assert_eq!(rt.reserved_workers(), 0, "budget leaked under chaos");
        }
        assert!(
            fault::injections_fired() > fired_before,
            "harness never fired at 5%+5% over 240 member bodies"
        );
        // Locks stayed usable: one clean region end-to-end.
        let ok = Arc::new(AtomicUsize::new(0));
        let ok2 = ok.clone();
        fault::install(None);
        fork_call(&rt, Some(4), move |_| {
            ok2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    });
}

/// Explicit-task storm: injected task-body panics must retire their
/// counters (taskgroup wait returns) and dependents must still run.
#[test]
fn task_storm_survives_injection() {
    with_faults("panic:0.05", || {
        let rt = OmpRuntime::for_tests(2);
        let done = Arc::new(AtomicUsize::new(0));
        // A Fork-site injection can kill the serialized master before it
        // spawns anything (~5% per attempt); retry until a region got
        // past the fork — what this test measures is task containment.
        for _attempt in 0..5 {
            done.store(0, Ordering::SeqCst);
            let done2 = done.clone();
            let _ = catch_unwind(AssertUnwindSafe(|| {
                fork_call(&rt, Some(1), move |_| {
                    let ctx = current_ctx().unwrap();
                    let done = done2.clone();
                    ctx.taskgroup(|| {
                        for _ in 0..200 {
                            let d = done.clone();
                            ctx.task(move || {
                                d.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                        // The group-end wait is the real assertion: a
                        // leaked counter would hang it forever.
                    });
                });
            }));
            assert_eq!(rt.reserved_workers(), 0);
            if done.load(Ordering::SeqCst) > 0 {
                break;
            }
        }
        // ~5% of 200 bodies injected; the rest completed.
        assert!(done.load(Ordering::SeqCst) > 100, "too few tasks survived");
    });
}

/// The serving scenario under chaos — the ISSUE 6 acceptance smoke:
/// 4 clients complete their streams with faults armed; crashed clients
/// are charged, survivors aggregate, nothing hangs.
#[test]
fn serve_smoke_completes_under_chaos() {
    with_faults("panic:0.01,delay:0.05:200", || {
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = ServeCfg::new(4, 2, 8, KernelMix::Vector);
        cfg.vec_len = 50_000; // over threshold: requests really fork
        let stats = serve_shared(&rt, &cfg);
        assert_eq!(
            stats.total_requests + stats.failed_requests,
            4 * 8,
            "requests neither completed nor charged"
        );
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
        // Whatever happened, the runtime still serves cleanly after.
        fault::install(None);
        let clean = serve_shared(&rt, &cfg);
        assert_eq!(clean.total_requests, 4 * 8);
        assert_eq!(clean.failed_clients, 0);
    });
}
