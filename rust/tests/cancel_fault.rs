//! End-to-end cancellation and fault-containment tests (ISSUE 6
//! acceptance), driven entirely through the crate's public surface:
//! `omp cancel(taskgroup)` skipping queued tasks, contained member
//! panics leaving the runtime poolable with a zero admission budget,
//! panicked `when_all` inputs failing instead of hanging, and the
//! policy-level deadline/token combinators.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use hpxmp::amt::cancel::CancelToken;
use hpxmp::amt::future::{when_all, Future, Outcome};
use hpxmp::omp::{current_ctx, fork_call, last_fork_was_pool_hit, CancelKind, OmpRuntime};
use hpxmp::par::{exec, ExecResult, HpxMpRuntime};

/// The headline acceptance test: `omp cancel(taskgroup)` observably
/// skips tasks that were queued but had not started when the cancel
/// fired — they retire (counters balance, the group's wait returns)
/// without running their bodies.
#[test]
fn omp_cancel_taskgroup_skips_not_yet_started_tasks() {
    // One AMT worker pins all explicit tasks to a single consumer, so
    // "not yet started" is deterministic: while the gated first task
    // blocks the worker, the 15 queued behind it cannot begin.
    let rt = OmpRuntime::for_tests(1);
    rt.icv.set_cancellation(true);
    let ran = Arc::new(AtomicUsize::new(0));
    let started = Arc::new(AtomicBool::new(false));
    let gate = Arc::new(AtomicBool::new(false));
    let (ran2, started2, gate2) = (ran.clone(), started.clone(), gate.clone());
    fork_call(&rt, Some(1), move |_| {
        let ctx = current_ctx().unwrap();
        let (ran, started, gate) = (ran2.clone(), started2.clone(), gate2.clone());
        ctx.taskgroup(|| {
            let (r, s, g) = (ran.clone(), started.clone(), gate.clone());
            ctx.task(move || {
                r.fetch_add(1, Ordering::SeqCst);
                s.store(true, Ordering::SeqCst);
                while !g.load(Ordering::SeqCst) {
                    std::hint::spin_loop();
                }
            });
            // Only cancel once the worker is provably inside task 1.
            while !started.load(Ordering::SeqCst) {
                std::hint::spin_loop();
            }
            for _ in 0..15 {
                let r = ran.clone();
                ctx.task(move || {
                    r.fetch_add(1, Ordering::SeqCst);
                });
            }
            assert!(ctx.cancel(CancelKind::Taskgroup), "cancel must arm");
            gate.store(true, Ordering::SeqCst);
            // taskgroup's group-end wait must still return: skipped
            // tasks retire without running.
        });
    });
    assert_eq!(
        ran.load(Ordering::SeqCst),
        1,
        "queued tasks ran despite the taskgroup cancel"
    );
    assert_eq!(rt.sched.task_panics(), 0, "skipping must not panic");
    assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
}

/// With the cancel-var ICV off (the default), the same cancel request is
/// a spec-mandated no-op and every task runs.
#[test]
fn taskgroup_cancel_without_icv_runs_everything() {
    let rt = OmpRuntime::for_tests(2);
    let ran = Arc::new(AtomicUsize::new(0));
    let ran2 = ran.clone();
    fork_call(&rt, Some(1), move |_| {
        let ctx = current_ctx().unwrap();
        let ran = ran2.clone();
        ctx.taskgroup(|| {
            for _ in 0..8 {
                let r = ran.clone();
                ctx.task(move || {
                    r.fetch_add(1, Ordering::SeqCst);
                });
            }
            assert!(!ctx.cancel(CancelKind::Taskgroup), "ICV off: no-op");
        });
    });
    assert_eq!(ran.load(Ordering::SeqCst), 8);
}

/// A panicking team member is contained: the region joins, the admission
/// budget returns to zero, and the team goes back to the pool un-poisoned
/// (the next fork is a pool hit, not a rebuild).
#[test]
fn contained_member_panic_leaves_runtime_poolable() {
    let rt = OmpRuntime::for_tests(4);
    for round in 0..3 {
        let r = catch_unwind(AssertUnwindSafe(|| {
            fork_call(&rt, Some(4), |ctx| {
                if ctx.tid == 2 {
                    panic!("member bomb");
                }
            });
        }));
        assert!(r.is_ok(), "round {round}: member panic escaped the region");
        assert_eq!(rt.reserved_workers(), 0, "round {round}: budget leaked");
    }
    assert!(rt.region_panics() >= 3, "containment gauge did not count");
    // The fast path survived: a fresh region re-arms a parked team.
    fork_call(&rt, Some(4), |_| {});
    assert!(last_fork_was_pool_hit(), "team pool poisoned by the panic");
    assert_eq!(rt.reserved_workers(), 0);
}

/// A `when_all` over futures where one input resolved `Panicked` must
/// fail (worst-severity outcome), not hang its waiter.
#[test]
fn when_all_with_panicked_input_fails_instead_of_hanging() {
    let inputs = [
        Future::ready(()),
        Future::with_outcome(Outcome::Panicked),
        Future::ready(()),
    ];
    let join = when_all(&inputs);
    join.wait(); // must return
    assert!(matches!(join.wait_outcome(), Outcome::Panicked));

    // Cancelled ranks below Panicked but above Value.
    let inputs = [Future::ready(()), Future::with_outcome(Outcome::Cancelled)];
    assert!(matches!(when_all(&inputs).wait_outcome(), Outcome::Cancelled));
}

/// Policy-level cancellation through the public exec API: a fired token
/// abandons every chunk; an un-cancelled run completes.
#[test]
fn policy_token_and_deadline_cancel_for_each() {
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
    let tok = CancelToken::new();
    tok.cancel();
    let ran = AtomicU32::new(0);
    let res = exec::for_each(&exec::par().on(&hpx).threads(2).token(&tok), 0..256, |r| {
        ran.fetch_add((r.end - r.start) as u32, Ordering::SeqCst);
    });
    assert!(matches!(res, ExecResult::Cancelled { .. }));
    assert_eq!(ran.load(Ordering::SeqCst), 0);

    // Expired deadline: same contract, no external token needed.
    let res = exec::for_each(
        &exec::par()
            .on(&hpx)
            .threads(2)
            .deadline(Duration::from_secs(0)),
        0..256,
        |_r| {},
    );
    assert!(matches!(res, ExecResult::Cancelled { .. }));

    // No budget, no token: the run completes.
    assert_eq!(
        exec::for_each(&exec::par().on(&hpx).threads(2), 0..256, |_r| {}),
        ExecResult::Done
    );
    assert_eq!(hpx.rt.reserved_workers(), 0, "admission budget leaked");
}

/// Hierarchical tokens: cancelling the parent fans out to children built
/// before *and* after the cancel.
#[test]
fn cancel_token_hierarchy_fans_out() {
    let parent = CancelToken::new();
    let child_before = parent.child();
    parent.cancel();
    let child_after = parent.child();
    assert!(parent.is_cancelled());
    assert!(child_before.is_cancelled());
    assert!(child_after.is_cancelled());
    // Child cancellation stays local.
    let p2 = CancelToken::new();
    let c2 = p2.child();
    c2.cancel();
    assert!(!p2.is_cancelled());
}
