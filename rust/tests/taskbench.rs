//! Integration tests for the Task Bench pattern grid (ISSUE 8): the
//! dependency graphs complete under every stealing configuration, and the
//! scheduler's counter algebra stays conserved while the fast paths
//! (steal-half batching, continuation inlining) are exercised for real.

use hpxmp::amt::{PolicyKind, Scheduler, Tuning};
use hpxmp::coordinator::taskbench::{run_graph, GraphCfg, Pattern};

const WIDTH: usize = 16;
const STEPS: usize = 8;

fn grid(pattern: Pattern) -> GraphCfg {
    GraphCfg { pattern, width: WIDTH, steps: STEPS, grain_us: 0 }
}

/// Every pattern completes under both tuning arms on the three stealing
/// policies the ablation sweeps — no hangs, no lost joins.
#[test]
fn every_pattern_completes_under_both_tuning_arms() {
    for policy in [PolicyKind::PriorityLocal, PolicyKind::Abp, PolicyKind::Local] {
        for tuning in [
            Tuning { steal_batch: 32, inline_cont: true },
            Tuning { steal_batch: 1, inline_cont: false },
        ] {
            let sched = Scheduler::with_tuning(4, policy, tuning);
            for pattern in Pattern::ALL {
                run_graph(&sched, &grid(pattern));
            }
            sched.shutdown();
        }
    }
}

/// With inlining off, every grid task round-trips through `spawn` — so one
/// graph spawns exactly `width * steps` tasks.  This pins the pattern →
/// future-graph mapping (a dropped or duplicated `then` would change the
/// count) independently of wall-clock behavior.
#[test]
fn graph_spawns_exactly_width_times_steps_tasks_without_inlining() {
    for pattern in Pattern::ALL {
        let sched = Scheduler::with_tuning(
            2,
            PolicyKind::PriorityLocal,
            Tuning { inline_cont: false, ..Tuning::default() },
        );
        run_graph(&sched, &grid(pattern));
        sched.wait_quiescent();
        let m = sched.metrics();
        assert_eq!(
            m.spawned,
            (WIDTH * STEPS) as u64,
            "pattern {} graph shape drifted: {m}",
            pattern.name()
        );
        sched.shutdown();
    }
}

/// The counter conservation identity after a storm of pattern graphs:
/// every spawned task is accounted for (`spawned == executed + cancelled`),
/// the steal pipeline is internally consistent (`steals_success <=
/// steals_attempted`, every success moved at least one task), and inlined
/// continuations stayed outside the spawn ledger.
#[test]
fn metrics_stay_conserved_across_pattern_storm() {
    let sched = Scheduler::with_tuning(
        4,
        PolicyKind::PriorityLocal,
        Tuning { steal_batch: 32, inline_cont: true },
    );
    for _ in 0..4 {
        for pattern in Pattern::ALL {
            run_graph(&sched, &grid(pattern));
        }
    }
    sched.wait_quiescent();
    let m = sched.metrics();
    assert_eq!(
        m.spawned,
        m.executed + m.cancelled,
        "conservation broken: {m}"
    );
    assert!(
        m.steals_success <= m.steals_attempted,
        "more hits than sweeps: {m}"
    );
    assert!(
        m.steal_batch_tasks >= m.steals_success,
        "a successful steal moved zero tasks: {m}"
    );
    // 20 graphs × width×steps continuations on 4 workers: with inlining on
    // at least one fulfilment must have run its continuation in place.
    assert!(m.continuations_inlined > 0, "inline path never engaged: {m}");
    sched.shutdown();
}
