//! Integration tests for the paper's Tables 1–3 (experiment ids T1–T3 in
//! DESIGN.md): the full conformance suite, against several scheduling
//! policies and team sizes, via the public crate surface only.

use std::sync::Arc;

use hpxmp::amt::PolicyKind;
use hpxmp::coordinator::conformance;
use hpxmp::omp::OmpRuntime;

fn assert_all_pass(rt: &Arc<OmpRuntime>, label: &str) {
    let checks = conformance::run_all(rt);
    let failed: Vec<String> = checks
        .iter()
        .filter(|c| !c.passed)
        .map(|c| format!("{}: {}", c.feature, c.detail))
        .collect();
    assert!(failed.is_empty(), "[{label}] failures: {failed:?}");
    assert_eq!(checks.len(), 21, "feature inventory drifted");
}

#[test]
fn tables_pass_on_default_policy() {
    let rt = OmpRuntime::for_tests(4);
    assert_all_pass(&rt, "priority-local");
}

#[test]
fn tables_pass_on_abp_policy() {
    let rt = OmpRuntime::new(4, PolicyKind::Abp);
    rt.icv.set_nthreads(4);
    assert_all_pass(&rt, "abp");
}

#[test]
fn tables_pass_on_global_policy() {
    let rt = OmpRuntime::new(4, PolicyKind::Global);
    rt.icv.set_nthreads(4);
    assert_all_pass(&rt, "global");
}

#[test]
fn tables_pass_on_static_priority_policy() {
    let rt = OmpRuntime::new(4, PolicyKind::StaticPriority);
    rt.icv.set_nthreads(4);
    assert_all_pass(&rt, "static-priority");
}

#[test]
fn tables_pass_on_hierarchical_policy() {
    let rt = OmpRuntime::new(4, PolicyKind::Hierarchical);
    rt.icv.set_nthreads(4);
    assert_all_pass(&rt, "hierarchical");
}

#[test]
fn small_worker_pool_clamps_teams_but_stays_correct() {
    // The conformance suite assumes 4-thread teams; with only 2 workers
    // team sizes clamp to 2 (DESIGN.md §4 liveness rule), so instead we
    // verify the clamp itself plus a correct 2-thread run.
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let rt = OmpRuntime::for_tests(2);
    let sizes = Arc::new(std::sync::Mutex::new(Vec::new()));
    let count = Arc::new(AtomicUsize::new(0));
    let (s, c) = (sizes.clone(), count.clone());
    hpxmp::omp::fork_call(&rt, Some(8), move |ctx| {
        s.lock().unwrap().push(ctx.num_threads());
        c.fetch_add(1, Ordering::SeqCst);
    });
    assert_eq!(count.load(Ordering::SeqCst), 2);
    assert!(sizes.lock().unwrap().iter().all(|&n| n == 2));
}

#[test]
fn render_reports_21_features() {
    let rt = OmpRuntime::for_tests(4);
    let checks = conformance::run_all(&rt);
    let report = conformance::render(&checks);
    assert!(report.contains("21/21 features pass"), "{report}");
}
