//! Property-based invariants over the scheduler and OpenMP runtime,
//! via the in-tree mini-prop framework (`util::prop`).
//!
//! These are the invariants the whole stack's soundness rests on
//! (ops.rs's disjoint-write `SendPtr` in particular assumes the loop
//! partition property).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use hpxmp::amt::task::Hint;
use hpxmp::amt::{PolicyKind, Priority, Scheduler};
use hpxmp::omp::loops::static_chunks;
use hpxmp::omp::team::fork_call;
use hpxmp::omp::{OmpRuntime, SchedKind, Schedule};
use hpxmp::util::prop::{ensure, ensure_eq, forall, PropCfg};
use hpxmp::util::rng::Xoshiro256;

/// Static loop partition: every iteration claimed exactly once, for any
/// (threads, n, chunk).
#[test]
fn prop_static_partition_exact() {
    forall(
        PropCfg { cases: 300, seed: 0xA11CE },
        |r| {
            let nthreads = 1 + r.next_below(17);
            let n = r.next_below(5000) as i64;
            let chunk = match r.next_below(3) {
                0 => None,
                _ => Some(1 + r.next_below(64)),
            };
            (nthreads, n, chunk)
        },
        |&(nthreads, n, chunk)| {
            let mut seen = vec![0u32; n as usize];
            for tid in 0..nthreads {
                for sub in static_chunks(tid, nthreads, n, chunk) {
                    ensure(sub.start >= 0 && sub.end <= n, "chunk out of range")?;
                    for i in sub {
                        seen[i as usize] += 1;
                    }
                }
            }
            ensure(
                seen.iter().all(|&c| c == 1),
                format!("partition broken for t={nthreads} n={n} chunk={chunk:?}"),
            )
        },
    );
}

/// Static partition is balanced: max-min ≤ chunk (or 1 for contiguous).
#[test]
fn prop_static_partition_balanced() {
    forall(
        PropCfg { cases: 200, seed: 7 },
        |r| {
            let nthreads = 1 + r.next_below(16);
            let n = r.next_below(2000) as i64;
            (nthreads, n)
        },
        |&(nthreads, n)| {
            let sizes: Vec<i64> = (0..nthreads)
                .map(|tid| {
                    static_chunks(tid, nthreads, n, None)
                        .map(|r| r.end - r.start)
                        .sum()
                })
                .collect();
            let max = *sizes.iter().max().unwrap();
            let min = *sizes.iter().min().unwrap();
            ensure(
                max - min <= 1,
                format!("imbalance {max}-{min} for t={nthreads} n={n}"),
            )
        },
    );
}

/// Task conservation across every scheduling policy: N spawned tasks run
/// exactly once each, under mixed priorities/hints, including tasks that
/// spawn child tasks.
#[test]
fn prop_scheduler_conserves_tasks() {
    forall(
        PropCfg { cases: 21, seed: 0xBEEF },
        |r| {
            let policy = PolicyKind::ALL[r.next_below(7)];
            let workers = 1 + r.next_below(4);
            let tasks = 50 + r.next_below(400);
            let seed = r.next_u64();
            (policy, workers, tasks, seed)
        },
        |&(policy, workers, tasks, seed)| {
            let sched = Scheduler::new(workers, policy);
            let count = Arc::new(AtomicUsize::new(0));
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let mut expected = 0usize;
            for i in 0..tasks {
                let prio = [Priority::Low, Priority::Normal, Priority::High]
                    [rng.next_below(3)];
                let hint = if rng.next_below(2) == 0 {
                    Hint::Any
                } else {
                    Hint::Worker(i % 8)
                };
                let spawn_child = rng.next_below(8) == 0;
                expected += 1 + spawn_child as usize;
                let c = count.clone();
                let sref = Arc::downgrade(&sched);
                sched.spawn(prio, hint, "prop", move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    if spawn_child {
                        if let Some(s) = sref.upgrade() {
                            let c = c.clone();
                            s.spawn(Priority::Normal, Hint::Any, "child", move || {
                                c.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                    }
                });
            }
            sched.wait_quiescent();
            let got = count.load(Ordering::SeqCst);
            sched.shutdown();
            ensure_eq(got, expected, &format!("policy {}", policy.name()))
        },
    );
}

/// Steal-half conservation over the raw queue layer (ISSUE 8): N producer
/// workers each publish a burst of tasks on their own deques, M thief
/// workers drain exclusively through batched `steal` — every task runs
/// exactly once, for any stealing policy and batch limit.  Duplication
/// would overshoot the counter; loss would hang (bounded by the deadline).
#[test]
fn prop_steal_half_conserves_tasks() {
    forall(
        PropCfg { cases: 16, seed: 0x57EA1 },
        |r| {
            let policy = PolicyKind::ALL[r.next_below(7)];
            let producers = 1 + r.next_below(3);
            let thieves = 1 + r.next_below(3);
            let per_producer = 200 + r.next_below(600);
            let limit = [2, 8, 32][r.next_below(3)];
            (policy, producers, thieves, per_producer, limit)
        },
        |&(policy, producers, thieves, per_producer, limit)| {
            let workers = producers + thieves;
            let queues = policy.build(workers);
            let total = producers * per_producer;
            let count = Arc::new(AtomicUsize::new(0));
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
            std::thread::scope(|scope| {
                for w in 0..workers {
                    let queues = &queues;
                    let count = count.clone();
                    scope.spawn(move || {
                        if w < producers {
                            for _ in 0..per_producer {
                                let c = count.clone();
                                queues.push(
                                    hpxmp::amt::Task::new(Priority::Normal, "prop", move || {
                                        c.fetch_add(1, Ordering::SeqCst);
                                    }),
                                    Hint::Worker(w),
                                    Some(w),
                                );
                            }
                        }
                        // Drain: own queue first (where stolen extras were
                        // requeued), then a batched steal sweep.
                        let mut spin = 0usize;
                        while count.load(Ordering::SeqCst) < total
                            && std::time::Instant::now() < deadline
                        {
                            if let Some(t) = queues.pop(w) {
                                t.run();
                            } else if let Some((t, _claimed)) = queues.steal(w, spin, limit) {
                                t.run();
                            } else {
                                spin += 1;
                                std::hint::spin_loop();
                            }
                        }
                    });
                }
            });
            let got = count.load(Ordering::SeqCst);
            ensure_eq(
                got,
                total,
                &format!(
                    "policy {} producers={producers} thieves={thieves} limit={limit}",
                    policy.name()
                ),
            )?;
            ensure(queues.approx_len() == 0, "queues drained")
        },
    );
}

/// Dynamic/guided worksharing covers the range exactly once for random
/// team sizes, ranges and chunks.
#[test]
fn prop_dispatch_covers_exactly() {
    forall(
        PropCfg { cases: 25, seed: 0xD15 },
        |r| {
            let threads = 1 + r.next_below(4);
            let n = 1 + r.next_below(3000) as i64;
            let chunk = 1 + r.next_below(97);
            let guided = r.next_below(2) == 1;
            (threads, n, chunk, guided)
        },
        |&(threads, n, chunk, guided)| {
            let rt = OmpRuntime::for_tests(threads);
            let seen = Arc::new(Mutex::new(vec![0u32; n as usize]));
            let s = seen.clone();
            let kind = if guided {
                SchedKind::Guided
            } else {
                SchedKind::Dynamic
            };
            fork_call(&rt, Some(threads), move |ctx| {
                ctx.for_dynamic(0..n, Schedule::new(kind, Some(chunk)), |i| {
                    s.lock().unwrap()[i as usize] += 1;
                });
            });
            let seen = seen.lock().unwrap();
            ensure(
                seen.iter().all(|&c| c == 1),
                format!("dispatch broken t={threads} n={n} chunk={chunk} guided={guided}"),
            )
        },
    );
}

/// Dependence chains execute in program order regardless of team size.
#[test]
fn prop_inout_chain_is_serialized() {
    forall(
        PropCfg { cases: 12, seed: 0xC0DE },
        |r| {
            let threads = 1 + r.next_below(4);
            let len = 2 + r.next_below(24);
            (threads, len)
        },
        |&(threads, len)| {
            use hpxmp::omp::{current_ctx, Dep, DepKind};
            let rt = OmpRuntime::for_tests(threads);
            let trace = Arc::new(Mutex::new(Vec::new()));
            let t = trace.clone();
            fork_call(&rt, Some(threads), move |c| {
                if c.tid == 0 {
                    let ctx = current_ctx().unwrap();
                    for step in 0..len {
                        let t = t.clone();
                        ctx.task_with_deps(
                            &[Dep {
                                addr: 0x5EED,
                                kind: DepKind::InOut,
                            }],
                            move || t.lock().unwrap().push(step),
                        );
                    }
                    ctx.taskwait();
                }
            });
            let got = trace.lock().unwrap().clone();
            ensure_eq(got, (0..len).collect::<Vec<_>>(), "chain order")
        },
    );
}

/// The barrier is a full synchronization: writes before it are visible
/// after it, for every policy.
#[test]
fn prop_barrier_publishes_writes() {
    forall(
        PropCfg { cases: 14, seed: 0xBA2 },
        |r| {
            let policy = PolicyKind::ALL[r.next_below(7)];
            let threads = 2 + r.next_below(3);
            (policy, threads)
        },
        |&(policy, threads)| {
            let rt = OmpRuntime::new(threads, policy);
            rt.icv.set_nthreads(threads);
            let slots = Arc::new((0..threads).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
            let fails = Arc::new(AtomicUsize::new(0));
            let (s, f) = (slots.clone(), fails.clone());
            fork_call(&rt, Some(threads), move |ctx| {
                s[ctx.tid].store(ctx.tid + 1, Ordering::Relaxed);
                ctx.barrier();
                for t in 0..threads {
                    if s[t].load(Ordering::Relaxed) != t + 1 {
                        f.fetch_add(1, Ordering::SeqCst);
                    }
                }
            });
            ensure_eq(
                fails.load(Ordering::SeqCst),
                0,
                &format!("policy {}", policy.name()),
            )
        },
    );
}

/// Blaze parallel ops bit-match the serial kernels for any size/threads —
/// the correctness contract behind every benchmark figure.
#[test]
fn prop_blaze_parallel_matches_serial() {
    use hpxmp::blaze::{self, DynVector};
    use hpxmp::par::exec::{par, Executor};
    use hpxmp::par::{HpxMpRuntime, LoopSched};
    forall(
        PropCfg { cases: 10, seed: 0xB1A2E },
        |r| {
            let threads = 1 + r.next_below(4);
            // Straddle the 38k threshold.
            let n = 30_000 + r.next_below(30_000);
            let sched = match r.next_below(3) {
                0 => LoopSched::Static { chunk: None },
                1 => LoopSched::Dynamic { chunk: 4096 },
                _ => LoopSched::Guided { chunk: 2048 },
            };
            let seed = r.next_u64();
            (threads, n, sched, seed)
        },
        |&(threads, n, sched, seed)| {
            let rt = HpxMpRuntime::new(OmpRuntime::for_tests(threads));
            let a = DynVector::random(n, seed);
            let b0 = DynVector::random(n, seed ^ 1);
            let mut b_par = b0.clone();
            let pol = par().on(&rt).threads(threads).chunk(sched);
            blaze::daxpy(&pol, 3.0, &a, &mut b_par);
            let mut b_ser = b0.clone();
            hpxmp::blaze::serial::daxpy_slice(3.0, a.as_slice(), b_ser.as_mut_slice());
            ensure(
                b_par.max_abs_diff(&b_ser) == 0.0,
                format!("daxpy mismatch n={n} threads={threads} {:?}", rt.name()),
            )
        },
    );
}
