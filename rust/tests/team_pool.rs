//! Multi-tenant team-pool integration tests (ISSUE 3): alternating-size
//! re-arm regression, the 8-client × 200-region concurrency stress (pool
//! fast-path attribution, `Ctx` leak check, metrics conservation), and
//! deterministic admission degradation under budget exhaustion.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use hpxmp::omp::{fork_call, last_fork_was_pool_hit, OmpRuntime};

/// Regression for the PR-1 size-mismatch discard: the single-slot cache
/// `take()`n-and-dropped a parked team whose size didn't match, so a
/// 2,4,2,4,… stream re-allocated every region.  The keyed pool must park
/// one team per size and re-arm **every** region after the first two.
#[test]
fn alternating_size_stream_rearms_instead_of_reallocating() {
    let rt = OmpRuntime::for_tests(4);
    // Warm one team per size (two cold misses).
    fork_call(&rt, Some(2), |_| {});
    fork_call(&rt, Some(4), |_| {});
    let (hits0, misses0) = (rt.pool_hits(), rt.pool_misses());
    for i in 0..100 {
        let size = if i % 2 == 0 { 2 } else { 4 };
        fork_call(&rt, Some(size), |_| {});
        assert!(
            last_fork_was_pool_hit(),
            "region {i} (size {size}) fell off the re-arm fast path"
        );
    }
    assert_eq!(rt.pool_hits() - hits0, 100, "every region must re-arm");
    assert_eq!(rt.pool_misses(), misses0, "no region may re-allocate");
}

/// The ISSUE 3 acceptance stress: 8 external OS threads each run 200
/// fork/join regions of varying requested sizes concurrently on ONE
/// shared runtime.  Checks: no deadlock (the test completes), every
/// member of every region runs exactly once, at least 2 client threads
/// hit the team-pool re-arm fast path, parked `Ctx`s hold no leaked
/// references once quiescent, and scheduler metrics add up.
#[test]
fn eight_clients_two_hundred_regions_stress() {
    const CLIENTS: usize = 8;
    const REGIONS: usize = 200;
    let rt = OmpRuntime::for_tests(8);

    let handles: Vec<_> = (0..CLIENTS)
        .map(|ci| {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut my_pool_hits = 0usize;
                for i in 0..REGIONS {
                    // Varying *requested* sizes; admission may grant less
                    // under concurrency, so assert against the granted
                    // team size observed inside the region.
                    let req = [1usize, 2, 4, 3][(ci + i) % 4];
                    let arrived = Arc::new(AtomicUsize::new(0));
                    let a = arrived.clone();
                    let granted = Arc::new(AtomicUsize::new(0));
                    let g = granted.clone();
                    fork_call(&rt, Some(req), move |ctx| {
                        g.store(ctx.num_threads(), Ordering::SeqCst);
                        assert!(ctx.tid < ctx.num_threads());
                        a.fetch_add(1, Ordering::SeqCst);
                        ctx.barrier();
                        assert_eq!(
                            a.load(Ordering::SeqCst),
                            ctx.num_threads(),
                            "barrier released before every member arrived"
                        );
                    });
                    let n = granted.load(Ordering::SeqCst);
                    assert!(n >= 1 && n <= req, "granted {n} outside 1..={req}");
                    assert_eq!(
                        arrived.load(Ordering::SeqCst),
                        n,
                        "client {ci} region {i}: member lost or duplicated"
                    );
                    if last_fork_was_pool_hit() {
                        my_pool_hits += 1;
                    }
                }
                my_pool_hits
            })
        })
        .collect();

    let per_client_hits: Vec<usize> = handles
        .into_iter()
        .map(|h| h.join().expect("client thread panicked (or deadlocked)"))
        .collect();

    // ≥ 2 distinct clients must have ridden the re-arm fast path.
    let clients_with_hits = per_client_hits.iter().filter(|&&h| h > 0).count();
    assert!(
        clients_with_hits >= 2,
        "only {clients_with_hits} clients hit the team pool (per-client: {per_client_hits:?})"
    );
    assert!(rt.pool_hits() > 0, "global pool hit counter stayed zero");

    // Quiesce, then audit: no reservation leaked, no live tasks, metrics
    // conserved (every spawned task executed), parked Ctxs unreferenced.
    rt.sched.wait_quiescent();
    assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    assert_eq!(rt.sched.live_tasks(), 0);
    assert_eq!(rt.sched.task_panics(), 0, "a region body panicked");
    let m = rt.sched.metrics();
    assert_eq!(m.spawned, m.executed, "spawned/executed diverged: {m}");

    let mut parked = 0usize;
    while let Some(hot) = rt.debug_take_hot_team() {
        parked += 1;
        for (i, ctx) in hot.ctxs.iter().enumerate() {
            assert_eq!(
                Arc::strong_count(ctx),
                1,
                "parked ctx {i} of a size-{} team holds leaked references",
                hot.team.size
            );
        }
        assert_eq!(Arc::strong_count(&hot.team), hot.ctxs.len() + 1);
    }
    assert!(parked >= 1, "no team left parked after the stress");
}

/// Deterministic admission degradation: on a 2-worker runtime, two live
/// size-2 regions reserve one worker slot each (masters run inline), so a
/// third concurrent top-level region finds the whole budget gone and must
/// serialize inline.  Pre-admission, its spawned member could never run —
/// the nesting guard forbids cross-team helping at the same level — so
/// this exact shape deadlocked.
#[test]
fn admission_serializes_when_budget_is_exhausted() {
    let rt = OmpRuntime::for_tests(2);
    let release = Arc::new(AtomicBool::new(false));
    let holders: Vec<_> = (0..2)
        .map(|_| {
            let rt = rt.clone();
            let release = release.clone();
            std::thread::spawn(move || {
                fork_call(&rt, Some(2), move |_| {
                    while !release.load(Ordering::SeqCst) {
                        std::thread::yield_now();
                    }
                });
            })
        })
        .collect();
    // Each holder reserves 1 of the 2 worker slots at fork entry and
    // keeps it until `release`: once the gauge reads 2, the budget is
    // provably exhausted for the whole window the third fork runs in.
    while rt.reserved_workers() < 2 {
        std::thread::yield_now();
    }
    let third_size = Arc::new(AtomicUsize::new(0));
    let s = third_size.clone();
    fork_call(&rt, Some(2), move |ctx| {
        s.store(ctx.num_threads(), Ordering::SeqCst);
    });
    let n = third_size.load(Ordering::SeqCst);
    assert_eq!(
        n, 1,
        "third concurrent region must degrade to serialized-inline while \
         the budget is held (got team size {n})"
    );
    release.store(true, Ordering::SeqCst);
    for h in holders {
        h.join().unwrap();
    }
    rt.sched.wait_quiescent();
    assert_eq!(rt.reserved_workers(), 0);
}

/// Disabling hot teams drains every parked team, from every shard.
#[test]
fn disabling_hot_teams_drains_the_pool() {
    let rt = OmpRuntime::for_tests(4);
    fork_call(&rt, Some(2), |_| {});
    fork_call(&rt, Some(3), |_| {});
    fork_call(&rt, Some(4), |_| {});
    assert!(rt.pool_parked() >= 3);
    rt.set_hot_team_enabled(false);
    assert_eq!(rt.pool_parked(), 0, "drain left teams parked");
    assert!(rt.debug_take_hot_team().is_none());
}
