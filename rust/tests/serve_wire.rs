//! Loopback integration tests for the socket front-end (ISSUE 9): a
//! real [`WireServer`] on an ephemeral port, driven through real
//! sockets.
//!
//! What must hold:
//!
//! * **Bitwise oracle** — every `Ok` reply equals `expected_reply`
//!   bit-for-bit, whatever batch the request rode in (coalescing is a
//!   scheduling decision, never a numerics decision).
//! * **Protocol robustness** — malformed/truncated frames get
//!   `BadRequest` (when addressable) and a hang-up; the server survives.
//! * **Accounting** — expired deadlines answer `Expired`; dropped
//!   connections mid-flight leak neither the pending gauge nor the
//!   admission budget.
//! * **Thread bound** — the server's thread count is a small constant
//!   independent of connection count (no thread-per-connection).
//! * **Chaos** — with the fault harness armed the server degrades to
//!   `Error` responses, never to a hang or a leak.
//!
//! The fault harness is process-global, so every test serializes on one
//! mutex; under the CI chaos rerun (`HPXMP_FAULT` in the environment)
//! strict status assertions relax — injected panics legitimately fail
//! batches — while the no-hang/no-leak assertions stay hard.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use hpxmp::blaze::DynVector;
use hpxmp::net::frame::{encode_request, Request, MAX_FRAME_LEN, REQ_ID_OFFSET};
use hpxmp::net::{
    expected_reply, BatchCfg, Status, WireAddr, WireClient, WireOp, WireServer,
};
use hpxmp::omp::OmpRuntime;
use hpxmp::util::fault::{self, FaultCfg};

static HARNESS: Mutex<()> = Mutex::new(());

fn harness() -> MutexGuard<'static, ()> {
    HARNESS.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Under the CI chaos rerun injected panics turn whole batches into
/// `Error` responses and can break client round-trips by design; the
/// correctness assertions relax to "accounting balanced, nothing hung".
fn tolerate_faults() -> bool {
    std::env::var("HPXMP_FAULT").is_ok()
}

/// Deterministic batching knobs, independent of `HPXMP_COALESCE*` env.
fn base_cfg() -> BatchCfg {
    BatchCfg {
        coalesce: true,
        coalesce_us: 150,
        ..BatchCfg::default()
    }
}

fn start(cfg: BatchCfg) -> (Arc<OmpRuntime>, WireServer, WireAddr) {
    let rt = OmpRuntime::for_tests(2);
    let server = WireServer::start_tcp(rt.clone(), "127.0.0.1:0", cfg).expect("bind wire server");
    let addr = WireAddr::Tcp(server.local_addr().expect("tcp addr").to_string());
    (rt, server, addr)
}

fn dim_for(op: WireOp) -> u32 {
    match op {
        WireOp::Daxpy | WireOp::VAdd => 64,
        WireOp::MatVec => 32,
        WireOp::MMult => 16,
    }
}

/// Request payload: `MMult` carries its A-seed as one double, everything
/// else a seeded random x — same convention as the load generator.
fn payload_for(op: WireOp, n: u32, seed: u64) -> Vec<f64> {
    if op == WireOp::MMult {
        vec![f64::from_bits(seed)]
    } else {
        DynVector::random(op.payload_len(n), seed).as_slice().to_vec()
    }
}

fn assert_bitwise(got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len(), "reply length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "element {i}: got {g}, want {w}");
    }
}

/// The admission budget releases on worker threads slightly after the
/// last response is written; poll it to zero instead of racing it.
fn assert_budget_drains(rt: &OmpRuntime) {
    let t0 = Instant::now();
    while rt.reserved_workers() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "admission budget leaked: {} workers still reserved",
            rt.reserved_workers()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// N concurrent connections per op, every `Ok` reply checked bit-for-bit
/// against the client-side oracle — the core coalescing-correctness
/// assertion, exercised across all four kernels at once so same-shape
/// requests from different connections really do share batches.
#[test]
fn bitwise_oracle_across_ops_and_connections() {
    let _g = harness();
    let (rt, server, addr) = start(base_cfg());
    let mut handles = Vec::new();
    for op in WireOp::ALL {
        for c in 0..4u64 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let n = dim_for(op);
                let mut cl = WireClient::connect(&addr).expect("connect");
                for r in 0..3u64 {
                    let payload = payload_for(op, n, 0xA5A5 ^ (c << 8) ^ r);
                    let resp = match cl.request(op, n, payload.clone(), 0) {
                        Ok(resp) => resp,
                        Err(_) if tolerate_faults() => return,
                        Err(e) => panic!("{} round-trip failed: {e}", op.name()),
                    };
                    match resp.status {
                        Status::Ok => {
                            assert_bitwise(&resp.payload, &expected_reply(op, n, &payload));
                        }
                        _ if tolerate_faults() => {}
                        s => panic!("{}: unexpected status {s:?}", op.name()),
                    }
                }
            }));
        }
    }
    for h in handles {
        h.join().expect("client thread");
    }
    assert!(server.drain(Duration::from_secs(10)), "pending stuck");
    assert_eq!(server.pending(), 0);
    assert_budget_drains(&rt);
}

#[test]
fn malformed_frames_get_bad_request_and_drop() {
    let _g = harness();
    let (_rt, server, addr) = start(base_cfg());
    let valid = Request {
        req_id: 77,
        op: WireOp::Daxpy,
        deadline_us: 0,
        n: 4,
        payload: vec![1.0, 2.0, 3.0, 4.0],
    };

    // Unknown op code: the header is readable, so the server answers
    // BadRequest at the right id, then hangs up (desynced stream).
    let mut cl = WireClient::connect(&addr).expect("connect");
    let mut bytes = encode_request(&valid);
    bytes[REQ_ID_OFFSET + 8] = 200;
    cl.send_raw(&bytes).expect("send");
    let resp = cl.recv().expect("bad-request reply");
    assert_eq!(resp.req_id, 77);
    assert_eq!(resp.status, Status::BadRequest);
    assert!(cl.recv().is_err(), "connection must be dropped after a bad frame");

    // Header n disagreeing with the payload length: same contract.
    let mut cl = WireClient::connect(&addr).expect("connect");
    let mut bytes = encode_request(&valid);
    bytes[19..23].copy_from_slice(&5u32.to_le_bytes());
    cl.send_raw(&bytes).expect("send");
    let resp = cl.recv().expect("bad-request reply");
    assert_eq!(resp.req_id, 77);
    assert_eq!(resp.status, Status::BadRequest);

    // Foreign protocol version: the fixed-offset contract keeps the id
    // readable, so the reply is an addressed BadRequest, not a desync.
    let mut cl = WireClient::connect(&addr).expect("connect");
    let mut bytes = encode_request(&valid);
    bytes[4] = 99; // version byte
    cl.send_raw(&bytes).expect("send");
    let resp = cl.recv().expect("bad-version reply");
    assert_eq!(resp.req_id, 77);
    assert_eq!(resp.status, Status::BadRequest);
    assert!(cl.recv().is_err(), "mismatched version must drop the connection");

    // Oversized length prefix: no id to address -> silent hang-up.
    let mut cl = WireClient::connect(&addr).expect("connect");
    cl.send_raw(&(MAX_FRAME_LEN + 1).to_le_bytes()).expect("send");
    assert!(cl.recv().is_err(), "oversized frame must drop the connection");

    // Truncated frame then disconnect: nothing to answer, nothing stuck.
    let mut cl = WireClient::connect(&addr).expect("connect");
    cl.send_raw(&encode_request(&valid)[..10]).expect("send");
    drop(cl);

    assert!(
        server.stats().bad_frames.load(Ordering::Relaxed) >= 3,
        "decode rejections must be counted"
    );

    // The server survived every abuse: a clean request still round-trips.
    let mut cl = WireClient::connect(&addr).expect("connect");
    let payload = payload_for(WireOp::VAdd, 8, 1);
    match cl.request(WireOp::VAdd, 8, payload.clone(), 0) {
        Ok(r) if r.status == Status::Ok => {
            assert_bitwise(&r.payload, &expected_reply(WireOp::VAdd, 8, &payload));
        }
        Ok(_) | Err(_) if tolerate_faults() => {}
        Ok(r) => panic!("unexpected status {:?}", r.status),
        Err(e) => panic!("server wedged after malformed frames: {e}"),
    }
}

/// A 1 µs budget cannot survive the coalescing window: both shedding
/// arms must answer `Expired` (shed: partitioned out before compute;
/// no-shed: the batch deadline cancels the dispatch on arrival), and a
/// generous budget completes unflagged.
#[test]
fn expired_deadlines_are_answered_expired() {
    let _g = harness();
    for shed in [true, false] {
        let (rt, server, addr) = start(BatchCfg { shed, ..base_cfg() });
        let mut cl = WireClient::connect(&addr).expect("connect");
        let payload = payload_for(WireOp::Daxpy, 64, 9);
        let resp = cl.request(WireOp::Daxpy, 64, payload.clone(), 1).expect("reply");
        match resp.status {
            Status::Expired => assert!(resp.payload.is_empty(), "expired must carry no payload"),
            Status::Error if tolerate_faults() => {}
            s => panic!("1us deadline must expire (shed={shed}), got {s:?}"),
        }
        let resp = cl
            .request(WireOp::Daxpy, 64, payload.clone(), 2_000_000)
            .expect("reply");
        match resp.status {
            Status::Ok => {
                assert!(!resp.deadline_missed, "2s budget flagged as missed");
                assert_bitwise(&resp.payload, &expected_reply(WireOp::Daxpy, 64, &payload));
            }
            _ if tolerate_faults() => {}
            s => panic!("unexpected status {s:?}"),
        }
        if !tolerate_faults() {
            assert!(
                server.stats().expired.load(Ordering::Relaxed) >= 1,
                "server-side expiry must be counted (shed={shed})"
            );
        }
        assert!(server.drain(Duration::from_secs(10)));
        assert_budget_drains(&rt);
    }
}

/// Hang up with requests still in flight, repeatedly: every admitted
/// request must still pass through `respond` exactly once (pending gauge
/// back to 0) and the admission budget must read zero — the
/// leak-freedom half of the ISSUE 9 acceptance.
#[test]
fn dropped_connection_mid_flight_leaks_nothing() {
    let _g = harness();
    let (rt, server, addr) = start(base_cfg());
    for round in 0..3 {
        let mut cl = WireClient::connect(&addr).expect("connect");
        for i in 0..16u64 {
            let req = Request {
                req_id: i,
                op: WireOp::Daxpy,
                deadline_us: 0,
                n: 4096,
                payload: payload_for(WireOp::Daxpy, 4096, i),
            };
            if cl.send(&req).is_err() {
                break;
            }
        }
        drop(cl); // responses now hit a dead sink — they must still settle
        assert!(
            server.drain(Duration::from_secs(10)),
            "round {round}: {} requests stuck pending",
            server.pending()
        );
        assert_eq!(server.pending(), 0, "round {round}");
    }
    assert_budget_drains(&rt);
    // The server still serves new connections afterwards.
    let mut cl = WireClient::connect(&addr).expect("connect");
    let payload = payload_for(WireOp::VAdd, 16, 5);
    match cl.request(WireOp::VAdd, 16, payload.clone(), 0) {
        Ok(r) if r.status == Status::Ok => {
            assert_bitwise(&r.payload, &expected_reply(WireOp::VAdd, 16, &payload));
        }
        Ok(_) | Err(_) if tolerate_faults() => {}
        Ok(r) => panic!("unexpected status {:?}", r.status),
        Err(e) => panic!("server wedged after dropped connections: {e}"),
    }
}

/// The "no thread-per-connection" bar: the server's thread set is fixed
/// at start (acceptor + IO shards + batcher) and must not grow when 32
/// connections arrive and round-trip.
#[test]
fn thread_count_stays_constant_across_connections() {
    let _g = harness();
    let (_rt, server, addr) = start(base_cfg());
    let tc = server.thread_count();
    assert!(tc <= 4, "expected acceptor + 2 io shards + batcher, got {tc}");
    let mut clients: Vec<WireClient> = (0..32)
        .map(|_| WireClient::connect(&addr).expect("connect"))
        .collect();
    for (i, cl) in clients.iter_mut().enumerate() {
        let payload = payload_for(WireOp::VAdd, 16, i as u64);
        match cl.request(WireOp::VAdd, 16, payload.clone(), 0) {
            Ok(r) if r.status == Status::Ok => {
                assert_bitwise(&r.payload, &expected_reply(WireOp::VAdd, 16, &payload));
            }
            Ok(_) | Err(_) if tolerate_faults() => {}
            Ok(r) => panic!("conn {i}: unexpected status {:?}", r.status),
            Err(e) => panic!("conn {i}: round-trip failed: {e}"),
        }
    }
    assert_eq!(
        server.thread_count(),
        tc,
        "server grew threads with connections"
    );
    assert!(server.stats().accepted.load(Ordering::Relaxed) >= 32);
}

/// A pipelined same-shape burst inside one wide window must coalesce
/// (batch > 1 observed server-side) and every member must still get its
/// own bitwise-exact reply.
#[test]
fn coalescing_batches_pipelined_bursts_bitwise() {
    let _g = harness();
    let (_rt, server, addr) = start(BatchCfg { coalesce_us: 5_000, ..base_cfg() });
    let mut cl = WireClient::connect(&addr).expect("connect");
    let n = 64u32;
    let payloads: Vec<Vec<f64>> =
        (0..8u64).map(|i| payload_for(WireOp::Daxpy, n, 0xB00 + i)).collect();
    for (i, p) in payloads.iter().enumerate() {
        cl.send(&Request {
            req_id: i as u64,
            op: WireOp::Daxpy,
            deadline_us: 0,
            n,
            payload: p.clone(),
        })
        .expect("send");
    }
    let mut got = 0;
    while got < payloads.len() {
        let resp = match cl.recv() {
            Ok(r) => r,
            Err(_) if tolerate_faults() => break,
            Err(e) => panic!("burst reply missing: {e}"),
        };
        match resp.status {
            Status::Ok => {
                let p = &payloads[resp.req_id as usize];
                assert_bitwise(&resp.payload, &expected_reply(WireOp::Daxpy, n, p));
            }
            _ if tolerate_faults() => {}
            s => panic!("unexpected status {s:?}"),
        }
        got += 1;
    }
    if !tolerate_faults() {
        assert!(
            server.stats().max_batch.load(Ordering::Relaxed) >= 2,
            "pipelined same-shape burst never coalesced"
        );
    }
}

/// `HPXMP_COALESCE=0` semantics: with coalescing off every request is
/// its own dispatch (batch of one), and replies stay bitwise-identical
/// to the batched arm's — the ablation the wire bench sweeps.
#[test]
fn unbatched_arm_dispatches_singles_same_numerics() {
    let _g = harness();
    let (_rt, server, addr) = start(BatchCfg { coalesce: false, ..base_cfg() });
    let mut cl = WireClient::connect(&addr).expect("connect");
    let n = 64u32;
    for i in 0..6u64 {
        let payload = payload_for(WireOp::Daxpy, n, 0xC00 + i);
        match cl.request(WireOp::Daxpy, n, payload.clone(), 0) {
            Ok(r) if r.status == Status::Ok => {
                assert_bitwise(&r.payload, &expected_reply(WireOp::Daxpy, n, &payload));
            }
            Ok(_) | Err(_) if tolerate_faults() => {}
            Ok(r) => panic!("unexpected status {:?}", r.status),
            Err(e) => panic!("round-trip failed: {e}"),
        }
    }
    if !tolerate_faults() {
        let s = server.stats();
        assert_eq!(
            s.batches.load(Ordering::Relaxed),
            s.batched_requests.load(Ordering::Relaxed),
            "unbatched arm must dispatch one request per batch"
        );
        assert!(s.max_batch.load(Ordering::Relaxed) <= 1);
    }
}

#[test]
fn uds_roundtrip_and_unlink() {
    let _g = harness();
    let path = std::env::temp_dir().join(format!("hpxmp-wire-{}.sock", std::process::id()));
    let rt = OmpRuntime::for_tests(2);
    let server =
        WireServer::start(rt, &[WireAddr::Uds(path.clone())], base_cfg()).expect("bind uds");
    let mut cl = WireClient::connect(&WireAddr::Uds(path.clone())).expect("connect uds");
    let payload = payload_for(WireOp::MatVec, 32, 3);
    match cl.request(WireOp::MatVec, 32, payload.clone(), 0) {
        Ok(r) if r.status == Status::Ok => {
            assert_bitwise(&r.payload, &expected_reply(WireOp::MatVec, 32, &payload));
        }
        Ok(_) | Err(_) if tolerate_faults() => {}
        Ok(r) => panic!("unexpected status {:?}", r.status),
        Err(e) => panic!("uds round-trip failed: {e}"),
    }
    drop(cl);
    drop(server);
    assert!(!path.exists(), "socket path must be unlinked on shutdown");
}

/// The fault harness armed over the whole wire path: injected panics may
/// fail batches (`Error` responses) but must never hang the server,
/// strand the pending gauge, or leak the admission budget — and service
/// must be clean again once the harness is cleared.
#[test]
fn chaos_profile_serves_without_hang_or_leak() {
    let _g = harness();
    fault::install(FaultCfg::parse("panic:0.05,delay:0.05:100", 42));
    let r = catch_unwind(AssertUnwindSafe(|| {
        let (rt, server, addr) = start(base_cfg());
        for c in 0..4u64 {
            let mut cl = WireClient::connect(&addr).expect("connect");
            for i in 0..12u64 {
                let payload = payload_for(WireOp::Daxpy, 256, (c << 8) | i);
                match cl.request(WireOp::Daxpy, 256, payload.clone(), 0) {
                    Ok(resp) => match resp.status {
                        Status::Ok => assert_bitwise(
                            &resp.payload,
                            &expected_reply(WireOp::Daxpy, 256, &payload),
                        ),
                        // Injected failures surface as terminal statuses,
                        // never as corrupt payloads or silence.
                        Status::Error | Status::Expired | Status::Shed => {}
                        s => panic!("unexpected status {s:?}"),
                    },
                    Err(_) => break,
                }
            }
        }
        assert!(
            server.drain(Duration::from_secs(15)),
            "chaos left {} requests pending",
            server.pending()
        );
        assert_eq!(server.pending(), 0);
        assert_budget_drains(&rt);
        // Clean service after the harness clears.
        fault::install(None);
        let mut cl = WireClient::connect(&addr).expect("connect");
        let payload = payload_for(WireOp::VAdd, 64, 77);
        let resp = cl
            .request(WireOp::VAdd, 64, payload.clone(), 0)
            .expect("clean round-trip after chaos");
        assert_eq!(resp.status, Status::Ok);
        assert_bitwise(&resp.payload, &expected_reply(WireOp::VAdd, 64, &payload));
    }));
    fault::install(None);
    if let Err(p) = r {
        std::panic::resume_unwind(p);
    }
}
