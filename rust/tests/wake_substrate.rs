//! Regression guards for the sleep/wake substrate (ISSUE 4): targeted
//! wakes under submitter concurrency, the lost-wakeup race (spawn vs a
//! worker entering park), wait_quiescent/shutdown interleavings, and the
//! no-busy-wait guarantee (quiescence waiters park and are notified on
//! retire — `quiesce_parks` metric).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use hpxmp::amt::task::Hint;
use hpxmp::amt::{IdleMode, PolicyKind, Priority, Scheduler};
use hpxmp::util::timing::spin_wait as busy_wait;

/// K submitter threads hammer a small pool with hinted spawns: every task
/// retires, spawn/execute conserve, and delivered wakes never exceed the
/// parks that minted their credits.
#[test]
fn stress_concurrent_submitters_on_small_pool() {
    let s = Scheduler::with_idle_mode(2, PolicyKind::PriorityLocal, IdleMode::Targeted);
    let done = Arc::new(AtomicUsize::new(0));
    let start = Arc::new(Barrier::new(9));
    let handles: Vec<_> = (0..8)
        .map(|ci| {
            let s = s.clone();
            let done = done.clone();
            let start = start.clone();
            std::thread::spawn(move || {
                start.wait();
                for i in 0..500 {
                    let done = done.clone();
                    let hint = if i % 3 == 0 {
                        Hint::Any
                    } else {
                        Hint::Worker((ci + i) % 2)
                    };
                    s.spawn(Priority::Normal, hint, "stress", move || {
                        done.fetch_add(1, Ordering::Relaxed);
                    });
                    if i % 64 == 0 {
                        // Periodically let the pool drain so parks (and the
                        // wake path out of them) actually happen mid-storm.
                        busy_wait(Duration::from_micros(200));
                    }
                }
            })
        })
        .collect();
    start.wait();
    for h in handles {
        h.join().unwrap();
    }
    s.wait_quiescent();
    assert_eq!(done.load(Ordering::Relaxed), 8 * 500);
    let m = s.metrics();
    assert_eq!(m.spawned, 8 * 500, "spawn accounting drifted");
    assert_eq!(m.executed, 8 * 500, "task lost or duplicated");
    assert_eq!(s.live_tasks(), 0);
    // Wake credits are minted only against announced parks: delivered
    // wakes can never exceed parks taken (main-loop + in-wait).
    assert!(
        m.wakes_targeted + m.wakes_any <= m.parked + m.wait_parks,
        "wake/park conservation violated: {m}"
    );
    s.shutdown();
}

/// The lost-wakeup race: a single worker repeatedly descends into park
/// while a spawn arrives at every phase of that descent (the busy-wait
/// varies the alignment).  A dropped wake would stall each cycle to the
/// park timeout; thousands of cycles finishing promptly — and wakes being
/// delivered at all — is the regression signal.
#[test]
fn lost_wakeup_spawn_racing_worker_park() {
    let s = Scheduler::with_idle_mode(1, PolicyKind::PriorityLocal, IdleMode::Targeted);
    let done = Arc::new(AtomicUsize::new(0));
    for i in 0..2000 {
        // Vary the spawn's alignment against the worker's spin → yield →
        // announce → park descent.
        busy_wait(Duration::from_micros(((i % 5) * 20) as u64));
        let done = done.clone();
        s.spawn(Priority::Normal, Hint::Worker(0), "probe", move || {
            done.fetch_add(1, Ordering::Relaxed);
        });
        s.wait_quiescent();
        assert_eq!(done.load(Ordering::Relaxed), i + 1, "task stalled at cycle {i}");
    }
    let m = s.metrics();
    assert_eq!(m.executed, 2000);
    assert!(
        m.wakes_targeted + m.wakes_any > 0,
        "worker never woken from park across 2000 idle/spawn cycles: {m}"
    );
    s.shutdown();
}

/// `wait_quiescent` racing `shutdown` (and each other) from several
/// threads must all drain the same task set and return — no deadlock, no
/// lost task, and shutdown stays idempotent afterwards.
#[test]
fn wait_quiescent_vs_shutdown_interleaving() {
    let s = Scheduler::with_idle_mode(2, PolicyKind::PriorityLocal, IdleMode::Targeted);
    let done = Arc::new(AtomicUsize::new(0));
    for _ in 0..500 {
        let done = done.clone();
        s.spawn(Priority::Normal, Hint::Any, "drain", move || {
            busy_wait(Duration::from_micros(5));
            done.fetch_add(1, Ordering::Relaxed);
        });
    }
    let mut waiters = Vec::new();
    for _ in 0..2 {
        let s = s.clone();
        waiters.push(std::thread::spawn(move || s.wait_quiescent()));
    }
    let s2 = s.clone();
    let stopper = std::thread::spawn(move || s2.shutdown());
    for w in waiters {
        w.join().unwrap();
    }
    stopper.join().unwrap();
    assert_eq!(done.load(Ordering::Relaxed), 500);
    assert_eq!(s.live_tasks(), 0);
    s.shutdown(); // idempotent after the racing shutdown
    let m = s.metrics();
    assert_eq!(m.executed, 500);
}

/// The old `wait_quiescent` sleep-polled in 50µs naps; the new one parks
/// and is notified on the final retire.  With a deliberately long-running
/// task, the external waiter must reach the park rung (`quiesce_parks`
/// counts it) — proof by counter that no busy-wait remains on this path.
#[test]
fn quiescent_waiter_parks_instead_of_polling() {
    let s = Scheduler::with_idle_mode(1, PolicyKind::PriorityLocal, IdleMode::Targeted);
    s.spawn(Priority::Normal, Hint::Worker(0), "slow", || {
        busy_wait(Duration::from_millis(20));
    });
    s.wait_quiescent();
    let m = s.metrics();
    assert_eq!(m.executed, 1);
    assert!(
        m.quiesce_parks >= 1,
        "quiescence waiter never parked across a 20ms task — busy-wait suspected: {m}"
    );
    s.shutdown();
}

/// Shutdown with work still queued drains everything first
/// (quiesce-then-stop), through the parked wait.
#[test]
fn shutdown_drains_pending_tasks_via_parked_wait() {
    let s = Scheduler::with_idle_mode(2, PolicyKind::Abp, IdleMode::Targeted);
    let done = Arc::new(AtomicUsize::new(0));
    for i in 0..200 {
        let done = done.clone();
        s.spawn(Priority::Normal, Hint::Worker(i % 2), "pending", move || {
            busy_wait(Duration::from_micros(20));
            done.fetch_add(1, Ordering::Relaxed);
        });
    }
    s.shutdown();
    assert_eq!(done.load(Ordering::Relaxed), 200, "shutdown dropped queued tasks");
}

/// The `HPXMP_GLOBAL_IDLE=1` ablation fallback (legacy global condvar)
/// passes the same submitter stress — it stays a correct, measurable
/// baseline for `benches/ablation_wake.rs`.
#[test]
fn global_idle_fallback_survives_submitter_stress() {
    let s = Scheduler::with_idle_mode(2, PolicyKind::PriorityLocal, IdleMode::Global);
    assert_eq!(s.idle_mode(), IdleMode::Global);
    let done = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..4)
        .map(|ci| {
            let s = s.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                for i in 0..250 {
                    let done = done.clone();
                    s.spawn(Priority::Normal, Hint::Worker((ci + i) % 2), "g", move || {
                        done.fetch_add(1, Ordering::Relaxed);
                    });
                    if i % 50 == 0 {
                        busy_wait(Duration::from_micros(100));
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    s.wait_quiescent();
    assert_eq!(done.load(Ordering::Relaxed), 1000);
    let m = s.metrics();
    assert_eq!(m.executed, 1000);
    s.shutdown();
}

/// A parked `Future::wait`er on a plain OS thread is woken by fulfilment
/// (the explicit wake channel), not stranded until a timeout: end-to-end
/// check of the WakeList path outside any worker context.
#[test]
fn parked_future_waiter_woken_by_fulfilment() {
    use hpxmp::amt::{Future, Promise};
    for _ in 0..20 {
        let p: Promise<usize> = Promise::new();
        let f: Future<usize> = p.get_future();
        let waiter = std::thread::spawn(move || {
            let t0 = Instant::now();
            f.wait();
            t0.elapsed()
        });
        // Give the waiter time to escalate into its parked phase.
        busy_wait(Duration::from_millis(2));
        p.set_value(7);
        let waited = waiter.join().unwrap();
        assert!(
            waited < Duration::from_secs(5),
            "future waiter stranded: {waited:?}"
        );
    }
}
