//! ISSUE 5: the policy-equivalence oracle matrix.
//!
//! Every Blaze kernel × {seq, par, task} × {hpxMP, baseline} must be
//! **bitwise equal** to the serial oracle (the `seq()` policy), including
//! non-square shapes — the correctness contract that makes the one-line
//! policy swap safe.  Chunked element-wise kernels perform the identical
//! per-element operations regardless of partition; the matmul task path
//! accumulates over the full depth in increasing k exactly like the
//! serial kernel — so equality is exact, not epsilon.
//!
//! **Which paths stay bitwise-equal after ISSUE 7** (the `KernelVariant`
//! numerics contract, see `blaze/kernel.rs` and DESIGN.md §12):
//!
//! * `Auto` (the default used by every test here) is
//!   numerics-preserving: element-wise kernels resolve to the portable
//!   unrolled loops (same per-element expression → bitwise-equal),
//!   matvec resolves to the scalar oracle loop, and matmul resolves to
//!   the scalar row kernel below `PACKED_MIN_DIM` = 256 — every shape in
//!   this file.  All assertions below therefore remain `== 0.0`, with
//!   or without the `simd` cargo feature.
//! * Explicit `.kernel(Packed)` matmul reorders the k-summation into
//!   MR×NR register lanes: results are policy- and tile-independent
//!   **bitwise among themselves** (each C element is one lane summed in
//!   ascending k) but only tolerance-equal to the scalar oracle —
//!   see `packed_variant_is_tolerance_equal_and_self_consistent`.
//! * Explicit `.kernel(Unrolled)` daxpy/matvec may contract through FMA
//!   when the `simd` feature is compiled *and* the CPU has avx2+fma —
//!   tolerance-equal only; covered in `tests/kernel_oracle.rs`.
//!
//! Plus: the RAII arrive-guard contract — `for_each_async` under
//! `task()` still fulfils its join future when a chunk body panics.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use hpxmp::baseline::BaselineRuntime;
use hpxmp::blaze::{self, DynMatrix, DynVector};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec::{self, for_each_async, seq, ExecMode, Executor, Policy};
use hpxmp::par::HpxMpRuntime;

/// The two real executors of the matrix.  `task()` on the baseline pool
/// degrades to eager inline execution (no AMT substrate) — the
/// "where applicable" edge — but must still be bitwise correct.
fn executors() -> Vec<(&'static str, Box<dyn Executor>)> {
    vec![
        (
            "hpxmp",
            Box::new(HpxMpRuntime::new(OmpRuntime::for_tests(4))) as Box<dyn Executor>,
        ),
        ("baseline", Box::new(BaselineRuntime::new(4))),
    ]
}

fn policies<'e>(ex: &'e dyn Executor) -> Vec<Policy<'e>> {
    ExecMode::ALL
        .iter()
        .map(|&m| Policy::with_mode(m).on(ex).threads(4).tile(16))
        .collect()
}

#[test]
fn dvecdvecadd_matrix_matches_serial_oracle() {
    let n = 50_000; // above the 38k threshold
    let a = DynVector::random(n, 1);
    let b = DynVector::random(n, 2);
    let mut oracle = DynVector::zeros(n);
    blaze::dvecdvecadd(&seq(), &a, &b, &mut oracle);
    for (name, ex) in executors() {
        for pol in policies(ex.as_ref()) {
            let mut c = DynVector::zeros(n);
            blaze::dvecdvecadd(&pol, &a, &b, &mut c);
            assert_eq!(c.max_abs_diff(&oracle), 0.0, "{name} {pol:?}");
        }
    }
}

#[test]
fn daxpy_matrix_matches_serial_oracle() {
    let n = 60_000;
    let a = DynVector::random(n, 3);
    let b0 = DynVector::random(n, 4);
    let mut oracle = b0.clone();
    blaze::daxpy(&seq(), 3.0, &a, &mut oracle);
    for (name, ex) in executors() {
        for pol in policies(ex.as_ref()) {
            let mut b = b0.clone();
            blaze::daxpy(&pol, 3.0, &a, &mut b);
            assert_eq!(b.max_abs_diff(&oracle), 0.0, "{name} {pol:?}");
        }
    }
}

#[test]
fn dmatdmatadd_matrix_matches_serial_oracle_including_non_square() {
    // (m, n) over the 36100-element threshold, square and not.
    for (m, n) in [(200usize, 200usize), (210, 190), (150, 300)] {
        let a = DynMatrix::random(m, n, 5);
        let b = DynMatrix::random(m, n, 6);
        let mut oracle = DynMatrix::zeros(m, n);
        blaze::dmatdmatadd(&seq(), &a, &b, &mut oracle);
        for (name, ex) in executors() {
            for pol in policies(ex.as_ref()) {
                let mut c = DynMatrix::zeros(m, n);
                blaze::dmatdmatadd(&pol, &a, &b, &mut c);
                assert_eq!(c.max_abs_diff(&oracle), 0.0, "{name} {pol:?} {m}x{n}");
            }
        }
    }
}

#[test]
fn dmatdmatmult_matrix_matches_serial_oracle_including_non_square() {
    // (m, k, n) over the 3025-element threshold: square/even tiles,
    // non-square, and tile-ragged shapes.
    for (m, k, n) in [(64usize, 64usize, 64usize), (100, 60, 130), (57, 119, 83)] {
        let a = DynMatrix::random(m, k, 7);
        let b = DynMatrix::random(k, n, 8);
        let mut oracle = DynMatrix::zeros(m, n);
        blaze::dmatdmatmult(&seq(), &a, &b, &mut oracle);
        for (name, ex) in executors() {
            for pol in policies(ex.as_ref()) {
                let mut c = DynMatrix::zeros(m, n);
                blaze::dmatdmatmult(&pol, &a, &b, &mut c);
                assert_eq!(
                    c.max_abs_diff(&oracle),
                    0.0,
                    "{name} {pol:?} ({m},{k},{n})"
                );
            }
        }
    }
}

#[test]
fn dmatdvecmult_matrix_matches_serial_oracle_including_non_square() {
    // (m, n) straddling the 330-row threshold, wide and tall.
    for (m, n) in [(400usize, 400usize), (400, 37), (350, 700)] {
        let a = DynMatrix::random(m, n, 9);
        let x = DynVector::random(n, 10);
        let mut oracle = DynVector::zeros(m);
        blaze::dmatdvecmult(&seq(), &a, &x, &mut oracle);
        for (name, ex) in executors() {
            for pol in policies(ex.as_ref()) {
                let mut y = DynVector::zeros(m);
                blaze::dmatdvecmult(&pol, &a, &x, &mut y);
                assert_eq!(y.max_abs_diff(&oracle), 0.0, "{name} {pol:?} {m}x{n}");
            }
        }
    }
}

#[test]
fn task_policy_tile_sizes_stay_bitwise_equal() {
    // The .tile(..) combinator must not perturb results: every tiling of
    // the same product agrees with the serial oracle exactly.
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let n = 130;
    let a = DynMatrix::random(n, n, 11);
    let b = DynMatrix::random(n, n, 12);
    let mut oracle = DynMatrix::zeros(n, n);
    blaze::dmatdmatmult(&seq(), &a, &b, &mut oracle);
    for tile in [8usize, 16, 33, 64, 256] {
        let mut c = DynMatrix::zeros(n, n);
        blaze::dmatdmatmult(&exec::task().on(&hpx).threads(4).tile(tile), &a, &b, &mut c);
        assert_eq!(c.max_abs_diff(&oracle), 0.0, "tile {tile}");
    }
}

#[test]
fn packed_variant_is_tolerance_equal_and_self_consistent() {
    // The ISSUE 7 packed matmul across the full executor × policy
    // matrix: within max_abs_diff <= 1e-11 of the scalar oracle (the
    // k-summation is reassociated into register lanes, so equality is
    // epsilon, not bitwise) — but bitwise-identical *across* policies,
    // executors, and tilings, because each C element is produced by
    // exactly one lane summed in ascending k regardless of
    // decomposition.
    use hpxmp::par::exec::KernelVariant;
    let (m, k, n) = (100usize, 60usize, 130usize);
    let a = DynMatrix::random(m, k, 31);
    let b = DynMatrix::random(k, n, 32);
    let mut oracle = DynMatrix::zeros(m, n);
    blaze::dmatdmatmult(&seq(), &a, &b, &mut oracle);
    let mut packed_ref = DynMatrix::zeros(m, n);
    blaze::dmatdmatmult(&seq().kernel(KernelVariant::Packed), &a, &b, &mut packed_ref);
    assert!(
        packed_ref.max_abs_diff(&oracle) <= 1e-11,
        "packed seq vs scalar oracle: {}",
        packed_ref.max_abs_diff(&oracle)
    );
    for (name, ex) in executors() {
        for pol in policies(ex.as_ref()) {
            for tile in [16usize, 33, 64] {
                let mut c = DynMatrix::zeros(m, n);
                blaze::dmatdmatmult(
                    &pol.kernel(KernelVariant::Packed).tile(tile).threshold(1),
                    &a,
                    &b,
                    &mut c,
                );
                assert_eq!(
                    c.max_abs_diff(&packed_ref),
                    0.0,
                    "packed not decomposition-independent: {name} {pol:?} tile {tile}"
                );
            }
        }
    }
}

#[test]
fn for_each_async_task_panicking_body_still_fulfils_join() {
    // The RAII arrive guard: a panicking chunk counts down on drop, so
    // the joined future fulfils and the panic stays isolated in the
    // worker layer.
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
    let ran = Arc::new(AtomicU32::new(0));
    let r2 = ran.clone();
    let fut = for_each_async(
        &exec::task().on(&hpx).threads(4),
        0..4,
        Arc::new(move |r: std::ops::Range<i64>| {
            if r.start == 2 {
                panic!("chunk body panics");
            }
            r2.fetch_add(1, Ordering::SeqCst);
        }),
    );
    fut.wait();
    assert_eq!(ran.load(Ordering::SeqCst), 3, "surviving chunks ran");
    assert_eq!(hpx.rt.sched.task_panics(), 1, "panic not isolated");
}

#[test]
fn policy_swap_is_one_line_on_one_buffer() {
    // The API promise in miniature: the same call site, three policies,
    // identical bits every time.
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let n = 50_000;
    let a = DynVector::random(n, 21);
    let b0 = DynVector::random(n, 22);
    let mut oracle = b0.clone();
    blaze::daxpy(&seq(), 3.0, &a, &mut oracle);
    for pol in [
        exec::seq().on(&hpx),
        exec::par().on(&hpx),
        exec::task().on(&hpx),
    ] {
        let mut b = b0.clone();
        blaze::daxpy(&pol, 3.0, &a, &mut b);
        assert_eq!(b.max_abs_diff(&oracle), 0.0, "{}", pol.label());
    }
}
