//! Integration tests of OpenMP semantics through the public API — the
//! behaviours an application linked against hpxMP would rely on, beyond
//! the per-module unit tests: combined constructs, reductions built from
//! primitives, firstprivate-style capture, nested regions, and the
//! kmpc/GOMP entry layers driving real computations.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use hpxmp::amt::PolicyKind;
use hpxmp::omp::api::*;
use hpxmp::omp::sync::AtomicF64;
use hpxmp::omp::team::{current_ctx, fork_call};
use hpxmp::omp::{OmpRuntime, SchedKind, Schedule};

fn rt4() -> Arc<OmpRuntime> {
    OmpRuntime::for_tests(4)
}

#[test]
fn reduction_pattern_sum_of_squares() {
    // reduction(+:sum) lowered the way Clang does: private partials +
    // atomic combine at the end.
    let rt = rt4();
    let sum = Arc::new(AtomicF64::new(0.0));
    let s = sum.clone();
    fork_call(&rt, Some(4), move |ctx| {
        let mut partial = 0.0;
        ctx.for_static(0..1000, None, |i| {
            partial += (i * i) as f64;
        });
        s.fetch_add(partial);
    });
    let expect: f64 = (0..1000).map(|i: i64| (i * i) as f64).sum();
    assert_eq!(sum.load(), expect);
}

#[test]
fn parallel_for_with_all_schedules_same_result() {
    let rt = rt4();
    let n = 10_000i64;
    let expect: i64 = (0..n).sum();
    for sched in [
        Schedule::new(SchedKind::Dynamic, Some(64)),
        Schedule::new(SchedKind::Guided, Some(16)),
        Schedule::new(SchedKind::Runtime, None), // resolves via ICV
    ] {
        let acc = Arc::new(AtomicUsize::new(0));
        let a = acc.clone();
        fork_call(&rt, Some(4), move |ctx| {
            ctx.for_dynamic(0..n, sched, |i| {
                a.fetch_add(i as usize, Ordering::Relaxed);
            });
        });
        assert_eq!(acc.load(Ordering::SeqCst) as i64, expect, "{sched:?}");
    }
}

#[test]
fn api_reports_team_state_inside_region() {
    let rt = rt4();
    let seen = Arc::new(Mutex::new(Vec::new()));
    let s = seen.clone();
    fork_call(&rt, Some(3), move |_| {
        s.lock().unwrap().push((
            omp_get_thread_num(),
            omp_get_num_threads(),
            omp_in_parallel(),
            omp_get_level(),
        ));
    });
    let mut got = seen.lock().unwrap().clone();
    got.sort();
    assert_eq!(got, vec![(0, 3, true, 1), (1, 3, true, 1), (2, 3, true, 1)]);
}

#[test]
fn single_plus_barrier_produces_consistent_phases() {
    // The canonical producer/consumer idiom: single fills, barrier, all read.
    let rt = rt4();
    let shared = Arc::new(Mutex::new(Vec::<i64>::new()));
    let failures = Arc::new(AtomicUsize::new(0));
    let (sh, f) = (shared.clone(), failures.clone());
    fork_call(&rt, Some(4), move |ctx| {
        ctx.single(|| {
            let mut g = sh.lock().unwrap();
            *g = (0..100).collect();
        });
        ctx.barrier();
        if sh.lock().unwrap().len() != 100 {
            f.fetch_add(1, Ordering::SeqCst);
        }
    });
    assert_eq!(failures.load(Ordering::SeqCst), 0);
}

#[test]
fn sections_distribute_work_once_each() {
    let rt = rt4();
    let hits: Arc<Vec<AtomicUsize>> = Arc::new((0..7).map(|_| AtomicUsize::new(0)).collect());
    let h = hits.clone();
    fork_call(&rt, Some(4), move |ctx| {
        let mut secs: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
        for i in 0..7 {
            let h = h.clone();
            secs.push(Box::new(move || {
                h[i].fetch_add(1, Ordering::SeqCst);
            }));
        }
        ctx.sections(secs);
    });
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::SeqCst), 1, "section {i}");
    }
}

#[test]
fn taskloop_grainsize_variants_cover_range() {
    let rt = rt4();
    for grain in [1usize, 3, 10, 1000] {
        let seen = Arc::new(Mutex::new(vec![0u32; 64]));
        let s = seen.clone();
        fork_call(&rt, Some(2), move |c| {
            if c.tid == 0 {
                let ctx = current_ctx().unwrap();
                let s = s.clone();
                ctx.taskloop(0..64, grain, move |i| {
                    s.lock().unwrap()[i as usize] += 1;
                });
            }
        });
        assert!(
            seen.lock().unwrap().iter().all(|&c| c == 1),
            "grain {grain}"
        );
    }
}

#[test]
fn fan_out_fan_in_dependence_diamond() {
    use hpxmp::omp::{Dep, DepKind};
    // writer -> {4 readers} -> final writer (diamond); final must see all.
    let rt = rt4();
    let stage = Arc::new(AtomicUsize::new(0));
    let violations = Arc::new(AtomicUsize::new(0));
    let (st, vi) = (stage.clone(), violations.clone());
    fork_call(&rt, Some(4), move |c| {
        if c.tid != 0 {
            return;
        }
        let ctx = current_ctx().unwrap();
        let token = 0xD1A;
        {
            let st = st.clone();
            ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::Out }], move || {
                st.store(1, Ordering::SeqCst);
            });
        }
        for _ in 0..4 {
            let (st, vi) = (st.clone(), vi.clone());
            ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::In }], move || {
                if st.load(Ordering::SeqCst) != 1 {
                    vi.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        {
            let (st, vi) = (st.clone(), vi.clone());
            ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::InOut }], move || {
                if st.swap(2, Ordering::SeqCst) != 1 {
                    vi.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        ctx.taskwait();
    });
    assert_eq!(violations.load(Ordering::SeqCst), 0);
    assert_eq!(stage.load(Ordering::SeqCst), 2);
}

#[test]
fn kmpc_layer_drives_a_real_loop() {
    use hpxmp::omp::kmpc::*;
    let rt = rt4();
    let data = Arc::new(Mutex::new(vec![0i64; 256]));
    let d = data.clone();
    fork_call(&rt, Some(4), move |ctx| {
        let (mut lo, mut hi, mut stride) = (0i64, 255i64, 0i64);
        kmpc_for_static_init(
            Ident::default(),
            ctx.tid,
            SchedType::Static,
            &mut lo,
            &mut hi,
            &mut stride,
            1,
            0,
        );
        let mut g = d.lock().unwrap();
        for i in lo..=hi.min(255) {
            g[i as usize] = i * 2;
        }
        drop(g);
        kmpc_barrier(Ident::default(), ctx.tid);
    });
    let got = data.lock().unwrap();
    assert!(got.iter().enumerate().all(|(i, &v)| v == 2 * i as i64));
}

#[test]
fn gomp_layer_drives_a_real_loop() {
    use hpxmp::omp::gcc::*;
    let rt = rt4();
    let sum = Arc::new(AtomicUsize::new(0));
    let s = sum.clone();
    fork_call(&rt, Some(3), move |_| {
        let l = gomp_loop_guided_start(0..1000, 8);
        while let Some(r) = gomp_loop_next(&l) {
            for i in r {
                s.fetch_add(i as usize, Ordering::Relaxed);
            }
        }
        gomp_loop_end_nowait(l);
    });
    assert_eq!(sum.load(Ordering::SeqCst), 999 * 1000 / 2);
}

#[test]
fn nested_active_parallelism_runs_all_members() {
    let rt = OmpRuntime::for_tests(4);
    rt.icv.nested.store(true, Ordering::Relaxed);
    let count = Arc::new(AtomicUsize::new(0));
    let c = count.clone();
    let rt2 = rt.clone();
    fork_call(&rt, Some(2), move |_| {
        let c = c.clone();
        fork_call(&rt2, Some(2), move |_| {
            c.fetch_add(1, Ordering::SeqCst);
        });
    });
    assert_eq!(count.load(Ordering::SeqCst), 4);
}

#[test]
fn many_regions_in_sequence_are_stable() {
    // Fork/join churn: the paper's benchmarks fork one region per
    // operation; 200 regions back-to-back must not wedge or leak.
    let rt = rt4();
    let total = Arc::new(AtomicUsize::new(0));
    for _ in 0..200 {
        let t = total.clone();
        fork_call(&rt, Some(4), move |_| {
            t.fetch_add(1, Ordering::Relaxed);
        });
    }
    assert_eq!(total.load(Ordering::SeqCst), 800);
    // fork_call returns at the join latch, which fires inside the last
    // implicit task's closure — the scheduler retires it just after, so
    // quiesce before checking for leaks.
    rt.sched.wait_quiescent();
    assert_eq!(rt.sched.live_tasks(), 0, "leaked live tasks");
}

#[test]
fn policies_all_run_parallel_for() {
    for policy in PolicyKind::ALL {
        let rt = OmpRuntime::new(4, policy);
        rt.icv.set_nthreads(4);
        let sum = Arc::new(AtomicUsize::new(0));
        let s = sum.clone();
        fork_call(&rt, Some(4), move |ctx| {
            ctx.for_static(0..100, None, |i| {
                s.fetch_add(i as usize, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4950, "policy {}", policy.name());
    }
}
