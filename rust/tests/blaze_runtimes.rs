//! Integration: Blaze-lite operations × both executors × schedules — the
//! correctness matrix underneath every figure, plus threshold behaviour
//! and cross-runtime agreement.  (The full policy × executor oracle
//! matrix lives in `exec_policies.rs`; this file keeps the
//! schedule-dimension and threshold checks.)

use hpxmp::baseline::BaselineRuntime;
use hpxmp::blaze::{self, thresholds, DynMatrix, DynVector};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec::{par, seq, Executor, Policy};
use hpxmp::par::{HpxMpRuntime, LoopSched};

fn executors() -> Vec<Box<dyn Executor>> {
    vec![
        Box::new(BaselineRuntime::new(4)),
        Box::new(HpxMpRuntime::new(OmpRuntime::for_tests(4))),
    ]
}

fn scheds() -> Vec<LoopSched> {
    vec![
        LoopSched::Static { chunk: None },
        LoopSched::Static { chunk: Some(1000) },
        LoopSched::Dynamic { chunk: 4096 },
        LoopSched::Guided { chunk: 1024 },
    ]
}

#[test]
fn dvecdvecadd_all_executors_and_schedules_agree() {
    let n = 50_000; // above threshold
    let a = DynVector::random(n, 1);
    let b = DynVector::random(n, 2);
    let mut expect = DynVector::zeros(n);
    blaze::dvecdvecadd(&seq(), &a, &b, &mut expect);
    for ex in executors() {
        for sched in scheds() {
            let mut c = DynVector::zeros(n);
            let pol = par().on(ex.as_ref()).threads(4).chunk(sched);
            blaze::dvecdvecadd(&pol, &a, &b, &mut c);
            assert_eq!(c.max_abs_diff(&expect), 0.0, "{} {:?}", ex.name(), sched);
        }
    }
}

#[test]
fn daxpy_all_executors_and_schedules_agree() {
    let n = 50_000;
    let a = DynVector::random(n, 3);
    let b0 = DynVector::random(n, 4);
    let mut expect = b0.clone();
    blaze::daxpy(&seq(), 3.0, &a, &mut expect);
    for ex in executors() {
        for sched in scheds() {
            let mut b = b0.clone();
            let pol = par().on(ex.as_ref()).threads(4).chunk(sched);
            blaze::daxpy(&pol, 3.0, &a, &mut b);
            assert_eq!(b.max_abs_diff(&expect), 0.0, "{} {:?}", ex.name(), sched);
        }
    }
}

#[test]
fn dmatdmatadd_all_executors_agree() {
    let n = 200; // 40k elements, above 36100
    let a = DynMatrix::random(n, n, 5);
    let b = DynMatrix::random(n, n, 6);
    let mut expect = DynMatrix::zeros(n, n);
    blaze::dmatdmatadd(&seq(), &a, &b, &mut expect);
    for ex in executors() {
        let mut c = DynMatrix::zeros(n, n);
        blaze::dmatdmatadd(&par().on(ex.as_ref()).threads(4), &a, &b, &mut c);
        assert_eq!(c.max_abs_diff(&expect), 0.0, "{}", ex.name());
    }
}

#[test]
fn dmatdmatmult_all_executors_agree() {
    let n = 96; // above 3025-element threshold
    let a = DynMatrix::random(n, n, 7);
    let b = DynMatrix::random(n, n, 8);
    let mut expect = DynMatrix::zeros(n, n);
    blaze::dmatdmatmult(&seq(), &a, &b, &mut expect);
    for ex in executors() {
        let mut c = DynMatrix::zeros(n, n);
        blaze::dmatdmatmult(&par().on(ex.as_ref()).threads(4), &a, &b, &mut c);
        assert_eq!(c.max_abs_diff(&expect), 0.0, "{}", ex.name());
    }
}

#[test]
fn below_threshold_both_executors_execute_serially_and_correctly() {
    // 10_000 < 38_000: the parallel seam must not even be entered —
    // verified indirectly (results exact vs serial kernel, single call).
    let n = 10_000;
    let a = DynVector::random(n, 9);
    let b0 = DynVector::random(n, 10);
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let base = BaselineRuntime::new(4);
    let mut expect = b0.clone();
    hpxmp::blaze::serial::daxpy_slice(3.0, a.as_slice(), expect.as_mut_slice());
    for ex in [&hpx as &dyn Executor, &base] {
        let mut b = b0.clone();
        blaze::daxpy(&par().on(ex).threads(4), 3.0, &a, &mut b);
        assert_eq!(b.max_abs_diff(&expect), 0.0, "{}", ex.name());
    }
    assert!(!thresholds::parallelize(n, thresholds::DAXPY_THRESHOLD));
}

#[test]
fn matmul_rectangular_shapes() {
    // Row distribution must handle M != N != K.
    let (m, k, n) = (70, 40, 90);
    let a = DynMatrix::random(m, k, 11);
    let b = DynMatrix::random(k, n, 12);
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let mut c_par = DynMatrix::zeros(m, n);
    blaze::dmatdmatmult(&par().on(&hpx).threads(4), &a, &b, &mut c_par);
    // Naive oracle.
    let mut c_ref = DynMatrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for kk in 0..k {
                s += a.at(i, kk) * b.at(kk, j);
            }
            *c_ref.at_mut(i, j) = s;
        }
    }
    assert!(c_par.max_abs_diff(&c_ref) < 1e-10);
}

#[test]
fn repeated_invocations_are_deterministic() {
    // Blazemark reruns the op thousands of times; results must not drift.
    let n = 60_000;
    let a = DynVector::random(n, 13);
    let b = DynVector::random(n, 14);
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let pol: Policy<'_> = par().on(&hpx).threads(4);
    let mut first = DynVector::zeros(n);
    blaze::dvecdvecadd(&pol, &a, &b, &mut first);
    for _ in 0..20 {
        let mut c = DynVector::zeros(n);
        blaze::dvecdvecadd(&pol, &a, &b, &mut c);
        assert_eq!(c.max_abs_diff(&first), 0.0);
    }
}
