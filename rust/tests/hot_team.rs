//! Hot-team fork/join fast-path integration tests (ISSUE 1): team-reuse
//! correctness under alternating sizes, `Ctx` leak checks on the parked
//! cache, `single` re-arm across regions, and 10k dynamic loops cycling
//! the lock-free worksharing ring.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use hpxmp::omp::{dep_in, dep_out, fork_call, OmpRuntime, SchedKind, Schedule};

/// 1,000 consecutive regions with alternating team sizes (1, 2, 4): every
/// iteration checks tids, team size, and barrier semantics.  The size
/// pattern contains same-size neighbors so both cache hits (re-armed
/// teams) and misses (size-change rebuilds) are exercised, plus the
/// inline serialized path for size 1.
#[test]
fn thousand_regions_alternating_sizes_stay_correct() {
    let rt = OmpRuntime::for_tests(4);
    let sizes = [1usize, 2, 2, 4, 4];
    for i in 0..1000 {
        let size = sizes[i % sizes.len()];
        let arrived = Arc::new(AtomicUsize::new(0));
        let tids = Arc::new(AtomicUsize::new(0));
        let (a, t) = (arrived.clone(), tids.clone());
        fork_call(&rt, Some(size), move |ctx| {
            assert_eq!(ctx.num_threads(), size, "region {i}: wrong team size");
            assert!(ctx.tid < size, "region {i}: tid out of range");
            t.fetch_or(1 << ctx.tid, Ordering::SeqCst);
            a.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            // After the barrier every member must observe all arrivals.
            assert_eq!(
                a.load(Ordering::SeqCst),
                size,
                "region {i}: barrier released early"
            );
        });
        assert_eq!(
            tids.load(Ordering::SeqCst),
            (1 << size) - 1,
            "region {i}: some tid missing or duplicated"
        );
        assert_eq!(arrived.load(Ordering::SeqCst), size);
    }
}

/// The parked cache must hold the only references to the member `Ctx`s
/// once the scheduler quiesces — even after regions that cloned contexts
/// into explicit tasks and dependence records.
#[test]
fn hot_team_cache_does_not_leak_ctxs() {
    let rt = OmpRuntime::for_tests(4);
    let sink = Arc::new(AtomicUsize::new(0));
    for _ in 0..50 {
        let s = sink.clone();
        fork_call(&rt, Some(4), move |_| {
            let ctx = hpxmp::omp::current_ctx().unwrap();
            let token = 0usize;
            let s1 = s.clone();
            ctx.task_with_deps(&[dep_out(&token)], move || {
                s1.fetch_add(1, Ordering::SeqCst);
            });
            let s2 = s.clone();
            ctx.task_with_deps(&[dep_in(&token)], move || {
                s2.fetch_add(1, Ordering::SeqCst);
            });
            ctx.taskwait();
        });
    }
    assert_eq!(sink.load(Ordering::SeqCst), 50 * 4 * 2);

    rt.sched.wait_quiescent();
    let hot = rt
        .debug_take_hot_team()
        .expect("top-level team parked after the last region");
    assert_eq!(hot.ctxs.len(), 4);
    for (i, ctx) in hot.ctxs.iter().enumerate() {
        assert_eq!(
            Arc::strong_count(ctx),
            1,
            "ctx {i}: leaked reference pinned by the hot-team lifecycle"
        );
    }
    // Each member holds one Team ref, plus the cache's own handle.
    assert_eq!(Arc::strong_count(&hot.team), hot.ctxs.len() + 1);
}

/// `single` claims are keyed by construct sequence, which restarts at 0
/// in every region: a re-armed team must clear the previous claims or
/// every `single` after the first region goes silent.
#[test]
fn single_fires_once_per_region_across_team_reuse() {
    let rt = OmpRuntime::for_tests(4);
    let hits = Arc::new(AtomicUsize::new(0));
    for _ in 0..10 {
        let h = hits.clone();
        fork_call(&rt, Some(4), move |ctx| {
            ctx.single(|| {
                h.fetch_add(1, Ordering::SeqCst);
            });
            ctx.barrier();
        });
    }
    assert_eq!(hits.load(Ordering::SeqCst), 10, "single lost across re-arm");
}

/// 10,000 back-to-back dynamic worksharing loops in one region: the
/// construct sequence wraps the fixed worksharing ring hundreds of times
/// while members run ahead of each other (`nowait` semantics, no barrier
/// between loops).  Every iteration of every loop must be claimed exactly
/// once — and the whole run takes no lock on the dispatch path for
/// constructs within ring-size of each other.
#[test]
fn ten_thousand_dynamic_loops_cycle_the_ring() {
    let rt = OmpRuntime::for_tests(2);
    let total = Arc::new(AtomicUsize::new(0));
    let t = total.clone();
    fork_call(&rt, Some(2), move |ctx| {
        for _ in 0..10_000 {
            ctx.for_dynamic(0..8, Schedule::new(SchedKind::Dynamic, Some(1)), |i| {
                t.fetch_add(i as usize + 1, Ordering::Relaxed);
            });
        }
    });
    let per_loop: usize = (1..=8).sum();
    assert_eq!(total.load(Ordering::SeqCst), 10_000 * per_loop);
}

/// Mixed worksharing after re-arm: dynamic + guided + static loops across
/// reused teams all partition exactly.
#[test]
fn worksharing_partitions_exactly_across_reused_teams() {
    let rt = OmpRuntime::for_tests(4);
    for round in 0..20 {
        let n = 256i64;
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
        let s = seen.clone();
        fork_call(&rt, Some(4), move |ctx| {
            ctx.for_dynamic(0..n, Schedule::new(SchedKind::Dynamic, Some(7)), |i| {
                s[i as usize].fetch_add(1, Ordering::Relaxed);
            });
            ctx.barrier();
            ctx.for_dynamic(0..n, Schedule::new(SchedKind::Guided, Some(4)), |i| {
                s[i as usize].fetch_add(1, Ordering::Relaxed);
            });
            ctx.barrier();
            ctx.for_static(0..n, Some(3), |i| {
                s[i as usize].fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(
            seen.iter().all(|c| c.load(Ordering::SeqCst) == 3),
            "round {round}: some iteration missed or duplicated"
        );
    }
}
