//! ISSUE 7: the kernel-variant oracle matrix.
//!
//! Every tuned variant in `blaze/kernel.rs` is checked against the
//! `serial.rs` scalar loops with an **explicit tolerance contract**:
//!
//! * portable unrolled element-wise kernels (vadd/daxpy/madd under
//!   `Auto` or with the `simd` feature off) — bitwise-equal
//!   (`max_abs_diff == 0.0`): the per-element expression is unchanged,
//!   only the loop is restructured;
//! * unrolled matvec — accumulator splitting reassociates the dot
//!   product: `max_abs_diff <= 1e-12 * k`;
//! * packed matmul — the MR×NR micro-kernel reassociates the
//!   k-summation into register lanes: `max_abs_diff <= 1e-11` for the
//!   unit-scale random operands used here;
//! * FMA paths (explicit variants, `simd` feature, avx2+fma CPU) —
//!   contraction changes rounding: same tolerances as above.
//!
//! Plus the placement layer: first-touch construction is bitwise
//! policy-independent, and the `.threshold()` knob moves the serial/
//! parallel crossover without changing results.

use hpxmp::blaze::{self, kernel, serial, DynMatrix, DynVector};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec::{self, seq, KernelVariant, Policy};
use hpxmp::par::HpxMpRuntime;

/// Max |a[i] - b[i]| over two equal-length slices.
fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[test]
fn unrolled_elementwise_kernels_are_bitwise_equal_to_serial() {
    // Loop restructuring only — no reassociation, no FMA under Auto.
    for n in [0usize, 1, 3, 4, 5, 17, 1000, 4097] {
        let a = DynVector::random(n, 1);
        let b = DynVector::random(n, 2);

        let mut c_oracle = vec![0.0; n];
        serial::vadd_slice(a.as_slice(), b.as_slice(), &mut c_oracle);
        let mut c = vec![0.0; n];
        kernel::vadd(KernelVariant::Unrolled, a.as_slice(), b.as_slice(), &mut c);
        // vadd has no multiply, so no contraction is possible: the
        // unrolled path is the same add in any build.
        assert_eq!(max_abs_diff(&c, &c_oracle), 0.0, "vadd n={n}");

        let mut y_oracle = b.as_slice().to_vec();
        serial::daxpy_slice(3.0, a.as_slice(), &mut y_oracle);
        let mut y = b.as_slice().to_vec();
        kernel::daxpy(KernelVariant::Auto, 3.0, a.as_slice(), &mut y);
        // Auto never engages FMA — bitwise by contract.
        assert_eq!(max_abs_diff(&y, &y_oracle), 0.0, "daxpy auto n={n}");
    }
}

#[test]
fn explicit_unrolled_daxpy_is_tolerance_equal_even_with_fma() {
    // With the simd feature + avx2+fma the explicit variant may
    // contract a*x+y; one rounding per element bounds the error.
    let n = 10_007;
    let a = DynVector::random(n, 3);
    let b = DynVector::random(n, 4);
    let mut y_oracle = b.as_slice().to_vec();
    serial::daxpy_slice(3.0, a.as_slice(), &mut y_oracle);
    let mut y = b.as_slice().to_vec();
    kernel::daxpy(KernelVariant::Unrolled, 3.0, a.as_slice(), &mut y);
    let tol = if kernel::simd_active() { 1e-14 } else { 0.0 };
    assert!(
        max_abs_diff(&y, &y_oracle) <= tol,
        "daxpy unrolled n={n}: {}",
        max_abs_diff(&y, &y_oracle)
    );
}

#[test]
fn unrolled_matvec_is_tolerance_equal_to_serial() {
    // Accumulator splitting reassociates the dot product.
    for (m, k) in [(1usize, 1usize), (7, 5), (33, 64), (400, 37), (350, 700)] {
        let a = DynMatrix::random(m, k, 5);
        let x = DynVector::random(k, 6);
        let mut y_oracle = vec![0.0; m];
        serial::matvec_rows(a.as_slice(), x.as_slice(), &mut y_oracle);
        let mut y = vec![0.0; m];
        kernel::matvec(KernelVariant::Unrolled, a.as_slice(), x.as_slice(), &mut y);
        let tol = 1e-12 * k as f64;
        assert!(
            max_abs_diff(&y, &y_oracle) <= tol,
            "matvec ({m},{k}): {} > {tol}",
            max_abs_diff(&y, &y_oracle)
        );
    }
}

#[test]
fn packed_matmul_is_tolerance_equal_to_serial_on_ragged_shapes() {
    // Through the full ops:: dispatch (explicit Packed variant), over
    // shapes that exercise every edge: ragged MR/NR panels, k smaller
    // than one KC strip, k spanning several strips, tall/wide extremes.
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    for (m, k, n) in [
        (1usize, 1usize, 1usize),
        (5, 3, 7),
        (57, 119, 83),
        (400, 37, 350),
        (70, 300, 9),
        (130, 513, 65),
    ] {
        let a = DynMatrix::random(m, k, 7);
        let b = DynMatrix::random(k, n, 8);
        let mut oracle = DynMatrix::zeros(m, n);
        blaze::dmatdmatmult(&seq(), &a, &b, &mut oracle);
        for pol in [
            seq().kernel(KernelVariant::Packed),
            exec::par()
                .on(&hpx)
                .threads(4)
                .kernel(KernelVariant::Packed)
                .threshold(1),
            exec::task()
                .on(&hpx)
                .threads(4)
                .kernel(KernelVariant::Packed)
                .threshold(1),
        ] {
            let mut c = DynMatrix::zeros(m, n);
            blaze::dmatdmatmult(&pol, &a, &b, &mut c);
            assert!(
                c.max_abs_diff(&oracle) <= 1e-11,
                "packed ({m},{k},{n}) {}: {}",
                pol.label(),
                c.max_abs_diff(&oracle)
            );
        }
    }
}

#[test]
fn auto_matmul_engages_packed_only_at_the_documented_floor() {
    use hpxmp::blaze::thresholds::PACKED_MIN_DIM;
    // Below the floor Auto must stay on the scalar row kernel (that is
    // what keeps the ISSUE 5 bitwise oracles green); at the floor it
    // switches to packed.
    let d = PACKED_MIN_DIM;
    assert!(!kernel::matmul_uses_packed(KernelVariant::Auto, d - 1, d, d));
    assert!(!kernel::matmul_uses_packed(KernelVariant::Auto, d, d - 1, d));
    assert!(!kernel::matmul_uses_packed(KernelVariant::Auto, d, d, d - 1));
    assert!(kernel::matmul_uses_packed(KernelVariant::Auto, d, d, d));
    assert!(kernel::matmul_uses_packed(KernelVariant::Packed, 8, 8, 8));
    assert!(!kernel::matmul_uses_packed(KernelVariant::Scalar, d, d, d));
    assert!(!kernel::matmul_uses_packed(KernelVariant::Unrolled, d, d, d));
}

#[test]
fn auto_matmul_above_the_floor_matches_the_scalar_oracle_within_tolerance() {
    // One above-floor product end-to-end: Auto resolves to packed and
    // must still agree with the scalar row kernel to tolerance.  Kept
    // just over the floor so the test stays fast.
    use hpxmp::blaze::thresholds::PACKED_MIN_DIM;
    let d = PACKED_MIN_DIM;
    let a = DynMatrix::random(d, d, 9);
    let b = DynMatrix::random(d, d, 10);
    let mut oracle = DynMatrix::zeros(d, d);
    blaze::dmatdmatmult(&seq().kernel(KernelVariant::Scalar), &a, &b, &mut oracle);
    let mut c = DynMatrix::zeros(d, d);
    blaze::dmatdmatmult(&seq(), &a, &b, &mut c);
    assert!(
        c.max_abs_diff(&oracle) <= 1e-11,
        "auto-packed at {d}: {}",
        c.max_abs_diff(&oracle)
    );
}

#[test]
fn first_touch_constructors_are_policy_independent() {
    // Placement must never change values: contents are a pure function
    // of (shape, seed), whatever policy faults the pages in.
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let par = exec::par().on(&hpx).threads(4);
    let task = exec::task().on(&hpx).threads(4);

    let v_seq = DynVector::random_first_touch(&seq(), 100_003, 42);
    let v_par = DynVector::random_first_touch(&par, 100_003, 42);
    let v_task = DynVector::random_first_touch(&task, 100_003, 42);
    assert_eq!(v_seq.max_abs_diff(&v_par), 0.0);
    assert_eq!(v_seq.max_abs_diff(&v_task), 0.0);
    // Different seed, different stream (first-touch reseeds per block, so
    // it is *not* the same stream as DynVector::random — only seed and
    // shape determine it).
    let v_other = DynVector::random_first_touch(&seq(), 100_003, 43);
    assert!(v_seq.max_abs_diff(&v_other) > 0.0);

    let m_seq = DynMatrix::random_first_touch(&seq(), 130, 101, 7);
    let m_par = DynMatrix::random_first_touch(&par, 130, 101, 7);
    assert_eq!(m_seq.max_abs_diff(&m_par), 0.0);
}

#[test]
fn threshold_knob_moves_the_crossover_not_the_answer() {
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
    let n = 1000; // well under every default threshold
    let a = DynVector::random(n, 11);
    let b0 = DynVector::random(n, 12);
    let mut oracle = b0.clone();
    blaze::daxpy(&seq(), 2.5, &a, &mut oracle);
    for pol in [
        exec::par().on(&hpx).threads(2).threshold(1), // force parallel
        exec::par().on(&hpx).threads(2).threshold(usize::MAX), // force serial
    ] {
        let mut b = b0.clone();
        blaze::daxpy(&pol, 2.5, &a, &mut b);
        assert_eq!(b.max_abs_diff(&oracle), 0.0);
    }
}

/// The simd-feature-off build contract: without the cargo feature the
/// runtime must report SIMD inactive regardless of the host CPU — the
/// portable kernels are the only code path.
#[cfg(not(feature = "simd"))]
#[test]
fn simd_is_inactive_when_the_feature_is_not_compiled() {
    assert!(!kernel::simd_compiled());
    assert!(!kernel::simd_active());
    assert!(kernel::simd_label().contains("not compiled"));
}

/// With the feature compiled, activity must equal what the CPU reports.
#[cfg(feature = "simd")]
#[test]
fn simd_activity_matches_cpu_detection() {
    assert!(kernel::simd_compiled());
    #[cfg(target_arch = "x86_64")]
    assert_eq!(
        kernel::simd_active(),
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
    );
    #[cfg(not(target_arch = "x86_64"))]
    assert!(!kernel::simd_active());
}

#[test]
fn policy_kernel_accessor_round_trips() {
    let pol = Policy::with_mode(exec::ExecMode::Seq).kernel(KernelVariant::Packed);
    assert_eq!(pol.kernel_variant(), KernelVariant::Packed);
    assert_eq!(seq().kernel_variant(), KernelVariant::Auto);
}
