//! Integration over the PJRT bridge: artifacts load, compile, execute, and
//! match the native kernels — the numerical contract of the three-layer
//! path.  Skipped gracefully when `make artifacts` has not run.

use std::sync::Arc;

use hpxmp::blaze::serial;
use hpxmp::runtime::{OffloadServer, Registry, XlaOffload};
use hpxmp::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    // Tests run from the workspace root or rust/; probe both.
    for cand in ["artifacts", "../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    None
}

#[test]
fn registry_loads_all_seven_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::open(dir).expect("open registry");
    assert_eq!(reg.specs().len(), 7);
    for op in ["daxpy", "dvecdvecadd", "dmatdmatadd"] {
        assert!(reg.find_op(op, "f32").is_some(), "{op} f32");
        assert!(reg.find_op(op, "f64").is_some(), "{op} f64");
    }
    assert!(reg.find_op("dmatdmatmult", "f32").is_some());
}

#[test]
fn daxpy_chunk_matches_native_bitwise() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Arc::new(Registry::open(dir).unwrap());
    let off = XlaOffload::new(reg.clone());
    let chunk = reg.find_op("daxpy", "f64").unwrap().input_shapes[1][0];
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut a = vec![0.0f64; chunk];
    let mut b = vec![0.0f64; chunk];
    rng.fill_f64(&mut a);
    rng.fill_f64(&mut b);
    let got = off.daxpy_chunk_f64(3.0, &a, &b).unwrap();
    let mut expect = b.clone();
    serial::daxpy_slice(3.0, &a, &mut expect);
    let max = got
        .iter()
        .zip(&expect)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(max < 1e-15, "daxpy chunk mismatch {max}");
}

#[test]
fn vadd_chunk_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Arc::new(Registry::open(dir).unwrap());
    let off = XlaOffload::new(reg.clone());
    let chunk = reg.find_op("dvecdvecadd", "f64").unwrap().input_shapes[0][0];
    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut a = vec![0.0f64; chunk];
    let mut b = vec![0.0f64; chunk];
    rng.fill_f64(&mut a);
    rng.fill_f64(&mut b);
    let got = off.vadd_chunk_f64(&a, &b).unwrap();
    let mut expect = vec![0.0f64; chunk];
    serial::vadd_slice(&a, &b, &mut expect);
    assert_eq!(got, expect, "vadd must be bitwise-identical");
}

#[test]
fn matmul_rowblock_matches_native_f32() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Arc::new(Registry::open(dir).unwrap());
    let off = XlaOffload::new(reg.clone());
    let spec = reg.find_op("dmatdmatmult", "f32").unwrap().clone();
    let (bm, k) = (spec.input_shapes[0][0], spec.input_shapes[0][1]);
    let n = spec.input_shapes[1][1];
    let mut rng = Xoshiro256::seed_from_u64(3);
    let mut a = vec![0.0f32; bm * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_f32(&mut a);
    rng.fill_f32(&mut b);
    let (got, gbm, gn) = off.matmul_rowblock_f32(&a, &b).unwrap();
    assert_eq!((gbm, gn), (bm, n));
    // f64 oracle.
    let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
    let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
    let mut row = vec![0.0f64; n];
    let mut max_err = 0.0f32;
    for i in 0..bm {
        serial::matmul_row(&af[i * k..(i + 1) * k], &bf, n, &mut row);
        for j in 0..n {
            max_err = max_err.max((got[i * n + j] - row[j] as f32).abs());
        }
    }
    assert!(max_err < 1e-2, "matmul block err {max_err}");
}

#[test]
fn full_daxpy_with_tail_offloads_and_matches() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Arc::new(Registry::open(dir).unwrap());
    let off = XlaOffload::new(reg.clone());
    let chunk = reg.find_op("daxpy", "f64").unwrap().input_shapes[1][0];
    let n = 2 * chunk + 777; // two chunks + odd tail
    let mut rng = Xoshiro256::seed_from_u64(4);
    let mut a = vec![0.0f64; n];
    let mut b = vec![0.0f64; n];
    rng.fill_f64(&mut a);
    rng.fill_f64(&mut b);
    let mut expect = b.clone();
    serial::daxpy_slice(2.5, &a, &mut expect);
    let chunks = off.daxpy_full_f64(2.5, &a, &mut b).unwrap();
    assert_eq!(chunks, 2);
    let max = b
        .iter()
        .zip(&expect)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(max < 1e-15, "full daxpy mismatch {max}");
}

#[test]
fn offload_server_is_usable_from_many_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let server = OffloadServer::start(dir).unwrap();
    let chunk = 65_536usize;
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let client = server.client();
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::seed_from_u64(t);
                let mut a = vec![0.0f64; chunk];
                let mut b = vec![0.0f64; chunk];
                rng.fill_f64(&mut a);
                rng.fill_f64(&mut b);
                let got = client.daxpy_chunk_f64(1.5, a.clone(), b.clone()).unwrap();
                let mut expect = b;
                serial::daxpy_slice(1.5, &a, &mut expect);
                // XLA may fuse b + beta*a into an FMA: allow 1-ulp drift.
                let max = got
                    .iter()
                    .zip(&expect)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f64, f64::max);
                assert!(max < 1e-15, "thread {t}: max err {max}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::open(dir).unwrap();
    let e1 = reg.executable("vadd_f64_65536").unwrap();
    let e2 = reg.executable("vadd_f64_65536").unwrap();
    assert!(Arc::ptr_eq(&e1, &e2), "compile cache miss");
}

#[test]
fn unknown_artifact_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = Registry::open(dir).unwrap();
    assert!(reg.executable("nonexistent").is_err());
    assert!(reg.find_op("dmatdmatmult", "f64").is_none());
}
