//! Integration tests for the futurized dataflow engine (ISSUE 2):
//! the `amt::future` layer driving OpenMP `depend` semantics, the async
//! `par` seam, and the tiled dataflow Blaze backend, end to end.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use hpxmp::amt::future::{when_all, Future, Promise};
use hpxmp::amt::{PolicyKind, Scheduler, Tuning};
use hpxmp::blaze::{dmatdmatmult, DynMatrix};
use hpxmp::omp::{current_ctx, fork_call, Dep, DepKind, OmpRuntime};
use hpxmp::par::exec::{seq, task};
use hpxmp::par::HpxMpRuntime;

#[test]
fn when_all_empty_set_is_ready_without_a_scheduler() {
    let futures: Vec<Future<i32>> = Vec::new();
    let joined = when_all(&futures);
    assert!(joined.is_ready());
    joined.wait();
}

#[test]
fn continuation_ordering_under_every_policy() {
    // A then-chain must execute strictly in chain order no matter which
    // scheduling policy dispatches the continuation tasks.
    for policy in PolicyKind::ALL {
        let sched = Scheduler::new(2, policy);
        let trace = Arc::new(Mutex::new(Vec::new()));
        let head = Promise::new();
        let mut tail: Future<()> = head.get_future();
        for step in 0..32usize {
            let trace = trace.clone();
            tail = tail.then(&sched, move |_| {
                trace.lock().unwrap().push(step);
            });
        }
        head.set_value(());
        tail.wait();
        assert_eq!(
            *trace.lock().unwrap(),
            (0..32).collect::<Vec<_>>(),
            "policy {}",
            policy.name()
        );
        sched.shutdown();
    }
}

#[test]
fn deep_then_chain_is_safe_with_inlining_on_and_off() {
    // Continuation inlining (ISSUE 8) runs ready continuations directly on
    // the fulfilling worker.  A 10k-link chain pins the depth bound: past
    // MAX_INLINE_DEPTH consecutive inline frames the dispatcher must fall
    // back to `spawn` (fresh task, depth 0), so the chain completes in
    // order without overflowing the worker stack — and behaves identically
    // with the path disabled.
    const LINKS: usize = 10_000;
    for inline_cont in [true, false] {
        let sched = Scheduler::with_tuning(
            2,
            PolicyKind::PriorityLocal,
            Tuning { inline_cont, ..Tuning::default() },
        );
        let count = Arc::new(AtomicUsize::new(0));
        let head = Promise::new();
        let mut tail: Future<()> = head.get_future();
        for step in 0..LINKS {
            let count = count.clone();
            tail = tail.then(&sched, move |_| {
                // Monotone stamp: link `step` must be the `step`-th to run.
                assert_eq!(count.swap(step + 1, Ordering::SeqCst), step);
            });
        }
        head.set_value(());
        tail.wait();
        assert_eq!(count.load(Ordering::SeqCst), LINKS, "inline={inline_cont}");
        let m = sched.metrics();
        if inline_cont {
            assert!(
                m.continuations_inlined > 0,
                "inlining enabled but never engaged: {m}"
            );
        } else {
            assert_eq!(m.continuations_inlined, 0, "inlining disabled: {m}");
        }
        sched.shutdown();
    }
}

#[test]
fn diamond_dependence_graph_via_task_with_deps() {
    // A (out x) -> {B, C} (in x) -> D (inout x): the classic diamond,
    // expressed through the futurized `depend` engine.
    let rt = OmpRuntime::for_tests(4);
    let order = Arc::new(Mutex::new(Vec::new()));
    let o = order.clone();
    fork_call(&rt, Some(1), move |_| {
        let ctx = current_ctx().unwrap();
        let token = 0x5EEDusize;
        let o2 = o.clone();
        ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::Out }], move || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            o2.lock().unwrap().push("A");
        });
        for name in ["B", "C"] {
            let o2 = o.clone();
            ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::In }], move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                o2.lock().unwrap().push(name);
            });
        }
        let o2 = o.clone();
        ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::InOut }], move || {
            o2.lock().unwrap().push("D");
        });
        ctx.taskwait();
    });
    let order = order.lock().unwrap();
    assert_eq!(order.len(), 4, "tasks lost: {order:?}");
    assert_eq!(order[0], "A", "writer must run first: {order:?}");
    assert_eq!(order[3], "D", "joining writer must run last: {order:?}");
    assert!(
        order[1..3].contains(&"B") && order[1..3].contains(&"C"),
        "readers must run between the writers: {order:?}"
    );
}

#[test]
fn taskwait_inside_dependent_continuations_cannot_self_deadlock() {
    // Stress: every link of a 50-deep dependence chain is a continuation
    // task that itself spawns children and taskwaits on them — the inner
    // taskwait must help-run pending tasks, never block the chain.
    let rt = OmpRuntime::for_tests(4);
    let done = Arc::new(AtomicUsize::new(0));
    let d = done.clone();
    fork_call(&rt, Some(2), move |ctx| {
        if ctx.tid != 0 {
            return;
        }
        let ctx = current_ctx().unwrap();
        let token = 0xBEEFusize;
        for _ in 0..50 {
            let d = d.clone();
            ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::InOut }], move || {
                let inner = current_ctx().unwrap();
                for _ in 0..4 {
                    let d = d.clone();
                    inner.task(move || {
                        d.fetch_add(1, Ordering::SeqCst);
                    });
                }
                inner.taskwait(); // inside a continuation-scheduled task
                d.fetch_add(10, Ordering::SeqCst);
            });
        }
        ctx.taskwait();
    });
    assert_eq!(done.load(Ordering::SeqCst), 50 * 14);
}

#[test]
fn depend_chains_survive_hot_team_reuse() {
    // Back-to-back regions reusing the cached hot team must each see a
    // pristine dependence scope (DepMap cleared at park) while the
    // futurized chain still orders within every region.
    let rt = OmpRuntime::for_tests(2);
    for region in 0..20 {
        let trace = Arc::new(Mutex::new(Vec::new()));
        let t = trace.clone();
        fork_call(&rt, Some(2), move |ctx| {
            if ctx.tid != 0 {
                return;
            }
            let ctx = current_ctx().unwrap();
            let token = 0xABCDusize;
            for step in 0..6 {
                let t = t.clone();
                ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::InOut }], move || {
                    t.lock().unwrap().push(step);
                });
            }
            ctx.taskwait();
        });
        assert_eq!(
            *trace.lock().unwrap(),
            (0..6).collect::<Vec<_>>(),
            "region {region}"
        );
    }
}

#[test]
fn dataflow_mmult_matches_serial_oracle_across_shapes() {
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    // (m, k, n) including non-square and tile-ragged shapes.
    for (m, k, n) in [(64usize, 64usize, 64usize), (100, 60, 130), (57, 119, 83)] {
        let a = DynMatrix::random(m, k, 41);
        let b = DynMatrix::random(k, n, 42);
        let mut c_df = DynMatrix::zeros(m, n);
        dmatdmatmult(&task().on(&hpx).threads(4).tile(32), &a, &b, &mut c_df);
        let mut c_ref = DynMatrix::zeros(m, n);
        dmatdmatmult(&seq(), &a, &b, &mut c_ref);
        assert_eq!(
            c_df.max_abs_diff(&c_ref),
            0.0,
            "dataflow mmult diverged at ({m},{k},{n})"
        );
    }
}

#[test]
fn async_parallel_for_chains_into_dataflow_mmult() {
    // The composition the paper says fork/join cannot express: an async
    // element-wise pass whose future gates a dependent reduction, with the
    // caller blocking exactly once.
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let n = 256i64;
    let data: Arc<Vec<AtomicUsize>> = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect());
    let d = data.clone();
    let phase1 = hpx.parallel_for_async(
        4,
        0..n,
        Arc::new(move |r: std::ops::Range<i64>| {
            for i in r {
                d[i as usize].store(i as usize + 1, Ordering::SeqCst);
            }
        }),
    );
    let sched = hpx.rt.sched.clone();
    let d = data.clone();
    let total = phase1.then(&sched, move |_| {
        d.iter().map(|v| v.load(Ordering::SeqCst)).sum::<usize>()
    });
    assert_eq!(total.get(), (1..=n as usize).sum::<usize>());
}
