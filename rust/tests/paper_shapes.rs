//! Shape assertions from DESIGN.md §5: the qualitative claims of the
//! paper's §6 that are stable enough to gate in CI.  (Quantitative bands
//! are produced by `cargo bench` and recorded in EXPERIMENTS.md — timing
//! ratios on a shared 1-core box are too noisy for hard test assertions,
//! so here we keep only the structural facts.)

use hpxmp::baseline::BaselineRuntime;
use hpxmp::blaze::{self, thresholds, DynVector};
use hpxmp::coordinator::blazemark::Op;
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec::{par, Executor};
use hpxmp::par::HpxMpRuntime;

/// Shape (i): below the threshold both runtimes execute the *identical*
/// serial kernel — results are bitwise equal and no parallel region runs.
#[test]
fn below_threshold_no_parallel_region() {
    let rt = OmpRuntime::for_tests(4);
    let hpx = HpxMpRuntime::new(rt.clone());
    let n = thresholds::DAXPY_THRESHOLD - 1;
    let a = DynVector::random(n, 1);
    let mut b = DynVector::random(n, 2);
    let spawned_before = rt.sched.metrics().spawned;
    blaze::daxpy(&par().on(&hpx).threads(4), 3.0, &a, &mut b);
    let spawned_after = rt.sched.metrics().spawned;
    assert_eq!(
        spawned_before, spawned_after,
        "below threshold must not fork a team"
    );
}

/// Shape (i'): at/above the threshold hpxMP *does* fork (the paper's
/// plots begin to separate exactly there).
#[test]
fn at_threshold_parallel_region_forks() {
    let rt = OmpRuntime::for_tests(4);
    let hpx = HpxMpRuntime::new(rt.clone());
    let n = thresholds::DAXPY_THRESHOLD;
    let a = DynVector::random(n, 3);
    let mut b = DynVector::random(n, 4);
    let before = rt.sched.metrics().spawned;
    blaze::daxpy(&par().on(&hpx).threads(4), 3.0, &a, &mut b);
    let after = rt.sched.metrics().spawned;
    assert!(after >= before + 4, "expected 4 implicit tasks");
}

/// Shape (ii): per-op thresholds order as the paper states — matmul
/// parallelizes at far smaller matrices than matrix addition.
#[test]
fn threshold_ordering_matches_paper() {
    assert!(thresholds::DMATDMATMULT_THRESHOLD < thresholds::DMATDMATADD_THRESHOLD);
    assert_eq!(thresholds::DAXPY_THRESHOLD, thresholds::DVECDVECADD_THRESHOLD);
}

/// Shape (iii): FLOP density ordering — dmatdmatmult amortizes runtime
/// overhead fastest (O(n³) flops vs O(n²) data), which is why the paper's
/// Fig 5/9 recover earliest.  Structural check on our FLOP model.
#[test]
fn flop_density_ordering() {
    // flops per element of the target
    let mult = Op::DMatDMatMult.flops(100) / (100.0 * 100.0);
    let add = Op::DMatDMatAdd.flops(100) / (100.0 * 100.0);
    assert!(mult > 10.0 * add);
}

/// Both runtimes compute identical results at sizes where the figures are
/// compared — the precondition for a meaningful performance ratio.
#[test]
fn comparable_regime_results_identical() {
    let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
    let base = BaselineRuntime::new(4);
    let n = 200_000;
    let a = DynVector::random(n, 5);
    let b0 = DynVector::random(n, 6);
    let mut bh = b0.clone();
    let mut bb = b0.clone();
    blaze::daxpy(&par().on(&hpx).threads(4), 3.0, &a, &mut bh);
    blaze::daxpy(&par().on(&base).threads(4), 3.0, &a, &mut bb);
    assert_eq!(bh.max_abs_diff(&bb), 0.0);
    assert_eq!(hpx.name(), "hpxMP");
    assert_eq!(base.name(), "OpenMP(baseline)");
}
