//! Shared bench scaffolding: build runtimes, run one figure, emit CSV +
//! ASCII under `results/`, and the env-grid parsing every ablation bench
//! shares (offline build: criterion unavailable; these are harness-less
//! `cargo bench` binaries).
//!
//! Each bench binary compiles this module privately and uses a different
//! subset of it, so the whole module opts out of dead-code warnings.
#![allow(dead_code)]

use hpxmp::amt::PolicyKind;
use hpxmp::baseline::BaselineRuntime;
use hpxmp::coordinator::blazemark::Op;
use hpxmp::coordinator::{heatmap_sweep, report, scaling_sweep};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::HpxMpRuntime;
use hpxmp::util::timing::BenchCfg;

/// Benches run with CWD = the package dir (`rust/`); reports belong in the
/// workspace-root `results/`.
pub fn results_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../results")
}

/// Parse a comma-separated usize grid from env var `name`, falling back to
/// `default` — the one implementation behind every `BENCH_*` grid
/// override (previously copy-pasted per bench).
pub fn env_grid(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|v| {
            v.split(',')
                .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("{name}: bad entry {t:?}")))
                .collect()
        })
        .unwrap_or_else(|| default.to_vec())
}

/// `BENCH_SMOKE=1` — the CI profile: shrink iteration counts and grids.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok()
}

/// Thread grid for heatmaps.  The paper sweeps 1–16 on a 16-core node; we
/// keep the sweep but note (EXPERIMENTS.md) that >num_procs rows are
/// oversubscribed on this testbed.  `BENCH_THREADS=1,2,4` overrides.
pub fn heatmap_threads() -> Vec<usize> {
    env_grid("BENCH_THREADS", &[1, 2, 4, 8, 12, 16])
}

/// The paper's scaling figures use 4, 8, 16 threads.
pub fn scaling_threads() -> Vec<usize> {
    env_grid("BENCH_SCALING_THREADS", &[4, 8, 16])
}

/// Concurrent-client grid for the serving/wake ablations.
/// `BENCH_CLIENTS=1,2,4` overrides.
pub fn clients_grid() -> Vec<usize> {
    env_grid("BENCH_CLIENTS", &[1, 2, 4, 8])
}

pub fn build(max_threads: usize) -> (HpxMpRuntime, BaselineRuntime) {
    let rt = OmpRuntime::new(max_threads, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(max_threads);
    (HpxMpRuntime::new(rt), BaselineRuntime::new(max_threads))
}

/// Regenerate one heatmap figure (Figs 2–5).
pub fn run_heatmap(op: Op) {
    let threads = heatmap_threads();
    let max = threads.iter().copied().max().unwrap();
    let (hpx, base) = build(max);
    let cfg = BenchCfg::quick();
    let sizes = op.heatmap_sizes();
    eprintln!(
        "[{}] heatmap: threads {threads:?} x sizes {sizes:?}",
        op.name()
    );
    let r = heatmap_sweep(&hpx, &base, op, &threads, &sizes, &cfg, true);
    let out = report::write_heatmap(results_dir(), &r).expect("write heatmap");
    println!("{out}");
    report::append_summary(
        results_dir(),
        &format!(
            "{} {} mean_ratio={:.3}",
            op.figures().0,
            op.name(),
            r.mean_ratio()
        ),
    )
    .ok();
}

/// Regenerate one scaling figure (Figs 6–9): series at 4/8/16 threads.
pub fn run_scaling(op: Op) {
    let threads = scaling_threads();
    let max = threads.iter().copied().max().unwrap();
    let (hpx, base) = build(max);
    let cfg = BenchCfg::quick();
    let sizes = op.scaling_sizes();
    for &t in &threads {
        eprintln!("[{}] scaling @{t} threads", op.name());
        let r = scaling_sweep(&hpx, &base, op, t, &sizes, &cfg, true);
        let out = report::write_scaling(results_dir(), &r).expect("write scaling");
        println!("{out}");
    }
}
