//! Shared bench scaffolding: build runtimes, run one figure, emit CSV +
//! ASCII under `results/`, and the env-grid parsing every ablation bench
//! shares (offline build: criterion unavailable; these are harness-less
//! `cargo bench` binaries).
//!
//! Each bench binary compiles this module privately and uses a different
//! subset of it, so the whole module opts out of dead-code warnings.
#![allow(dead_code)]

use hpxmp::amt::PolicyKind;
use hpxmp::baseline::BaselineRuntime;
use hpxmp::coordinator::blazemark::Op;
use hpxmp::coordinator::{heatmap_sweep, report, scaling_sweep};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::{ExecMode, HpxMpRuntime, Policy};
use hpxmp::util::timing::BenchCfg;

/// Benches run with CWD = the package dir (`rust/`); reports belong in the
/// workspace-root `results/`.
pub fn results_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../results")
}

/// Parse a comma-separated usize grid from env var `name`, falling back to
/// `default` — the one implementation behind every `BENCH_*` grid
/// override (previously copy-pasted per bench).
pub fn env_grid(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|v| {
            v.split(',')
                .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("{name}: bad entry {t:?}")))
                .collect()
        })
        .unwrap_or_else(|| default.to_vec())
}

/// `BENCH_SMOKE=1` — the CI profile: shrink iteration counts and grids.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok()
}

/// Thread grid for heatmaps.  The paper sweeps 1–16 on a 16-core node; we
/// keep the sweep but note (EXPERIMENTS.md) that >num_procs rows are
/// oversubscribed on this testbed.  `BENCH_THREADS=1,2,4` overrides;
/// under `BENCH_SMOKE=1` the default shrinks to `[1, 2]`.
pub fn heatmap_threads() -> Vec<usize> {
    let default: &[usize] = if smoke() { &[1, 2] } else { &[1, 2, 4, 8, 12, 16] };
    env_grid("BENCH_THREADS", default)
}

/// The paper's scaling figures use 4, 8, 16 threads (smoke: just 2).
pub fn scaling_threads() -> Vec<usize> {
    let default: &[usize] = if smoke() { &[2] } else { &[4, 8, 16] };
    env_grid("BENCH_SCALING_THREADS", default)
}

/// Truncate a size grid to its first three entries under `BENCH_SMOKE=1`
/// — the figure sweeps keep their shape but finish in CI time.
pub fn smoke_sizes(sizes: Vec<usize>) -> Vec<usize> {
    if smoke() {
        sizes.into_iter().take(3).collect()
    } else {
        sizes
    }
}

/// Steady-state timing profile: `quick()` normally, a few-iteration
/// profile under `BENCH_SMOKE=1`.
pub fn bench_cfg() -> BenchCfg {
    if smoke() {
        BenchCfg {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 5,
            min_time: std::time::Duration::from_millis(2),
        }
    } else {
        BenchCfg::quick()
    }
}

/// Concurrent-client grid for the serving/wake ablations.
/// `BENCH_CLIENTS=1,2,4` overrides.
pub fn clients_grid() -> Vec<usize> {
    env_grid("BENCH_CLIENTS", &[1, 2, 4, 8])
}

/// Offered-load grid (requests/second) for the wire-serving ablation.
/// `BENCH_RATES=100,1000` overrides; under `BENCH_SMOKE=1` the default
/// shrinks to two light rates so CI stays inside its timeout.
pub fn rates_grid() -> Vec<usize> {
    let default: &[usize] = if smoke() { &[100, 1000] } else { &[500, 1000, 2000, 4000, 8000] };
    env_grid("BENCH_RATES", default)
}

pub fn build(max_threads: usize) -> (HpxMpRuntime, BaselineRuntime) {
    let rt = OmpRuntime::new(max_threads, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(max_threads);
    (HpxMpRuntime::new(rt), BaselineRuntime::new(max_threads))
}

/// Execution policy for the figure sweeps: `par` unless `HPXMP_EXEC`
/// overrides (the same env binding the CLI honors), so the whole figure
/// suite re-runs under `task` dataflow with one env var.
pub fn exec_mode() -> ExecMode {
    ExecMode::from_env(ExecMode::Par)
}

/// Regenerate one heatmap figure (Figs 2–5).
///
/// Under `par` (the default) one shared max-width runtime serves every
/// thread row — team size is what the row varies, and the pool stays
/// warm across rows.  Under `HPXMP_EXEC=task` each row gets its own
/// exactly-t-worker runtime: a task graph parallelizes over *every* AMT
/// worker, so a shared wide pool would make all rows identical (same
/// rule as `hpxmp heatmap --exec task` and `ablation_exec`).
pub fn run_heatmap(op: Op) {
    let mode = exec_mode();
    let threads = heatmap_threads();
    let max = threads.iter().copied().max().unwrap();
    let shared = build(max);
    let cfg = bench_cfg();
    let sizes = smoke_sizes(op.heatmap_sizes());
    eprintln!(
        "[{}] heatmap: threads {threads:?} x sizes {sizes:?}",
        op.name()
    );
    let mut acc: Option<hpxmp::coordinator::HeatmapResult> = None;
    for &t in &threads {
        let row_rt;
        let (hpx, base) = if mode == ExecMode::Task {
            row_rt = build(t);
            (&row_rt.0, &row_rt.1)
        } else {
            (&shared.0, &shared.1)
        };
        let hpol = Policy::with_mode(mode).on(hpx);
        let bpol = Policy::with_mode(mode).on(base);
        let row = heatmap_sweep(&hpol, &bpol, op, &[t], &sizes, &cfg, true);
        match &mut acc {
            None => acc = Some(row),
            Some(a) => {
                a.threads.push(t);
                a.ratio.extend(row.ratio);
                a.hpx_mflops.extend(row.hpx_mflops);
                a.base_mflops.extend(row.base_mflops);
            }
        }
    }
    let r = acc.expect("non-empty thread grid");
    let out = report::write_heatmap(results_dir(), &r).expect("write heatmap");
    println!("{out}");
    report::append_summary(
        results_dir(),
        &format!(
            "{} {} mean_ratio={:.3}",
            op.figures().0,
            op.name(),
            r.mean_ratio()
        ),
    )
    .ok();
}

/// Regenerate one scaling figure (Figs 6–9): series at 4/8/16 threads.
/// Same per-row runtime-sizing rule for task mode as [`run_heatmap`].
pub fn run_scaling(op: Op) {
    let mode = exec_mode();
    let threads = scaling_threads();
    let max = threads.iter().copied().max().unwrap();
    let shared = build(max);
    let cfg = bench_cfg();
    let sizes = smoke_sizes(op.scaling_sizes());
    for &t in &threads {
        eprintln!("[{}] scaling @{t} threads", op.name());
        let row_rt;
        let (hpx, base) = if mode == ExecMode::Task {
            row_rt = build(t);
            (&row_rt.0, &row_rt.1)
        } else {
            (&shared.0, &shared.1)
        };
        let hpol = Policy::with_mode(mode).on(hpx);
        let bpol = Policy::with_mode(mode).on(base);
        let r = scaling_sweep(&hpol, &bpol, op, t, &sizes, &cfg, true);
        let out = report::write_scaling(results_dir(), &r).expect("write scaling");
        println!("{out}");
    }
}
