//! Task Bench pattern-grid ablation (ISSUE 8): the proof layer for the
//! scheduler fast paths — steal-half batching, locality-aware victim
//! selection, continuation inlining.
//!
//! Runs the `coordinator::taskbench` sweep — five dependency patterns
//! (stencil, nearest, fft, spread, random) × scheduling policies × task
//! grains × thread counts — under two tuning arms built in-process via
//! `Scheduler::with_tuning`:
//!
//! * `steal-half` — batched steals (up to half the victim's queue) +
//!   continuation inlining: the ISSUE 8 fast paths, the default.
//! * `steal-one`  — single-task steals, no inlining: the pre-ISSUE-8
//!   behavior (what `HPXMP_STEAL_ONE=1 HPXMP_INLINE_CONT=0` gives a
//!   whole process).
//!
//! Emits `results/BENCH_taskbench.json`:
//!
//! ```json
//! { "bench": "taskbench",
//!   "rows": [ {"pattern": "stencil", "policy": "priority-local",
//!              "threads": 4, "grain_us": 0, "mode": "steal-half",
//!              "us_per_task": 1.93, "eff": 0.0, "metg_us": 6.0}, ... ],
//!   "speedup_stealhalf_vs_single": {"1": r1, "2": r2, ...} }
//! ```
//!
//! `metg_us` is the automatically solved minimum effective task
//! granularity (ISSUE 9): per (pattern, policy, threads, tuning)
//! combination, `solve_metg` binary-searches the grain axis for the
//! smallest grain sustaining >= 50% parallel efficiency; `null` when no
//! grain up to the search ceiling reaches it.
//!
//! `us_per_task` is the METG-style overhead row (grain 0 = pure runtime
//! overhead per task); `eff` is parallel efficiency at that grain.  The
//! headline is, per thread count, the **best** `steal-one / steal-half`
//! time ratio over matching (pattern, policy, grain) cells — >1 means
//! the fast paths won somewhere at that width.  `BENCH_SMOKE=1` shrinks
//! the grid for CI; `BENCH_THREADS=1,2` overrides the thread grid.

use hpxmp::amt::{PolicyKind, Tuning};
use hpxmp::coordinator::taskbench::{render, sweep, Pattern, SweepCfg, TbRow};

mod common;

fn main() {
    let smoke = common::smoke();
    let cfg = SweepCfg {
        patterns: Pattern::ALL.to_vec(),
        policies: vec![PolicyKind::PriorityLocal, PolicyKind::Abp, PolicyKind::Local],
        threads: common::heatmap_threads(),
        grains_us: if smoke { vec![0, 20] } else { vec![0, 5, 20] },
        width: if smoke { 32 } else { 64 },
        steps: if smoke { 16 } else { 32 },
        reps: if smoke { 2 } else { 3 },
        tunings: vec![
            ("steal-half", Tuning { steal_batch: 32, inline_cont: true }),
            ("steal-one", Tuning { steal_batch: 1, inline_cont: false }),
        ],
        metg: true,
    };
    eprintln!(
        "[taskbench] {}x{} grid, threads {:?}, grains {:?} us",
        cfg.width, cfg.steps, cfg.threads, cfg.grains_us
    );
    let rows = sweep(&cfg);
    print!("{}", render(&rows));

    // Headline: per thread count, best steal-one/steal-half ratio over
    // matching (pattern, policy, grain) cells.
    let cell = |mode: &str, t: usize, r: &TbRow| -> Option<f64> {
        rows.iter()
            .find(|o| {
                o.mode == mode
                    && o.threads == t
                    && o.pattern == r.pattern
                    && o.policy == r.policy
                    && o.grain_us == r.grain_us
            })
            .map(|o| o.us_per_task)
    };
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &t in &cfg.threads {
        let mut best: Option<f64> = None;
        for r in rows.iter().filter(|r| r.mode == "steal-half" && r.threads == t) {
            if let Some(one) = cell("steal-one", t, r) {
                if r.us_per_task > 0.0 {
                    let s = one / r.us_per_task;
                    best = Some(best.map_or(s, |b: f64| b.max(s)));
                }
            }
        }
        if let Some(s) = best {
            println!("best speedup steal-half vs steal-one @{t} threads: {s:.2}x");
            speedups.push((t, s));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"taskbench\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let metg = r
            .metg_us
            .map_or_else(|| "null".to_string(), |m| format!("{m:.1}"));
        json.push_str(&format!(
            "    {{\"pattern\": \"{}\", \"policy\": \"{}\", \"threads\": {}, \"grain_us\": {}, \
             \"mode\": \"{}\", \"us_per_task\": {:.4}, \"eff\": {:.4}, \"metg_us\": {}}}{}\n",
            r.pattern,
            r.policy,
            r.threads,
            r.grain_us,
            r.mode,
            r.us_per_task,
            r.eff,
            metg,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"speedup_stealhalf_vs_single\": {");
    for (i, (t, s)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            t,
            s
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_taskbench.json");
    std::fs::write(&path, json).expect("write BENCH_taskbench.json");
    println!("{}", path.display());
}
