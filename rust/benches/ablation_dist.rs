//! Dist ablation (ISSUE 10): does sharding the serving stack across
//! worker *processes* buy throughput over one in-process server, and
//! what does the distributed `dmatdmatmult` cost against the
//! single-process packed kernel?
//!
//! For every (shards × offered rate) cell the bench spawns a fresh
//! worker fleet (`ShardPool` + `Router`, the exact `hpxmp serve
//! --shards` stack, workers being real child processes of the `hpxmp`
//! binary) behind a wire front-end on an ephemeral loopback port, and
//! drives it with the same seeded open-loop generator as the
//! single-process arm:
//!
//! * `single`  — PR 9 in-process server (`WireServer::start_tcp`), all
//!   cores on one runtime;
//! * `dist-S`  — S worker processes with the cores split between them,
//!   requests forwarded by connection key.
//!
//! After the grid, the **scatter/gather probe** times `dist_matmul`
//! (broadcast B, scatter A row bands, gather C over remote futures)
//! against `packed_matmul` and checks the gather bitwise.
//!
//! Emits `results/BENCH_dist.json`:
//!
//! ```json
//! { "bench": "dist",
//!   "rows": [ {"rate": 1000, "shards": 2, "mode": "dist",
//!              "reqs_per_sec": r, "goodput_per_sec": g,
//!              "p50_us": p, "p99_us": q, "shed": s, "lost": l}, ... ],
//!   "dist_mmult": {"n": 256, "dist_ms": d, "single_ms": s,
//!                  "bitwise": true},
//!   "throughput_sharded_vs_single": x }
//! ```
//!
//! The headline `throughput_sharded_vs_single` is the best
//! dist/single completed-throughput ratio over rates at shards >= 2
//! (>= 1.0 is the ISSUE 10 acceptance bar: process isolation must not
//! cost throughput at some operating point).  `BENCH_SHARDS` /
//! `BENCH_RATES` override the grids; `BENCH_SMOKE=1` shrinks durations
//! for CI.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hpxmp::amt::PolicyKind;
use hpxmp::blaze::{kernel, DynVector};
use hpxmp::dist::{dist_matmul, Router, ShardCfg, ShardPool};
use hpxmp::net::{
    BatchCfg, Dist, LoadgenCfg, LoadgenReport, WireAddr, WireOp, WireServer, WireStats,
};
use hpxmp::omp::{icv, OmpRuntime};

mod common;

struct Cell {
    rate: usize,
    shards: usize,
    mode: &'static str,
    report: LoadgenReport,
}

fn loadgen(addr: WireAddr, rate: usize, conns: usize, duration: Duration) -> LoadgenReport {
    hpxmp::net::run_loadgen(&LoadgenCfg {
        addr,
        op: WireOp::Daxpy,
        n: hpxmp::net::default_wire_n(WireOp::Daxpy),
        rate: rate as f64,
        conns,
        dist: Dist::Poisson,
        duration,
        deadline_us: 0,
        seed: 0x5eed_d157,
    })
    .expect("loadgen run")
}

/// Single-process baseline: the PR 9 in-process server on all cores.
fn run_single(workers: usize, rate: usize, conns: usize, duration: Duration) -> Cell {
    let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(workers);
    let server =
        WireServer::start_tcp(rt, "127.0.0.1:0", BatchCfg::default()).expect("bind wire server");
    let addr = WireAddr::Tcp(server.local_addr().expect("tcp addr").to_string());
    let report = loadgen(addr, rate, conns, duration);
    server.drain(Duration::from_secs(5));
    Cell { rate, shards: 1, mode: "single", report }
}

/// Dist arm: a fresh worker fleet behind the shard router, cores split
/// between the processes.
fn run_dist(
    shards: usize,
    workers: usize,
    rate: usize,
    conns: usize,
    duration: Duration,
) -> Option<Cell> {
    let mut cfg = ShardCfg::new(shards, (workers / shards).max(1)).expect("shard cfg");
    cfg.program = PathBuf::from(env!("CARGO_BIN_EXE_hpxmp"));
    let mut pool = ShardPool::start(cfg).expect("start pool");
    if !pool.wait_ready(Duration::from_secs(10)) {
        eprintln!("[dist] fleet of {shards} never came up; skipping cell");
        pool.shutdown();
        return None;
    }
    let stats = Arc::new(WireStats::default());
    let router = Router::new(&pool, stats.clone(), 4096);
    let server = WireServer::start_with(router, stats, &[WireAddr::Tcp("127.0.0.1:0".into())])
        .expect("bind dist front-end");
    let addr = WireAddr::Tcp(server.local_addr().expect("tcp addr").to_string());
    let report = loadgen(addr, rate, conns, duration);
    server.drain(Duration::from_secs(5));
    drop(server);
    pool.shutdown();
    Some(Cell { rate, shards, mode: "dist", report })
}

fn main() {
    let smoke = common::smoke();
    let workers = icv::num_procs().max(2);
    let rates = common::rates_grid();
    let shards_grid = common::env_grid("BENCH_SHARDS", &[1, 2]);
    let conns = 8usize;
    let duration = if smoke {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    eprintln!(
        "[dist] shards {shards_grid:?} x rates {rates:?}, {workers} cores, {conns} conns, \
         {}ms per cell",
        duration.as_millis()
    );

    let mut cells: Vec<Cell> = Vec::new();
    for &rate in &rates {
        let start = cells.len();
        cells.push(run_single(workers, rate, conns, duration));
        for &shards in &shards_grid {
            if let Some(c) = run_dist(shards, workers, rate, conns, duration) {
                cells.push(c);
            }
        }
        for c in &cells[start..] {
            println!(
                "rate {:>6} {:<8} shards {:>2} -> {:>9.1} req/s  p50 {:>8.0}us  \
                 p99 {:>8.0}us  shed {:>5}  lost {:>4}",
                c.rate,
                c.mode,
                c.shards,
                c.report.reqs_per_sec(),
                c.report.stats.p50_us(),
                c.report.stats.p99_us(),
                c.report.stats.shed,
                c.report.lost,
            );
        }
    }

    // Headline: best dist/single completed-throughput ratio at >= 2
    // process shards (same offered rate in both arms).
    let mut ratio: Option<f64> = None;
    for &rate in &rates {
        let single = cells
            .iter()
            .find(|c| c.mode == "single" && c.rate == rate)
            .map(|c| c.report.reqs_per_sec());
        for &shards in shards_grid.iter().filter(|&&s| s >= 2) {
            let dist = cells
                .iter()
                .find(|c| c.mode == "dist" && c.shards == shards && c.rate == rate)
                .map(|c| c.report.reqs_per_sec());
            if let (Some(s), Some(d)) = (single, dist) {
                if s > 0.0 {
                    let r = d / s;
                    ratio = Some(ratio.map_or(r, |t: f64| t.max(r)));
                }
            }
        }
    }
    let ratio = ratio.unwrap_or(0.0);
    println!("throughput sharded vs single: {ratio:.3}x");

    // Scatter/gather probe: distributed dmatdmatmult against the
    // single-process packed kernel, timed and checked bitwise.
    let n = if smoke { 192 } else { 512 };
    let a = DynVector::random(n * n, 0xD157_A).as_slice().to_vec();
    let b = DynVector::random(n * n, 0xD157_B).as_slice().to_vec();
    let mmult_shards = shards_grid.iter().copied().filter(|&s| s >= 2).max().unwrap_or(2);
    let mut cfg = ShardCfg::new(mmult_shards, (workers / mmult_shards).max(1)).expect("shard cfg");
    cfg.program = PathBuf::from(env!("CARGO_BIN_EXE_hpxmp"));
    let mut pool = ShardPool::start(cfg).expect("start pool");
    let (dist_ms, bitwise) = if pool.wait_ready(Duration::from_secs(10)) {
        let t0 = Instant::now();
        let c = dist_matmul(&pool, &a, &b, n).expect("dist mmult");
        let dist_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut want = vec![0.0f64; n * n];
        kernel::packed_matmul(&a, &b, n, n, n, &mut want);
        let bitwise = c
            .iter()
            .zip(&want)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        (dist_ms, bitwise)
    } else {
        eprintln!("[dist] mmult fleet never came up; recording a miss");
        (f64::NAN, false)
    };
    pool.shutdown();
    let t0 = Instant::now();
    let mut single_c = vec![0.0f64; n * n];
    kernel::packed_matmul(&a, &b, n, n, n, &mut single_c);
    let single_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "dist mmult n={n} @{mmult_shards} shards: {dist_ms:.1}ms vs single {single_ms:.1}ms, \
         bitwise {bitwise}"
    );

    let mut json = String::from("{\n  \"bench\": \"dist\",\n  \"rows\": [\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"rate\": {}, \"shards\": {}, \"mode\": \"{}\", \"reqs_per_sec\": {:.2}, \
             \"goodput_per_sec\": {:.2}, \"p50_us\": {:.1}, \"p99_us\": {:.1}, \
             \"shed\": {}, \"lost\": {}}}{}\n",
            c.rate,
            c.shards,
            c.mode,
            c.report.reqs_per_sec(),
            c.report.goodput_per_sec(),
            c.report.stats.p50_us(),
            c.report.stats.p99_us(),
            c.report.stats.shed,
            c.report.lost,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"dist_mmult\": {{\"n\": {n}, \"shards\": {mmult_shards}, \
         \"dist_ms\": {dist_ms:.2}, \"single_ms\": {single_ms:.2}, \"bitwise\": {bitwise}}},\n  \
         \"throughput_sharded_vs_single\": {ratio:.3}\n}}\n"
    ));

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_dist.json");
    std::fs::write(&path, json).expect("write BENCH_dist.json");
    println!("{}", path.display());
    // Fail the bench *after* the artifact is on disk, so a CI miss still
    // uploads the numbers that show what went wrong.
    assert!(bitwise, "distributed mmult must be bitwise identical to the oracle");
}
