//! Paper Fig7: daxpy scaling series (MFLOP/s vs size) at 4/8/16
//! threads, both runtimes.  Emits `results/fig7_*_scaling_*.csv`.

mod common;

use hpxmp::coordinator::blazemark::Op;

fn main() {
    common::run_scaling(Op::parse("daxpy").unwrap());
}
