//! Sleep/wake substrate ablation (ISSUE 4) — the regression guard for the
//! per-worker parking + targeted-wake refactor.
//!
//! At each submitter count `k` in `BENCH_CLIENTS`, under both idle
//! substrates —
//!
//! * `hpxmp-targeted` — per-worker parkers + lock-free idle set, spawns
//!   wake the worker whose queue got the task (the default), and
//! * `hpxmp-global`   — the legacy one-mutex/one-condvar idle system
//!   (`HPXMP_GLOBAL_IDLE=1`), every wake through one lock —
//!
//! it measures:
//!
//! * `spawn_latency` — spawn-to-task-start latency (µs) with `k`
//!   concurrent submitter threads spawning hinted tasks onto a mostly-idle
//!   pool (each spawn must *wake* a parked worker — the herd-vs-targeted
//!   path in isolation);
//! * `empty_region` — empty `parallel` region round-trip (µs) with `k`
//!   concurrent fork/join clients on one runtime (the full stack: batch
//!   spawn, targeted wakes, barrier, join).
//!
//! Plus one `hpxmp serve` smoke per substrate (p50/p99 request latency,
//! best of two runs to damp scheduler noise).
//!
//! Emits `results/BENCH_wake.json`: `rows[]` of
//! `{construct, runtime, submitters, us_per_op}`, a `serve` block, and the
//! headline `wake_targeted_vs_global` — per submitter count, the best
//! global/targeted time ratio across constructs (≥ 1.0 means the targeted
//! substrate is no slower; the gap should grow with submitter count).
//! `BENCH_CLIENTS` overrides the submitter grid, `BENCH_SMOKE=1` shrinks
//! iteration counts for CI.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use hpxmp::amt::task::Hint;
use hpxmp::amt::{PolicyKind, Priority, Scheduler};
use hpxmp::coordinator::serve::{serve_shared, KernelMix, ServeCfg};
use hpxmp::omp::{fork_call, icv, OmpRuntime};

mod common;

struct Row {
    construct: &'static str,
    runtime: &'static str,
    submitters: usize,
    us_per_op: f64,
}

/// Select the idle substrate for every runtime built afterwards.
fn set_idle_mode(global: bool) {
    if global {
        std::env::set_var("HPXMP_GLOBAL_IDLE", "1");
    } else {
        std::env::remove_var("HPXMP_GLOBAL_IDLE");
    }
}

use hpxmp::util::timing::spin_wait as busy_wait;

/// Spawn-to-start latency: `k` submitters spawn one hinted task at a time
/// onto a pool that is parked between spawns (a ~150µs gap lets the
/// workers run dry and park), so every spawn exercises the wake path.
fn bench_spawn_latency(runtime: &'static str, k: usize, iters: usize, rows: &mut Vec<Row>) {
    let workers = icv::num_procs().max(2);
    let sched = Scheduler::new(workers, PolicyKind::PriorityLocal);
    let total_ns = Arc::new(AtomicU64::new(0));
    let count = Arc::new(AtomicU64::new(0));
    let start = Arc::new(Barrier::new(k + 1));
    let handles: Vec<_> = (0..k)
        .map(|ci| {
            let sched = sched.clone();
            let total_ns = total_ns.clone();
            let count = count.clone();
            let start = start.clone();
            std::thread::spawn(move || {
                start.wait();
                for i in 0..iters {
                    let total_ns = total_ns.clone();
                    let count = count.clone();
                    let t0 = Instant::now();
                    sched.spawn(
                        Priority::Normal,
                        Hint::Worker((ci * 7 + i) % workers),
                        "wake_probe",
                        move || {
                            total_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            count.fetch_add(1, Ordering::Relaxed);
                        },
                    );
                    // Let the pool drain and park before the next probe.
                    busy_wait(Duration::from_micros(150));
                }
            })
        })
        .collect();
    start.wait();
    for h in handles {
        h.join().expect("submitter panicked");
    }
    sched.wait_quiescent();
    let n = count.load(Ordering::Relaxed).max(1);
    let us = total_ns.load(Ordering::Relaxed) as f64 / n as f64 / 1e3;
    let m = sched.metrics();
    eprintln!(
        "[wake] spawn_latency {runtime} k={k}: {us:.3} us/op  ({m})"
    );
    sched.shutdown();
    rows.push(Row {
        construct: "spawn_latency",
        runtime,
        submitters: k,
        us_per_op: us,
    });
}

/// Empty fork/join region round-trip with `k` concurrent clients on one
/// runtime — the serving-shaped wake workload (batch spawn + targeted
/// wakes + barrier + join per request).
fn bench_empty_region(runtime: &'static str, k: usize, iters: usize, rows: &mut Vec<Row>) {
    let workers = icv::num_procs().max(2);
    let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(2);
    // Warm the workers and the team pool.
    for _ in 0..5 {
        fork_call(&rt, Some(2), |_| {});
    }
    let start = Arc::new(Barrier::new(k + 1));
    let handles: Vec<_> = (0..k)
        .map(|_| {
            let rt = rt.clone();
            let start = start.clone();
            std::thread::spawn(move || {
                start.wait();
                let t0 = Instant::now();
                for _ in 0..iters {
                    fork_call(&rt, Some(2), |_| {});
                }
                t0.elapsed().as_secs_f64() / iters as f64
            })
        })
        .collect();
    start.wait();
    let mut per_client: Vec<f64> = Vec::with_capacity(k);
    for h in handles {
        per_client.push(h.join().expect("client panicked"));
    }
    // Join the worker pool before the next cell: a lingering pool's parked
    // workers would charge their idle churn to whichever substrate runs
    // later.
    rt.sched.shutdown();
    let us = per_client.iter().sum::<f64>() / per_client.len() as f64 * 1e6;
    eprintln!("[wake] empty_region {runtime} k={k}: {us:.3} us/op");
    rows.push(Row {
        construct: "empty_region",
        runtime,
        submitters: k,
        us_per_op: us,
    });
}

/// One `hpxmp serve` smoke under the active substrate; best of two runs
/// (by p99) to damp scheduler noise.
fn bench_serve(requests: usize) -> (f64, f64) {
    let mut best: Option<(f64, f64)> = None;
    for _ in 0..2 {
        let workers = icv::num_procs().max(2);
        let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
        rt.icv.set_nthreads(2);
        let cfg = ServeCfg::new(2, 2, requests, KernelMix::Vector);
        let stats = serve_shared(&rt, &cfg);
        rt.sched.shutdown(); // no pool bleed-over into the next run/cell
        let cell = (stats.p50_us, stats.p99_us);
        best = Some(match best {
            Some(b) if b.1 <= cell.1 => b,
            _ => cell,
        });
    }
    best.unwrap()
}

fn main() {
    let smoke = common::smoke();
    let submitters = common::clients_grid();
    let spawn_iters = if smoke { 200 } else { 2000 };
    let region_iters = if smoke { 200 } else { 2000 };
    let serve_requests = if smoke { 25 } else { 100 };

    let mut rows: Vec<Row> = Vec::new();
    let mut serve: Vec<(&'static str, f64, f64)> = Vec::new();
    for (runtime, global) in [("hpxmp-targeted", false), ("hpxmp-global", true)] {
        set_idle_mode(global);
        for &k in &submitters {
            eprintln!("[wake] {runtime} submitters={k}");
            bench_spawn_latency(runtime, k, spawn_iters, &mut rows);
            bench_empty_region(runtime, k, region_iters, &mut rows);
        }
        let (p50, p99) = bench_serve(serve_requests);
        eprintln!("[wake] serve {runtime}: p50={p50:.1}us p99={p99:.1}us");
        serve.push((runtime, p50, p99));
    }
    set_idle_mode(false);

    // Table.
    println!(
        "{:<14} {:<16} {:>10} {:>12}",
        "construct", "runtime", "submitters", "us/op"
    );
    for r in &rows {
        println!(
            "{:<14} {:<16} {:>10} {:>12.3}",
            r.construct, r.runtime, r.submitters, r.us_per_op
        );
    }

    // Headline: per submitter count, best global/targeted time ratio over
    // the two constructs (>1 = targeted wins that cell).
    let mut ratios: Vec<(usize, f64)> = Vec::new();
    for &k in &submitters {
        let mut best: Option<f64> = None;
        for construct in ["spawn_latency", "empty_region"] {
            let find = |rt: &str| {
                rows.iter()
                    .find(|r| r.construct == construct && r.runtime == rt && r.submitters == k)
                    .map(|r| r.us_per_op)
            };
            if let (Some(t), Some(g)) = (find("hpxmp-targeted"), find("hpxmp-global")) {
                if t > 0.0 {
                    let ratio = g / t;
                    best = Some(best.map_or(ratio, |b: f64| b.max(ratio)));
                }
            }
        }
        if let Some(b) = best {
            println!("targeted vs global @{k} submitters (best cell): {b:.3}x");
            ratios.push((k, b));
        }
    }

    // JSON report (same format family as the other ablation benches).
    let mut json = String::from("{\n  \"bench\": \"wake\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"construct\": \"{}\", \"runtime\": \"{}\", \"submitters\": {}, \"us_per_op\": {:.4}}}{}\n",
            r.construct,
            r.runtime,
            r.submitters,
            r.us_per_op,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"serve\": {\n");
    for (i, (runtime, p50, p99)) in serve.iter().enumerate() {
        json.push_str(&format!(
            "    \"{}\": {{\"p50_us\": {:.2}, \"p99_us\": {:.2}}}{}\n",
            runtime,
            p50,
            p99,
            if i + 1 == serve.len() { "" } else { "," }
        ));
    }
    json.push_str("  },\n  \"wake_targeted_vs_global\": {");
    for (i, (k, ratio)) in ratios.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            k,
            ratio
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_wake.json");
    std::fs::write(&path, json).expect("write BENCH_wake.json");
    println!("{}", path.display());
}
