//! Paper Fig3: daxpy performance-ratio heatmap (hpxMP / OpenMP,
//! threads x size).  Emits `results/fig3_daxpy_heatmap.csv` + ASCII render.

mod common;

use hpxmp::coordinator::blazemark::Op;

fn main() {
    common::run_heatmap(Op::parse("daxpy").unwrap());
}
