//! Dataflow ablation (ISSUE 2): fork-join vs the futurized dataflow
//! engine, on the two workloads the issue names.  Since ISSUE 5 the two
//! mmult paths are the same kernel under two execution policies
//! (`par().on(&hpx)` vs `task().on(&hpx)` — the generic tiled graph).
//!
//! * `mmult_<n>` — `dmatdmatmult` at size `n`: the fork-join row-band
//!   policy (`runtime: "fork-join"`) against the `when_all`/`then` tiled
//!   task graph policy (`runtime: "dataflow"`); reported as `us_per_op`
//!   = microseconds per whole product (lower is better).
//! * `chain_<len>` — a Task-Bench-style dependency chain of `len`
//!   sequentially dependent empty tasks: a raw future `then`-chain
//!   (`runtime: "future-chain"`) against the same chain expressed as
//!   OpenMP `task depend(inout)` on one address (`runtime: "omp-depend"`);
//!   `us_per_op` = microseconds per chain link (task creation + dependence
//!   resolution + scheduling).
//!
//! Emits `results/BENCH_dataflow.json` in the same `rows[]` format as
//! `BENCH_fork_overhead.json`, plus `speedup_dataflow_vs_forkjoin`: the
//! per-thread-count **best** `fork-join / dataflow` time ratio across the
//! mmult sizes (>1 means the dataflow path beat fork/join somewhere).
//! `BENCH_SMOKE=1` shrinks sizes and iteration counts for CI.

use std::time::Instant;

use hpxmp::amt::future::{Future, Promise};
use hpxmp::amt::PolicyKind;
use hpxmp::blaze::{dmatdmatmult, DynMatrix};
use hpxmp::omp::{current_ctx, fork_call, Dep, DepKind, OmpRuntime};
use hpxmp::par::exec::{par, task};
use hpxmp::par::HpxMpRuntime;

mod common;

struct Row {
    construct: String,
    runtime: &'static str,
    threads: usize,
    us_per_op: f64,
}

/// Mean seconds per call of `f` over `iters` calls.
fn time_per(iters: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_mmult(hpx: &HpxMpRuntime, threads: usize, n: usize, iters: usize, rows: &mut Vec<Row>) {
    let fj_pol = par().on(hpx).threads(threads);
    let df_pol = task().on(hpx).threads(threads);
    let a = DynMatrix::random(n, n, 17);
    let b = DynMatrix::random(n, n, 18);
    let mut c = DynMatrix::zeros(n, n);

    // Warm both paths (populates the hot team / spins up workers).
    dmatdmatmult(&fj_pol, &a, &b, &mut c);
    dmatdmatmult(&df_pol, &a, &b, &mut c);

    let fj = time_per(iters, || dmatdmatmult(&fj_pol, &a, &b, &mut c));
    rows.push(Row {
        construct: format!("mmult_{n}"),
        runtime: "fork-join",
        threads,
        us_per_op: fj * 1e6,
    });
    let df = time_per(iters, || dmatdmatmult(&df_pol, &a, &b, &mut c));
    rows.push(Row {
        construct: format!("mmult_{n}"),
        runtime: "dataflow",
        threads,
        us_per_op: df * 1e6,
    });
}

fn bench_chains(hpx: &HpxMpRuntime, threads: usize, len: usize, rows: &mut Vec<Row>) {
    // Raw future then-chain: creation + scheduling of `len` dependent
    // continuations, timed end to end.
    let sched = hpx.rt.sched.clone();
    let t0 = Instant::now();
    let head = Promise::new();
    let mut tail: Future<()> = head.get_future();
    for _ in 0..len {
        tail = tail.then(&sched, |_| {});
    }
    head.set_value(());
    tail.wait();
    rows.push(Row {
        construct: format!("chain_{len}"),
        runtime: "future-chain",
        threads,
        us_per_op: t0.elapsed().as_secs_f64() / len as f64 * 1e6,
    });

    // The same chain through OpenMP `task depend(inout)` on one address —
    // what the futurized tasking engine turns into exactly the structure
    // above, plus task-object and sibling-map overhead.
    let t0 = Instant::now();
    fork_call(&hpx.rt, Some(1), move |_| {
        let ctx = current_ctx().unwrap();
        let token = 0xC0FFEEusize;
        for _ in 0..len {
            ctx.task_with_deps(&[Dep { addr: token, kind: DepKind::InOut }], || {});
        }
        ctx.taskwait();
    });
    rows.push(Row {
        construct: format!("chain_{len}"),
        runtime: "omp-depend",
        threads,
        us_per_op: t0.elapsed().as_secs_f64() / len as f64 * 1e6,
    });
}

fn main() {
    let threads = common::heatmap_threads();
    let smoke = common::smoke();
    let sizes: Vec<usize> = if smoke {
        vec![150, 230]
    } else {
        vec![150, 230, 300, 400]
    };
    let iters = if smoke { 5 } else { 20 };
    let chain_len = if smoke { 512 } else { 4096 };

    let mut rows: Vec<Row> = Vec::new();
    for &t in &threads {
        eprintln!("[dataflow] {t} thread(s)");
        let rt = OmpRuntime::new(t, PolicyKind::PriorityLocal);
        rt.icv.set_nthreads(t);
        let hpx = HpxMpRuntime::new(rt);
        for &n in &sizes {
            bench_mmult(&hpx, t, n, iters, &mut rows);
        }
        bench_chains(&hpx, t, chain_len, &mut rows);
    }

    println!(
        "{:<12} {:<14} {:>8} {:>14}",
        "construct", "runtime", "threads", "us/op"
    );
    for r in &rows {
        println!(
            "{:<12} {:<14} {:>8} {:>14.3}",
            r.construct, r.runtime, r.threads, r.us_per_op
        );
    }

    // Best fork-join/dataflow time ratio per thread count over the sizes.
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &t in &threads {
        let mut best: Option<f64> = None;
        for &n in &sizes {
            let find = |rt: &str| {
                rows.iter()
                    .find(|r| r.construct == format!("mmult_{n}") && r.runtime == rt && r.threads == t)
                    .map(|r| r.us_per_op)
            };
            if let (Some(fj), Some(df)) = (find("fork-join"), find("dataflow")) {
                if df > 0.0 {
                    let s = fj / df;
                    best = Some(best.map_or(s, |b: f64| b.max(s)));
                }
            }
        }
        if let Some(s) = best {
            println!("best mmult speedup dataflow vs fork-join @{t} threads: {s:.2}x");
            speedups.push((t, s));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"dataflow\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"construct\": \"{}\", \"runtime\": \"{}\", \"threads\": {}, \"us_per_op\": {:.4}}}{}\n",
            r.construct,
            r.runtime,
            r.threads,
            r.us_per_op,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"speedup_dataflow_vs_forkjoin\": {");
    for (i, (t, s)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            t,
            s
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_dataflow.json");
    std::fs::write(&path, json).expect("write BENCH_dataflow.json");
    println!("{}", path.display());
}
