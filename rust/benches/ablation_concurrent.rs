//! Concurrent-regions serving ablation (ISSUE 3) — the regression guard
//! for the multi-tenant team pool + admission path.
//!
//! Task-Bench-style methodology over the serving scenario: at each
//! `{mix, clients, threads}` cell, M client threads issue back-to-back
//! streams of Blaze kernel requests (each request = one top-level
//! `parallel` region) through
//!
//! * `hpxmp-shared`        — ONE hpxMP runtime shared by every client
//!                           (team pool + admission arbitrate), and
//! * `baseline-per-client` — a private warm OS-thread pool per client
//!                           (the "competing threading systems" regime:
//!                           K clients × n pool threads on one machine).
//!
//! Emits `results/BENCH_concurrent.json`: `rows[]` with requests/sec and
//! p50/p99 request latency per cell, plus the headline
//! `throughput_shared_vs_percclient` map — per client count, the best
//! shared/per-client throughput ratio over the (mix, threads) grid.
//! Target: ≥ 1.0 at ≥ 4 concurrent clients on at least one mix.
//!
//! `BENCH_THREADS` / `BENCH_CLIENTS` override the grids; `BENCH_SMOKE=1`
//! shrinks the request counts for CI.

use hpxmp::amt::PolicyKind;
use hpxmp::coordinator::serve::{serve_per_client, serve_shared, KernelMix, ServeCfg, ServeStats};
use hpxmp::omp::{icv, OmpRuntime};

mod common;

fn main() {
    let smoke = common::smoke();
    let threads = common::heatmap_threads();
    let clients = common::clients_grid();
    let requests = if smoke { 25 } else { 150 };

    let mut rows: Vec<ServeStats> = Vec::new();
    for mix in KernelMix::ALL {
        for &c in &clients {
            for &t in &threads {
                eprintln!("[concurrent] mix={} clients={c} threads={t}", mix.name());
                let cfg = ServeCfg::new(c, t, requests, mix);
                // The shared scheduler is sized to the machine, not to
                // K·n: admission is exactly what the cell measures.
                let workers = icv::num_procs().max(t);
                let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
                rt.icv.set_nthreads(t);
                rows.push(serve_shared(&rt, &cfg));
                rows.push(serve_per_client(&cfg));
            }
        }
    }

    // Table.
    println!(
        "{:<7} {:<20} {:>8} {:>8} {:>12} {:>10} {:>10}",
        "mix", "runtime", "clients", "threads", "reqs/s", "p50 us", "p99 us"
    );
    for r in &rows {
        println!(
            "{:<7} {:<20} {:>8} {:>8} {:>12.1} {:>10.1} {:>10.1}",
            r.mix.name(),
            r.runtime,
            r.clients,
            r.threads,
            r.reqs_per_sec,
            r.p50_us,
            r.p99_us
        );
    }

    // Headline: per client count, the best shared/per-client throughput
    // ratio across the (mix, threads) grid.
    let mut ratios: Vec<(usize, f64)> = Vec::new();
    for &c in &clients {
        let mut best: Option<f64> = None;
        for mix in KernelMix::ALL {
            for &t in &threads {
                let find = |name: &str| {
                    rows.iter()
                        .find(|r| {
                            r.runtime == name && r.mix == mix && r.clients == c && r.threads == t
                        })
                        .map(|r| r.reqs_per_sec)
                };
                if let (Some(s), Some(p)) = (find("hpxmp-shared"), find("baseline-per-client")) {
                    if p > 0.0 {
                        let ratio = s / p;
                        best = Some(best.map_or(ratio, |b: f64| b.max(ratio)));
                    }
                }
            }
        }
        if let Some(b) = best {
            println!("shared vs per-client throughput @{c} clients (best cell): {b:.3}x");
            ratios.push((c, b));
        }
    }

    // JSON report (same format family as BENCH_fork_overhead.json).
    let mut json = String::from("{\n  \"bench\": \"concurrent\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mix\": \"{}\", \"runtime\": \"{}\", \"clients\": {}, \"threads\": {}, \
             \"reqs_per_sec\": {:.2}, \"p50_us\": {:.2}, \"p99_us\": {:.2}}}{}\n",
            r.mix.name(),
            r.runtime,
            r.clients,
            r.threads,
            r.reqs_per_sec,
            r.p50_us,
            r.p99_us,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"throughput_shared_vs_percclient\": {");
    for (i, (c, ratio)) in ratios.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            c,
            ratio
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_concurrent.json");
    std::fs::write(&path, json).expect("write BENCH_concurrent.json");
    println!("{}", path.display());
}
