//! Exec-policy ablation (ISSUE 5): every Blaze kernel under every
//! execution policy — the regression guard for the unified
//! `exec::Policy` API.
//!
//! Sweeps kernel × policy × threads:
//!
//! * kernels — all five Blazemark ops at one over-threshold size each
//!   (`BENCH_SMOKE=1` shrinks sizes and iteration counts for CI);
//! * policies — `seq` (measured once, at the `threads=1` row: serial
//!   execution is thread-count-independent), `par` (fork-join team on
//!   hpxMP), `task` (the futurized chunk/tile graph on the same
//!   runtime, built with exactly `t` workers so the graph cannot borrow
//!   cores the team was denied);
//! * threads — `BENCH_THREADS` (default 1,2,4,8).
//!
//! Emits `results/BENCH_exec.json`:
//!
//! * `rows[]`: `{kernel, policy, threads, us_per_op}` per cell (lower is
//!   better);
//! * `speedup_task_vs_par`: per kernel, the **best** `par / task` time
//!   ratio over the thread grid — the headline for "every kernel gained
//!   a dataflow execution" (>1 means the task graph beat fork-join
//!   somewhere on the grid).

use std::time::Instant;

use hpxmp::amt::PolicyKind;
use hpxmp::coordinator::blazemark::{measure, Op};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec::{ExecMode, Policy};
use hpxmp::par::HpxMpRuntime;
use hpxmp::util::timing::BenchCfg;

mod common;

struct Row {
    kernel: &'static str,
    policy: &'static str,
    threads: usize,
    us_per_op: f64,
}

/// One over-threshold size per kernel (full / smoke profile).
fn size_for(op: Op, smoke: bool) -> usize {
    match op {
        Op::DVecDVecAdd | Op::Daxpy => {
            if smoke {
                65_536
            } else {
                262_144
            }
        }
        Op::DMatDMatAdd => {
            if smoke {
                230
            } else {
                300
            }
        }
        Op::DMatDMatMult => {
            if smoke {
                150
            } else {
                230
            }
        }
        Op::DMatDVecMult => {
            if smoke {
                455
            } else {
                700
            }
        }
    }
}

/// µs per op via the shared MFLOP/s cell: `measure` already medians over
/// the BenchCfg steady-state loop, so invert back through the FLOP count.
fn us_per_op(pol: &Policy<'_>, op: Op, n: usize, cfg: &BenchCfg) -> f64 {
    let mflops = measure(pol, op, n, cfg);
    op.flops(n) / (mflops * 1e6) * 1e6
}

fn main() {
    let threads = common::env_grid("BENCH_THREADS", &[1, 2, 4, 8]);
    let smoke = common::smoke();
    let cfg = if smoke {
        BenchCfg {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 5,
            min_time: std::time::Duration::from_millis(2),
        }
    } else {
        BenchCfg::quick()
    };

    let t0 = Instant::now();
    let mut rows: Vec<Row> = Vec::new();
    for op in Op::ALL {
        let n = size_for(op, smoke);
        // seq once per kernel: the serial baseline row.
        let us = us_per_op(&Policy::with_mode(ExecMode::Seq), op, n, &cfg);
        rows.push(Row {
            kernel: op.name(),
            policy: "seq",
            threads: 1,
            us_per_op: us,
        });
        for &t in &threads {
            // Exactly t workers per cell: a fair par-vs-task comparison
            // (the task graph parallelizes over every scheduler worker).
            let rt = OmpRuntime::new(t, PolicyKind::PriorityLocal);
            rt.icv.set_nthreads(t);
            let hpx = HpxMpRuntime::new(rt);
            for mode in [ExecMode::Par, ExecMode::Task] {
                let pol = Policy::with_mode(mode).on(&hpx).threads(t);
                let us = us_per_op(&pol, op, n, &cfg);
                rows.push(Row {
                    kernel: op.name(),
                    policy: mode.name(),
                    threads: t,
                    us_per_op: us,
                });
                eprintln!(
                    "[exec] {:<12} {:<4} threads={t:<2} n={n:<7} {us:>12.2} us/op",
                    op.name(),
                    mode.name()
                );
            }
        }
    }

    println!(
        "{:<14} {:<6} {:>8} {:>14}",
        "kernel", "policy", "threads", "us/op"
    );
    for r in &rows {
        println!(
            "{:<14} {:<6} {:>8} {:>14.3}",
            r.kernel, r.policy, r.threads, r.us_per_op
        );
    }

    // Headline: per kernel, best par/task time ratio over the thread grid.
    let mut speedups: Vec<(&'static str, f64)> = Vec::new();
    for op in Op::ALL {
        let mut best: Option<f64> = None;
        for &t in &threads {
            let find = |policy: &str| {
                rows.iter()
                    .find(|r| r.kernel == op.name() && r.policy == policy && r.threads == t)
                    .map(|r| r.us_per_op)
            };
            if let (Some(par_us), Some(task_us)) = (find("par"), find("task")) {
                if task_us > 0.0 {
                    let s = par_us / task_us;
                    best = Some(best.map_or(s, |b: f64| b.max(s)));
                }
            }
        }
        if let Some(s) = best {
            println!("best speedup task vs par [{}]: {s:.3}x", op.name());
            speedups.push((op.name(), s));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"exec\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"policy\": \"{}\", \"threads\": {}, \"us_per_op\": {:.4}}}{}\n",
            r.kernel,
            r.policy,
            r.threads,
            r.us_per_op,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"speedup_task_vs_par\": {");
    for (i, (k, s)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            k,
            s
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_exec.json");
    std::fs::write(&path, json).expect("write BENCH_exec.json");
    println!("{}", path.display());
    eprintln!("[exec] done in {:.1}s", t0.elapsed().as_secs_f64());
}
