//! EPCC syncbench-style fork/join overhead ablation — the regression
//! guard for the hot-team fast path (ISSUE 1; paper §6's small-size
//! regime, where hpxMP trails libomp by per-region AMT task-management
//! overhead).
//!
//! Times three constructs at each thread count in `BENCH_THREADS`
//! (default 1,2,4,8,16):
//!
//! * `parallel` — empty fork/join region round-trip;
//! * `barrier`  — barrier round-trip inside a live region;
//! * `for`      — region + static worksharing loop over a tiny range.
//!
//! Each hpxMP construct runs twice: on the **hot** path (team cache on,
//! the default) and the **cold** path (`set_hot_team_enabled(false)`,
//! which re-allocates `Team`/`Ctx`/`Join` per region — the pre-hot-team
//! behavior).  The baseline warm OS-thread pool is the libomp stand-in.
//!
//! Emits `results/BENCH_fork_overhead.json` and prints a table plus the
//! hot/cold speedup per thread count.  `BENCH_SMOKE=1` shrinks the
//! iteration counts for CI.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use hpxmp::amt::PolicyKind;
use hpxmp::baseline::BaselinePool;
use hpxmp::omp::{fork_call, OmpRuntime};

mod common;

/// Mean seconds per call of `f` over `iters` calls.
fn time_per(iters: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

struct Row {
    construct: &'static str,
    runtime: &'static str,
    threads: usize,
    us_per_op: f64,
}

/// Time the three constructs against one hpxMP runtime configuration.
fn bench_hpxmp(
    label: &'static str,
    threads: usize,
    hot: bool,
    iters_region: usize,
    iters_barrier: usize,
    rows: &mut Vec<Row>,
) {
    let rt = OmpRuntime::new(threads, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(threads);
    rt.set_hot_team_enabled(hot);

    // Warm up workers (and, on the hot path, populate the team cache).
    for _ in 0..5 {
        fork_call(&rt, Some(threads), |_| {});
    }

    let region = time_per(iters_region, || fork_call(&rt, Some(threads), |_| {}));
    rows.push(Row {
        construct: "parallel",
        runtime: label,
        threads,
        us_per_op: region * 1e6,
    });

    // Barrier round-trip inside one live region, timed by thread 0.
    let per_barrier = Arc::new(Mutex::new(0.0f64));
    {
        let out = per_barrier.clone();
        fork_call(&rt, Some(threads), move |ctx| {
            ctx.barrier(); // align the team before sampling
            let t0 = Instant::now();
            for _ in 0..iters_barrier {
                ctx.barrier();
            }
            let per = t0.elapsed().as_secs_f64() / iters_barrier as f64;
            if ctx.tid == 0 {
                *out.lock().unwrap() = per;
            }
        });
    }
    rows.push(Row {
        construct: "barrier",
        runtime: label,
        threads,
        us_per_op: *per_barrier.lock().unwrap() * 1e6,
    });

    // Region + static worksharing loop over a tiny range (EPCC "for").
    let n = (threads as i64) * 16;
    let forloop = time_per(iters_region, || {
        fork_call(&rt, Some(threads), move |ctx| {
            ctx.for_static(0..n, None, |i| {
                std::hint::black_box(i);
            });
        });
    });
    rows.push(Row {
        construct: "for",
        runtime: label,
        threads,
        us_per_op: forloop * 1e6,
    });
}

/// Baseline warm OS-thread pool (the libomp comparator).
fn bench_baseline(threads: usize, iters_region: usize, rows: &mut Vec<Row>) {
    let pool = BaselinePool::new(threads);
    for _ in 0..5 {
        pool.fork(threads, &|_, _| {});
    }
    let region = time_per(iters_region, || pool.fork(threads, &|_, _| {}));
    rows.push(Row {
        construct: "parallel",
        runtime: "baseline",
        threads,
        us_per_op: region * 1e6,
    });

    let n = (threads as i64) * 16;
    let forloop = time_per(iters_region, || {
        pool.fork(threads, &|tid, team| {
            // Contiguous static split, like `schedule(static)`.
            let per = n / team as i64 + i64::from(n % team as i64 != 0);
            let lo = (tid as i64 * per).min(n);
            let hi = ((tid as i64 + 1) * per).min(n);
            for i in lo..hi {
                std::hint::black_box(i);
            }
        });
    });
    rows.push(Row {
        construct: "for",
        runtime: "baseline",
        threads,
        us_per_op: forloop * 1e6,
    });
}

fn main() {
    let threads = common::heatmap_threads();
    let smoke = common::smoke();
    let iters_region = if smoke { 50 } else { 500 };
    let iters_barrier = if smoke { 100 } else { 1000 };

    let mut rows: Vec<Row> = Vec::new();
    for &t in &threads {
        eprintln!("[fork_overhead] {t} thread(s)");
        bench_hpxmp("hpxmp-hot", t, true, iters_region, iters_barrier, &mut rows);
        bench_hpxmp("hpxmp-cold", t, false, iters_region, iters_barrier, &mut rows);
        bench_baseline(t, iters_region, &mut rows);
    }

    // Table + hot/cold speedups.
    println!(
        "{:<10} {:<12} {:>8} {:>14}",
        "construct", "runtime", "threads", "us/op"
    );
    for r in &rows {
        println!(
            "{:<10} {:<12} {:>8} {:>14.3}",
            r.construct, r.runtime, r.threads, r.us_per_op
        );
    }
    let mut speedups: Vec<(usize, f64)> = Vec::new();
    for &t in &threads {
        let find = |rt: &str| {
            rows.iter()
                .find(|r| r.construct == "parallel" && r.runtime == rt && r.threads == t)
                .map(|r| r.us_per_op)
        };
        if let (Some(hot), Some(cold)) = (find("hpxmp-hot"), find("hpxmp-cold")) {
            if hot > 0.0 {
                let s = cold / hot;
                println!("empty-region speedup hot vs cold @{t} threads: {s:.2}x");
                speedups.push((t, s));
            }
        }
    }

    // JSON report.
    let mut json = String::from("{\n  \"bench\": \"fork_overhead\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"construct\": \"{}\", \"runtime\": \"{}\", \"threads\": {}, \"us_per_op\": {:.4}}}{}\n",
            r.construct,
            r.runtime,
            r.threads,
            r.us_per_op,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"speedup_hot_vs_cold_empty_region\": {");
    for (i, (t, s)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            t,
            s
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_fork_overhead.json");
    std::fs::write(&path, json).expect("write BENCH_fork_overhead.json");
    println!("{}", path.display());
}
