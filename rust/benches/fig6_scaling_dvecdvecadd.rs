//! Paper Fig6: dvecdvecadd scaling series (MFLOP/s vs size) at 4/8/16
//! threads, both runtimes.  Emits `results/fig6_*_scaling_*.csv`.

mod common;

use hpxmp::coordinator::blazemark::Op;

fn main() {
    common::run_scaling(Op::parse("dvecdvecadd").unwrap());
}
