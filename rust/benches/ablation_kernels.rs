//! Kernel-variant roofline (ISSUE 7): every Blaze kernel under every
//! `KernelVariant`, reported in GFLOP/s — the regression guard for the
//! raw-compute layer (`blaze/kernel.rs`).
//!
//! Sweeps kernel × variant × size × policy × threads:
//!
//! * kernels — all five Blazemark ops;
//! * variants — `scalar` (the `serial.rs` oracle loops) vs `unrolled`
//!   (4-wide accumulator-split loops, FMA when the `simd` feature is
//!   compiled and the CPU has avx2+fma); `dmatdmatmult` additionally
//!   runs `packed` (the cache-blocked MR×NR micro-kernel over packed
//!   panels) instead of `unrolled`, since the row kernel is the scalar
//!   path there;
//! * sizes — two to three per op (`BENCH_SMOKE=1` shrinks the grid and
//!   iteration counts for CI);
//! * policies — `seq` once per (kernel, variant, size) at threads=1,
//!   then `par` and `task` at each `BENCH_THREADS` entry (default
//!   1,2,4), each cell on a runtime built with exactly `t` workers;
//! * operands — first-touch constructors under the cell's policy, so
//!   pages land where the workers that traverse them run.
//!
//! Emits `results/BENCH_kernels.json`:
//!
//! * `simd`: the compile/runtime SIMD state the run executed under;
//! * `rows[]`: `{kernel, variant, policy, threads, n, gflops}` per cell
//!   (higher is better);
//! * `speedup_packed_vs_scalar_dmatdmatmult`: at the largest matmul
//!   size, the best packed/scalar GFLOP/s ratio over matching
//!   (policy, threads) cells — the ISSUE 7 headline;
//! * `speedup_unrolled_vs_scalar`: same ratio per remaining kernel at
//!   its largest size.

use std::time::Instant;

use hpxmp::amt::PolicyKind;
use hpxmp::blaze::{self, kernel, DynMatrix, DynVector};
use hpxmp::coordinator::blazemark::Op;
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec::{ExecMode, KernelVariant, Policy};
use hpxmp::par::HpxMpRuntime;
use hpxmp::util::timing::{bench, mflops, BenchCfg};

mod common;

struct Row {
    kernel: &'static str,
    variant: &'static str,
    policy: &'static str,
    threads: usize,
    n: usize,
    gflops: f64,
}

/// Variants worth comparing per op.  `dmatdmatmult` pits the packed
/// micro-kernel against the scalar row kernel (its `unrolled` spelling
/// resolves to the same row path, so benching it would duplicate a
/// column); everything else pits unrolled against scalar.
fn variants_for(op: Op) -> &'static [KernelVariant] {
    match op {
        Op::DMatDMatMult => &[KernelVariant::Scalar, KernelVariant::Packed],
        _ => &[KernelVariant::Scalar, KernelVariant::Unrolled],
    }
}

/// Size grid per op (full / smoke profile).  The largest matmul size is
/// where the `speedup_packed_vs_scalar_dmatdmatmult` headline is read,
/// so it sits well past the packed crossover even under smoke.
fn sizes_for(op: Op, smoke: bool) -> Vec<usize> {
    match op {
        Op::DVecDVecAdd | Op::Daxpy => {
            if smoke {
                vec![65_536]
            } else {
                vec![262_144, 1_048_576]
            }
        }
        Op::DMatDMatAdd => {
            if smoke {
                vec![230]
            } else {
                vec![300, 500]
            }
        }
        Op::DMatDMatMult => {
            if smoke {
                vec![128, 256]
            } else {
                vec![192, 384, 576]
            }
        }
        Op::DMatDVecMult => {
            if smoke {
                vec![455]
            } else {
                vec![700, 1200]
            }
        }
    }
}

/// GFLOP/s for one cell: first-touch operands under `pol`, then the
/// shared steady-state timing loop.
fn gflops(pol: &Policy<'_>, op: Op, n: usize, cfg: &BenchCfg) -> f64 {
    let summary = match op {
        Op::DVecDVecAdd => {
            let a = DynVector::random_first_touch(pol, n, 11);
            let b = DynVector::random_first_touch(pol, n, 12);
            let mut c = DynVector::zeros_first_touch(pol, n);
            bench(cfg, || blaze::dvecdvecadd(pol, &a, &b, &mut c))
        }
        Op::Daxpy => {
            let a = DynVector::random_first_touch(pol, n, 13);
            let mut b = DynVector::random_first_touch(pol, n, 14);
            bench(cfg, || blaze::daxpy(pol, 3.0, &a, &mut b))
        }
        Op::DMatDMatAdd => {
            let a = DynMatrix::random_first_touch(pol, n, n, 15);
            let b = DynMatrix::random_first_touch(pol, n, n, 16);
            let mut c = DynMatrix::zeros_first_touch(pol, n, n);
            bench(cfg, || blaze::dmatdmatadd(pol, &a, &b, &mut c))
        }
        Op::DMatDMatMult => {
            let a = DynMatrix::random_first_touch(pol, n, n, 17);
            let b = DynMatrix::random_first_touch(pol, n, n, 18);
            let mut c = DynMatrix::zeros_first_touch(pol, n, n);
            bench(cfg, || blaze::dmatdmatmult(pol, &a, &b, &mut c))
        }
        Op::DMatDVecMult => {
            let a = DynMatrix::random_first_touch(pol, n, n, 19);
            let x = DynVector::random_first_touch(pol, n, 20);
            let mut y = DynVector::zeros_first_touch(pol, n);
            bench(cfg, || blaze::dmatdvecmult(pol, &a, &x, &mut y))
        }
    };
    mflops(&summary, op.flops(n)) / 1e3
}

fn main() {
    let threads = common::env_grid("BENCH_THREADS", &[1, 2, 4]);
    let smoke = common::smoke();
    let cfg = if smoke {
        BenchCfg {
            warmup_iters: 1,
            min_iters: 2,
            max_iters: 5,
            min_time: std::time::Duration::from_millis(2),
        }
    } else {
        BenchCfg::quick()
    };

    eprintln!("[kernels] simd: {}", kernel::simd_label());
    let t0 = Instant::now();
    let mut rows: Vec<Row> = Vec::new();
    for op in Op::ALL {
        for &v in variants_for(op) {
            for n in sizes_for(op, smoke) {
                // seq once per (kernel, variant, size): the serial roofline row.
                let pol = Policy::with_mode(ExecMode::Seq).kernel(v);
                let g = gflops(&pol, op, n, &cfg);
                rows.push(Row {
                    kernel: op.name(),
                    variant: v.name(),
                    policy: "seq",
                    threads: 1,
                    n,
                    gflops: g,
                });
                for &t in &threads {
                    // Exactly t workers per cell, as in ablation_exec: the
                    // task graph parallelizes over every scheduler worker.
                    let rt = OmpRuntime::new(t, PolicyKind::PriorityLocal);
                    rt.icv.set_nthreads(t);
                    let hpx = HpxMpRuntime::new(rt);
                    for mode in [ExecMode::Par, ExecMode::Task] {
                        let pol = Policy::with_mode(mode).on(&hpx).threads(t).kernel(v);
                        let g = gflops(&pol, op, n, &cfg);
                        rows.push(Row {
                            kernel: op.name(),
                            variant: v.name(),
                            policy: mode.name(),
                            threads: t,
                            n,
                            gflops: g,
                        });
                        eprintln!(
                            "[kernels] {:<12} {:<8} {:<4} threads={t:<2} n={n:<7} {g:>8.3} GFLOP/s",
                            op.name(),
                            v.name(),
                            mode.name()
                        );
                    }
                }
            }
        }
    }

    println!(
        "{:<14} {:<9} {:<6} {:>8} {:>9} {:>10}",
        "kernel", "variant", "policy", "threads", "n", "GFLOP/s"
    );
    for r in &rows {
        println!(
            "{:<14} {:<9} {:<6} {:>8} {:>9} {:>10.3}",
            r.kernel, r.variant, r.policy, r.threads, r.n, r.gflops
        );
    }

    // Headlines: per kernel, the best fast-variant/scalar GFLOP/s ratio
    // over matching (policy, threads) cells at the largest size.
    let fast = |op: Op| variants_for(op)[1].name();
    let mut headlines: Vec<(&'static str, &'static str, f64)> = Vec::new();
    for op in Op::ALL {
        let n = *sizes_for(op, smoke).last().expect("non-empty size grid");
        let mut best: Option<f64> = None;
        let cells: Vec<(&'static str, usize)> = std::iter::once(("seq", 1))
            .chain(threads.iter().flat_map(|&t| [("par", t), ("task", t)]))
            .collect();
        for (policy, t) in cells {
            let find = |variant: &str| {
                rows.iter()
                    .find(|r| {
                        r.kernel == op.name()
                            && r.variant == variant
                            && r.policy == policy
                            && r.threads == t
                            && r.n == n
                    })
                    .map(|r| r.gflops)
            };
            if let (Some(s), Some(f)) = (find("scalar"), find(fast(op))) {
                if s > 0.0 {
                    let ratio = f / s;
                    best = Some(best.map_or(ratio, |b: f64| b.max(ratio)));
                }
            }
        }
        if let Some(b) = best {
            println!("best speedup {} vs scalar [{}]: {b:.3}x", fast(op), op.name());
            headlines.push((op.name(), fast(op), b));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"kernels\",\n");
    json.push_str(&format!("  \"simd\": \"{}\",\n  \"rows\": [\n", kernel::simd_label()));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"variant\": \"{}\", \"policy\": \"{}\", \"threads\": {}, \"n\": {}, \"gflops\": {:.4}}}{}\n",
            r.kernel,
            r.variant,
            r.policy,
            r.threads,
            r.n,
            r.gflops,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    for (k, _, b) in headlines.iter().filter(|(k, _, _)| *k == "dmatdmatmult") {
        json.push_str(&format!(
            "  \"speedup_packed_vs_scalar_{k}\": {b:.3},\n"
        ));
    }
    json.push_str("  \"speedup_unrolled_vs_scalar\": {");
    let unrolled: Vec<_> = headlines
        .iter()
        .filter(|(_, v, _)| *v == "unrolled")
        .collect();
    for (i, (k, _, b)) in unrolled.iter().enumerate() {
        json.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            k,
            b
        ));
    }
    json.push_str("}\n}\n");

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_kernels.json");
    std::fs::write(&path, json).expect("write BENCH_kernels.json");
    println!("{}", path.display());
    eprintln!("[kernels] done in {:.1}s", t0.elapsed().as_secs_f64());
}
