//! Paper Fig2: dvecdvecadd performance-ratio heatmap (hpxMP / OpenMP,
//! threads x size).  Emits `results/fig2_dvecdvecadd_heatmap.csv` + ASCII render.

mod common;

use hpxmp::coordinator::blazemark::Op;

fn main() {
    common::run_heatmap(Op::parse("dvecdvecadd").unwrap());
}
