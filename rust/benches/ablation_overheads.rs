//! Microbenchmarks of the runtime's hot paths — the quantities the §Perf
//! optimization loop tracks (EXPERIMENTS.md):
//!
//! * empty fork/join round-trip (hpxMP vs baseline pool) — the per-region
//!   cost that separates the runtimes at small sizes in every figure;
//! * barrier round-trip inside a live region;
//! * explicit-task spawn+taskwait throughput;
//! * dynamic-loop chunk dispatch rate;
//! * AMT spawn/steal throughput.
//!
//! Emits `results/ablation_overheads.csv`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use hpxmp::amt::PolicyKind;
use hpxmp::baseline::BaselinePool;
use hpxmp::omp::team::{current_ctx, fork_call};
use hpxmp::omp::{OmpRuntime, SchedKind, Schedule};
use hpxmp::util::csv::CsvWriter;

const THREADS: usize = 4;

fn time_per<T>(iters: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let rt = OmpRuntime::new(THREADS, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(THREADS);
    let pool = BaselinePool::new(THREADS);
    let mut rows: Vec<(String, f64)> = Vec::new();

    // --- empty region: hpxMP fork_call vs baseline pool.fork ---------------
    let hpx_region = time_per(300, || {
        fork_call(&rt, Some(THREADS), |_| {});
    });
    rows.push(("hpxmp_empty_region_us".into(), hpx_region * 1e6));

    let base_region = time_per(300, || {
        pool.fork(THREADS, &|_, _| {});
    });
    rows.push(("baseline_empty_region_us".into(), base_region * 1e6));

    // --- barrier round-trip inside one region ------------------------------
    {
        let t_us = Arc::new(std::sync::Mutex::new(0.0f64));
        let t2 = t_us.clone();
        fork_call(&rt, Some(THREADS), move |ctx| {
            const N: usize = 200;
            ctx.barrier();
            let t0 = Instant::now();
            for _ in 0..N {
                ctx.barrier();
            }
            let per = t0.elapsed().as_secs_f64() / N as f64;
            if ctx.tid == 0 {
                *t2.lock().unwrap() = per * 1e6;
            }
        });
        rows.push(("hpxmp_barrier_us".into(), *t_us.lock().unwrap()));
    }

    // --- explicit task spawn + taskwait -------------------------------------
    {
        let rate = Arc::new(std::sync::Mutex::new(0.0f64));
        let r2 = rate.clone();
        fork_call(&rt, Some(2), move |c| {
            if c.tid == 0 {
                let ctx = current_ctx().unwrap();
                let done = Arc::new(AtomicUsize::new(0));
                const N: usize = 20_000;
                let t0 = Instant::now();
                for _ in 0..N {
                    let d = done.clone();
                    ctx.task(move || {
                        d.fetch_add(1, Ordering::Relaxed);
                    });
                }
                ctx.taskwait();
                let dt = t0.elapsed().as_secs_f64();
                assert_eq!(done.load(Ordering::SeqCst), N);
                *r2.lock().unwrap() = N as f64 / dt;
            }
        });
        rows.push(("hpxmp_tasks_per_s".into(), *rate.lock().unwrap()));
    }

    // --- dynamic chunk dispatch rate ----------------------------------------
    {
        let rate = Arc::new(std::sync::Mutex::new(0.0f64));
        let r2 = rate.clone();
        let total = Arc::new(AtomicUsize::new(0));
        fork_call(&rt, Some(THREADS), move |ctx| {
            const N: i64 = 200_000;
            let t0 = Instant::now();
            let desc = ctx.dispatch_init(0..N, Schedule::new(SchedKind::Dynamic, Some(1)));
            let mut claimed = 0usize;
            while let Some(r) = ctx.dispatch_next(&desc, 0) {
                claimed += (r.end - r.start) as usize;
            }
            ctx.dispatch_fini(&desc);
            total.fetch_add(claimed, Ordering::Relaxed);
            ctx.barrier(); // all claims accounted
            let dt = t0.elapsed().as_secs_f64();
            if ctx.tid == 0 {
                *r2.lock().unwrap() = total.load(Ordering::Relaxed) as f64 / dt;
            }
        });
        rows.push(("hpxmp_chunks_per_s".into(), *rate.lock().unwrap()));
    }

    // --- raw AMT spawn throughput -------------------------------------------
    {
        let done = Arc::new(AtomicUsize::new(0));
        const N: usize = 100_000;
        let t0 = Instant::now();
        for i in 0..N {
            let d = done.clone();
            rt.sched.spawn(
                hpxmp::amt::Priority::Normal,
                hpxmp::amt::task::Hint::Worker(i),
                "bench",
                move || {
                    d.fetch_add(1, Ordering::Relaxed);
                },
            );
        }
        rt.sched.wait_quiescent();
        let dt = t0.elapsed().as_secs_f64();
        rows.push(("amt_spawn_tasks_per_s".into(), N as f64 / dt));
    }

    // --- report -----------------------------------------------------------
    let mut w = CsvWriter::create(std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../results/ablation_overheads.csv")).expect("csv");
    w.row(&["metric", "value"]).unwrap();
    println!("{:<28} {:>14}", "metric", "value");
    for (k, v) in &rows {
        println!("{k:<28} {v:>14.2}");
        w.row(&[k.clone(), format!("{v:.3}")]).unwrap();
    }
    w.flush().unwrap();
    println!("wrote results/ablation_overheads.csv");
    let m = rt.sched.metrics();
    println!("scheduler metrics: {m}");
}
