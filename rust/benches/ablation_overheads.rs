//! Microbenchmarks of the runtime's hot paths — the quantities the §Perf
//! optimization loop tracks (EXPERIMENTS.md):
//!
//! * empty region round-trip through the `exec::Policy` seam — `par` on
//!   hpxMP, `par` on the baseline pool, and `task` on hpxMP — the
//!   per-region cost that separates the runtimes at small sizes in
//!   every figure;
//! * barrier round-trip inside a live region;
//! * explicit-task spawn+taskwait throughput;
//! * dynamic-loop chunk dispatch rate;
//! * AMT spawn/steal throughput.
//!
//! The region rows go through `exec::par()/task()` like every kernel
//! does; the remaining rows deliberately reach *below* the policy seam
//! (`ctx.barrier`, `ctx.task`, `ctx.dispatch_next`, `sched.spawn`) —
//! they measure the substrate constructs themselves, which have no
//! policy-level spelling.
//!
//! `BENCH_SMOKE=1` shrinks iteration counts for CI; `BENCH_THREADS`
//! (first entry, default 4) sets the team width.
//!
//! Emits `results/ablation_overheads.csv`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use hpxmp::amt::PolicyKind;
use hpxmp::baseline::BaselinePool;
use hpxmp::omp::team::{current_ctx, fork_call};
use hpxmp::omp::{OmpRuntime, SchedKind, Schedule};
use hpxmp::par::exec;
use hpxmp::par::HpxMpRuntime;
use hpxmp::util::csv::CsvWriter;

mod common;

fn time_per<T>(iters: usize, mut f: impl FnMut() -> T) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let smoke = common::smoke();
    let threads = common::env_grid("BENCH_THREADS", &[4])[0];
    // Iteration counts per measurement (full / smoke).
    let (region_iters, barrier_iters, task_n, dispatch_n, spawn_n) = if smoke {
        (30, 20, 2_000, 20_000i64, 10_000)
    } else {
        (300, 200, 20_000, 200_000i64, 100_000)
    };

    let rt = OmpRuntime::new(threads, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(threads);
    let hpx = HpxMpRuntime::new(rt);
    let pool = BaselinePool::new(threads);
    let mut rows: Vec<(String, f64)> = Vec::new();

    // --- empty region through the Policy seam ------------------------------
    // One for_each over an empty body: the full fork + chunk + join cost a
    // kernel pays before doing any work.
    let hpx_pol = exec::par().on(&hpx).threads(threads);
    let hpx_region = time_per(region_iters, || {
        exec::for_each(&hpx_pol, 0..threads as i64, |_r| {});
    });
    rows.push(("hpxmp_empty_region_us".into(), hpx_region * 1e6));

    let base_pol = exec::par().on(&pool).threads(threads);
    let base_region = time_per(region_iters, || {
        exec::for_each(&base_pol, 0..threads as i64, |_r| {});
    });
    rows.push(("baseline_empty_region_us".into(), base_region * 1e6));

    let task_pol = exec::task().on(&hpx).threads(threads);
    let task_region = time_per(region_iters, || {
        exec::for_each(&task_pol, 0..threads as i64, |_r| {});
    });
    rows.push(("hpxmp_empty_task_graph_us".into(), task_region * 1e6));

    // --- barrier round-trip inside one region (substrate: ctx.barrier) -----
    {
        let t_us = Arc::new(std::sync::Mutex::new(0.0f64));
        let t2 = t_us.clone();
        let n = barrier_iters;
        fork_call(&hpx.rt, Some(threads), move |ctx| {
            ctx.barrier();
            let t0 = Instant::now();
            for _ in 0..n {
                ctx.barrier();
            }
            let per = t0.elapsed().as_secs_f64() / n as f64;
            if ctx.tid == 0 {
                *t2.lock().unwrap() = per * 1e6;
            }
        });
        rows.push(("hpxmp_barrier_us".into(), *t_us.lock().unwrap()));
    }

    // --- explicit task spawn + taskwait (substrate: ctx.task) --------------
    {
        let rate = Arc::new(std::sync::Mutex::new(0.0f64));
        let r2 = rate.clone();
        let n = task_n;
        fork_call(&hpx.rt, Some(2), move |c| {
            if c.tid == 0 {
                let ctx = current_ctx().unwrap();
                let done = Arc::new(AtomicUsize::new(0));
                let t0 = Instant::now();
                for _ in 0..n {
                    let d = done.clone();
                    ctx.task(move || {
                        d.fetch_add(1, Ordering::Relaxed);
                    });
                }
                ctx.taskwait();
                let dt = t0.elapsed().as_secs_f64();
                assert_eq!(done.load(Ordering::SeqCst), n);
                *r2.lock().unwrap() = n as f64 / dt;
            }
        });
        rows.push(("hpxmp_tasks_per_s".into(), *rate.lock().unwrap()));
    }

    // --- dynamic chunk dispatch rate (substrate: ctx.dispatch_next) --------
    {
        let rate = Arc::new(std::sync::Mutex::new(0.0f64));
        let r2 = rate.clone();
        let total = Arc::new(AtomicUsize::new(0));
        let n = dispatch_n;
        fork_call(&hpx.rt, Some(threads), move |ctx| {
            let t0 = Instant::now();
            let desc = ctx.dispatch_init(0..n, Schedule::new(SchedKind::Dynamic, Some(1)));
            let mut claimed = 0usize;
            while let Some(r) = ctx.dispatch_next(&desc, 0) {
                claimed += (r.end - r.start) as usize;
            }
            ctx.dispatch_fini(&desc);
            total.fetch_add(claimed, Ordering::Relaxed);
            ctx.barrier(); // all claims accounted
            let dt = t0.elapsed().as_secs_f64();
            if ctx.tid == 0 {
                *r2.lock().unwrap() = total.load(Ordering::Relaxed) as f64 / dt;
            }
        });
        rows.push(("hpxmp_chunks_per_s".into(), *rate.lock().unwrap()));
    }

    // --- raw AMT spawn throughput (substrate: sched.spawn) ------------------
    {
        let done = Arc::new(AtomicUsize::new(0));
        let n = spawn_n;
        let t0 = Instant::now();
        for i in 0..n {
            let d = done.clone();
            hpx.rt.sched.spawn(
                hpxmp::amt::Priority::Normal,
                hpxmp::amt::task::Hint::Worker(i),
                "bench",
                move || {
                    d.fetch_add(1, Ordering::Relaxed);
                },
            );
        }
        hpx.rt.sched.wait_quiescent();
        let dt = t0.elapsed().as_secs_f64();
        rows.push(("amt_spawn_tasks_per_s".into(), n as f64 / dt));
    }

    // --- report -----------------------------------------------------------
    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let mut w = CsvWriter::create(dir.join("ablation_overheads.csv")).expect("csv");
    w.row(&["metric", "value"]).unwrap();
    println!("{:<28} {:>14}", "metric", "value");
    for (k, v) in &rows {
        println!("{k:<28} {v:>14.2}");
        w.row(&[k.clone(), format!("{v:.3}")]).unwrap();
    }
    w.flush().unwrap();
    println!("wrote results/ablation_overheads.csv");
    let m = hpx.rt.sched.metrics();
    println!("scheduler metrics: {m}");
}
