//! Robustness ablation (ISSUE 6) — serving under injected faults, and
//! deadline-aware load shedding vs. queue-everything at overload.
//!
//! Two experiments, one JSON report (`results/BENCH_robust.json`):
//!
//! 1. **Fault sweep** — the shared-runtime serving scenario at injected
//!    per-body panic rates 0 / 0.1% / 1% (fixed seed): throughput and
//!    p99 must degrade gracefully (crashed clients charged, survivors
//!    aggregated, no hangs), never collapse.
//! 2. **Shed ablation** — an overloaded configuration (clients × threads
//!    ≫ workers) with a tight per-request deadline, run with shedding
//!    off (every request queues, most die late) and on (saturated
//!    arrivals are rejected after bounded backoff).  Headline:
//!    `goodput_shed_vs_noshed` — deadline-met requests per second,
//!    shed / noshed.  Target ≥ 1.0: shedding must protect goodput.
//!
//! `BENCH_SMOKE=1` shrinks request counts for CI.

use hpxmp::amt::PolicyKind;
use hpxmp::coordinator::serve::{serve_shared, KernelMix, ServeCfg, ServeStats};
use hpxmp::omp::{icv, OmpRuntime};
use hpxmp::util::fault::{self, FaultCfg};

mod common;

const SEED: u64 = 42;

fn run_cell(cfg: &ServeCfg, workers: usize) -> ServeStats {
    let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(cfg.threads);
    serve_shared(&rt, cfg)
}

fn main() {
    let smoke = common::smoke();

    // --- 1. fault sweep ---------------------------------------------------
    let fault_rates = [0.0f64, 0.001, 0.01];
    let requests = if smoke { 20 } else { 100 };
    let workers = icv::num_procs().max(2);
    let mut fault_rows: Vec<(f64, ServeStats)> = Vec::new();
    for &rate in &fault_rates {
        eprintln!("[robust] fault sweep: panic rate {rate}");
        if rate > 0.0 {
            fault::install(FaultCfg::parse(&format!("panic:{rate}"), SEED));
        } else {
            fault::install(None);
        }
        let cfg = ServeCfg::new(4, 2, requests, KernelMix::Vector);
        fault_rows.push((rate, run_cell(&cfg, workers)));
    }
    fault::install(None);

    println!(
        "{:<10} {:>12} {:>10} {:>8} {:>8}",
        "fault", "reqs/s", "p99 us", "failed", "done"
    );
    for (rate, s) in &fault_rows {
        println!(
            "{:<10} {:>12.1} {:>10.1} {:>8} {:>8}",
            format!("panic:{rate}"),
            s.reqs_per_sec,
            s.p99_us,
            s.failed_requests,
            s.total_requests
        );
    }

    // --- 2. shed ablation at overload --------------------------------------
    // 2 workers serving 8 clients of 2-thread regions: the admission
    // budget is saturated almost continuously, so an un-shed stream
    // queues every request into deadline death.
    let shed_requests = if smoke { 15 } else { 60 };
    let mut mk = |shed: bool| {
        let mut cfg = ServeCfg::new(8, 2, shed_requests, KernelMix::Vector);
        cfg.deadline_us = Some(2_000);
        cfg.shed = shed;
        cfg.retries = 2;
        eprintln!("[robust] overload shed={shed}");
        run_cell(&cfg, 2)
    };
    let noshed = mk(false);
    let shed = mk(true);

    println!(
        "{:<8} {:>12} {:>12} {:>8} {:>8} {:>8}",
        "shed", "reqs/s", "goodput/s", "shed", "misses", "retries"
    );
    for (label, s) in [("off", &noshed), ("on", &shed)] {
        println!(
            "{:<8} {:>12.1} {:>12.1} {:>8} {:>8} {:>8}",
            label, s.reqs_per_sec, s.goodput_per_sec, s.shed, s.deadline_misses, s.retries
        );
    }
    // Both-zero goodput (degenerate) reads as parity, not as a win.
    let headline = (shed.goodput_per_sec + 1e-9) / (noshed.goodput_per_sec + 1e-9);
    println!("goodput shed vs noshed at overload: {headline:.3}x");

    // --- JSON report --------------------------------------------------------
    let mut json = String::from("{\n  \"bench\": \"robust\",\n  \"rows\": [\n");
    for (rate, s) in &fault_rows {
        json.push_str(&format!(
            "    {{\"experiment\": \"fault_sweep\", \"fault_rate\": {rate}, \
             \"reqs_per_sec\": {:.2}, \"p99_us\": {:.2}, \"failed_requests\": {}, \
             \"total_requests\": {}}},\n",
            s.reqs_per_sec, s.p99_us, s.failed_requests, s.total_requests
        ));
    }
    for (label, s) in [("off", &noshed), ("on", &shed)] {
        json.push_str(&format!(
            "    {{\"experiment\": \"shed\", \"shed\": \"{label}\", \
             \"reqs_per_sec\": {:.2}, \"goodput_per_sec\": {:.2}, \"shed_requests\": {}, \
             \"deadline_misses\": {}, \"retries\": {}}}{}\n",
            s.reqs_per_sec,
            s.goodput_per_sec,
            s.shed,
            s.deadline_misses,
            s.retries,
            if label == "on" { "" } else { "," }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"goodput_shed_vs_noshed\": {headline:.3}\n}}\n"
    ));

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_robust.json");
    std::fs::write(&path, json).expect("write BENCH_robust.json");
    println!("{}", path.display());
}
