//! Paper Fig4: dmatdmatadd performance-ratio heatmap (hpxMP / OpenMP,
//! threads x size).  Emits `results/fig4_dmatdmatadd_heatmap.csv` + ASCII render.

mod common;

use hpxmp::coordinator::blazemark::Op;

fn main() {
    common::run_heatmap(Op::parse("dmatdmatadd").unwrap());
}
