//! Paper Fig5: dmatdmatmult performance-ratio heatmap (hpxMP / OpenMP,
//! threads x size).  Emits `results/fig5_dmatdmatmult_heatmap.csv` + ASCII render.

mod common;

use hpxmp::coordinator::blazemark::Op;

fn main() {
    common::run_heatmap(Op::parse("dmatdmatmult").unwrap());
}
