//! Ablation: the seven §3.2 scheduling policies under three workloads —
//! the design-choice study DESIGN.md calls out (which policy should back
//! an OpenMP runtime?).  Emits `results/ablation_policies.csv`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use hpxmp::amt::{task::Hint, PolicyKind, Priority, Scheduler};
use hpxmp::omp::{fork_call, OmpRuntime};
use hpxmp::util::csv::CsvWriter;

const WORKERS: usize = 4;

/// Raw task throughput: spawn N trivial tasks, quiesce.
fn bench_spawn(policy: PolicyKind, tasks: usize) -> f64 {
    let s = Scheduler::new(WORKERS, policy);
    let done = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    for i in 0..tasks {
        let d = done.clone();
        s.spawn(Priority::Normal, Hint::Worker(i), "t", move || {
            d.fetch_add(1, Ordering::Relaxed);
        });
    }
    s.wait_quiescent();
    let dt = t0.elapsed().as_secs_f64();
    s.shutdown();
    tasks as f64 / dt
}

/// Fork/join churn: OpenMP regions per second.
fn bench_fork_join(policy: PolicyKind, regions: usize) -> f64 {
    let rt = OmpRuntime::new(WORKERS, policy);
    rt.icv.set_nthreads(WORKERS);
    let sink = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    for _ in 0..regions {
        let s = sink.clone();
        fork_call(&rt, Some(WORKERS), move |_| {
            s.fetch_add(1, Ordering::Relaxed);
        });
    }
    let dt = t0.elapsed().as_secs_f64();
    regions as f64 / dt
}

/// Imbalanced work: tasks with skewed costs — stresses stealing.
fn bench_imbalanced(policy: PolicyKind, tasks: usize) -> f64 {
    let s = Scheduler::new(WORKERS, policy);
    let done = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    for i in 0..tasks {
        let d = done.clone();
        // Every 16th task is ~100x heavier.
        let spin = if i % 16 == 0 { 20_000 } else { 200 };
        s.spawn(Priority::Normal, Hint::Worker(i % WORKERS), "t", move || {
            let mut acc = 0u64;
            for k in 0..spin {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            std::hint::black_box(acc);
            d.fetch_add(1, Ordering::Relaxed);
        });
    }
    s.wait_quiescent();
    let dt = t0.elapsed().as_secs_f64();
    s.shutdown();
    tasks as f64 / dt
}

fn main() {
    let mut w = CsvWriter::create(std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../results/ablation_policies.csv")).expect("csv");
    w.row(&["policy", "spawn_tasks_per_s", "fork_join_regions_per_s", "imbalanced_tasks_per_s"])
        .unwrap();
    println!(
        "{:<18} {:>16} {:>16} {:>18}",
        "policy", "spawn ktasks/s", "regions/s", "imbalanced kt/s"
    );
    for policy in PolicyKind::ALL {
        let spawn = bench_spawn(policy, 50_000);
        let fj = bench_fork_join(policy, 500);
        let imb = bench_imbalanced(policy, 5_000);
        println!(
            "{:<18} {:>16.1} {:>16.1} {:>18.1}",
            policy.name(),
            spawn / 1e3,
            fj,
            imb / 1e3
        );
        w.row(&[
            policy.name().to_string(),
            format!("{spawn:.1}"),
            format!("{fj:.1}"),
            format!("{imb:.1}"),
        ])
        .unwrap();
    }
    w.flush().unwrap();
    println!("wrote results/ablation_policies.csv");
}
