//! Ablation: the seven §3.2 scheduling policies under three workloads —
//! the design-choice study DESIGN.md calls out (which policy should back
//! an OpenMP runtime?).
//!
//! The fork/join workload goes through the `exec::par()` policy seam
//! (the same path every kernel takes); the spawn and imbalanced
//! workloads deliberately drive the raw [`Scheduler`] — the ablated
//! variable *is* the scheduler policy, below any policy-API spelling.
//!
//! `BENCH_SMOKE=1` shrinks workload sizes for CI; `BENCH_THREADS`
//! (first entry, default 4) sets the worker count.
//!
//! Emits `results/ablation_policies.csv`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use hpxmp::amt::{task::Hint, PolicyKind, Priority, Scheduler};
use hpxmp::omp::OmpRuntime;
use hpxmp::par::exec;
use hpxmp::par::HpxMpRuntime;
use hpxmp::util::csv::CsvWriter;

mod common;

/// Raw task throughput: spawn N trivial tasks, quiesce.
fn bench_spawn(policy: PolicyKind, workers: usize, tasks: usize) -> f64 {
    let s = Scheduler::new(workers, policy);
    let done = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    for i in 0..tasks {
        let d = done.clone();
        s.spawn(Priority::Normal, Hint::Worker(i), "t", move || {
            d.fetch_add(1, Ordering::Relaxed);
        });
    }
    s.wait_quiescent();
    let dt = t0.elapsed().as_secs_f64();
    s.shutdown();
    tasks as f64 / dt
}

/// Fork/join churn: parallel regions per second, each region a
/// `exec::for_each` under `par()` on an hpxMP runtime built over the
/// ablated scheduler policy.
fn bench_fork_join(policy: PolicyKind, workers: usize, regions: usize) -> f64 {
    let rt = OmpRuntime::new(workers, policy);
    rt.icv.set_nthreads(workers);
    let hpx = HpxMpRuntime::new(rt);
    let pol = exec::par().on(&hpx).threads(workers);
    let sink = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    for _ in 0..regions {
        let s = &sink;
        exec::for_each(&pol, 0..workers as i64, move |_r| {
            s.fetch_add(1, Ordering::Relaxed);
        });
    }
    let dt = t0.elapsed().as_secs_f64();
    regions as f64 / dt
}

/// Imbalanced work: tasks with skewed costs — stresses stealing.
fn bench_imbalanced(policy: PolicyKind, workers: usize, tasks: usize) -> f64 {
    let s = Scheduler::new(workers, policy);
    let done = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    for i in 0..tasks {
        let d = done.clone();
        // Every 16th task is ~100x heavier.
        let spin = if i % 16 == 0 { 20_000 } else { 200 };
        s.spawn(Priority::Normal, Hint::Worker(i % workers), "t", move || {
            let mut acc = 0u64;
            for k in 0..spin {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            std::hint::black_box(acc);
            d.fetch_add(1, Ordering::Relaxed);
        });
    }
    s.wait_quiescent();
    let dt = t0.elapsed().as_secs_f64();
    s.shutdown();
    tasks as f64 / dt
}

fn main() {
    let smoke = common::smoke();
    let workers = common::env_grid("BENCH_THREADS", &[4])[0];
    let (spawn_n, region_n, imb_n) = if smoke {
        (5_000, 50, 500)
    } else {
        (50_000, 500, 5_000)
    };

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let mut w = CsvWriter::create(dir.join("ablation_policies.csv")).expect("csv");
    w.row(&["policy", "spawn_tasks_per_s", "fork_join_regions_per_s", "imbalanced_tasks_per_s"])
        .unwrap();
    println!(
        "{:<18} {:>16} {:>16} {:>18}",
        "policy", "spawn ktasks/s", "regions/s", "imbalanced kt/s"
    );
    for policy in PolicyKind::ALL {
        let spawn = bench_spawn(policy, workers, spawn_n);
        let fj = bench_fork_join(policy, workers, region_n);
        let imb = bench_imbalanced(policy, workers, imb_n);
        println!(
            "{:<18} {:>16.1} {:>16.1} {:>18.1}",
            policy.name(),
            spawn / 1e3,
            fj,
            imb / 1e3
        );
        w.row(&[
            policy.name().to_string(),
            format!("{spawn:.1}"),
            format!("{fj:.1}"),
            format!("{imb:.1}"),
        ])
        .unwrap();
    }
    w.flush().unwrap();
    println!("wrote results/ablation_policies.csv");
}
