//! Wire-serving ablation (ISSUE 9): does same-kernel request coalescing
//! buy throughput over dispatch-per-request, and does backpressure keep
//! goodput up under overload?
//!
//! For every (connections × offered rate) cell the bench starts a fresh
//! in-process [`WireServer`] on an ephemeral loopback port and drives it
//! with the seeded open-loop generator (`net::run_loadgen`, Poisson
//! arrivals) under two arms:
//!
//! * `batched`   — the default coalescing window (one fused team fork
//!   and one cached/packed-operand pass per same-shape window);
//! * `unbatched` — `BatchCfg::coalesce = false`, i.e. what
//!   `HPXMP_COALESCE=0` gives a whole process: every request is its own
//!   dispatch.
//!
//! After the grid, an **overload probe** reruns the batched arm at 2×
//! the best measured throughput with a 5 ms deadline so the shed path
//! (admission headroom + pending cap, DESIGN.md §14) is what's under
//! test: goodput should degrade, not collapse.
//!
//! Emits `results/BENCH_serve_wire.json`:
//!
//! ```json
//! { "bench": "serve_wire",
//!   "rows": [ {"rate": 1000, "conns": 8, "mode": "batched",
//!              "reqs_per_sec": 987.0, "goodput_per_sec": 987.0,
//!              "p50_us": 212.0, "p99_us": 840.0, "shed": 0,
//!              "deadline_misses": 0, "lost": 0, "batches": 310,
//!              "max_batch": 9}, ... ],
//!   "saturation_rps": s,
//!   "throughput_batched_vs_unbatched": r,
//!   "overload_goodput_ratio": g }
//! ```
//!
//! The headline `throughput_batched_vs_unbatched` is the best
//! batched/unbatched completed-requests ratio over rates at the widest
//! connection count (>1 means coalescing won); `overload_goodput_ratio`
//! is goodput at 2× saturation over the best pre-overload goodput
//! (>= 0.5 means shedding kept the server inside 2× of its best).
//! `BENCH_RATES` / `BENCH_CLIENTS` override the grids; `BENCH_SMOKE=1`
//! shrinks durations and the connection grid for CI.

use std::time::Duration;

use hpxmp::amt::PolicyKind;
use hpxmp::net::{BatchCfg, Dist, LoadgenCfg, LoadgenReport, WireAddr, WireOp, WireServer};
use hpxmp::omp::{icv, OmpRuntime};

mod common;

struct Cell {
    rate: usize,
    conns: usize,
    mode: &'static str,
    report: LoadgenReport,
    batches: usize,
    max_batch: usize,
}

/// One fresh server + one loadgen run; returns the merged measurement.
fn run_cell(
    workers: usize,
    coalesce: bool,
    rate: usize,
    conns: usize,
    duration: Duration,
    deadline_us: u32,
) -> Cell {
    let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(workers);
    let cfg = BatchCfg { coalesce, ..BatchCfg::default() };
    let server = WireServer::start_tcp(rt, "127.0.0.1:0", cfg).expect("bind wire server");
    let addr = WireAddr::Tcp(server.local_addr().expect("tcp addr").to_string());
    let report = hpxmp::net::run_loadgen(&LoadgenCfg {
        addr,
        op: WireOp::Daxpy,
        n: hpxmp::net::default_wire_n(WireOp::Daxpy),
        rate: rate as f64,
        conns,
        dist: Dist::Poisson,
        duration,
        deadline_us,
        seed: 0x5eed_417e,
    })
    .expect("loadgen run");
    server.drain(Duration::from_secs(5));
    let stats = server.stats();
    Cell {
        rate,
        conns,
        mode: if coalesce { "batched" } else { "unbatched" },
        report,
        batches: stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        max_batch: stats.max_batch.load(std::sync::atomic::Ordering::Relaxed),
    }
}

fn main() {
    let smoke = common::smoke();
    let workers = icv::num_procs().max(2);
    let rates = common::rates_grid();
    let mut conns_grid = common::clients_grid();
    if smoke && conns_grid.len() > 2 {
        conns_grid = vec![conns_grid[0], *conns_grid.last().unwrap()];
    }
    let duration = if smoke {
        Duration::from_millis(300)
    } else {
        Duration::from_secs(2)
    };
    eprintln!(
        "[serve_wire] rates {rates:?} x conns {conns_grid:?}, {workers} workers, \
         {}ms per cell",
        duration.as_millis()
    );

    let mut cells: Vec<Cell> = Vec::new();
    for &conns in &conns_grid {
        for &rate in &rates {
            for coalesce in [true, false] {
                let c = run_cell(workers, coalesce, rate, conns, duration, 0);
                println!(
                    "rate {:>6} conns {:>3} {:<9} -> {:>9.1} req/s  p50 {:>8.0}us  \
                     p99 {:>8.0}us  shed {:>5}  batches {:>6} (max {})",
                    c.rate,
                    c.conns,
                    c.mode,
                    c.report.reqs_per_sec(),
                    c.report.stats.p50_us(),
                    c.report.stats.p99_us(),
                    c.report.stats.shed,
                    c.batches,
                    c.max_batch
                );
                cells.push(c);
            }
        }
    }

    // Headline 1: best batched/unbatched completed-throughput ratio over
    // rates at the widest connection count.
    let wide = *conns_grid.iter().max().unwrap();
    let mut tp_ratio: Option<f64> = None;
    for &rate in &rates {
        let find = |mode: &str| {
            cells
                .iter()
                .find(|c| c.mode == mode && c.rate == rate && c.conns == wide)
                .map(|c| c.report.reqs_per_sec())
        };
        if let (Some(b), Some(u)) = (find("batched"), find("unbatched")) {
            if u > 0.0 {
                let r = b / u;
                tp_ratio = Some(tp_ratio.map_or(r, |t: f64| t.max(r)));
            }
        }
    }
    let tp_ratio = tp_ratio.unwrap_or(0.0);
    println!("throughput batched vs unbatched @{wide} conns: {tp_ratio:.3}x");

    // Headline 2: drive the batched arm at 2x the best throughput seen,
    // with a deadline so shedding is live, and compare goodput against
    // the best pre-overload cell.
    let saturation = cells
        .iter()
        .filter(|c| c.mode == "batched")
        .map(|c| c.report.reqs_per_sec())
        .fold(0.0f64, f64::max);
    let pre_goodput = cells
        .iter()
        .filter(|c| c.mode == "batched")
        .map(|c| c.report.goodput_per_sec())
        .fold(0.0f64, f64::max);
    let overload = run_cell(
        workers,
        true,
        (saturation * 2.0).max(100.0) as usize,
        wide,
        duration,
        5_000,
    );
    let overload_ratio = if pre_goodput > 0.0 {
        overload.report.goodput_per_sec() / pre_goodput
    } else {
        0.0
    };
    println!(
        "overload probe @{:.0} req/s: goodput {:.1}/s = {:.3}x of best ({:.1}/s), shed {}",
        saturation * 2.0,
        overload.report.goodput_per_sec(),
        overload_ratio,
        pre_goodput,
        overload.report.stats.shed
    );
    cells.push(overload);

    let mut json = String::from("{\n  \"bench\": \"serve_wire\",\n  \"rows\": [\n");
    for (i, c) in cells.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"rate\": {}, \"conns\": {}, \"mode\": \"{}\", \"reqs_per_sec\": {:.2}, \
             \"goodput_per_sec\": {:.2}, \"p50_us\": {:.1}, \"p99_us\": {:.1}, \
             \"shed\": {}, \"deadline_misses\": {}, \"lost\": {}, \"batches\": {}, \
             \"max_batch\": {}}}{}\n",
            c.rate,
            c.conns,
            c.mode,
            c.report.reqs_per_sec(),
            c.report.goodput_per_sec(),
            c.report.stats.p50_us(),
            c.report.stats.p99_us(),
            c.report.stats.shed,
            c.report.stats.deadline_misses,
            c.report.lost,
            c.batches,
            c.max_batch,
            if i + 1 == cells.len() { "" } else { "," }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"saturation_rps\": {saturation:.2},\n  \
         \"throughput_batched_vs_unbatched\": {tp_ratio:.3},\n  \
         \"overload_goodput_ratio\": {overload_ratio:.3}\n}}\n"
    ));

    let dir = common::results_dir();
    std::fs::create_dir_all(&dir).expect("create results dir");
    let path = dir.join("BENCH_serve_wire.json");
    std::fs::write(&path, json).expect("write BENCH_serve_wire.json");
    println!("{}", path.display());
}
