//! A small, fast, seedable PRNG (xoshiro256**) for workload generation,
//! scheduler jitter, and the in-tree property-testing framework.
//!
//! Deterministic by construction: every benchmark and property test seeds
//! explicitly, so runs are reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so that small/consecutive seeds decorrelate.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift reduction).
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Fill a slice with uniform values in `[-1, 1)` (benchmark operands).
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.range_f64(-1.0, 1.0);
        }
    }

    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.range_f64(-1.0, 1.0) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn next_below_in_bounds() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for bound in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
