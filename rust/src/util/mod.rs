//! Support substrates built in-tree (the build environment is offline, so
//! rand/clap/criterion/proptest equivalents live here).

pub mod cli;
pub mod csv;
pub mod fault;
pub mod heatmap;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timing;

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering from poisoning (ISSUE 6 fault containment).
///
/// Only for state with the *valid-at-every-unlock* invariant: every
/// critical section either completes its mutation or performs none (plain
/// reads/writes of `Copy` fields, `Vec` push/pop/clear, `HashMap`
/// insert/remove — no multi-step states observable mid-panic).  Each call
/// site documents why its protected state satisfies this; given that, the
/// poison flag carries no information and clearing it is sound — while
/// propagating it would let one contained panic (a chaos injection, a
/// user task) wedge every other tenant of the shared structure.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
