//! Support substrates built in-tree (the build environment is offline, so
//! rand/clap/criterion/proptest equivalents live here).

pub mod cli;
pub mod csv;
pub mod heatmap;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timing;
