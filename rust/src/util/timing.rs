//! Steady-state measurement loop — the in-tree stand-in for criterion
//! (offline build), methodologically modelled on Blazemark: warm up, then
//! repeat the operation until a minimum wall-time AND minimum repetition
//! count are reached, and summarize per-iteration time.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Busy-spin for `d` — the sleep stand-in for tests/benches that need a
/// wall-clock delay without an OS sleep (`src/` carries no sleep-based
/// waits — ISSUE 4; one shared helper instead of per-test copies).
pub fn spin_wait(d: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchCfg {
    /// Iterations run (and discarded) before sampling starts.
    pub warmup_iters: usize,
    /// Minimum sampled iterations.
    pub min_iters: usize,
    /// Maximum sampled iterations (caps very fast ops).
    pub max_iters: usize,
    /// Minimum total sampled wall time.
    pub min_time: Duration,
}

impl Default for BenchCfg {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            min_time: Duration::from_millis(50),
        }
    }
}

impl BenchCfg {
    /// A faster profile for sweeps with many cells (heatmaps).
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(15),
        }
    }
}

/// Run `f` under `cfg`, returning per-iteration seconds.
pub fn bench(cfg: &BenchCfg, mut f: impl FnMut()) -> Summary {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.min_iters * 2);
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        let done_time = start.elapsed() >= cfg.min_time && samples.len() >= cfg.min_iters;
        if done_time || samples.len() >= cfg.max_iters {
            break;
        }
    }
    Summary::of(&samples)
}

/// MFLOP/s given a per-iteration time summary and the FLOP count of one
/// iteration (the paper reports Blazemark MFLOP/s; we use the median
/// iteration like Blazemark's steady-state estimator).
pub fn mflops(summary: &Summary, flops_per_iter: f64) -> f64 {
    flops_per_iter / summary.median / 1.0e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let cfg = BenchCfg {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 10,
            min_time: Duration::from_micros(1),
        };
        let s = bench(&cfg, || n += 1);
        assert!(s.n >= 3);
        assert!(n as usize >= s.n + 1); // warmup included
    }

    #[test]
    fn mflops_scales_with_flops() {
        let s = Summary {
            n: 1,
            mean: 1e-3,
            stddev: 0.0,
            min: 1e-3,
            max: 1e-3,
            median: 1e-3,
        };
        assert!((mflops(&s, 2.0e6) - 2000.0).abs() < 1e-9);
    }
}
