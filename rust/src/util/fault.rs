//! Chaos-injection harness (ISSUE 6; DESIGN.md §11).
//!
//! Deterministic fault injection at the runtime's three execution
//! boundaries, so cancellation and panic containment are testable in CI
//! instead of only under real failures:
//!
//! * [`Site::TaskRun`] — inside `TaskNode::execute`, after the retire
//!   guard is armed (an injected panic exercises the OMP tasking layer's
//!   counter/promise containment).
//! * [`Site::Fork`] — inside a team member's implicit-task body, inside
//!   its `catch_unwind` (exercises barrier/join containment and the
//!   un-poisoned return of the team to the pool).
//! * [`Site::Continuation`] — at the head of a spawned `then` body
//!   (exercises `Outcome::Panicked` propagation through future chains
//!   via the promise-drop backstop).
//!
//! Every site sits *inside* an already-contained region: injection can
//! never leak counters or wedge a barrier that real panics would not
//! also wedge — by construction the harness only widens coverage of
//! paths the containment machinery already owns.
//!
//! Configured from `HPXMP_FAULT` (comma-separated actions):
//!
//! ```text
//! HPXMP_FAULT=panic:0.01,delay:0.05:200,cancel:0.002
//!             ^panic w.p. 1%   ^200µs sleep w.p. 5%   ^token-fire w.p. 0.2%
//! HPXMP_FAULT_SEED=42          # optional; default 0xC0FFEE
//! ```
//!
//! Draws come from a per-thread [`Xoshiro256`] seeded from the global
//! seed plus a per-thread counter — deterministic for a fixed seed and
//! thread schedule, and re-seeded whenever a new config is
//! [`install`]ed (epoch bump), so in-process benches can sweep fault
//! rates without stale generator state.  The disabled fast path is one
//! relaxed atomic load.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use once_cell::sync::Lazy;

use super::rng::Xoshiro256;
use crate::amt::cancel::CancelToken;

/// Where in the runtime an injection check sits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Explicit-task body (OMP tasking layer).
    TaskRun,
    /// Implicit-task body of a parallel region member.
    Fork,
    /// Spawned future continuation (`then` body head).
    Continuation,
}

/// One parsed fault configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultCfg {
    /// Probability of `panic!` per injection point.
    pub panic_p: f64,
    /// Probability of a busy-thread `sleep(delay_us)` per injection point.
    pub delay_p: f64,
    pub delay_us: u64,
    /// Probability of firing the ambient cancel token (if one is set via
    /// [`set_ambient_token`]) per injection point.
    pub cancel_p: f64,
    /// RNG seed; per-thread streams derive from it.
    pub seed: u64,
}

impl FaultCfg {
    /// Parse the `HPXMP_FAULT` grammar: `panic:p`, `delay:p:us`,
    /// `cancel:p`, comma-separated.  Unknown or malformed actions are
    /// ignored (chaos config must never crash the host).  Returns `None`
    /// when no action carries a positive probability.
    pub fn parse(spec: &str, seed: u64) -> Option<Self> {
        let mut cfg = FaultCfg {
            seed,
            ..Default::default()
        };
        for action in spec.split(',') {
            let mut parts = action.trim().split(':');
            let (kind, p) = (parts.next().unwrap_or(""), parts.next());
            let p: f64 = match p.and_then(|s| s.parse().ok()) {
                Some(p) => p,
                None => continue,
            };
            match kind {
                "panic" => cfg.panic_p = p,
                "delay" => {
                    cfg.delay_p = p;
                    cfg.delay_us = parts.next().and_then(|s| s.parse().ok()).unwrap_or(100);
                }
                "cancel" => cfg.cancel_p = p,
                _ => {}
            }
        }
        (cfg.panic_p > 0.0 || cfg.delay_p > 0.0 || cfg.cancel_p > 0.0).then_some(cfg)
    }

    /// Read `HPXMP_FAULT` / `HPXMP_FAULT_SEED` from the environment.
    pub fn from_env() -> Option<Self> {
        let spec = std::env::var("HPXMP_FAULT").ok()?;
        let seed = std::env::var("HPXMP_FAULT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self::parse(&spec, seed)
    }
}

struct Global {
    /// Fast-path gate: false -> `inject` is one relaxed load.
    enabled: AtomicBool,
    /// Bumped on every `install`; per-thread RNGs re-seed when they
    /// observe a new epoch.
    epoch: AtomicU64,
    cfg: Mutex<Option<Arc<FaultCfg>>>,
    /// Counts injections actually fired (all sites), for observability
    /// and test assertions.
    fired: AtomicUsize,
    /// Target of `cancel:p` injections, when a scope has armed one.
    ambient_token: Mutex<Option<CancelToken>>,
}

static GLOBAL: Lazy<Global> = Lazy::new(|| {
    let g = Global {
        enabled: AtomicBool::new(false),
        epoch: AtomicU64::new(0),
        cfg: Mutex::new(None),
        fired: AtomicUsize::new(0),
        ambient_token: Mutex::new(None),
    };
    if let Some(cfg) = FaultCfg::from_env() {
        *g.cfg.lock().unwrap() = Some(Arc::new(cfg));
        g.epoch.store(1, Ordering::Release);
        g.enabled.store(true, Ordering::Release);
    }
    g
});

fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    // Chaos state is trivially valid (Option swaps only); recover from
    // poisoning so an injected panic cannot disable the harness itself.
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Install (or clear, with `None`) the active fault configuration.
/// In-process benches use this to sweep rates; the environment variable
/// is only read once at first use.
pub fn install(cfg: Option<FaultCfg>) {
    let mut slot = lock_recover(&GLOBAL.cfg);
    GLOBAL.enabled.store(cfg.is_some(), Ordering::Release);
    *slot = cfg.map(Arc::new);
    GLOBAL.epoch.fetch_add(1, Ordering::AcqRel);
}

/// Arm (or clear) the token that `cancel:p` injections fire.  Scopes that
/// want chaos-driven cancellation (the serve loop, tests) install their
/// region token here.
pub fn set_ambient_token(token: Option<CancelToken>) {
    *lock_recover(&GLOBAL.ambient_token) = token;
}

/// Total injections fired since process start (panics + delays + cancels).
pub fn injections_fired() -> usize {
    GLOBAL.fired.load(Ordering::Relaxed)
}

/// Whether any fault configuration is active.
pub fn enabled() -> bool {
    GLOBAL.enabled.load(Ordering::Relaxed)
}

static THREAD_SALT: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (epoch the stream was seeded under, generator).
    static STREAM: std::cell::RefCell<(u64, Xoshiro256)> =
        std::cell::RefCell::new((0, Xoshiro256::seed_from_u64(0)));
}

/// Possibly inject a fault at `site`.  No-op (one atomic load) when
/// disabled.  May panic — call only from inside a containment region
/// (see the module docs for the placement invariant).
#[inline]
pub fn inject(site: Site) {
    if !GLOBAL.enabled.load(Ordering::Relaxed) {
        return;
    }
    inject_slow(site);
}

#[cold]
fn inject_slow(site: Site) {
    let cfg = match lock_recover(&GLOBAL.cfg).clone() {
        Some(cfg) => cfg,
        None => return,
    };
    let epoch = GLOBAL.epoch.load(Ordering::Acquire);
    let draw = STREAM.with(|s| {
        let mut s = s.borrow_mut();
        if s.0 != epoch {
            let salt = THREAD_SALT.fetch_add(1, Ordering::Relaxed);
            *s = (
                epoch,
                Xoshiro256::seed_from_u64(cfg.seed ^ (salt.wrapping_mul(0x9E3779B97F4A7C15))),
            );
        }
        s.1.next_f64()
    });
    // One draw decides among the actions via stacked thresholds, so the
    // per-site fault rate is exactly the configured sum.
    let mut lo = 0.0;
    if draw < lo + cfg.delay_p {
        GLOBAL.fired.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(Duration::from_micros(cfg.delay_us));
        return;
    }
    lo += cfg.delay_p;
    if draw < lo + cfg.cancel_p {
        if let Some(tok) = lock_recover(&GLOBAL.ambient_token).clone() {
            GLOBAL.fired.fetch_add(1, Ordering::Relaxed);
            tok.cancel();
        }
        return;
    }
    lo += cfg.cancel_p;
    if draw < lo + cfg.panic_p {
        GLOBAL.fired.fetch_add(1, Ordering::Relaxed);
        panic!("injected fault at {site:?} (HPXMP_FAULT)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let cfg = FaultCfg::parse("panic:0.01,delay:0.05:200,cancel:0.002", 7).unwrap();
        assert_eq!(cfg.panic_p, 0.01);
        assert_eq!(cfg.delay_p, 0.05);
        assert_eq!(cfg.delay_us, 200);
        assert_eq!(cfg.cancel_p, 0.002);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn parse_ignores_malformed_actions() {
        let cfg = FaultCfg::parse("bogus:x,panic:0.5,:::", 1).unwrap();
        assert_eq!(cfg.panic_p, 0.5);
        assert_eq!(cfg.delay_p, 0.0);
    }

    #[test]
    fn parse_all_zero_is_none() {
        assert!(FaultCfg::parse("panic:0,delay:0:10", 1).is_none());
        assert!(FaultCfg::parse("", 1).is_none());
    }

    #[test]
    fn delay_defaults_to_100us_when_omitted() {
        let cfg = FaultCfg::parse("delay:0.5", 1).unwrap();
        assert_eq!(cfg.delay_us, 100);
    }
}
