//! ASCII heatmap renderer for the paper's Figure 2–5 ratio plots.
//!
//! The paper shows r = MFLOP/s(hpxMP) / MFLOP/s(OpenMP) on a
//! threads-by-size grid.  We render the same grid in the terminal with a
//! ramp of glyphs and also emit CSV (see `util::csv`) for plotting.

/// A dense grid of ratio cells: `rows` = thread counts, `cols` = sizes.
pub struct Heatmap {
    pub row_labels: Vec<String>,
    pub col_labels: Vec<String>,
    pub cells: Vec<Vec<f64>>, // cells[row][col]
}

/// Ramp from "much slower" to "faster": the paper's colour scale, ASCII-fied.
const RAMP: &[(f64, char)] = &[
    (0.25, '.'),
    (0.50, ':'),
    (0.70, '-'),
    (0.85, '='),
    (0.95, '+'),
    (1.05, '#'),
    (1.20, '%'),
    (f64::INFINITY, '@'),
];

pub fn glyph(ratio: f64) -> char {
    for &(hi, g) in RAMP {
        if ratio < hi {
            return g;
        }
    }
    '@'
}

impl Heatmap {
    pub fn new(row_labels: Vec<String>, col_labels: Vec<String>) -> Self {
        let cells = vec![vec![f64::NAN; col_labels.len()]; row_labels.len()];
        Self {
            row_labels,
            col_labels,
            cells,
        }
    }

    pub fn set(&mut self, row: usize, col: usize, v: f64) {
        self.cells[row][col] = v;
    }

    /// Render the grid with per-cell glyphs plus a legend; `title` echoes
    /// the paper figure this reproduces.
    pub fn render(&self, title: &str) -> String {
        let mut s = String::new();
        s.push_str(&format!("{title}\n"));
        let label_w = self
            .row_labels
            .iter()
            .map(|l| l.len())
            .max()
            .unwrap_or(4)
            .max(4);
        for (r, rl) in self.row_labels.iter().enumerate() {
            s.push_str(&format!("{rl:>label_w$} |"));
            for c in 0..self.col_labels.len() {
                let v = self.cells[r][c];
                s.push(if v.is_nan() { ' ' } else { glyph(v) });
            }
            s.push('\n');
        }
        s.push_str(&format!("{:>label_w$} +{}\n", "", "-".repeat(self.col_labels.len())));
        s.push_str(&format!(
            "{:>label_w$}  cols: {} .. {}\n",
            "",
            self.col_labels.first().map(String::as_str).unwrap_or(""),
            self.col_labels.last().map(String::as_str).unwrap_or("")
        ));
        s.push_str("legend: <0.25 '.'  <0.5 ':'  <0.7 '-'  <0.85 '='  <0.95 '+'  ~1 '#'  <1.2 '%'  >1.2 '@'\n");
        s
    }

    /// Mean ratio over all populated cells (used by shape assertions).
    pub fn mean(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for row in &self.cells {
            for &v in row {
                if !v.is_nan() {
                    sum += v;
                    n += 1;
                }
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyph_ramp_is_monotone() {
        let gs: Vec<char> = [0.1, 0.3, 0.6, 0.8, 0.9, 1.0, 1.1, 2.0]
            .iter()
            .map(|&r| glyph(r))
            .collect();
        assert_eq!(gs, vec!['.', ':', '-', '=', '+', '#', '%', '@']);
    }

    #[test]
    fn render_contains_labels_and_legend() {
        let mut h = Heatmap::new(
            vec!["1".into(), "2".into()],
            vec!["100".into(), "200".into()],
        );
        h.set(0, 0, 1.0);
        h.set(0, 1, 0.5);
        h.set(1, 0, 0.9);
        h.set(1, 1, 1.3);
        let r = h.render("Fig X");
        assert!(r.contains("Fig X"));
        assert!(r.contains("legend:"));
        assert!(r.contains('#'));
        assert!(r.contains('@'));
    }

    #[test]
    fn mean_ignores_nan() {
        let mut h = Heatmap::new(vec!["1".into()], vec!["a".into(), "b".into()]);
        h.set(0, 0, 2.0);
        assert_eq!(h.mean(), 2.0);
    }
}
