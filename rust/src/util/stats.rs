//! Summary statistics for benchmark timing samples.

/// Summary of a sample of measurements (nanoseconds or any unit).
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Relative stddev (coefficient of variation); used to decide whether a
    /// measurement has converged.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Nearest-rank percentile of `samples` (`q` in 0..=100): the smallest
/// sample such that at least `q`% of the sample set is ≤ it.  Used by the
/// serving benches for p50/p99 request latencies.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if q == 0.0 {
        return sorted[0];
    }
    let rank = (q / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_sample() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // sample stddev of 1,2,3,4 = sqrt(5/3)
        assert!((s.stddev - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        let s = Summary::of(&[9.0, 1.0, 5.0]);
        assert_eq!(s.median, 5.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        // Unsorted input and tiny samples.
        assert_eq!(percentile(&[5.0, 1.0, 9.0], 50.0), 5.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }
}
