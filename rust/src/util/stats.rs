//! Summary statistics for benchmark timing samples.

/// Summary of a sample of measurements (nanoseconds or any unit).
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Relative stddev (coefficient of variation); used to decide whether a
    /// measurement has converged.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Nearest-rank percentile of `samples` (`q` in 0..=100): the smallest
/// sample such that at least `q`% of the sample set is ≤ it.  Used by the
/// serving benches for p50/p99 request latencies.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if q == 0.0 {
        return sorted[0];
    }
    let rank = (q / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Per-request serving statistics shared by the in-process serving
/// scenario (`coordinator::serve`) and the wire front-end (`net`), so
/// both report the identical row schema (ISSUE 9): latencies, shed /
/// retry / deadline accounting, and the derived p50/p99 + goodput.
///
/// One accumulator per client (or connection); [`RequestStats::merge`]
/// folds them into the run-level aggregate.
#[derive(Clone, Debug, Default)]
pub struct RequestStats {
    /// Per-request latency in seconds (completed requests only).
    pub latencies_s: Vec<f64>,
    /// Requests rejected by the load shedder (never executed, never timed).
    pub shed: usize,
    /// Backoff attempts taken before submit-or-shed decisions.
    pub retries: usize,
    /// Completed requests that finished after their deadline.
    pub deadline_misses: usize,
    /// Completed requests that finished within their deadline (equals
    /// `completed()` when no deadline is configured).
    pub in_deadline: usize,
    /// Requests that returned an error outcome (wire: `Status::Error`).
    pub failed: usize,
}

impl RequestStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Self {
            latencies_s: Vec::with_capacity(n),
            ..Self::default()
        }
    }

    /// Record one completed request: its latency and whether it blew its
    /// deadline (`missed = false` when no deadline is configured).
    pub fn record(&mut self, latency_s: f64, missed: bool) {
        self.latencies_s.push(latency_s);
        if missed {
            self.deadline_misses += 1;
        } else {
            self.in_deadline += 1;
        }
    }

    /// Fold another accumulator (one client / connection) into this one.
    pub fn merge(&mut self, other: &RequestStats) {
        self.latencies_s.extend_from_slice(&other.latencies_s);
        self.shed += other.shed;
        self.retries += other.retries;
        self.deadline_misses += other.deadline_misses;
        self.in_deadline += other.in_deadline;
        self.failed += other.failed;
    }

    /// Requests that actually completed (timed).
    pub fn completed(&self) -> usize {
        self.latencies_s.len()
    }

    /// p50 latency in microseconds (0 when nothing completed).
    pub fn p50_us(&self) -> f64 {
        if self.latencies_s.is_empty() {
            0.0
        } else {
            percentile(&self.latencies_s, 50.0) * 1e6
        }
    }

    /// p99 latency in microseconds (0 when nothing completed).
    pub fn p99_us(&self) -> f64 {
        if self.latencies_s.is_empty() {
            0.0
        } else {
            percentile(&self.latencies_s, 99.0) * 1e6
        }
    }

    /// Completed requests per wall second.
    pub fn reqs_per_sec(&self, wall_s: f64) -> f64 {
        self.completed() as f64 / wall_s.max(1e-9)
    }

    /// Requests completed *within* their deadline per wall second — the
    /// serving metric shedding is supposed to protect.
    pub fn goodput_per_sec(&self, wall_s: f64) -> f64 {
        self.in_deadline as f64 / wall_s.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_sample() {
        let s = Summary::of(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // sample stddev of 1,2,3,4 = sqrt(5/3)
        assert!((s.stddev - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        let s = Summary::of(&[9.0, 1.0, 5.0]);
        assert_eq!(s.median, 5.0);
    }

    #[test]
    fn request_stats_record_merge_and_derived_metrics() {
        let mut a = RequestStats::new();
        a.record(0.001, false);
        a.record(0.002, true);
        a.shed = 3;
        a.retries = 5;
        let mut b = RequestStats::with_capacity(4);
        b.record(0.004, false);
        b.failed = 1;
        let mut total = RequestStats::new();
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.completed(), 3);
        assert_eq!(total.in_deadline, 2);
        assert_eq!(total.deadline_misses, 1);
        assert_eq!(total.shed, 3);
        assert_eq!(total.retries, 5);
        assert_eq!(total.failed, 1);
        assert_eq!(total.p50_us(), 2000.0);
        assert_eq!(total.p99_us(), 4000.0);
        assert!((total.reqs_per_sec(1.0) - 3.0).abs() < 1e-12);
        assert!((total.goodput_per_sec(2.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn request_stats_empty_is_all_zero() {
        let s = RequestStats::new();
        assert_eq!(s.completed(), 0);
        assert_eq!(s.p50_us(), 0.0);
        assert_eq!(s.p99_us(), 0.0);
        assert_eq!(s.reqs_per_sec(0.0), 0.0);
        assert_eq!(s.goodput_per_sec(1.0), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        // Unsorted input and tiny samples.
        assert_eq!(percentile(&[5.0, 1.0, 9.0], 50.0), 5.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }
}
