//! Minimal CSV writer for benchmark reports (offline build: no csv crate).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// A simple row-oriented CSV writer; quotes fields containing separators.
pub struct CsvWriter<W: Write> {
    out: W,
}

impl CsvWriter<BufWriter<File>> {
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(Self {
            out: BufWriter::new(File::create(path)?),
        })
    }
}

impl<W: Write> CsvWriter<W> {
    pub fn from_writer(out: W) -> Self {
        Self { out }
    }

    pub fn row<S: AsRef<str>>(&mut self, fields: &[S]) -> std::io::Result<()> {
        let mut first = true;
        for f in fields {
            if !first {
                write!(self.out, ",")?;
            }
            first = false;
            let f = f.as_ref();
            if f.contains(',') || f.contains('"') || f.contains('\n') {
                write!(self.out, "\"{}\"", f.replace('"', "\"\""))?;
            } else {
                write!(self.out, "{f}")?;
            }
        }
        writeln!(self.out)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_plain_rows() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::from_writer(&mut buf);
            w.row(&["a", "b", "c"]).unwrap();
            w.row(&["1", "2", "3"]).unwrap();
        }
        assert_eq!(String::from_utf8(buf).unwrap(), "a,b,c\n1,2,3\n");
    }

    #[test]
    fn quotes_fields_with_commas_and_quotes() {
        let mut buf = Vec::new();
        {
            let mut w = CsvWriter::from_writer(&mut buf);
            w.row(&["x,y", "he said \"hi\""]).unwrap();
        }
        assert_eq!(
            String::from_utf8(buf).unwrap(),
            "\"x,y\",\"he said \"\"hi\"\"\"\n"
        );
    }
}
