//! Tiny CLI argument parser (offline build: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; used by `main.rs` and the example binaries.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `value_opts` lists option names that consume a following value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_opts: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&rest) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(rest.to_string(), v);
                        }
                        None => {
                            out.flags.push(rest.to_string());
                        }
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(value_opts: &[&str]) -> Self {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// Parse a comma-separated list of integers (e.g. `--threads 1,2,4`).
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], vals: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), vals)
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = parse(&["bench", "--verbose", "x"], &[]);
        assert_eq!(a.positional, vec!["bench", "x"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = parse(&["--op", "daxpy", "--threads=4"], &["op"]);
        assert_eq!(a.get("op"), Some("daxpy"));
        assert_eq!(a.get_usize("threads", 0), 4);
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--threads=1,2,8"], &[]);
        assert_eq!(a.get_usize_list("threads", &[16]), vec![1, 2, 8]);
        assert_eq!(a.get_usize_list("missing", &[16]), vec![16]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("op", "all"), "all");
        assert_eq!(a.get_usize("reps", 3), 3);
    }
}
