//! Tiny CLI argument parser (offline build: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; used by `main.rs` and the example binaries.  Also home of
//! the one closed-set name parser ([`lookup_choice`] / [`parse_choice`])
//! behind every `--xxx <name>` selector and `HPXMP_*` env binding
//! (execution mode, AMT policy, Blaze op, serving mix), so unknown
//! values everywhere produce the same "valid set" error instead of a
//! per-call-site panic or a silent default.

use std::collections::HashMap;

/// Match `s` case-insensitively against a `(name, value)` table (aliases
/// are extra rows).  The shared lookup behind [`parse_choice`] and every
/// `parse() -> Option<Self>` selector in the crate.
pub fn lookup_choice<T: Copy>(s: &str, choices: &[(&str, T)]) -> Option<T> {
    let s = s.trim();
    choices
        .iter()
        .find(|(name, _)| s.eq_ignore_ascii_case(name))
        .map(|(_, v)| *v)
}

/// Like [`lookup_choice`], but an unknown value yields an error listing
/// the whole valid set — what CLI flags and env vars should surface
/// instead of silently falling back to a default.
pub fn parse_choice<T: Copy>(what: &str, s: &str, choices: &[(&str, T)]) -> Result<T, String> {
    lookup_choice(s, choices).ok_or_else(|| {
        let names: Vec<&str> = choices.iter().map(|(name, _)| *name).collect();
        format!("unknown {what} '{s}' (valid: {})", names.join("|"))
    })
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `value_opts` lists option names that consume a following value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_opts: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&rest) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(rest.to_string(), v);
                        }
                        None => {
                            out.flags.push(rest.to_string());
                        }
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(value_opts: &[&str]) -> Self {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// Parse a comma-separated list of integers (e.g. `--threads 1,2,4`).
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], vals: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), vals)
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = parse(&["bench", "--verbose", "x"], &[]);
        assert_eq!(a.positional, vec!["bench", "x"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = parse(&["--op", "daxpy", "--threads=4"], &["op"]);
        assert_eq!(a.get("op"), Some("daxpy"));
        assert_eq!(a.get_usize("threads", 0), 4);
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--threads=1,2,8"], &[]);
        assert_eq!(a.get_usize_list("threads", &[16]), vec![1, 2, 8]);
        assert_eq!(a.get_usize_list("missing", &[16]), vec![16]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("op", "all"), "all");
        assert_eq!(a.get_usize("reps", 3), 3);
    }

    #[test]
    fn choice_lookup_is_case_insensitive_and_alias_aware() {
        let choices = [("par", 1), ("parallel", 1), ("task", 2)];
        assert_eq!(lookup_choice("PAR", &choices), Some(1));
        assert_eq!(lookup_choice(" parallel ", &choices), Some(1));
        assert_eq!(lookup_choice("task", &choices), Some(2));
        assert_eq!(lookup_choice("nope", &choices), None);
    }

    #[test]
    fn parse_choice_error_lists_valid_set() {
        let choices = [("seq", 0), ("par", 1)];
        let err = parse_choice("exec mode", "bogus", &choices).unwrap_err();
        assert!(err.contains("unknown exec mode 'bogus'"), "{err}");
        assert!(err.contains("seq|par"), "{err}");
        assert_eq!(parse_choice("exec mode", "par", &choices), Ok(1));
    }
}
