//! In-tree mini property-testing framework (offline build: no proptest).
//!
//! `forall` runs a property over `cases` pseudo-random inputs drawn from a
//! generator; on failure it reports the seed and the case index so the
//! exact input can be replayed deterministically.  Shrinking is replaced by
//! deterministic replay — adequate for the scheduler/runtime invariants we
//! test (task conservation, chunk-partition exactness, dependence order).

use super::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropCfg {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropCfg {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` on `cfg.cases` inputs produced by `gen`.  Panics with the
/// replay seed on the first failing case.
pub fn forall<T: std::fmt::Debug>(
    cfg: PropCfg,
    mut gen: impl FnMut(&mut Xoshiro256) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cfg.cases {
        // Derive a per-case RNG so failures replay independently of the
        // number of draws earlier cases made.
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ (case as u64).wrapping_mul(0x9E37));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}):\n  input: {input:?}\n  {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_eq<A: PartialEq + std::fmt::Debug>(a: A, b: A, ctx: &str) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{ctx}: {a:?} != {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            PropCfg { cases: 10, seed: 1 },
            |r| r.next_below(100),
            |&x| {
                n += 1;
                ensure(x < 100, "bound")
            },
        );
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        forall(
            PropCfg { cases: 50, seed: 2 },
            |r| r.next_below(10),
            |&x| ensure(x < 5, "x too big"),
        );
    }

    #[test]
    fn ensure_eq_formats_context() {
        assert!(ensure_eq(1, 1, "same").is_ok());
        let e = ensure_eq(1, 2, "diff").unwrap_err();
        assert!(e.contains("diff"));
    }
}
