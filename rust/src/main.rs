//! `hpxmp` — the launcher CLI.
//!
//! Subcommands (each regenerating part of the paper's evaluation):
//!
//! ```text
//! hpxmp info                              runtime/platform summary
//! hpxmp conformance                       Tables 1-3 live feature report
//! hpxmp heatmap  --op <op|all> [...]      Figs 2-5 ratio heatmaps
//! hpxmp scaling  --op <op|all> [...]      Figs 6-9 scaling series
//! hpxmp dataflow [--sizes a,b,c]          fork-join vs futurized dataflow mmult
//! hpxmp serve    [--clients M --mix m]    multi-tenant serving: shared vs per-client
//! hpxmp serve    --listen <addr> [...]    wire server (TCP/UDS, coalescing front-end)
//! hpxmp serve    --listen <addr> --shards N  dist front-end over a worker-process fleet
//! hpxmp worker   --connect <addr> [...]   dist worker process (spawned by the coordinator)
//! hpxmp dist-mmult [--shards N --size n]  distributed matmul vs single-process oracle
//! hpxmp loadgen  [--addr a --rate R]      open-loop load generator for the wire server
//! hpxmp offload  [--size N]               three-layer PJRT smoke run
//! hpxmp policies [--tasks N]              AMT policy ablation
//! hpxmp taskbench [--pattern p --grain-us g,h]  Task Bench dependency-pattern grid
//! ```
//!
//! Common options: `--threads 1,2,4,...`, `--workers N`, `--policy <name>`,
//! `--quick`, `--out results/`.

use std::sync::Arc;

use hpxmp::amt::PolicyKind;
use hpxmp::baseline::BaselineRuntime;
use hpxmp::coordinator::{
    blazemark::{self, Op},
    conformance, report, sweep,
};
use hpxmp::omp::{icv, OmpRuntime};
use hpxmp::par::{exec, ExecMode, HpxMpRuntime, Policy};
use hpxmp::util::cli::Args;
use hpxmp::util::timing::BenchCfg;

const VALUE_OPTS: &[&str] = &[
    "op", "threads", "workers", "policy", "sizes", "out", "size", "tasks", "clients", "requests",
    "mix", "exec", "tile", "deadline-us", "retries", "kernel", "threshold", "pattern", "width",
    "steps", "grain-us", "listen", "addr", "rate", "conns", "dist", "duration", "coalesce-us",
    "max-batch", "max-pending", "seed", "connect", "slot", "stall-us", "shards",
];

fn main() {
    let args = Args::from_env(VALUE_OPTS);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = (|| -> anyhow::Result<()> {
        let mode = exec_mode(&args)?;
        // Validate the policy knobs up front so every subcommand rejects
        // bad values instead of silently defaulting mid-run.
        kernel_variant(&args)?;
        if let Some(s) = args.get("threshold") {
            s.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--threshold: {e}"))?;
        }
        match cmd {
            "info" => cmd_info(&args, mode),
            "conformance" => cmd_conformance(&args),
            "heatmap" => cmd_heatmap(&args, mode),
            "scaling" => cmd_scaling(&args, mode),
            "dataflow" => cmd_dataflow(&args),
            "serve" => cmd_serve(&args, mode),
            "worker" => cmd_worker(&args),
            "dist-mmult" => cmd_dist_mmult(&args),
            "loadgen" => cmd_loadgen(&args),
            "offload" => cmd_offload(&args),
            "policies" => cmd_policies(&args),
            "taskbench" => cmd_taskbench(&args),
            _ => {
                print_help();
                Ok(())
            }
        }
    })();
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// The `--exec` selector, threaded through every subcommand (`HPXMP_EXEC`
/// is the env fallback): which execution model kernels run under.
/// Unknown values list the valid set instead of silently defaulting.
fn exec_mode(args: &Args) -> anyhow::Result<ExecMode> {
    match args.get("exec") {
        Some(s) => ExecMode::parse_or_list(s).map_err(|e| anyhow::anyhow!(e)),
        None => Ok(ExecMode::from_env(ExecMode::Par)),
    }
}

/// The `--kernel` selector (`HPXMP_KERNEL` is the env fallback): which
/// micro-kernel variant the Blaze operations dispatch to (ISSUE 7).
/// `auto` is numerics-preserving; `scalar|unrolled|packed` force a path.
fn kernel_variant(args: &Args) -> anyhow::Result<exec::KernelVariant> {
    match args.get("kernel") {
        Some(s) => exec::KernelVariant::parse_or_list(s).map_err(|e| anyhow::anyhow!(e)),
        None => Ok(exec::KernelVariant::from_env(exec::KernelVariant::Auto)),
    }
}

fn print_help() {
    println!(
        "hpxmp — OpenMP-over-AMT runtime (hpxMP reproduction)\n\n\
         usage: hpxmp <info|conformance|heatmap|scaling|dataflow|serve|worker|dist-mmult|loadgen|offload|policies|taskbench> [options]\n\n\
         options:\n\
           --op <dvecdvecadd|daxpy|dmatdmatadd|dmatdmatmult|dmatdvecmult|all>\n\
           --exec <seq|par|task>     execution policy for every kernel (env: HPXMP_EXEC;\n\
                                     default par; task = futurized dataflow)\n\
           --tile N                  task-mode tile edge for dmatdmatmult (default 64)\n\
           --kernel <auto|scalar|unrolled|packed>  micro-kernel variant (env: HPXMP_KERNEL;\n\
                                     auto preserves scalar numerics — see DESIGN.md §12)\n\
           --threshold N             serial→parallel element-count crossover override\n\
           --threads 1,2,4,8,16      thread counts (heatmap) / counts per figure (scaling)\n\
           --workers N               AMT worker threads (default: max(threads))\n\
           --policy <name>           priority-local|static|local|global|abp|hierarchical|periodic\n\
           --sizes a,b,c             override the size grid\n\
           --clients M               concurrent serving clients (serve; default 4)\n\
           --requests N              requests per client (serve; default 200)\n\
           --mix <vec|mixed>         serving kernel mix (serve; default mixed)\n\
           --deadline-us D           per-request deadline in microseconds (serve)\n\
           --shed                    shed requests when the runtime is saturated (serve)\n\
           --retries N               backoff attempts before a shed (serve; default 2)\n\
           --listen <addr>           serve the wire protocol on tcp:host:port or uds:/path\n\
           --shards N                serve --listen through N worker processes (dist mode;\n\
                                     requests are routed by key with failover to survivors)\n\
           --connect <addr>          worker: coordinator address to dial back (required)\n\
           --slot N                  worker: shard slot announced in the hello (default 0)\n\
           --stall-us D              worker: artificial delay before each task (tests)\n\
           --coalesce-us W           wire coalescing window in us (serve --listen; default 150;\n\
                                     env HPXMP_COALESCE=0 disables batching)\n\
           --max-batch N             flush a coalescing bucket at N requests (default 32)\n\
           --max-pending N           hard shed cap on queued+in-flight requests (default 1024)\n\
           --duration S              run seconds (serve --listen: 0 = forever; loadgen: 5)\n\
           --addr <addr>             loadgen target (default 127.0.0.1:7070)\n\
           --rate R --conns C        loadgen offered load: R req/s total over C connections\n\
           --dist <poisson|uniform>  loadgen inter-arrival distribution (default poisson)\n\
           --seed N                  loadgen payload/arrival seed\n\
           --pattern <stencil|nearest|fft|spread|random|all>  dependency pattern (taskbench)\n\
           --width N --steps N       task-grid shape (taskbench; default 64 x 32)\n\
           --grain-us g,h            per-task busy-work grains in us (taskbench; default 0,20)\n\
           --metg                    solve METG per pattern (taskbench; binary-search grain\n\
                                     for the smallest with eff >= 0.5)\n\
           --quick                   fast measurement profile\n\
           --out DIR                 report directory (default results/)\n"
    );
}

fn build_runtimes(args: &Args, max_threads: usize) -> anyhow::Result<(HpxMpRuntime, BaselineRuntime)> {
    build_runtimes_with_workers(args, args.get_usize("workers", max_threads.max(icv::num_procs())), max_threads)
}

/// Like [`build_runtimes`] but with the AMT worker count pinned — the
/// `--exec task` sweeps build one runtime per thread count with exactly
/// `t` workers, because a task graph parallelizes over *every* worker
/// (a wider pool would hand it cores the `t`-thread row never claimed,
/// flattening the thread axis of the figure).
fn build_runtimes_with_workers(
    args: &Args,
    workers: usize,
    max_threads: usize,
) -> anyhow::Result<(HpxMpRuntime, BaselineRuntime)> {
    let policy = match args.get("policy") {
        Some(p) => PolicyKind::parse_or_list(p).map_err(|e| anyhow::anyhow!(e))?,
        None => PolicyKind::PriorityLocal,
    };
    let rt = OmpRuntime::new(workers, policy);
    Ok((HpxMpRuntime::new(rt), BaselineRuntime::new(max_threads)))
}

/// Stamp the subcommand's execution policy onto a runtime: the one-line
/// seq/par/task swap, applied uniformly across subcommands.
fn policy_on<'e>(mode: ExecMode, ex: &'e dyn exec::Executor, args: &Args) -> Policy<'e> {
    // `--kernel` was validated in main(); the fallback is unreachable.
    let kv = kernel_variant(args).unwrap_or(exec::KernelVariant::Auto);
    let mut pol = Policy::with_mode(mode)
        .on(ex)
        .tile(args.get_usize("tile", exec::DEFAULT_TILE))
        .kernel(kv);
    if let Some(t) = args.get("threshold").and_then(|s| s.parse().ok()) {
        pol = pol.threshold(t);
    }
    pol
}

fn bench_cfg(args: &Args) -> BenchCfg {
    if args.flag("quick") {
        BenchCfg::quick()
    } else {
        BenchCfg::default()
    }
}

fn ops_from(args: &Args) -> anyhow::Result<Vec<Op>> {
    match args.get_or("op", "all") {
        "all" => Ok(Op::ALL.to_vec()),
        s => Ok(vec![Op::parse_or_list(s).map_err(|e| anyhow::anyhow!(e))?]),
    }
}

fn cmd_info(args: &Args, mode: ExecMode) -> anyhow::Result<()> {
    println!("hpxmp-rs — hpxMP reproduction (Zhang et al. 2019)");
    println!("  num_procs        : {}", icv::num_procs());
    println!("  OMP_NUM_THREADS  : {:?}", std::env::var("OMP_NUM_THREADS").ok());
    println!("  HPXMP_POLICY     : {}", icv::policy_from_env().name());
    println!("  exec policy      : {} (of seq|par|task)", mode.name());
    println!(
        "  kernel variant   : {} (of auto|scalar|unrolled|packed)",
        kernel_variant(args)?.name()
    );
    println!("  simd             : {}", hpxmp::blaze::kernel::simd_label());
    {
        let t = hpxmp::amt::Tuning::from_env();
        println!(
            "  scheduler tuning : steal_batch={} (HPXMP_STEAL_ONE), inline_cont={} \
             (HPXMP_INLINE_CONT, depth bound {})",
            t.steal_batch,
            t.inline_cont,
            hpxmp::amt::MAX_INLINE_DEPTH
        );
    }
    {
        let a = hpxmp::amt::arena::stats();
        println!(
            "  task arena       : {} fresh, {} reused, {} boxed-fallback, {} recycled, {} freed",
            a.fresh_allocs, a.reuses, a.fallbacks, a.recycled, a.freed
        );
    }
    {
        let d = hpxmp::dist::stats();
        println!(
            "  dist             : {} routed, {} bands, {} fulfilled, {} failed, {} cancelled, \
             {} reroutes, {} respawns",
            d.routed, d.bands, d.fulfilled, d.failed, d.cancelled, d.reroutes, d.reconnects
        );
    }
    println!(
        "  policies         : {}",
        PolicyKind::ALL
            .iter()
            .map(|p| p.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    match hpxmp::runtime::Registry::open("artifacts") {
        Ok(reg) => {
            println!("  artifacts        : {} loaded", reg.specs().len());
            for s in reg.specs() {
                println!("    - {} ({} {})", s.name, s.op, s.dtype);
            }
        }
        Err(e) => println!("  artifacts        : unavailable ({e})"),
    }
    Ok(())
}

fn cmd_conformance(args: &Args) -> anyhow::Result<()> {
    let workers = args.get_usize("workers", 4);
    let rt = OmpRuntime::new(workers, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(workers);
    let checks = conformance::run_all(&rt);
    print!("{}", conformance::render(&checks));
    if checks.iter().any(|c| !c.passed) {
        anyhow::bail!("conformance failures");
    }
    Ok(())
}

fn cmd_heatmap(args: &Args, mode: ExecMode) -> anyhow::Result<()> {
    let threads = args.get_usize_list("threads", &[1, 2, 4, 8, 12, 16]);
    let max_t = threads.iter().copied().max().unwrap_or(1);
    let (hpx, base) = build_runtimes(args, max_t)?;
    let cfg = bench_cfg(args);
    let out = args.get_or("out", "results");
    for op in ops_from(args)? {
        let sizes = args
            .get("sizes")
            .map(|_| args.get_usize_list("sizes", &[]))
            .unwrap_or_else(|| op.heatmap_sizes());
        let r = if mode == ExecMode::Task {
            // Task graphs parallelize over every AMT worker, so each
            // thread row needs its own exactly-t-worker runtime — one
            // shared max-width pool would make every row identical.
            let mut acc: Option<sweep::HeatmapResult> = None;
            for &t in &threads {
                let (hpx_t, base_t) = build_runtimes_with_workers(args, t, t)?;
                let hpol = policy_on(mode, &hpx_t, args);
                let bpol = policy_on(mode, &base_t, args);
                let row = sweep::heatmap_sweep(&hpol, &bpol, op, &[t], &sizes, &cfg, true);
                match &mut acc {
                    None => acc = Some(row),
                    Some(a) => {
                        a.threads.push(t);
                        a.ratio.extend(row.ratio);
                        a.hpx_mflops.extend(row.hpx_mflops);
                        a.base_mflops.extend(row.base_mflops);
                    }
                }
            }
            acc.expect("non-empty thread grid")
        } else {
            let hpol = policy_on(mode, &hpx, args);
            let bpol = policy_on(mode, &base, args);
            sweep::heatmap_sweep(&hpol, &bpol, op, &threads, &sizes, &cfg, true)
        };
        print!("{}", report::write_heatmap(out, &r)?);
    }
    Ok(())
}

fn cmd_scaling(args: &Args, mode: ExecMode) -> anyhow::Result<()> {
    let threads = args.get_usize_list("threads", &[4, 8, 16]);
    let max_t = threads.iter().copied().max().unwrap_or(1);
    let (hpx, base) = build_runtimes(args, max_t)?;
    let cfg = bench_cfg(args);
    let out = args.get_or("out", "results");
    for op in ops_from(args)? {
        let sizes = args
            .get("sizes")
            .map(|_| args.get_usize_list("sizes", &[]))
            .unwrap_or_else(|| op.scaling_sizes());
        for &t in &threads {
            // Same per-row sizing rule as cmd_heatmap for task mode.
            let r = if mode == ExecMode::Task {
                let (hpx_t, base_t) = build_runtimes_with_workers(args, t, t)?;
                let hpol = policy_on(mode, &hpx_t, args);
                let bpol = policy_on(mode, &base_t, args);
                sweep::scaling_sweep(&hpol, &bpol, op, t, &sizes, &cfg, true)
            } else {
                let hpol = policy_on(mode, &hpx, args);
                let bpol = policy_on(mode, &base, args);
                sweep::scaling_sweep(&hpol, &bpol, op, t, &sizes, &cfg, true)
            };
            print!("{}", report::write_scaling(out, &r)?);
        }
    }
    Ok(())
}

/// Fork-join vs futurized dataflow `dmatdmatmult` (ISSUE 2, now one
/// policy swap — ISSUE 5): the same product measured under
/// `par().on(&hpx)` (row bands) and `task().on(&hpx)` (the generic tiled
/// `when_all`/`then` graph), side by side.
///
/// The runtime is built with exactly `t` AMT workers per thread count —
/// the dataflow graph parallelizes over every worker, so a wider pool
/// would hand it cores the fork-join team was told not to use.
fn cmd_dataflow(args: &Args) -> anyhow::Result<()> {
    let threads = args.get_usize_list("threads", &[4]);
    let sizes = args.get_usize_list("sizes", &[150, 230, 300]);
    let tile = args.get_usize("tile", exec::DEFAULT_TILE);
    let cfg = bench_cfg(args);
    for &t in &threads {
        let rt = OmpRuntime::new(t, PolicyKind::PriorityLocal);
        rt.icv.set_nthreads(t);
        let hpx = HpxMpRuntime::new(rt);
        let fj_pol = exec::par().on(&hpx).threads(t);
        let df_pol = exec::task().on(&hpx).threads(t).tile(tile);
        for &n in &sizes {
            let fj = blazemark::measure(&fj_pol, Op::DMatDMatMult, n, &cfg);
            let df = blazemark::measure(&df_pol, Op::DMatDMatMult, n, &cfg);
            println!(
                "dmatdmatmult n={n:<4} threads={t:<2} fork-join {fj:>9.1} MFLOP/s | dataflow {df:>9.1} MFLOP/s | ratio {:.3}",
                df / fj
            );
        }
    }
    Ok(())
}

/// Multi-tenant serving (ISSUE 3): M concurrent client threads issue
/// streams of mixed Blaze kernels through the OpenMP layer, once on one
/// **shared** hpxMP runtime (the team pool + admission arbitrating) and
/// once with a private warm OS-thread **pool per client** (the competing-
/// threading-systems regime the paper's composition pitch argues against).
fn cmd_serve(args: &Args, mode: ExecMode) -> anyhow::Result<()> {
    use hpxmp::coordinator::serve::{serve_per_client, serve_shared, KernelMix, ServeCfg};
    if let Some(listen) = args.get("listen") {
        return cmd_serve_wire(args, listen);
    }
    let clients = args.get_usize("clients", 4);
    let threads = args.get_usize("threads", 2);
    let requests = args.get_usize("requests", if args.flag("quick") { 50 } else { 200 });
    let mix = KernelMix::parse_or_list(args.get_or("mix", "mixed"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let workers = args.get_usize("workers", icv::num_procs().max(threads));
    let policy = match args.get("policy") {
        Some(p) => PolicyKind::parse_or_list(p).map_err(|e| anyhow::anyhow!(e))?,
        None => PolicyKind::PriorityLocal,
    };

    let deadline_us = match args.get("deadline-us") {
        Some(s) => Some(
            s.parse::<u64>()
                .map_err(|e| anyhow::anyhow!("--deadline-us: {e}"))?,
        ),
        None => None,
    };

    let rt = OmpRuntime::new(workers, policy);
    rt.icv.set_nthreads(threads);
    let mut cfg = ServeCfg::new(clients, threads, requests, mix);
    cfg.mode = mode;
    cfg.deadline_us = deadline_us;
    cfg.shed = args.flag("shed");
    cfg.retries = args.get_usize("retries", 2);
    println!(
        "serve: {clients} clients x {requests} requests, {threads}-thread regions, \
         mix={}, exec={}, shared runtime has {workers} workers{}{}",
        mix.name(),
        mode.name(),
        match deadline_us {
            Some(d) => format!(", deadline {d} us"),
            None => String::new(),
        },
        if cfg.shed { ", shedding on" } else { "" }
    );
    let shared = serve_shared(&rt, &cfg);
    let per = serve_per_client(&cfg);
    println!(
        "{:<20} {:>12} {:>12} {:>12} {:>10} {:>8} {:>8} {:>8}",
        "runtime", "reqs/s", "p50 us", "p99 us", "goodput/s", "shed", "misses", "failed"
    );
    for s in [&shared, &per] {
        println!(
            "{:<20} {:>12.1} {:>12.1} {:>12.1} {:>10.1} {:>8} {:>8} {:>8}",
            s.runtime,
            s.reqs_per_sec,
            s.p50_us,
            s.p99_us,
            s.goodput_per_sec,
            s.shed,
            s.deadline_misses,
            s.failed_requests
        );
    }
    println!(
        "shared vs per-client throughput: {:.3}x  (team pool: {} hits / {} misses, {} parked)",
        shared.reqs_per_sec / per.reqs_per_sec,
        rt.pool_hits(),
        rt.pool_misses(),
        rt.pool_parked()
    );
    Ok(())
}

/// `hpxmp serve --listen <addr>` (ISSUE 9): the socket front-end.  Binds
/// the wire protocol on TCP (`host:port` / `tcp:host:port`) or a Unix
/// socket (`uds:/path`) and serves kernel requests through the
/// coalescing engine until `--duration` seconds elapse (0 = run until
/// killed), printing the wire counters once per second.
fn cmd_serve_wire(args: &Args, listen: &str) -> anyhow::Result<()> {
    use hpxmp::net::{BatchCfg, WireAddr, WireServer};
    if args.get_usize("shards", 0) > 0 {
        return cmd_serve_dist(args, listen);
    }
    let addr = WireAddr::parse(listen).map_err(|e| anyhow::anyhow!(e))?;
    let workers = args.get_usize("workers", icv::num_procs().max(2));
    let policy = match args.get("policy") {
        Some(p) => PolicyKind::parse_or_list(p).map_err(|e| anyhow::anyhow!(e))?,
        None => PolicyKind::PriorityLocal,
    };
    let rt = OmpRuntime::new(workers, policy);
    rt.icv.set_nthreads(workers);
    let dflt = BatchCfg::default();
    let cfg = BatchCfg {
        coalesce_us: args.get_usize("coalesce-us", dflt.coalesce_us as usize) as u64,
        max_batch: args.get_usize("max-batch", dflt.max_batch),
        max_pending: args.get_usize("max-pending", dflt.max_pending),
        default_deadline_us: args.get_usize("deadline-us", dflt.default_deadline_us as usize)
            as u32,
        ..dflt
    };
    let duration = args.get_usize("duration", 0);
    let server = WireServer::start(rt, &[addr.clone()], cfg)?;
    let bound = server
        .local_addr()
        .map(|a| format!("tcp:{a}"))
        .unwrap_or_else(|| addr.to_string());
    println!(
        "wire server on {bound}: {workers} workers, coalesce {} ({} us window, batch <= {}), \
         pending cap {}, {} server threads",
        if cfg.coalesce { "on" } else { "off (HPXMP_COALESCE=0)" },
        cfg.coalesce_us,
        cfg.max_batch,
        cfg.max_pending,
        server.thread_count()
    );
    let start = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        let s = server.stats();
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "t={:>4}s conns {} reqs {} ok {} shed {} expired {} misses {} errors {} \
             batches {} (max {}) pending {}",
            start.elapsed().as_secs(),
            s.accepted.load(Relaxed),
            s.requests.load(Relaxed),
            s.ok.load(Relaxed),
            s.shed.load(Relaxed),
            s.expired.load(Relaxed),
            s.deadline_misses.load(Relaxed),
            s.errors.load(Relaxed),
            s.batches.load(Relaxed),
            s.max_batch.load(Relaxed),
            s.pending()
        );
        if duration > 0 && start.elapsed().as_secs() >= duration as u64 {
            break;
        }
    }
    server.drain(std::time::Duration::from_secs(5));
    Ok(())
}

/// `hpxmp serve --listen <addr> --shards N` (ISSUE 10): the dist
/// front-end.  Spawns and supervises N `hpxmp worker` processes, binds
/// the same wire protocol, and routes decoded requests to the fleet by
/// request key with failover to survivors; replies are written by the
/// remote futures' completion hooks.
fn cmd_serve_dist(args: &Args, listen: &str) -> anyhow::Result<()> {
    use hpxmp::dist::{Router, ShardCfg, ShardPool};
    use hpxmp::net::{WireAddr, WireServer, WireStats};
    let addr = WireAddr::parse(listen).map_err(|e| anyhow::anyhow!(e))?;
    let shards = args.get_usize("shards", 2);
    let workers = args.get_usize("workers", icv::num_procs().max(2));
    let threads_per = (workers / shards).max(1);
    let max_pending = args.get_usize("max-pending", 1024);
    let mut cfg = ShardCfg::new(shards, threads_per)?;
    cfg.stall_us = args.get_usize("stall-us", 0) as u64;
    let mut pool = ShardPool::start(cfg)?;
    if !pool.wait_ready(std::time::Duration::from_secs(10)) {
        anyhow::bail!("dist: only {}/{} workers connected", pool.live(), shards);
    }
    let stats = Arc::new(WireStats::default());
    let router = Router::new(&pool, stats.clone(), max_pending);
    let server = WireServer::start_with(router, stats, &[addr.clone()])?;
    let bound = server
        .local_addr()
        .map(|a| format!("tcp:{a}"))
        .unwrap_or_else(|| addr.to_string());
    println!(
        "dist front-end on {bound}: {shards} worker processes x {threads_per} threads, \
         pending cap {max_pending}, {} server threads",
        server.thread_count()
    );
    let duration = args.get_usize("duration", 0);
    let start = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        let s = server.stats();
        let d = hpxmp::dist::stats();
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "t={:>4}s conns {} reqs {} ok {} shed {} errors {} pending {} | live {}/{} \
             routed {} remote-pending {} reroutes {} respawns {}",
            start.elapsed().as_secs(),
            s.accepted.load(Relaxed),
            s.requests.load(Relaxed),
            s.ok.load(Relaxed),
            s.shed.load(Relaxed),
            s.errors.load(Relaxed),
            s.pending(),
            pool.live(),
            shards,
            report::render_counts(&pool.routed_per_shard()),
            pool.pending_remote(),
            d.reroutes,
            d.reconnects
        );
        if duration > 0 && start.elapsed().as_secs() >= duration as u64 {
            break;
        }
    }
    server.drain(std::time::Duration::from_secs(5));
    drop(server);
    pool.shutdown();
    Ok(())
}

/// `hpxmp worker` (ISSUE 10): one dist worker process.  Spawned by the
/// coordinator (`serve --shards` / `dist-mmult`); dials `--connect`,
/// serves submits on its own AMT runtime, exits on shutdown or when the
/// coordinator goes away.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    use hpxmp::dist::{run_worker, WorkerCfg};
    use hpxmp::net::WireAddr;
    let connect = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("worker requires --connect <addr>"))?;
    let cfg = WorkerCfg {
        connect: WireAddr::parse(connect).map_err(|e| anyhow::anyhow!("--connect: {e}"))?,
        threads: args.get_usize("threads", 2),
        slot: args.get_usize("slot", 0) as u32,
        stall_us: args.get_usize("stall-us", 0) as u64,
    };
    run_worker(&cfg)?;
    Ok(())
}

/// `hpxmp dist-mmult` (ISSUE 10): distributed `C = A · B` across a
/// worker fleet, checked bitwise against the single-process packed
/// oracle.
fn cmd_dist_mmult(args: &Args) -> anyhow::Result<()> {
    use hpxmp::blaze::{kernel, DynMatrix};
    use hpxmp::dist::{dist_matmul, ShardCfg, ShardPool};
    let shards = args.get_usize("shards", 2);
    let n = args.get_usize("size", 256);
    let seed = args.get_usize("seed", 0x5eed) as u64;
    let workers = args.get_usize("workers", icv::num_procs().max(2));
    let threads_per = (workers / shards).max(1);
    let mut pool = ShardPool::start(ShardCfg::new(shards, threads_per)?)?;
    if !pool.wait_ready(std::time::Duration::from_secs(10)) {
        anyhow::bail!("dist: only {}/{} workers connected", pool.live(), shards);
    }
    let a = DynMatrix::random(n, n, seed);
    let b = DynMatrix::random(n, n, seed ^ 0x9E37_79B9);
    let t0 = std::time::Instant::now();
    let c = dist_matmul(&pool, a.as_slice(), b.as_slice(), n).map_err(|e| anyhow::anyhow!(e))?;
    let dist_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = std::time::Instant::now();
    let mut oracle = vec![0.0f64; n * n];
    kernel::packed_matmul(a.as_slice(), b.as_slice(), n, n, n, &mut oracle);
    let oracle_ms = t1.elapsed().as_secs_f64() * 1e3;
    let bitwise = c
        .iter()
        .zip(&oracle)
        .all(|(x, y)| x.to_bits() == y.to_bits());
    println!(
        "dist-mmult n={n} over {shards} workers x {threads_per} threads: {dist_ms:.1} ms \
         (single-process packed oracle {oracle_ms:.1} ms), bitwise {}",
        if bitwise { "IDENTICAL" } else { "MISMATCH" }
    );
    pool.shutdown();
    anyhow::ensure!(bitwise, "distributed product differs from the oracle");
    Ok(())
}

/// `hpxmp loadgen` (ISSUE 9): the seeded open-loop generator against a
/// running wire server — `--addr`, `--rate` total req/s across
/// `--conns` connections, `--dist poisson|uniform`.
fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    use hpxmp::net::{run_loadgen, Dist, LoadgenCfg, WireAddr, WireOp};
    let addr = WireAddr::parse(args.get_or("addr", "127.0.0.1:7070"))
        .map_err(|e| anyhow::anyhow!("--addr: {e}"))?;
    let op = hpxmp::util::cli::parse_choice("op", args.get_or("op", "daxpy"), WireOp::CHOICES)
        .map_err(|e| anyhow::anyhow!(e))?;
    let cfg = LoadgenCfg {
        addr,
        op,
        n: args.get_usize("size", hpxmp::net::default_wire_n(op) as usize) as u32,
        rate: args.get_usize("rate", 1000) as f64,
        conns: args.get_usize("conns", 4),
        dist: Dist::parse(args.get_or("dist", "poisson")).map_err(|e| anyhow::anyhow!(e))?,
        duration: std::time::Duration::from_secs(args.get_usize("duration", 5) as u64),
        deadline_us: args.get_usize("deadline-us", 0) as u32,
        seed: args.get_usize("seed", 0x5eed) as u64,
    };
    println!(
        "loadgen: {} {} n={} rate {}/s over {} conns ({:?}), {}s{}",
        cfg.addr,
        args.get_or("op", "daxpy"),
        cfg.n,
        cfg.rate,
        cfg.conns,
        cfg.dist,
        cfg.duration.as_secs(),
        if cfg.deadline_us > 0 {
            format!(", deadline {} us", cfg.deadline_us)
        } else {
            String::new()
        }
    );
    let rep = run_loadgen(&cfg)?;
    println!(
        "sent {}  completed {}  {:.1} req/s  goodput {:.1}/s  p50 {:.0} us  p99 {:.0} us  \
         shed {}  misses {}  failed {}  lost {}",
        rep.sent,
        rep.stats.completed(),
        rep.reqs_per_sec(),
        rep.goodput_per_sec(),
        rep.stats.p50_us(),
        rep.stats.p99_us(),
        rep.stats.shed,
        rep.stats.deadline_misses,
        rep.stats.failed,
        rep.lost
    );
    Ok(())
}

fn cmd_offload(args: &Args) -> anyhow::Result<()> {
    use hpxmp::runtime::{Registry, XlaOffload};
    let reg = Arc::new(Registry::open("artifacts")?);
    let off = XlaOffload::new(reg);
    let n = args.get_usize("size", 65_536 * 2 + 1000); // 2 chunks + tail
    let mut a = vec![0.0f64; n];
    let mut b = vec![0.0f64; n];
    for i in 0..n {
        a[i] = (i % 97) as f64 * 0.01;
        b[i] = (i % 31) as f64 * 0.1;
    }
    let expect: Vec<f64> = a.iter().zip(&b).map(|(x, y)| y + 3.0 * x).collect();
    let chunks = off.daxpy_full_f64(3.0, &a, &mut b)?;
    let max_err = b
        .iter()
        .zip(&expect)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    println!("offload daxpy n={n}: {chunks} PJRT chunks + native tail, max_err={max_err:e}");
    anyhow::ensure!(max_err < 1e-12, "offload numerics mismatch");
    println!("offload OK");
    Ok(())
}

fn cmd_policies(args: &Args) -> anyhow::Result<()> {
    use hpxmp::amt::{task::Hint, Priority, Scheduler};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Instant;
    let tasks = args.get_usize("tasks", 100_000);
    let workers = args.get_usize("workers", icv::num_procs().max(2));
    println!("policy ablation: {tasks} empty tasks on {workers} workers");
    for policy in PolicyKind::ALL {
        let s = Scheduler::new(workers, policy);
        let done = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        for i in 0..tasks {
            let d = done.clone();
            s.spawn(Priority::Normal, Hint::Worker(i), "bench", move || {
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        s.wait_quiescent();
        let dt = t0.elapsed();
        let m = s.metrics();
        println!(
            "  {:<18} {:>8.1} ktasks/s   (steals {}/{} moving {} tasks, {} inlined, parked={})",
            policy.name(),
            tasks as f64 / dt.as_secs_f64() / 1e3,
            m.steals_success,
            m.steals_attempted,
            m.steal_batch_tasks,
            m.continuations_inlined,
            m.parked
        );
        s.shutdown();
    }
    Ok(())
}

/// Task Bench dependency-pattern grid (ISSUE 8): METG-style per-task
/// overhead of future graphs under the scheduler fast paths.  The tuning
/// arm comes from the environment (`HPXMP_STEAL_ONE` / `HPXMP_INLINE_CONT`)
/// so the ablation is a one-variable rerun; the `ablation_taskbench`
/// bench runs both arms in-process and emits JSON.
fn cmd_taskbench(args: &Args) -> anyhow::Result<()> {
    use hpxmp::amt::Tuning;
    use hpxmp::coordinator::taskbench::{render, sweep, Pattern, SweepCfg};
    let patterns = match args.get_or("pattern", "all") {
        "all" => Pattern::ALL.to_vec(),
        s => vec![Pattern::parse_or_list(s).map_err(|e| anyhow::anyhow!(e))?],
    };
    let policies = match args.get("policy") {
        Some(p) => vec![PolicyKind::parse_or_list(p).map_err(|e| anyhow::anyhow!(e))?],
        None => vec![PolicyKind::PriorityLocal, PolicyKind::Abp, PolicyKind::Local],
    };
    let threads = args.get_usize_list("threads", &[icv::num_procs().max(2)]);
    let tuning = Tuning::from_env();
    let mode = if tuning.steal_batch > 1 { "steal-half" } else { "steal-one" };
    let cfg = SweepCfg {
        patterns,
        policies,
        threads,
        grains_us: args
            .get_usize_list("grain-us", &[0, 20])
            .into_iter()
            .map(|g| g as u64)
            .collect(),
        width: args.get_usize("width", 64),
        steps: args.get_usize("steps", 32),
        reps: if args.flag("quick") { 2 } else { 5 },
        tunings: vec![(mode, tuning)],
        metg: args.flag("metg"),
    };
    println!(
        "taskbench: {} x {} grid, tuning {mode} (steal_batch={}, inline_cont={})",
        cfg.width, cfg.steps, tuning.steal_batch, tuning.inline_cont
    );
    print!("{}", render(&sweep(&cfg)));
    Ok(())
}
