//! The unit of scheduling: an HPX-thread analog.
//!
//! In hpxMP every OpenMP implicit or explicit task becomes one HPX thread
//! (`hpx::applier::register_thread_nullary`, paper Listings 3 & 5), tagged
//! with a priority (`thread_priority_low` for implicit team threads,
//! normal for explicit tasks).  Our [`Task`] carries the same information.

use std::sync::atomic::{AtomicU64, Ordering};

use super::arena::Payload;
use super::cancel::CancelToken;

/// Scheduling priority, mirroring `hpx::threads::thread_priority_*`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    Normal,
    High,
}

/// Placement hint given at spawn time, mirroring the `os_thread` hint HPX's
/// `register_thread_nullary` accepts (Listing 3 passes the loop index `i`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hint {
    /// No preference: the policy decides (round-robin or submitter-local).
    Any,
    /// Prefer the queue of worker `w` (wraps modulo worker count).
    Worker(usize),
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A schedulable task: an owned closure plus scheduling metadata.
pub struct Task {
    pub id: u64,
    pub priority: Priority,
    /// Description shown by metrics/tracing ("omp_implicit_task", ...).
    pub desc: &'static str,
    /// Cancellation scope, if any: checked by the worker at dispatch — a
    /// cancelled task's body is dropped unrun (ISSUE 6).  Bodies whose
    /// side effects others wait on must release them from `Drop` guards,
    /// not from the closure tail.
    pub cancel: Option<CancelToken>,
    f: Payload,
}

impl Task {
    pub fn new(
        priority: Priority,
        desc: &'static str,
        f: impl FnOnce() + Send + 'static,
    ) -> Self {
        Self {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            priority,
            desc,
            cancel: None,
            f: Payload::new(f),
        }
    }

    /// Attach a cancellation scope (builder-style).
    pub fn with_cancel(mut self, token: CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Whether the task's cancellation scope (if any) has fired.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|t| t.is_cancelled())
    }

    /// Build from an already-boxed body — the batch-spawn path hands over
    /// pre-boxed closures, and re-boxing a `Box<dyn FnOnce>` through
    /// [`Task::new`] would pay a second allocation per task.
    pub fn from_boxed(
        priority: Priority,
        desc: &'static str,
        f: Box<dyn FnOnce() + Send + 'static>,
    ) -> Self {
        Self::from_payload(priority, desc, Payload::Boxed(f))
    }

    /// Build from a pre-wrapped [`Payload`] — the arena-aware bulk-spawn
    /// path (ISSUE 7) constructs payloads at chunk-closure creation so
    /// the spawn path allocates from the worker arena, not malloc.
    pub fn from_payload(priority: Priority, desc: &'static str, f: Payload) -> Self {
        Self {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            priority,
            desc,
            cancel: None,
            f,
        }
    }

    /// Consume and execute the task body.
    pub fn run(self) {
        self.f.invoke()
    }
}

impl std::fmt::Debug for Task {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Task")
            .field("id", &self.id)
            .field("priority", &self.priority)
            .field("desc", &self.desc)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn task_ids_are_unique_and_increasing() {
        let a = Task::new(Priority::Normal, "a", || {});
        let b = Task::new(Priority::Normal, "b", || {});
        assert!(b.id > a.id);
    }

    #[test]
    fn run_executes_closure_once() {
        let n = Arc::new(AtomicUsize::new(0));
        let n2 = n.clone();
        let t = Task::new(Priority::High, "inc", move || {
            n2.fetch_add(1, Ordering::SeqCst);
        });
        t.run();
        assert_eq!(n.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn priority_ordering() {
        assert!(Priority::High > Priority::Normal);
        assert!(Priority::Normal > Priority::Low);
    }
}
