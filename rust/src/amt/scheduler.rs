//! The scheduler: worker pool + policy queues + spawn/quiesce/shutdown.
//!
//! This is the "HPX runtime" of the reproduction: `Scheduler::spawn` is our
//! `hpx::applier::register_thread_nullary` (paper Listing 3), taking a
//! priority, a placement hint and a description.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::metrics::{Metrics, MetricsSnapshot};
use super::policy::{PolicyKind, Queues};
use super::task::{Hint, Priority, Task};
use super::worker;

/// State shared by all workers of one scheduler instance.
pub struct Shared {
    pub(super) queues: Box<dyn Queues>,
    /// Tasks spawned but not yet retired (queued + running).
    pub(super) live: AtomicUsize,
    pub(super) shutdown: AtomicBool,
    pub(super) idle_lock: Mutex<()>,
    pub(super) idle_cv: Condvar,
    pub(super) sleepers: AtomicUsize,
    pub(super) metrics: Metrics,
    pub(super) panics: AtomicU64,
    /// Rotating cursor behind [`Scheduler::hint_base`]: spreads the
    /// placement hints of concurrent submitters (e.g. many fork/join
    /// clients on one scheduler) across distinct worker queues.
    hint_cursor: AtomicUsize,
    policy: PolicyKind,
}

/// An AMT scheduler instance: `n` OS workers multiplexing tasks under a
/// [`PolicyKind`].
pub struct Scheduler {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Scheduler {
    pub fn new(workers: usize, policy: PolicyKind) -> Arc<Self> {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queues: policy.build(workers),
            live: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            idle_lock: Mutex::new(()),
            idle_cv: Condvar::new(),
            sleepers: AtomicUsize::new(0),
            metrics: Metrics::default(),
            panics: AtomicU64::new(0),
            hint_cursor: AtomicUsize::new(0),
            policy,
        });
        let handles = (0..workers)
            .map(|i| {
                let s = shared.clone();
                std::thread::Builder::new()
                    .name(format!("hpx-worker-{i}"))
                    .spawn(move || worker::worker_loop(s, i))
                    .expect("spawn worker")
            })
            .collect();
        Arc::new(Self {
            shared,
            handles: Mutex::new(handles),
        })
    }

    pub fn policy(&self) -> PolicyKind {
        self.shared.policy
    }

    pub fn workers(&self) -> usize {
        self.shared.queues.workers()
    }

    /// Claim a placement-hint base for a batch of `span` related tasks:
    /// successive claims advance a rotating cursor, so K concurrent
    /// submitters (fork/join clients, dataflow producers) get
    /// *interleaved* worker-queue hints instead of all pinning their
    /// batches onto workers `0..span` — the hint-distribution half of
    /// multi-tenant fair-share (DESIGN.md §8).  The caller hints task `i`
    /// of the batch to worker `(base + i) % workers`.
    pub fn hint_base(&self, span: usize) -> usize {
        if span == 0 {
            return 0;
        }
        self.shared.hint_cursor.fetch_add(span, Ordering::Relaxed) % self.workers()
    }

    /// Register a task — `hpx::applier::register_thread_nullary` analog.
    pub fn spawn(
        &self,
        priority: Priority,
        hint: Hint,
        desc: &'static str,
        f: impl FnOnce() + Send + 'static,
    ) {
        let task = Task::new(priority, desc, f);
        // AcqRel: the Release half pairs with `wait_quiescent`'s Acquire
        // load (a quiescence observer must see the increment before any
        // effect of the task), the Acquire half orders against prior
        // retirements.  A plain Acquire RMW published nothing.
        self.shared.live.fetch_add(1, Ordering::AcqRel);
        Metrics::inc(&self.shared.metrics.spawned);
        let submitter = worker::current().and_then(|(s, w)| {
            if Arc::ptr_eq(&s, &self.shared) {
                Some(w)
            } else {
                None
            }
        });
        self.shared.queues.push(task, hint, submitter);
        self.wake_n(1);
    }

    /// Register a whole team of tasks in one pass — the fork fast path
    /// (paper §5.1: one `register_thread_nullary` per OpenMP thread, but a
    /// naive loop over [`Scheduler::spawn`] pays one `live` update and one
    /// idle-lock acquisition *per task*).  Here: one `live` update, one
    /// queue pass, and one wake covering `min(batch, sleepers)` workers
    /// under a single lock acquisition.
    pub fn spawn_batch(
        &self,
        priority: Priority,
        desc: &'static str,
        bodies: Vec<(Hint, Box<dyn FnOnce() + Send + 'static>)>,
    ) {
        let n = bodies.len();
        if n == 0 {
            return;
        }
        // AcqRel for the same `wait_quiescent` pairing as `spawn`.
        self.shared.live.fetch_add(n, Ordering::AcqRel);
        Metrics::add(&self.shared.metrics.spawned, n as u64);
        let submitter = worker::current().and_then(|(s, w)| {
            if Arc::ptr_eq(&s, &self.shared) {
                Some(w)
            } else {
                None
            }
        });
        for (hint, f) in bodies {
            self.shared
                .queues
                .push(Task::from_boxed(priority, desc, f), hint, submitter);
        }
        // A submitting worker reaches its next scheduling point immediately
        // after this call (fork masters help-wait on the join), so it will
        // run one of the batch itself: only the rest need wake-ups.  The
        // wake request is clamped to the worker count: under concurrent
        // spawn_batch callers each batch may only claim as many wake-ups
        // as there are workers to wake, keeping the notify loop bounded
        // and the idle-lock hold time fair across clients.
        let wakes = if submitter.is_some() { n - 1 } else { n };
        self.wake_n(wakes.min(self.workers()));
    }

    /// Notify up to `n` sleeping workers under one idle-lock acquisition;
    /// skips the lock entirely when nobody sleeps (the hot-path case for
    /// back-to-back fork/join regions that keep workers spinning).
    fn wake_n(&self, n: usize) {
        if n == 0 || self.shared.sleepers.load(Ordering::SeqCst) == 0 {
            return;
        }
        let _g = self.shared.idle_lock.lock().unwrap();
        let sleeping = self.shared.sleepers.load(Ordering::SeqCst);
        if n >= sleeping {
            self.shared.idle_cv.notify_all();
        } else {
            for _ in 0..n {
                self.shared.idle_cv.notify_one();
            }
        }
    }

    /// Block the *calling* (non-worker) thread until all spawned tasks have
    /// retired.  Worker threads must use `worker::help_one` loops instead.
    pub fn wait_quiescent(&self) {
        let mut spins = 0u32;
        while self.shared.live.load(Ordering::Acquire) != 0 {
            // If we're a worker of this scheduler, help instead of idling.
            if !worker::help_one() {
                spins += 1;
                if spins < 100 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
            } else {
                spins = 0;
            }
        }
    }

    /// Number of tasks not yet retired.
    pub fn live_tasks(&self) -> usize {
        self.shared.live.load(Ordering::Acquire)
    }

    /// Panics observed inside tasks (isolated, not propagated).
    pub fn task_panics(&self) -> u64 {
        self.shared.panics.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting progress and join all workers.  Pending tasks are
    /// drained before shutdown completes (quiesce-then-stop).
    pub fn shutdown(&self) {
        self.wait_quiescent();
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _g = self.shared.idle_lock.lock().unwrap();
            self.shared.idle_cv.notify_all();
        }
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as AU;

    #[test]
    fn spawn_and_quiesce_runs_everything() {
        for policy in PolicyKind::ALL {
            let s = Scheduler::new(2, policy);
            let c = Arc::new(AU::new(0));
            for _ in 0..200 {
                let c = c.clone();
                s.spawn(Priority::Normal, Hint::Any, "t", move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            s.wait_quiescent();
            assert_eq!(c.load(Ordering::SeqCst), 200, "policy {}", policy.name());
            s.shutdown();
        }
    }

    #[test]
    fn spawn_batch_runs_everything_under_every_policy() {
        for policy in PolicyKind::ALL {
            let s = Scheduler::new(2, policy);
            let c = Arc::new(AU::new(0));
            let bodies: Vec<(Hint, Box<dyn FnOnce() + Send>)> = (0..64)
                .map(|i| {
                    let c = c.clone();
                    let body: Box<dyn FnOnce() + Send> = Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                    (Hint::Worker(i % 2), body)
                })
                .collect();
            s.spawn_batch(Priority::Low, "batch", bodies);
            s.wait_quiescent();
            assert_eq!(c.load(Ordering::SeqCst), 64, "policy {}", policy.name());
            let m = s.metrics();
            assert_eq!(m.spawned, 64);
            assert_eq!(m.executed, 64);
            s.shutdown();
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        s.spawn_batch(Priority::Normal, "none", Vec::new());
        assert_eq!(s.live_tasks(), 0);
        s.shutdown();
    }

    #[test]
    fn tasks_can_spawn_tasks() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let c = Arc::new(AU::new(0));
        {
            let s2 = Arc::downgrade(&s);
            let c = c.clone();
            s.spawn(Priority::Normal, Hint::Any, "parent", move || {
                let s = s2.upgrade().unwrap();
                for _ in 0..10 {
                    let c = c.clone();
                    s.spawn(Priority::Normal, Hint::Any, "child", move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }
        s.wait_quiescent();
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panicking_task_is_isolated() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        s.spawn(Priority::Normal, Hint::Any, "boom", || panic!("boom"));
        let c = Arc::new(AU::new(0));
        let c2 = c.clone();
        s.spawn(Priority::Normal, Hint::Any, "after", move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        s.wait_quiescent();
        assert_eq!(s.task_panics(), 1);
        assert_eq!(c.load(Ordering::SeqCst), 1, "worker survived the panic");
    }

    #[test]
    fn metrics_count_spawned_and_executed() {
        let s = Scheduler::new(2, PolicyKind::Abp);
        for _ in 0..50 {
            s.spawn(Priority::Normal, Hint::Any, "t", || {});
        }
        s.wait_quiescent();
        let m = s.metrics();
        assert_eq!(m.spawned, 50);
        assert_eq!(m.executed, 50);
    }

    #[test]
    fn shutdown_is_idempotent() {
        let s = Scheduler::new(2, PolicyKind::Global);
        s.spawn(Priority::Normal, Hint::Any, "t", || {});
        s.shutdown();
        s.shutdown();
    }

    #[test]
    fn hint_base_interleaves_consecutive_batches() {
        let s = Scheduler::new(4, PolicyKind::PriorityLocal);
        let a = s.hint_base(3);
        let b = s.hint_base(3);
        assert!(a < 4 && b < 4);
        assert_ne!(a, b, "consecutive batches must start on different queues");
        assert_eq!(s.hint_base(0), 0, "empty batch claims no cursor space");
        s.shutdown();
    }

    #[test]
    fn worker_hint_lands_on_requested_queue_for_static() {
        // With static-priority (no stealing), a Worker(i) hint pins work.
        let s = Scheduler::new(4, PolicyKind::StaticPriority);
        let hits = Arc::new(AU::new(0));
        for i in 0..4 {
            let hits = hits.clone();
            s.spawn(Priority::Normal, Hint::Worker(i), "pinned", move || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        s.wait_quiescent();
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }
}
