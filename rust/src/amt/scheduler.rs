//! The scheduler: worker pool + policy queues + spawn/quiesce/shutdown.
//!
//! This is the "HPX runtime" of the reproduction: `Scheduler::spawn` is our
//! `hpx::applier::register_thread_nullary` (paper Listing 3), taking a
//! priority, a placement hint and a description.
//!
//! Since ISSUE 4 the idle system is the per-worker parking substrate of
//! [`super::park`]: spawns issue **targeted wakes** — first the worker
//! whose queue the placement hint put the task on, else any sleeper popped
//! from the lock-free [`IdleSet`] — instead of funneling every wake-up
//! through one global mutex/condvar.  `HPXMP_GLOBAL_IDLE=1` selects the
//! old global-condvar design ([`GlobalIdle`]) so `benches/ablation_wake.rs`
//! can measure the difference.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::arena::Payload;
use super::cancel::CancelToken;
use super::metrics::{Metrics, MetricsSnapshot};
use super::park::{GlobalIdle, IdleMode, IdleSet, Parker, WakeList};
use super::policy::{PolicyKind, Queues};
use super::task::{Hint, Priority, Task};
use super::worker;
use super::worker::Tick;

/// How long an idle worker sleeps per park before re-scanning the queues.
/// Wakes are explicit (targeted unpark / condvar notify); this timeout is
/// only the self-heal bound for protocol races, so it can be generous
/// without costing wake latency.
const WORKER_PARK_TIMEOUT: Duration = Duration::from_micros(500);

/// Scheduler fast-path knobs (ISSUE 8), captured once at construction —
/// the same env-kill ablation idiom as `HPXMP_HOT_TEAM`/`HPXMP_GLOBAL_IDLE`:
/// `HPXMP_STEAL_ONE=1` reverts to classic one-task steals,
/// `HPXMP_INLINE_CONT=0` disables continuation inlining.  Benches and tests
/// override in-process via [`Scheduler::with_tuning`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tuning {
    /// Max tasks one steal visit may claim (steal-half batching;
    /// 1 = classic single steal).
    pub steal_batch: usize,
    /// Run ready continuations inline on the fulfilling worker (bounded
    /// by [`MAX_INLINE_DEPTH`]) instead of requeueing through `spawn`.
    pub inline_cont: bool,
}

/// Inline-continuation depth bound: past this many nested `set_value` →
/// run-continuation frames on one worker stack, continuations fall back to
/// `Scheduler::spawn` (restarting at depth 0 on a fresh task).  Bounds both
/// stack growth (a 10k-link `then` chain must not overflow) and the time
/// one worker monopolizes a chain before other workers can steal into it.
pub const MAX_INLINE_DEPTH: usize = 16;

impl Tuning {
    /// Default steal-batch bound.  `steal_batch` caps what the half-claim
    /// may take in one visit, so a single thief cannot drain a very deep
    /// victim wholesale (fairness toward other thieves).
    pub const STEAL_BATCH_MAX: usize = 32;

    pub fn from_env() -> Self {
        Self {
            steal_batch: if env_flag("HPXMP_STEAL_ONE", false) {
                1
            } else {
                Self::STEAL_BATCH_MAX
            },
            inline_cont: env_flag("HPXMP_INLINE_CONT", true),
        }
    }
}

impl Default for Tuning {
    fn default() -> Self {
        Self {
            steal_batch: Self::STEAL_BATCH_MAX,
            inline_cont: true,
        }
    }
}

/// `"0" | "false" | "off" | "no"` (or unset ⇒ `default`) — the shared
/// boolean-env convention (`hot_team_from_env` et al.).
fn env_flag(name: &str, default: bool) -> bool {
    match std::env::var(name) {
        Ok(v) => !matches!(v.trim(), "0" | "false" | "off" | "no"),
        Err(_) => default,
    }
}

/// The idle substrate of one scheduler instance (DESIGN.md §9).
pub(super) enum IdleBackend {
    /// Per-worker parkers + lock-free idle set: targeted wakes.
    PerWorker { parkers: Vec<Arc<Parker>>, idle: IdleSet },
    /// One global mutex/condvar all workers share — the pre-ISSUE-4
    /// design, kept for the `HPXMP_GLOBAL_IDLE=1` ablation.
    Global(GlobalIdle),
}

/// State shared by all workers of one scheduler instance.
pub struct Shared {
    pub(super) queues: Box<dyn Queues>,
    /// Tasks spawned but not yet retired (queued + running).
    pub(super) live: AtomicUsize,
    pub(super) shutdown: AtomicBool,
    pub(super) idle: IdleBackend,
    /// Parked waiters to notify when `live` drains to zero
    /// (`wait_quiescent`/`shutdown` — the replacement for their old
    /// 50µs sleep-poll loop).
    pub(super) quiesce: WakeList,
    pub(super) metrics: Metrics,
    pub(super) panics: AtomicU64,
    /// Rotating cursor behind [`Scheduler::hint_base`]: spreads the
    /// placement hints of concurrent submitters (e.g. many fork/join
    /// clients on one scheduler) across distinct worker queues.
    hint_cursor: AtomicUsize,
    policy: PolicyKind,
    pub(super) tuning: Tuning,
}

impl Shared {
    /// Worker `w`'s parker, when the targeted substrate is active.
    pub(super) fn worker_parker(&self, w: usize) -> Option<Arc<Parker>> {
        match &self.idle {
            IdleBackend::PerWorker { parkers, .. } => Some(parkers[w].clone()),
            IdleBackend::Global(_) => None,
        }
    }

    /// Park idle worker `me` from its main loop: announce in the idle set,
    /// re-check the queues (the lost-wake dichotomy — see `IdleSet` docs:
    /// either a submitter sees our bit or we see its task), then sleep.
    pub(super) fn worker_park(&self, me: usize) {
        match &self.idle {
            IdleBackend::PerWorker { parkers, idle } => {
                idle.announce(me);
                if self.queues.approx_len() != 0 || self.shutdown.load(Ordering::Acquire) {
                    idle.retract(me);
                    return;
                }
                parkers[me].park_timeout(WORKER_PARK_TIMEOUT);
                // Harmless if a waker already claimed (cleared) our bit.
                idle.retract(me);
            }
            IdleBackend::Global(g) => {
                g.park(
                    || self.queues.approx_len() == 0 && !self.shutdown.load(Ordering::Acquire),
                    WORKER_PARK_TIMEOUT,
                );
            }
        }
    }

    /// Park worker `me` from *inside a blocking construct* (`WaitState`
    /// escalation).  With `announce`, the waiter advertises itself in the
    /// idle set so targeted wakes treat it as a schedulable core — it will
    /// help-run whatever it is woken for.  A requeue-backoff waiter (the
    /// §4 nesting guard fired) must pass `announce = false`: it cannot run
    /// the task it just requeued, and claiming wake credits for it would
    /// starve the workers that can.
    pub(super) fn waiter_park(&self, me: usize, timeout: Duration, announce: bool) {
        match &self.idle {
            IdleBackend::PerWorker { parkers, idle } => {
                if announce {
                    idle.announce(me);
                    if self.shutdown.load(Ordering::Acquire) {
                        idle.retract(me);
                        return;
                    }
                    // Queue re-check after announcing (the lost-wake
                    // dichotomy).  Occupied queues don't cancel the park —
                    // the pending work is either freshly pushed to *our*
                    // queue (its targeted wake cuts the nap short; we are
                    // announced) or unstealable under the active policy
                    // (nothing we can do but get out of the way) — they
                    // only shorten it, so the wait loop cannot spin hot on
                    // this re-check (it has no yield rung left).
                    let t = if self.queues.approx_len() != 0 {
                        timeout.min(Duration::from_micros(20))
                    } else {
                        timeout
                    };
                    parkers[me].park_timeout(t);
                    idle.retract(me);
                } else {
                    parkers[me].park_timeout(timeout);
                }
            }
            // Global fallback: blind timed nap, like the old 20µs
            // sleep-wait rung but latched-wake capable.
            IdleBackend::Global(_) => {
                super::park::thread_parker().park_timeout(timeout);
            }
        }
    }

    /// Wake up to `want` workers for freshly pushed tasks.  `preferred`
    /// lists the workers whose queues received the tasks (in push order):
    /// each is claimed from the idle set if asleep — the targeted-wake
    /// fast path — and the remainder of the budget falls back to popping
    /// arbitrary sleepers.  No global lock anywhere; concurrent wakers
    /// contend only on CAS-claiming individual idle bits.
    pub(super) fn wake_workers<I>(&self, preferred: I, want: usize)
    where
        I: IntoIterator<Item = usize>,
    {
        if want == 0 {
            return;
        }
        match &self.idle {
            IdleBackend::PerWorker { parkers, idle } => {
                let mut woken = 0usize;
                for w in preferred {
                    if woken == want {
                        return;
                    }
                    if idle.take(w) {
                        parkers[w].unpark();
                        Metrics::inc(&self.metrics.wakes_targeted);
                        woken += 1;
                    }
                }
                while woken < want {
                    match idle.pop_any() {
                        Some(v) => {
                            parkers[v].unpark();
                            Metrics::inc(&self.metrics.wakes_any);
                            woken += 1;
                        }
                        None => return,
                    }
                }
            }
            IdleBackend::Global(g) => g.wake(want),
        }
    }

    /// Wake every worker unconditionally (shutdown).
    pub(super) fn wake_all_workers(&self) {
        match &self.idle {
            IdleBackend::PerWorker { parkers, .. } => {
                for p in parkers {
                    p.unpark();
                }
            }
            IdleBackend::Global(g) => g.wake_all(),
        }
    }
}

/// An AMT scheduler instance: `n` OS workers multiplexing tasks under a
/// [`PolicyKind`].
pub struct Scheduler {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Scheduler {
    pub fn new(workers: usize, policy: PolicyKind) -> Arc<Self> {
        Self::with_config(workers, policy, IdleMode::from_env(), Tuning::from_env())
    }

    /// Build with an explicit idle substrate (tests/benches; [`Self::new`]
    /// reads `HPXMP_GLOBAL_IDLE`).
    pub fn with_idle_mode(workers: usize, policy: PolicyKind, mode: IdleMode) -> Arc<Self> {
        Self::with_config(workers, policy, mode, Tuning::from_env())
    }

    /// Build with explicit steal/inline knobs — the in-process ablation
    /// hook `benches/ablation_taskbench.rs` pairs configs through
    /// (env kills only bind at process start; a bench comparing both
    /// behaviors needs per-instance control).
    pub fn with_tuning(workers: usize, policy: PolicyKind, tuning: Tuning) -> Arc<Self> {
        Self::with_config(workers, policy, IdleMode::from_env(), tuning)
    }

    /// The one real constructor.
    pub fn with_config(
        workers: usize,
        policy: PolicyKind,
        mode: IdleMode,
        tuning: Tuning,
    ) -> Arc<Self> {
        let workers = workers.max(1);
        let tuning = Tuning {
            steal_batch: tuning.steal_batch.max(1),
            ..tuning
        };
        let idle = match mode {
            IdleMode::Targeted => IdleBackend::PerWorker {
                parkers: (0..workers).map(|_| Arc::new(Parker::new())).collect(),
                idle: IdleSet::new(workers),
            },
            IdleMode::Global => IdleBackend::Global(GlobalIdle::new()),
        };
        let shared = Arc::new(Shared {
            queues: policy.build(workers),
            live: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            idle,
            quiesce: WakeList::new(),
            metrics: Metrics::default(),
            panics: AtomicU64::new(0),
            hint_cursor: AtomicUsize::new(0),
            policy,
            tuning,
        });
        let handles = (0..workers)
            .map(|i| {
                let s = shared.clone();
                std::thread::Builder::new()
                    .name(format!("hpx-worker-{i}"))
                    .spawn(move || worker::worker_loop(s, i))
                    .expect("spawn worker")
            })
            .collect();
        Arc::new(Self {
            shared,
            handles: Mutex::new(handles),
        })
    }

    pub fn policy(&self) -> PolicyKind {
        self.shared.policy
    }

    /// The steal/inline knobs this instance runs with.
    pub fn tuning(&self) -> Tuning {
        self.shared.tuning
    }

    /// True when the calling thread is a worker of *this* scheduler.
    pub fn on_worker(&self) -> bool {
        worker::current().is_some_and(|(s, _)| Arc::ptr_eq(&s, &self.shared))
    }

    /// Try to enter an inline-continuation frame on the calling worker
    /// (ISSUE 8: continuation inlining).  Succeeds only when inlining is
    /// enabled, the caller is a worker of this scheduler, and the
    /// per-worker depth is below [`MAX_INLINE_DEPTH`]; the caller must
    /// pair a `true` return with [`Scheduler::end_inline`].
    pub(crate) fn try_begin_inline(&self) -> bool {
        if !self.shared.tuning.inline_cont || !self.on_worker() {
            return false;
        }
        if !worker::inline_enter(MAX_INLINE_DEPTH) {
            return false;
        }
        Metrics::inc(&self.shared.metrics.continuations_inlined);
        true
    }

    /// Leave an inline-continuation frame entered via
    /// [`Scheduler::try_begin_inline`].
    pub(crate) fn end_inline(&self) {
        worker::inline_exit();
    }

    /// Account a panic that escaped an *inlined* continuation body — the
    /// containment parity with `worker::execute`'s catch_unwind path.
    pub(crate) fn note_inline_panic(&self) {
        self.shared.panics.fetch_add(1, Ordering::SeqCst);
    }

    /// Which idle substrate this instance runs on.
    pub fn idle_mode(&self) -> IdleMode {
        match self.shared.idle {
            IdleBackend::PerWorker { .. } => IdleMode::Targeted,
            IdleBackend::Global(_) => IdleMode::Global,
        }
    }

    pub fn workers(&self) -> usize {
        self.shared.queues.workers()
    }

    /// Claim a placement-hint base for a batch of `span` related tasks:
    /// successive claims advance a rotating cursor, so K concurrent
    /// submitters (fork/join clients, dataflow producers) get
    /// *interleaved* worker-queue hints instead of all pinning their
    /// batches onto workers `0..span` — the hint-distribution half of
    /// multi-tenant fair-share (DESIGN.md §8).  The caller hints task `i`
    /// of the batch to worker `(base + i) % workers`.
    pub fn hint_base(&self, span: usize) -> usize {
        if span == 0 {
            return 0;
        }
        self.shared.hint_cursor.fetch_add(span, Ordering::Relaxed) % self.workers()
    }

    /// Register a task — `hpx::applier::register_thread_nullary` analog.
    pub fn spawn(
        &self,
        priority: Priority,
        hint: Hint,
        desc: &'static str,
        f: impl FnOnce() + Send + 'static,
    ) {
        self.spawn_task(Task::new(priority, desc, f), hint);
    }

    /// [`Scheduler::spawn`] with a cancellation scope: if `token` has
    /// fired by the time a worker dequeues the task, the body is dropped
    /// unrun (the scheduler-dispatch cancellation point — ISSUE 6; the
    /// skip is counted in `metrics().cancelled`).
    pub fn spawn_cancellable(
        &self,
        priority: Priority,
        hint: Hint,
        desc: &'static str,
        token: CancelToken,
        f: impl FnOnce() + Send + 'static,
    ) {
        self.spawn_task(Task::new(priority, desc, f).with_cancel(token), hint);
    }

    /// Register a pre-built [`Task`] (the common tail of the spawn paths).
    pub fn spawn_task(&self, task: Task, hint: Hint) {
        // AcqRel: the Release half pairs with `wait_quiescent`'s Acquire
        // load (a quiescence observer must see the increment before any
        // effect of the task), the Acquire half orders against prior
        // retirements.  A plain Acquire RMW published nothing.
        self.shared.live.fetch_add(1, Ordering::AcqRel);
        Metrics::inc(&self.shared.metrics.spawned);
        let submitter = worker::current().and_then(|(s, w)| {
            if Arc::ptr_eq(&s, &self.shared) {
                Some(w)
            } else {
                None
            }
        });
        // Targeted wake: the hinted worker's queue holds the task, so it
        // is the one to rouse; unhinted tasks wake any sleeper.
        let target = match hint {
            Hint::Worker(w) => Some(w % self.workers()),
            Hint::Any => None,
        };
        self.shared.queues.push(task, hint, submitter);
        self.shared.wake_workers(target, 1);
    }

    /// Register a whole team of tasks in one pass — the fork fast path
    /// (paper §5.1: one `register_thread_nullary` per OpenMP thread, but a
    /// naive loop over [`Scheduler::spawn`] pays one `live` update and one
    /// wake negotiation *per task*).  Here: one `live` update, one queue
    /// pass, and one wake sweep that unparks the hinted workers first
    /// (their queues hold the tasks) and tops up from the idle set.
    pub fn spawn_batch(
        &self,
        priority: Priority,
        desc: &'static str,
        bodies: Vec<(Hint, Box<dyn FnOnce() + Send + 'static>)>,
    ) {
        self.spawn_batch_cancellable(priority, desc, None, bodies);
    }

    /// [`Scheduler::spawn_batch`] with an optional shared cancellation
    /// scope: every task of the batch checks `token` at dispatch, so a
    /// deadline/cancel abandons the not-yet-started remainder of a bulk
    /// operation in O(1) per task.
    pub fn spawn_batch_cancellable(
        &self,
        priority: Priority,
        desc: &'static str,
        token: Option<CancelToken>,
        bodies: Vec<(Hint, Box<dyn FnOnce() + Send + 'static>)>,
    ) {
        self.spawn_batch_payloads(
            priority,
            desc,
            token,
            bodies
                .into_iter()
                .map(|(h, f)| (h, Payload::Boxed(f)))
                .collect(),
        );
    }

    /// [`Scheduler::spawn_batch_cancellable`] over pre-wrapped
    /// [`Payload`]s — the arena-aware bulk path (ISSUE 7): callers that
    /// build payloads with [`Payload::new`] place small chunk closures
    /// in recycled per-worker arena blocks, keeping malloc off the
    /// spawn fast path entirely.
    pub fn spawn_batch_payloads(
        &self,
        priority: Priority,
        desc: &'static str,
        token: Option<CancelToken>,
        bodies: Vec<(Hint, Payload)>,
    ) {
        let n = bodies.len();
        if n == 0 {
            return;
        }
        // AcqRel for the same `wait_quiescent` pairing as `spawn`.
        self.shared.live.fetch_add(n, Ordering::AcqRel);
        Metrics::add(&self.shared.metrics.spawned, n as u64);
        let submitter = worker::current().and_then(|(s, w)| {
            if Arc::ptr_eq(&s, &self.shared) {
                Some(w)
            } else {
                None
            }
        });
        let workers = self.workers();
        let mut targets: Vec<usize> = Vec::with_capacity(n);
        for (hint, f) in bodies {
            if let Hint::Worker(w) = hint {
                targets.push(w % workers);
            }
            let mut task = Task::from_payload(priority, desc, f);
            task.cancel = token.clone();
            self.shared.queues.push(task, hint, submitter);
        }
        // A submitting worker reaches its next scheduling point immediately
        // after this call (fork masters help-wait on the join), so it will
        // run one of the batch itself: only the rest need wake-ups.  The
        // wake request is clamped to the worker count: under concurrent
        // spawn_batch callers each batch may only claim as many wake-ups
        // as there are workers to wake, keeping the sweep bounded and the
        // wake path fair across clients.
        let wakes = if submitter.is_some() { n - 1 } else { n };
        self.shared.wake_workers(targets, wakes.min(workers));
    }

    /// Block the calling thread until all spawned tasks have retired,
    /// through the unified wait engine: a worker of this scheduler helps
    /// run tasks; any other thread escalates spin → yield → park and is
    /// *notified on retire* (the `quiesce` wake list) instead of the old
    /// 50µs sleep-poll loop.  `quiesce_parks` counts the parks — the
    /// regression guard that no busy-wait crept back in.
    pub fn wait_quiescent(&self) {
        let shared = &self.shared;
        worker::wait_until_observed(
            Some(&shared.quiesce),
            || shared.live.load(Ordering::Acquire) == 0,
            |tick| {
                if tick == Tick::Parked {
                    Metrics::inc(&shared.metrics.quiesce_parks);
                }
            },
        );
    }

    /// Number of tasks not yet retired.
    pub fn live_tasks(&self) -> usize {
        self.shared.live.load(Ordering::Acquire)
    }

    /// Panics observed inside tasks (isolated, not propagated).
    pub fn task_panics(&self) -> u64 {
        self.shared.panics.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting progress and join all workers.  Pending tasks are
    /// drained before shutdown completes (quiesce-then-stop); the drain
    /// itself is a parked, retire-notified wait — no polling.
    pub fn shutdown(&self) {
        self.wait_quiescent();
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wake_all_workers();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as AU;

    #[test]
    fn spawn_and_quiesce_runs_everything() {
        for policy in PolicyKind::ALL {
            let s = Scheduler::new(2, policy);
            let c = Arc::new(AU::new(0));
            for _ in 0..200 {
                let c = c.clone();
                s.spawn(Priority::Normal, Hint::Any, "t", move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            s.wait_quiescent();
            assert_eq!(c.load(Ordering::SeqCst), 200, "policy {}", policy.name());
            s.shutdown();
        }
    }

    #[test]
    fn spawn_batch_runs_everything_under_every_policy() {
        for policy in PolicyKind::ALL {
            let s = Scheduler::new(2, policy);
            let c = Arc::new(AU::new(0));
            let bodies: Vec<(Hint, Box<dyn FnOnce() + Send>)> = (0..64)
                .map(|i| {
                    let c = c.clone();
                    let body: Box<dyn FnOnce() + Send> = Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                    (Hint::Worker(i % 2), body)
                })
                .collect();
            s.spawn_batch(Priority::Low, "batch", bodies);
            s.wait_quiescent();
            assert_eq!(c.load(Ordering::SeqCst), 64, "policy {}", policy.name());
            let m = s.metrics();
            assert_eq!(m.spawned, 64);
            assert_eq!(m.executed, 64);
            s.shutdown();
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        s.spawn_batch(Priority::Normal, "none", Vec::new());
        assert_eq!(s.live_tasks(), 0);
        s.shutdown();
    }

    #[test]
    fn tasks_can_spawn_tasks() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let c = Arc::new(AU::new(0));
        {
            let s2 = Arc::downgrade(&s);
            let c = c.clone();
            s.spawn(Priority::Normal, Hint::Any, "parent", move || {
                let s = s2.upgrade().unwrap();
                for _ in 0..10 {
                    let c = c.clone();
                    s.spawn(Priority::Normal, Hint::Any, "child", move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }
        s.wait_quiescent();
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panicking_task_is_isolated() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        s.spawn(Priority::Normal, Hint::Any, "boom", || panic!("boom"));
        let c = Arc::new(AU::new(0));
        let c2 = c.clone();
        s.spawn(Priority::Normal, Hint::Any, "after", move || {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        s.wait_quiescent();
        assert_eq!(s.task_panics(), 1);
        assert_eq!(c.load(Ordering::SeqCst), 1, "worker survived the panic");
    }

    #[test]
    fn metrics_count_spawned_and_executed() {
        let s = Scheduler::new(2, PolicyKind::Abp);
        for _ in 0..50 {
            s.spawn(Priority::Normal, Hint::Any, "t", || {});
        }
        s.wait_quiescent();
        let m = s.metrics();
        assert_eq!(m.spawned, 50);
        assert_eq!(m.executed, 50);
    }

    #[test]
    fn cancelled_token_skips_bodies_at_dispatch() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        let token = CancelToken::new();
        token.cancel();
        let c = Arc::new(AU::new(0));
        for _ in 0..8 {
            let c = c.clone();
            s.spawn_cancellable(Priority::Normal, Hint::Any, "t", token.clone(), move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        s.wait_quiescent();
        assert_eq!(c.load(Ordering::SeqCst), 0, "cancelled bodies must not run");
        assert_eq!(s.metrics().cancelled, 8);
        assert_eq!(s.metrics().executed, 0);
        s.shutdown();
    }

    #[test]
    fn live_token_leaves_spawns_untouched() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let token = CancelToken::new();
        let c = Arc::new(AU::new(0));
        for _ in 0..16 {
            let c = c.clone();
            s.spawn_cancellable(Priority::Normal, Hint::Any, "t", token.clone(), move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        s.wait_quiescent();
        assert_eq!(c.load(Ordering::SeqCst), 16);
        assert_eq!(s.metrics().cancelled, 0);
        s.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let s = Scheduler::new(2, PolicyKind::Global);
        s.spawn(Priority::Normal, Hint::Any, "t", || {});
        s.shutdown();
        s.shutdown();
    }

    #[test]
    fn hint_base_interleaves_consecutive_batches() {
        let s = Scheduler::new(4, PolicyKind::PriorityLocal);
        let a = s.hint_base(3);
        let b = s.hint_base(3);
        assert!(a < 4 && b < 4);
        assert_ne!(a, b, "consecutive batches must start on different queues");
        assert_eq!(s.hint_base(0), 0, "empty batch claims no cursor space");
        s.shutdown();
    }

    #[test]
    fn worker_hint_lands_on_requested_queue_for_static() {
        // With static-priority (no stealing), a Worker(i) hint pins work.
        let s = Scheduler::new(4, PolicyKind::StaticPriority);
        let hits = Arc::new(AU::new(0));
        for i in 0..4 {
            let hits = hits.clone();
            s.spawn(Priority::Normal, Hint::Worker(i), "pinned", move || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        s.wait_quiescent();
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn global_idle_mode_still_runs_everything() {
        // The `HPXMP_GLOBAL_IDLE=1` ablation fallback stays functional:
        // same conservation guarantees on the legacy condvar substrate.
        let s = Scheduler::with_idle_mode(2, PolicyKind::PriorityLocal, IdleMode::Global);
        assert_eq!(s.idle_mode(), IdleMode::Global);
        let c = Arc::new(AU::new(0));
        for i in 0..100 {
            let c = c.clone();
            s.spawn(Priority::Normal, Hint::Worker(i % 2), "t", move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        s.wait_quiescent();
        assert_eq!(c.load(Ordering::SeqCst), 100);
        let m = s.metrics();
        assert_eq!(m.wakes_targeted + m.wakes_any, 0, "global mode counts no targeted wakes");
        s.shutdown();
    }

    #[test]
    fn default_mode_is_targeted_and_wakes_are_counted() {
        let s = Scheduler::with_idle_mode(2, PolicyKind::PriorityLocal, IdleMode::Targeted);
        assert_eq!(s.idle_mode(), IdleMode::Targeted);
        // Give the workers time to park, then spawn onto both queues.
        for round in 0..50 {
            let c = Arc::new(AU::new(0));
            crate::util::timing::spin_wait(Duration::from_micros(300));
            for i in 0..2 {
                let c = c.clone();
                s.spawn(Priority::Normal, Hint::Worker(i), "t", move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            s.wait_quiescent();
            assert_eq!(c.load(Ordering::SeqCst), 2, "round {round}");
        }
        let m = s.metrics();
        // Wake credits are only minted against announced parks: delivered
        // wakes can never exceed parks taken.
        assert!(
            m.wakes_targeted + m.wakes_any <= m.parked + m.wait_parks,
            "wake/park accounting violated: {m}"
        );
        s.shutdown();
    }
}
