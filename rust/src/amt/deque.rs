//! A Chase–Lev work-stealing deque, built from scratch on atomics.
//!
//! This is the lock-free structure underlying HPX's ABP and thread-local
//! scheduling policies (paper §3.2: "a double ended lock-free queue per OS
//! thread; threads are inserted on the top of the queue and are stolen from
//! the bottom during work stealing").
//!
//! Design notes:
//! * Fixed-capacity ring buffer (power of two).  Growth is delegated to the
//!   caller: `push` returns the task back when full and the policy layer
//!   spills to a mutex-guarded overflow queue.  A fixed buffer sidesteps
//!   the memory-reclamation problem of the growable variant (no
//!   epochs/hazard pointers needed) while keeping the hot path lock-free.
//! * Indices are monotonically increasing `isize`s; the ring index is
//!   `idx & mask`.  The owner pushes/pops at `bottom`; thieves CAS `top`.
//! * Memory orderings follow Lê/Pop/Cocchiarella/Zappa Nardelli,
//!   "Correct and Efficient Work-Stealing for Weak Memory Models" (the
//!   C11 version of Chase–Lev).

use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, Ordering};

use crossbeam_utils::CachePadded;

use super::task::Task;

/// Owner side pushes/pops at the bottom; thieves steal from the top.
pub struct ChaseLev {
    top: CachePadded<AtomicIsize>,
    bottom: CachePadded<AtomicIsize>,
    buf: Box<[AtomicPtr<Task>]>,
    mask: isize,
}

unsafe impl Send for ChaseLev {}
unsafe impl Sync for ChaseLev {}

impl ChaseLev {
    /// `capacity` is rounded up to a power of two (min 64).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(64);
        let buf = (0..cap)
            .map(|_| AtomicPtr::new(std::ptr::null_mut()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Self {
            top: CachePadded::new(AtomicIsize::new(0)),
            bottom: CachePadded::new(AtomicIsize::new(0)),
            buf,
            mask: (cap - 1) as isize,
        }
    }

    #[inline]
    fn slot(&self, idx: isize) -> &AtomicPtr<Task> {
        &self.buf[(idx & self.mask) as usize]
    }

    /// Owner-only push.  Returns `Err(task)` when the ring is full.
    pub fn push(&self, task: Task) -> Result<(), Task> {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        if b - t > self.mask {
            return Err(task); // full — caller spills to overflow
        }
        let ptr = Box::into_raw(Box::new(task));
        self.slot(b).store(ptr, Ordering::Relaxed);
        self.bottom.store(b + 1, Ordering::Release);
        Ok(())
    }

    /// Owner-only pop (LIFO end — cache-warm execution order).
    pub fn pop(&self) -> Option<Task> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        self.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Empty: restore bottom.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let ptr = self.slot(b).load(Ordering::Relaxed);
        if t == b {
            // Last element: race against thieves for it.
            let won = self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            self.bottom.store(b + 1, Ordering::Relaxed);
            if !won {
                return None; // a thief got it
            }
        }
        // Safety: exactly one side (owner or winning thief) takes each slot.
        Some(*unsafe { Box::from_raw(ptr) })
    }

    /// Thief-side steal (FIFO end — oldest task, best locality for victim).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        let ptr = self.slot(t).load(Ordering::Relaxed);
        if self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_err()
        {
            return Steal::Retry; // lost the race
        }
        // Safety: the CAS made us the unique owner of slot t.
        Steal::Success(*unsafe { Box::from_raw(ptr) })
    }

    /// Thief-side multi-steal: claim up to half the victim's queue in one
    /// visit ("steal half", ISSUE 8).  The first task is returned as
    /// `Steal::Success`; every additional claimed task is appended to
    /// `extra` for the thief to push onto its *own* queue.  `limit` bounds
    /// the total take (1 reproduces the classic single steal).
    ///
    /// Safety note on the protocol: we do **not** bump `top` by k in a
    /// single CAS.  The C11 Chase–Lev owner `pop` takes slot `b` directly
    /// (no CAS) whenever `top <= b-1` after its SeqCst fence — the fence
    /// argument only excludes thieves from the *single* slot the owner is
    /// taking.  A k-slot bump claimed against a stale `bottom` could
    /// therefore overlap slots concurrent owner pops have already taken,
    /// double-running tasks.  Instead we loop the proven single-slot CAS:
    /// each iteration is an ordinary steal, individually correct, and the
    /// batch stops at the first `Empty`/`Retry`.  One visit still amortizes
    /// the victim-cache-line traffic: after the first success the `top`
    /// line is already exclusive in our cache, so the follow-up CASes are
    /// near-free compared with probing a fresh victim.
    pub fn steal_batch(&self, limit: usize, extra: &mut Vec<Task>) -> Steal {
        let first = match self.steal() {
            Steal::Success(t) => t,
            other => return other,
        };
        // Take at most half of what is left (rounded up so a 1-deep queue
        // still yields its task to a single steal), capped by `limit`.
        let want = self.len_estimate().div_ceil(2).min(limit.saturating_sub(1));
        for _ in 0..want {
            match self.steal() {
                Steal::Success(t) => extra.push(t),
                // Contention or exhaustion ends the batch — never spin here;
                // the thief already has work in hand.
                Steal::Empty | Steal::Retry => break,
            }
        }
        Steal::Success(first)
    }

    /// Approximate occupancy (racy; for metrics/back-pressure only).
    pub fn len_estimate(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty_estimate(&self) -> bool {
        self.len_estimate() == 0
    }
}

impl Drop for ChaseLev {
    fn drop(&mut self) {
        // Drain remaining tasks so their closures are dropped.
        while self.pop().is_some() {}
    }
}

/// Result of a steal attempt.
#[derive(Debug)]
pub enum Steal {
    Success(Task),
    Empty,
    Retry,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::task::Priority;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn mk(counter: &Arc<AtomicUsize>) -> Task {
        let c = counter.clone();
        Task::new(Priority::Normal, "t", move || {
            c.fetch_add(1, Ordering::SeqCst);
        })
    }

    #[test]
    fn lifo_pop_fifo_steal() {
        let q = ChaseLev::with_capacity(64);
        let c = Arc::new(AtomicUsize::new(0));
        let ids: Vec<u64> = (0..3)
            .map(|_| {
                let t = mk(&c);
                let id = t.id;
                q.push(t).unwrap();
                id
            })
            .collect();
        // Owner pops newest first.
        assert_eq!(q.pop().unwrap().id, ids[2]);
        // Thief steals oldest.
        match q.steal() {
            Steal::Success(t) => assert_eq!(t.id, ids[0]),
            other => panic!("expected success, got {other:?}"),
        }
        assert_eq!(q.pop().unwrap().id, ids[1]);
        assert!(q.pop().is_none());
    }

    #[test]
    fn push_full_returns_task() {
        let q = ChaseLev::with_capacity(64); // rounds to 64
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            q.push(mk(&c)).unwrap();
        }
        assert!(q.push(mk(&c)).is_err());
        // Draining one slot makes room again.
        q.pop().unwrap();
        assert!(q.push(mk(&c)).is_ok());
    }

    #[test]
    fn steal_empty() {
        let q = ChaseLev::with_capacity(64);
        assert!(matches!(q.steal(), Steal::Empty));
    }

    #[test]
    fn concurrent_producer_thieves_conserve_tasks() {
        // The core conservation invariant: every pushed task is executed
        // exactly once across owner pops and concurrent steals.
        const N: usize = 10_000;
        let q = Arc::new(ChaseLev::with_capacity(1024));
        let executed = Arc::new(AtomicUsize::new(0));

        let thieves: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let done = executed.clone();
                std::thread::spawn(move || loop {
                    match q.steal() {
                        Steal::Success(t) => t.run(),
                        Steal::Empty => {
                            if done.load(Ordering::SeqCst) >= N {
                                break;
                            }
                            std::thread::yield_now();
                        }
                        Steal::Retry => {}
                    }
                })
            })
            .collect();

        let mut pushed = 0usize;
        while pushed < N {
            let t = mk(&executed);
            match q.push(t) {
                Ok(()) => pushed += 1,
                Err(t) => {
                    // Ring full: owner executes inline (what the policy
                    // layer's overflow path does).
                    t.run();
                    pushed += 1;
                }
            }
            if pushed % 7 == 0 {
                if let Some(t) = q.pop() {
                    t.run();
                }
            }
        }
        // Drain remainder as owner.
        while let Some(t) = q.pop() {
            t.run();
        }
        while executed.load(Ordering::SeqCst) < N {
            std::thread::yield_now();
        }
        for th in thieves {
            th.join().unwrap();
        }
        assert_eq!(executed.load(Ordering::SeqCst), N);
    }

    #[test]
    fn steal_batch_takes_about_half_oldest_first() {
        let q = ChaseLev::with_capacity(64);
        let c = Arc::new(AtomicUsize::new(0));
        let ids: Vec<u64> = (0..8)
            .map(|_| {
                let t = mk(&c);
                let id = t.id;
                q.push(t).unwrap();
                id
            })
            .collect();
        let mut extra = Vec::new();
        let first = match q.steal_batch(32, &mut extra) {
            Steal::Success(t) => t,
            other => panic!("expected success, got {other:?}"),
        };
        // Oldest first, then the extras in FIFO order.
        assert_eq!(first.id, ids[0]);
        // 7 left after the first take → claims ceil(7/2) = 4 extras.
        assert_eq!(extra.len(), 4);
        for (i, t) in extra.iter().enumerate() {
            assert_eq!(t.id, ids[i + 1]);
        }
        // The victim keeps the rest.
        assert_eq!(q.len_estimate(), 3);
    }

    #[test]
    fn steal_batch_limit_one_is_single_steal() {
        let q = ChaseLev::with_capacity(64);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..6 {
            q.push(mk(&c)).unwrap();
        }
        let mut extra = Vec::new();
        assert!(matches!(q.steal_batch(1, &mut extra), Steal::Success(_)));
        assert!(extra.is_empty());
        assert_eq!(q.len_estimate(), 5);
    }

    #[test]
    fn steal_batch_empty() {
        let q = ChaseLev::with_capacity(64);
        let mut extra = Vec::new();
        assert!(matches!(q.steal_batch(8, &mut extra), Steal::Empty));
        assert!(extra.is_empty());
    }

    #[test]
    fn concurrent_batch_thieves_conserve_tasks() {
        // Steal-half under contention: every task runs exactly once across
        // owner pops and batched steals (extras run on the thief too).
        const N: usize = 10_000;
        let q = Arc::new(ChaseLev::with_capacity(1024));
        let executed = Arc::new(AtomicUsize::new(0));

        let thieves: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let done = executed.clone();
                std::thread::spawn(move || {
                    let mut extra = Vec::new();
                    loop {
                        match q.steal_batch(16, &mut extra) {
                            Steal::Success(t) => {
                                t.run();
                                for t in extra.drain(..) {
                                    t.run();
                                }
                            }
                            Steal::Empty => {
                                if done.load(Ordering::SeqCst) >= N {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                            Steal::Retry => {}
                        }
                    }
                })
            })
            .collect();

        let mut pushed = 0usize;
        while pushed < N {
            let t = mk(&executed);
            match q.push(t) {
                Ok(()) => pushed += 1,
                Err(t) => {
                    t.run();
                    pushed += 1;
                }
            }
            if pushed % 7 == 0 {
                if let Some(t) = q.pop() {
                    t.run();
                }
            }
        }
        while let Some(t) = q.pop() {
            t.run();
        }
        while executed.load(Ordering::SeqCst) < N {
            std::thread::yield_now();
        }
        for th in thieves {
            th.join().unwrap();
        }
        assert_eq!(executed.load(Ordering::SeqCst), N);
    }

    #[test]
    fn drop_releases_queued_tasks() {
        let c = Arc::new(AtomicUsize::new(0));
        {
            let q = ChaseLev::with_capacity(64);
            for _ in 0..5 {
                q.push(mk(&c)).unwrap();
            }
            // q dropped with tasks still queued — must not leak (miri-level
            // property; here we just ensure no panic and closures dropped
            // unexecuted).
        }
        assert_eq!(c.load(Ordering::SeqCst), 0);
    }
}
