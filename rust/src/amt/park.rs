//! The sleep/wake substrate (ISSUE 4): per-worker parkers, a lock-free
//! idle-worker set for O(1) "find a sleeper", explicit wake lists for
//! event-driven waits, and the legacy single-condvar fallback kept for
//! the `HPXMP_GLOBAL_IDLE=1` ablation.
//!
//! Before this module, every spawn, barrier, join and future-wait funneled
//! through ONE `Mutex<()>` + `Condvar` with SeqCst sleeper accounting — a
//! thundering-herd design: M concurrent submitters serialized on one lock
//! to wake workers that then all collided on the same wait queue.  The
//! replacement is eventcount-style:
//!
//! * [`Parker`] — one per worker (plus a thread-local one for application
//!   threads): a 3-state atomic (`EMPTY`/`NOTIFIED`/`PARKED`) in front of
//!   a *private* mutex/condvar.  `unpark` on a non-parked parker is one
//!   uncontended atomic swap; a notification arriving before `park` is
//!   latched and consumed without ever touching the lock.
//! * [`IdleSet`] — an atomic bitset of idle workers.  Wakers claim a
//!   sleeper by clearing its bit (`take`/`pop_any`), so "wake the worker
//!   whose queue just got the task, else any sleeper" is two RMWs with no
//!   shared lock, and the old `sleepers` counter is *folded into the set*
//!   (occupancy = the bits themselves — nothing to keep in sync).
//! * [`WakeList`] — registered waiter parkers for constructs with an
//!   explicit completion event (join latch, task counters, futures,
//!   scheduler quiescence): the event side pays one relaxed-ish load when
//!   nobody waits, one unpark per waiter when somebody does.
//! * [`GlobalIdle`] — the pre-refactor global-condvar idle system, kept
//!   behind `HPXMP_GLOBAL_IDLE=1` so `benches/ablation_wake.rs` can
//!   measure exactly what the targeted substrate buys.
//!
//! **The one invariant every user of this module leans on:** a parker may
//! be woken spuriously or late, but never *lost* — `unpark` latches, and
//! every park is timed.  Protocol races (a task pushed while a worker is
//! between "announce idle" and "sleep", an event fired while a waiter is
//! between "register" and "park") therefore cost at most one park timeout,
//! never liveness.  See DESIGN.md §9.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Parker
// ---------------------------------------------------------------------------

const EMPTY: u32 = 0;
const NOTIFIED: u32 = 1;
const PARKED: u32 = 2;

/// Eventcount-style one-thread parker: `unpark` is cheap when the target
/// is awake, latched when it has not parked yet, and a condvar signal only
/// when the target is actually asleep.  Exactly one thread may park on a
/// given parker at a time (each worker owns its own; application threads
/// use [`thread_parker`]); any number may unpark.
pub struct Parker {
    state: AtomicU32,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Default for Parker {
    fn default() -> Self {
        Self::new()
    }
}

impl Parker {
    pub fn new() -> Self {
        Self {
            state: AtomicU32::new(EMPTY),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Block for at most `timeout`, or until [`Parker::unpark`].  Returns
    /// `true` when a notification was consumed (including one latched
    /// before the call — that fast path never touches the lock).
    pub fn park_timeout(&self, timeout: Duration) -> bool {
        // Consume a latched notification without blocking.  Acquire pairs
        // with the Release swap in `unpark`: everything the waker wrote
        // before unparking is visible to us now.
        if self
            .state
            .compare_exchange(NOTIFIED, EMPTY, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            return true;
        }
        let guard = self.lock.lock().unwrap();
        // Publish PARKED under the lock.  An unpark racing us either ran
        // before this CAS (we observe NOTIFIED and leave) or sees PARKED
        // and then blocks on our lock until we are inside `wait_timeout` —
        // its signal cannot fall between our publication and our wait.
        if self
            .state
            .compare_exchange(EMPTY, PARKED, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            // NOTIFIED slipped in between the fast path and the lock.
            self.state.swap(EMPTY, Ordering::Acquire);
            return true;
        }
        let (guard, _timed_out) = self.cv.wait_timeout(guard, timeout).unwrap();
        drop(guard);
        // Collapse whatever happened (notify, timeout, spurious wake) back
        // to EMPTY and report whether a notification was pending.
        self.state.swap(EMPTY, Ordering::Acquire) == NOTIFIED
    }

    /// Wake (or pre-notify) the parker's owner.  The notification latches:
    /// if the owner is not parked, its next `park_timeout` returns
    /// immediately instead of sleeping — this is what closes every
    /// "event fired just before the sleeper slept" race in the system.
    pub fn unpark(&self) {
        // Release pairs with the Acquire swaps in `park_timeout`.
        if self.state.swap(NOTIFIED, Ordering::Release) == PARKED {
            // The owner is on (or irrevocably headed into) the condvar
            // wait: take the lock so our notify cannot land in the gap
            // between its state publication and its wait, then signal.
            drop(self.lock.lock().unwrap());
            self.cv.notify_one();
        }
    }
}

thread_local! {
    static THREAD_PARKER: Arc<Parker> = Arc::new(Parker::new());
}

/// The calling thread's own parker (application threads blocking in joins,
/// quiescence waits, future waits...).  Worker threads use the parker the
/// scheduler allocated for their slot instead, so targeted wakes and wait
/// parks share one latch per worker.
pub fn thread_parker() -> Arc<Parker> {
    THREAD_PARKER.with(|p| p.clone())
}

// ---------------------------------------------------------------------------
// IdleSet
// ---------------------------------------------------------------------------

/// Lock-free bitset of idle workers — the "find a sleeper in O(1)" half of
/// the substrate.  The old SeqCst `sleepers` counter is folded in here:
/// set bits *are* the sleeper accounting, and claiming a bit *is* the wake
/// admission, one `fetch_and` instead of counter + lock + condvar.
///
/// Memory-ordering invariant (the lost-wake argument, DESIGN.md §9): a
/// worker **announces** (sets its bit, AcqRel) and only then re-checks the
/// queues; a submitter **pushes** (through a queue mutex — every external
/// push is mutex-protected) and only then scans the set (AcqRel RMW on
/// `take`/`pop_any`).  If the submitter's scan misses the bit, the
/// worker's announce had not happened yet, so the worker's *subsequent*
/// queue re-check is ordered after the push's mutex release and sees the
/// task.  Either the bit is seen or the task is — never neither.  The
/// Acquire/Release pairs on the word are sufficient because the queue
/// mutex supplies the cross-location ordering; the worker's timed park is
/// the formal backstop regardless.
pub struct IdleSet {
    words: Vec<AtomicU64>,
    workers: usize,
}

impl IdleSet {
    pub fn new(workers: usize) -> Self {
        Self {
            words: (0..workers.div_ceil(64)).map(|_| AtomicU64::new(0)).collect(),
            workers,
        }
    }

    #[inline]
    fn split(w: usize) -> (usize, u64) {
        (w / 64, 1u64 << (w % 64))
    }

    /// Mark worker `w` idle (it is about to park and can be claimed).
    pub fn announce(&self, w: usize) {
        debug_assert!(w < self.workers);
        let (i, mask) = Self::split(w);
        self.words[i].fetch_or(mask, Ordering::AcqRel);
    }

    /// Remove worker `w`'s idle mark (it is awake again); harmless if a
    /// waker already claimed the bit.
    pub fn retract(&self, w: usize) {
        let (i, mask) = Self::split(w);
        self.words[i].fetch_and(!mask, Ordering::AcqRel);
    }

    /// Claim worker `w`'s idle credit: `true` exactly once per announce —
    /// the targeted-wake fast path ("the task went on `w`'s queue; is `w`
    /// asleep?").
    pub fn take(&self, w: usize) -> bool {
        let (i, mask) = Self::split(w);
        self.words[i].fetch_and(!mask, Ordering::AcqRel) & mask != 0
    }

    /// Claim *any* idle worker (fallback when the targeted worker is
    /// awake/busy).  Scans whole words, so it is O(words) ≈ O(1) for
    /// machine-sized pools; each claim is one CAS.
    pub fn pop_any(&self) -> Option<usize> {
        for (i, word) in self.words.iter().enumerate() {
            let mut cur = word.load(Ordering::Acquire);
            while cur != 0 {
                let bit = cur.trailing_zeros();
                let mask = 1u64 << bit;
                match word.compare_exchange_weak(
                    cur,
                    cur & !mask,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => return Some(i * 64 + bit as usize),
                    Err(now) => cur = now,
                }
            }
        }
        None
    }

    /// Racy idle-worker estimate (diagnostics only).
    pub fn len_estimate(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Relaxed).count_ones() as usize)
            .sum()
    }
}

// ---------------------------------------------------------------------------
// WakeList
// ---------------------------------------------------------------------------

/// Registered waiter parkers for one waitable event (join latch reaching
/// zero, a counter draining, a future fulfilling, scheduler quiescence).
///
/// The event side calls [`WakeList::notify_all`] *after* publishing the
/// state change; the cost is a single load when nobody waits.  The waiter
/// side registers lazily — only once it escalates far enough to park (see
/// `worker::wait_until`) — re-checks its condition, then parks.  A notify
/// that races the registration is caught by that re-check or by the
/// latched unpark; one that is missed entirely (the counter load below is
/// deliberately not a full Dekker fence) costs one park *timeout*, never
/// liveness — timed parks are the substrate-wide backstop.
#[derive(Default)]
pub struct WakeList {
    /// Registered-waiter count, maintained under `list`'s lock; SeqCst so
    /// the notify fast path and the register side agree on a single total
    /// order in the common case (pairing documented above — the timed
    /// park, not this counter, is what correctness rests on).
    waiting: AtomicUsize,
    list: Mutex<Vec<Arc<Parker>>>,
}

impl WakeList {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `p` to be unparked at the next notify.  Call before the
    /// final condition re-check that precedes parking.
    pub fn register(&self, p: &Arc<Parker>) {
        let mut list = self.list.lock().unwrap();
        list.push(p.clone());
        self.waiting.fetch_add(1, Ordering::SeqCst);
    }

    /// Remove `p` (waiter done).  Idempotent: removing an absent parker
    /// is a no-op.
    pub fn deregister(&self, p: &Arc<Parker>) {
        let mut list = self.list.lock().unwrap();
        if let Some(i) = list.iter().position(|q| Arc::ptr_eq(q, p)) {
            list.swap_remove(i);
            self.waiting.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Unpark every registered waiter.  One load and done when nobody
    /// waits — cheap enough to call on every event (every task retire,
    /// every counter decrement to zero).
    pub fn notify_all(&self) {
        if self.waiting.load(Ordering::SeqCst) == 0 {
            return;
        }
        for p in self.list.lock().unwrap().iter() {
            p.unpark();
        }
    }

    /// Registered waiters right now (diagnostics/tests).
    pub fn waiting(&self) -> usize {
        self.waiting.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// GlobalIdle — the pre-refactor design, kept for the ablation
// ---------------------------------------------------------------------------

/// The old global idle system: ONE lock + condvar all workers sleep on,
/// with a sleeper counter guarding the wake fast path.  Selected by
/// `HPXMP_GLOBAL_IDLE=1` so `ablation_wake` can measure targeted-vs-global
/// head to head; not used otherwise.
pub struct GlobalIdle {
    lock: Mutex<()>,
    cv: Condvar,
    /// Workers inside (or committed to) the condvar wait.  The increment
    /// is a Release under the lock and the wake fast path reads Acquire:
    /// a waker that loads 0 may only skip the lock because any
    /// concurrently-parking worker re-checks the queues *under the lock*
    /// after the waker's push, and the 500µs wait timeout self-heals the
    /// residual window.  (This replaces the old undocumented SeqCst
    /// accounting — the pairing is the documented invariant now.)
    sleepers: AtomicUsize,
}

impl Default for GlobalIdle {
    fn default() -> Self {
        Self::new()
    }
}

impl GlobalIdle {
    pub fn new() -> Self {
        Self {
            lock: Mutex::new(()),
            cv: Condvar::new(),
            sleepers: AtomicUsize::new(0),
        }
    }

    /// Park the calling worker for up to `timeout` if `should_sleep` still
    /// holds under the idle lock (the re-check that closes the sleep/wake
    /// race in this design).
    pub fn park(&self, should_sleep: impl FnOnce() -> bool, timeout: Duration) {
        let guard = self.lock.lock().unwrap();
        self.sleepers.fetch_add(1, Ordering::Release);
        if should_sleep() {
            let (guard, _) = self.cv.wait_timeout(guard, timeout).unwrap();
            drop(guard);
        } else {
            drop(guard);
        }
        self.sleepers.fetch_sub(1, Ordering::Release);
    }

    /// Notify up to `n` sleepers under one lock acquisition; skips the
    /// lock when nobody sleeps.
    pub fn wake(&self, n: usize) {
        if n == 0 || self.sleepers.load(Ordering::Acquire) == 0 {
            return;
        }
        let _g = self.lock.lock().unwrap();
        let sleeping = self.sleepers.load(Ordering::Acquire);
        if n >= sleeping {
            self.cv.notify_all();
        } else {
            for _ in 0..n {
                self.cv.notify_one();
            }
        }
    }

    /// Wake every sleeper (shutdown).
    pub fn wake_all(&self) {
        let _g = self.lock.lock().unwrap();
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// IdleMode
// ---------------------------------------------------------------------------

/// Which idle substrate a scheduler instance runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IdleMode {
    /// Per-worker parkers + idle set, targeted wakes (the default).
    Targeted,
    /// The legacy single global condvar (`HPXMP_GLOBAL_IDLE=1`) — the
    /// ablation baseline.
    Global,
}

impl IdleMode {
    /// `HPXMP_GLOBAL_IDLE` — defaults to [`IdleMode::Targeted`];
    /// `1|true|on|yes` selects the global fallback.
    pub fn from_env() -> Self {
        match std::env::var("HPXMP_GLOBAL_IDLE") {
            Ok(v) if matches!(
                v.trim().to_ascii_lowercase().as_str(),
                "1" | "true" | "on" | "yes"
            ) =>
            {
                IdleMode::Global
            }
            _ => IdleMode::Targeted,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IdleMode::Targeted => "targeted",
            IdleMode::Global => "global",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn unpark_before_park_is_latched() {
        let p = Parker::new();
        p.unpark();
        let t0 = Instant::now();
        assert!(p.park_timeout(Duration::from_secs(5)), "latched notify lost");
        assert!(t0.elapsed() < Duration::from_secs(1), "latched notify slept");
        // Consumed: the next park must actually wait.
        assert!(!p.park_timeout(Duration::from_micros(50)));
    }

    #[test]
    fn park_times_out_without_notify() {
        let p = Parker::new();
        assert!(!p.park_timeout(Duration::from_micros(200)));
    }

    #[test]
    fn unpark_wakes_a_parked_thread() {
        let p = Arc::new(Parker::new());
        let p2 = p.clone();
        let t = std::thread::spawn(move || p2.park_timeout(Duration::from_secs(10)));
        // Give the thread a moment to actually park, then wake it.
        crate::util::timing::spin_wait(Duration::from_millis(5));
        p.unpark();
        assert!(t.join().unwrap(), "parked thread saw a timeout, not the notify");
    }

    #[test]
    fn repeated_unparks_coalesce_to_one_notification() {
        let p = Parker::new();
        p.unpark();
        p.unpark();
        p.unpark();
        assert!(p.park_timeout(Duration::from_secs(1)));
        assert!(!p.park_timeout(Duration::from_micros(50)), "notify duplicated");
    }

    #[test]
    fn idle_set_take_claims_exactly_once() {
        let s = IdleSet::new(70); // spans two words
        s.announce(3);
        s.announce(69);
        assert_eq!(s.len_estimate(), 2);
        assert!(s.take(3));
        assert!(!s.take(3), "one announce claimed twice");
        assert!(s.take(69));
        assert_eq!(s.len_estimate(), 0);
    }

    #[test]
    fn idle_set_pop_any_drains_all_workers() {
        let s = IdleSet::new(10);
        for w in 0..10 {
            s.announce(w);
        }
        let mut got: Vec<usize> = std::iter::from_fn(|| s.pop_any()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(s.pop_any().is_none());
    }

    #[test]
    fn idle_set_retract_clears_unclaimed_bit() {
        let s = IdleSet::new(4);
        s.announce(2);
        s.retract(2);
        assert!(!s.take(2));
        assert!(s.pop_any().is_none());
    }

    #[test]
    fn wake_list_notifies_registered_parkers() {
        let wl = WakeList::new();
        let p = Arc::new(Parker::new());
        wl.register(&p);
        assert_eq!(wl.waiting(), 1);
        wl.notify_all();
        assert!(p.park_timeout(Duration::from_secs(1)), "notify not delivered");
        wl.deregister(&p);
        assert_eq!(wl.waiting(), 0);
        wl.notify_all(); // no waiters: must not panic or block
    }

    #[test]
    fn wake_list_deregister_is_idempotent() {
        let wl = WakeList::new();
        let p = Arc::new(Parker::new());
        wl.register(&p);
        wl.deregister(&p);
        wl.deregister(&p);
        assert_eq!(wl.waiting(), 0);
    }

    #[test]
    fn idle_mode_parses_env_values() {
        // Not exercising the env var itself (process-global, racy across
        // parallel tests) — just the name mapping.
        assert_eq!(IdleMode::Targeted.name(), "targeted");
        assert_eq!(IdleMode::Global.name(), "global");
    }
}
