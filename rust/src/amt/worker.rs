//! Worker threads, the thread-local scheduling context, and the unified
//! wait engine ([`WaitState`]).
//!
//! Each worker is an OS thread bound to one queue slot of the active
//! policy.  The thread-local [`current`] context is what lets code *inside*
//! a task reach its scheduler — the mechanism behind cooperative task
//! scheduling points (`help_one`), which the OpenMP layer's barriers,
//! `taskwait`, and `taskyield` are built on (an HPX thread yielding to the
//! scheduler in real hpxMP).
//!
//! Since ISSUE 4 a worker with nothing runnable parks on **its own**
//! [`Parker`](super::park::Parker) (after announcing itself in the
//! scheduler's idle set), and every blocking construct in the system —
//! barrier, hot-team join, `taskwait`/`taskgroup`, `Future::wait`,
//! `wait_quiescent`, shutdown — blocks through the one escalation state
//! machine here: **help → spin → yield → timed-park** (DESIGN.md §9).

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use super::park::{self, Parker, WakeList};
use super::scheduler::Shared;
use super::task::Task;

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Shared>, usize)>> = const { RefCell::new(None) };
    /// Set when the task just executed by `help_one` immediately requeued
    /// itself (the OMP nesting guard).  Wait loops treat such a "help" as
    /// a miss so they back off instead of re-stealing the same task in a
    /// hot loop — without this, a blocked team member can livelock a core
    /// ping-ponging another member's implicit task (measured: ~900 ms per
    /// empty parallel region on the 1-core testbed; EXPERIMENTS.md §Perf).
    static REQUEUED: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
    /// Inline-continuation nesting depth on this worker (ISSUE 8): each
    /// `set_value` that runs a ready continuation directly pushes a frame;
    /// past [`super::scheduler::MAX_INLINE_DEPTH`] the continuation falls
    /// back to `spawn` (fresh task, depth 0) so chains cannot overflow the
    /// worker stack or starve the queues.
    static INLINE_DEPTH: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Claim an inline-continuation frame if the depth bound allows.
pub(super) fn inline_enter(max: usize) -> bool {
    INLINE_DEPTH.with(|d| {
        let v = d.get();
        if v >= max {
            false
        } else {
            d.set(v + 1);
            true
        }
    })
}

/// Release a frame claimed by [`inline_enter`].
pub(super) fn inline_exit() {
    INLINE_DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
}

/// Mark that the currently-executing task requeued itself unexecuted.
pub fn note_requeue() {
    REQUEUED.with(|r| r.set(true));
}

/// Consume the requeue flag (true if the last helped task was a requeue).
pub fn take_requeued() -> bool {
    REQUEUED.with(|r| r.replace(false))
}

/// The (scheduler, worker-index) of the calling thread, if it is a worker.
pub fn current() -> Option<(Arc<Shared>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

pub(super) fn set_current(ctx: Option<(Arc<Shared>, usize)>) {
    CURRENT.with(|c| *c.borrow_mut() = ctx);
}

/// Execute one task, with panic isolation and accounting.
pub(super) fn execute(shared: &Shared, task: Task) {
    // Scheduler-dispatch cancellation point (ISSUE 6): a task whose
    // cancel token fired is dropped unrun.  Dropping the closure still
    // runs its RAII guards (task-layer completion promises, OMP retire
    // guards), so waiters observe completion — with a `Cancelled`/empty
    // outcome — instead of hanging.
    if task.is_cancelled() {
        Metrics::inc(&shared.metrics.cancelled);
        let result = catch_unwind(AssertUnwindSafe(|| drop(task)));
        if result.is_err() {
            shared.panics.fetch_add(1, Ordering::SeqCst);
        }
        if shared.live.fetch_sub(1, Ordering::Release) == 1 {
            shared.quiesce.notify_all();
        }
        return;
    }
    Metrics::inc(&shared.metrics.executed);
    let result = catch_unwind(AssertUnwindSafe(|| task.run()));
    if result.is_err() {
        shared.panics.fetch_add(1, Ordering::SeqCst);
    }
    // live was incremented at spawn; the task is now fully retired.  The
    // last retirement notifies parked quiescence waiters
    // (`wait_quiescent`/`shutdown`) — one cheap load when nobody waits.
    if shared.live.fetch_sub(1, Ordering::Release) == 1 {
        shared.quiesce.notify_all();
    }
}

/// The main loop of one worker thread.
pub(super) fn worker_loop(shared: Arc<Shared>, me: usize) {
    set_current(Some((shared.clone(), me)));
    let mut spin = 0usize;
    loop {
        if let Some(task) = shared.queues.pop(me) {
            spin = 0;
            execute(&shared, task);
            continue;
        }
        Metrics::inc(&shared.metrics.steals_attempted);
        if let Some((task, claimed)) = shared.queues.steal(me, spin, shared.tuning.steal_batch) {
            Metrics::inc(&shared.metrics.steals_success);
            Metrics::add(&shared.metrics.steal_batch_tasks, claimed as u64);
            spin = 0;
            execute(&shared, task);
            continue;
        }
        spin = spin.wrapping_add(1);
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        // Nothing runnable: brief spin first (new work often arrives
        // immediately in fork/join phases), then park on our own parker.
        // Spawns targeting our queue unpark us directly; the timeout is
        // the self-heal bound, not the wake mechanism.
        if spin < 64 {
            std::hint::spin_loop();
            std::thread::yield_now();
            continue;
        }
        Metrics::inc(&shared.metrics.parked);
        shared.worker_park(me);
        spin = 0;
    }
    // Flush this worker's arena magazines to the depot: blocks cached
    // here become reusable by surviving workers instead of idling in
    // dead TLS.
    super::arena::trim_thread();
    set_current(None);
}

/// Cooperative scheduling point: if the calling thread is a worker, try to
/// pop-or-steal one task and run it inline.  Returns `true` if a task ran.
///
/// This is what makes closure-based tasks compose with blocking OpenMP
/// semantics: a team thread waiting at a barrier *becomes* the scheduler
/// for a moment (help-first execution), exactly like a task scheduling
/// point in the OpenMP spec.
pub fn help_one() -> bool {
    if let Some((shared, me)) = current() {
        let got = shared.queues.pop(me).or_else(|| {
            Metrics::inc(&shared.metrics.steals_attempted);
            shared
                .queues
                .steal(me, 0, shared.tuning.steal_batch)
                .map(|(t, claimed)| {
                    Metrics::inc(&shared.metrics.steals_success);
                    Metrics::add(&shared.metrics.steal_batch_tasks, claimed as u64);
                    t
                })
        });
        if let Some(task) = got {
            Metrics::inc(&shared.metrics.helped);
            execute(&shared, task);
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// The unified wait engine
// ---------------------------------------------------------------------------

/// Escalation thresholds: busy spin below `WAIT_SPIN` ticks, OS yield
/// below `WAIT_YIELD`, timed parks beyond.
const WAIT_SPIN: u32 = 32;
const WAIT_YIELD: u32 = 256;
/// First park timeout; doubles per consecutive park up to the cap.
const PARK_BASE_US: u64 = 20;
/// Timeout cap for waits with no explicit wake channel (the condition
/// flips without a notify — e.g. a barrier generation): short, so the
/// re-check cadence matches the old 20µs nap.
const PARK_CAP_US: u64 = 200;
/// Timeout cap once the waiter is registered on a [`WakeList`]: the event
/// will unpark us explicitly, so the timeout is only the backstop for the
/// deliberately-unfenced `notify_all` fast path.  Long enough that a
/// master joined on a long region self-wakes ~100×/s (µs-scale each —
/// noise), short enough that the ~never missed-notify race stalls a
/// waiter by at most one cap.
const PARK_CAP_NOTIFIED_US: u64 = 10_000;

/// What one [`WaitState::tick`] did — the escalation rung taken.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tick {
    /// Ran a pending task (help-first execution).
    Helped,
    Spun,
    Yielded,
    /// Timed-parked on the thread's parker.
    Parked,
}

/// The escalation state machine every blocking construct shares
/// (DESIGN.md §9): **help → spin → yield → timed-park**.
///
/// * *help* — a worker thread runs pending tasks instead of idling (task
///   scheduling point); a help that merely requeued a §4-guarded implicit
///   task counts as a miss *and* arms requeue-backoff (see below).
/// * *spin/yield* — the short-wait rungs, unchanged from the old
///   `wait_tick`.
/// * *timed-park* — the thread parks on its parker (a worker's own slot
///   parker, or the thread-local one for application threads) with an
///   escalating timeout.  A parking worker announces itself in the idle
///   set so targeted wakes can recruit it to help — **except** under
///   requeue-backoff, where it cannot run the task it just bounced and
///   must leave the wake credit to a worker that can.
///
/// Constructs with an explicit completion event additionally register the
/// parker on the event's [`WakeList`] (see [`wait_until`]) so the park is
/// cut short by a real notification instead of a timeout.
pub struct WaitState {
    spins: u32,
    /// Consecutive parks — drives the timeout escalation.
    parks: u32,
    /// Last help attempt hit the §4 nesting guard (popped a task that
    /// requeued itself): back off without claiming wake credits.
    requeue_backoff: bool,
    /// Registered on a `WakeList`: a real notification will arrive, so
    /// parks may stretch toward `PARK_CAP_NOTIFIED_US`.
    wake_channel: bool,
    /// Lazily resolved park target (worker slot parker or TLS parker).
    parker: Option<Arc<Parker>>,
}

impl Default for WaitState {
    fn default() -> Self {
        Self::new()
    }
}

impl WaitState {
    pub fn new() -> Self {
        Self {
            spins: 0,
            parks: 0,
            requeue_backoff: false,
            wake_channel: false,
            parker: None,
        }
    }

    /// Whether the *next* [`WaitState::tick`] would reach the park rung —
    /// the moment for a waiter to register on its wake list (then re-check
    /// its condition, then tick).
    fn about_to_park(&self) -> bool {
        self.spins + 1 >= WAIT_YIELD
    }

    /// Mark that the waiter is registered on a [`WakeList`]; parks may use
    /// the longer backstop timeout from here on.
    fn note_wake_channel(&mut self) {
        self.wake_channel = true;
    }

    /// The parker this wait parks on: the worker's own slot parker when
    /// called from a worker thread (so targeted wakes and wait parks share
    /// one latch), else the calling thread's TLS parker.
    fn parker(&mut self) -> Arc<Parker> {
        if self.parker.is_none() {
            self.parker = Some(match current() {
                Some((shared, me)) => shared
                    .worker_parker(me)
                    .unwrap_or_else(park::thread_parker),
                None => park::thread_parker(),
            });
        }
        self.parker.as_ref().unwrap().clone()
    }

    /// One escalation step.  Call in a loop around the wait condition.
    pub fn tick(&mut self) -> Tick {
        if help_one() {
            if !take_requeued() {
                self.spins = 0;
                self.parks = 0;
                self.requeue_backoff = false;
                return Tick::Helped;
            }
            // Helped task bounced off the §4 nesting guard: escalate like
            // a miss, and remember not to advertise ourselves as a
            // schedulable core while it sits requeued in the queues.
            self.requeue_backoff = true;
        } else {
            self.requeue_backoff = false;
        }
        self.spins += 1;
        if self.spins < WAIT_SPIN {
            std::hint::spin_loop();
            Tick::Spun
        } else if self.spins < WAIT_YIELD {
            std::thread::yield_now();
            Tick::Yielded
        } else {
            self.park();
            Tick::Parked
        }
    }

    fn park(&mut self) {
        let cap = if self.wake_channel {
            PARK_CAP_NOTIFIED_US
        } else {
            PARK_CAP_US
        };
        let us = (PARK_BASE_US << self.parks.min(8)).min(cap);
        self.parks = self.parks.saturating_add(1);
        let timeout = Duration::from_micros(us);
        match current() {
            Some((shared, me)) => {
                Metrics::inc(&shared.metrics.wait_parks);
                shared.waiter_park(me, timeout, !self.requeue_backoff);
            }
            None => {
                self.parker().park_timeout(timeout);
            }
        }
    }
}

/// Block until `cond` holds, through the unified [`WaitState`] engine.
///
/// `wakers`, when given, is the construct's explicit wake channel (the
/// event side calls `notify_all` after publishing the state change): the
/// waiter registers **lazily** — only once escalation reaches the park
/// rung — so short waits stay entirely lock-free, then re-checks `cond`
/// before the first park so an event that raced the registration is never
/// waited out.  Every blocking edge of the system (team barrier, hot-team
/// join, `taskwait`/`taskgroup` counters, `Future::wait`, scheduler
/// quiescence) is a thin wrapper over this function.
pub fn wait_until(wakers: Option<&WakeList>, cond: impl FnMut() -> bool) {
    wait_until_observed(wakers, cond, |_| {});
}

/// [`wait_until`] with a per-tick observer — the ONE implementation of the
/// lazy-register / re-check / park / deregister protocol (callers that
/// need instrumentation, like `Scheduler::wait_quiescent`'s
/// `quiesce_parks` counter, observe the rungs instead of reimplementing
/// the race-sensitive registration dance).
pub fn wait_until_observed(
    wakers: Option<&WakeList>,
    mut cond: impl FnMut() -> bool,
    mut observe: impl FnMut(Tick),
) {
    if cond() {
        return;
    }
    let mut ws = WaitState::new();
    let mut registered: Option<Arc<Parker>> = None;
    loop {
        if cond() {
            break;
        }
        if registered.is_none() && ws.about_to_park() {
            if let Some(list) = wakers {
                let p = ws.parker();
                list.register(&p);
                registered = Some(p);
                ws.note_wake_channel();
                continue; // re-check cond before the first park
            }
        }
        observe(ws.tick());
    }
    if let (Some(list), Some(p)) = (wakers, registered.as_ref()) {
        list.deregister(p);
    }
}
