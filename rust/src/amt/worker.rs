//! Worker threads and the thread-local scheduling context.
//!
//! Each worker is an OS thread bound to one queue slot of the active
//! policy.  The thread-local [`current`] context is what lets code *inside*
//! a task reach its scheduler — the mechanism behind cooperative task
//! scheduling points (`help_one`), which the OpenMP layer's barriers,
//! `taskwait`, and `taskyield` are built on (an HPX thread yielding to the
//! scheduler in real hpxMP).

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use super::metrics::Metrics;
use super::scheduler::Shared;
use super::task::Task;

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Shared>, usize)>> = const { RefCell::new(None) };
    /// Set when the task just executed by `help_one` immediately requeued
    /// itself (the OMP nesting guard).  Wait loops treat such a "help" as
    /// a miss so they back off instead of re-stealing the same task in a
    /// hot loop — without this, a blocked team member can livelock a core
    /// ping-ponging another member's implicit task (measured: ~900 ms per
    /// empty parallel region on the 1-core testbed; EXPERIMENTS.md §Perf).
    static REQUEUED: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Mark that the currently-executing task requeued itself unexecuted.
pub fn note_requeue() {
    REQUEUED.with(|r| r.set(true));
}

/// Consume the requeue flag (true if the last helped task was a requeue).
pub fn take_requeued() -> bool {
    REQUEUED.with(|r| r.replace(false))
}

/// The (scheduler, worker-index) of the calling thread, if it is a worker.
pub fn current() -> Option<(Arc<Shared>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

pub(super) fn set_current(ctx: Option<(Arc<Shared>, usize)>) {
    CURRENT.with(|c| *c.borrow_mut() = ctx);
}

/// Execute one task, with panic isolation and accounting.
pub(super) fn execute(shared: &Shared, task: Task) {
    Metrics::inc(&shared.metrics.executed);
    let result = catch_unwind(AssertUnwindSafe(|| task.run()));
    if result.is_err() {
        shared.panics.fetch_add(1, Ordering::SeqCst);
    }
    // live was incremented at spawn; the task is now fully retired.
    shared.live.fetch_sub(1, Ordering::Release);
}

/// The main loop of one worker thread.
pub(super) fn worker_loop(shared: Arc<Shared>, me: usize) {
    set_current(Some((shared.clone(), me)));
    let mut spin = 0usize;
    loop {
        if let Some(task) = shared.queues.pop(me) {
            spin = 0;
            execute(&shared, task);
            continue;
        }
        if let Some(task) = shared.queues.steal(me, spin) {
            Metrics::inc(&shared.metrics.stolen);
            spin = 0;
            execute(&shared, task);
            continue;
        }
        spin = spin.wrapping_add(1);
        if shared.shutdown.load(Ordering::Acquire) {
            break;
        }
        // Nothing runnable: brief spin first (new work often arrives
        // immediately in fork/join phases), then park with a timeout so a
        // missed notify self-heals.
        if spin < 64 {
            std::hint::spin_loop();
            std::thread::yield_now();
            continue;
        }
        Metrics::inc(&shared.metrics.parked);
        let guard = shared.idle_lock.lock().unwrap();
        shared.sleepers.fetch_add(1, Ordering::SeqCst);
        // Re-check under the lock to close the sleep/wake race.
        if shared.queues.approx_len() == 0 && !shared.shutdown.load(Ordering::Acquire) {
            let _ = shared
                .idle_cv
                .wait_timeout(guard, Duration::from_micros(500))
                .unwrap();
        }
        shared.sleepers.fetch_sub(1, Ordering::SeqCst);
        spin = 0;
    }
    set_current(None);
}

/// Cooperative scheduling point: if the calling thread is a worker, try to
/// pop-or-steal one task and run it inline.  Returns `true` if a task ran.
///
/// This is what makes closure-based tasks compose with blocking OpenMP
/// semantics: a team thread waiting at a barrier *becomes* the scheduler
/// for a moment (help-first execution), exactly like a task scheduling
/// point in the OpenMP spec.
pub fn help_one() -> bool {
    if let Some((shared, me)) = current() {
        if let Some(task) = shared
            .queues
            .pop(me)
            .or_else(|| shared.queues.steal(me, 0))
        {
            Metrics::inc(&shared.metrics.helped);
            execute(&shared, task);
            return true;
        }
    }
    false
}

/// One escalating help-first wait step: help-run a task, else spin, else
/// yield, else sleep.  A help that merely requeued a guarded implicit task
/// counts as a miss (see [`note_requeue`]) so the waiter backs off and the
/// task's home worker gets the core.
///
/// This is the single wait primitive every blocking edge of the system
/// shares: `Future::wait` ([`crate::amt::future`]), the OpenMP layer's
/// barriers, `taskwait`/`taskgroup`, and the hot-team join all tick
/// through here, so they are all task scheduling points with identical
/// back-off behavior.
#[inline]
pub fn wait_tick(spins: &mut u32) {
    if help_one() && !take_requeued() {
        *spins = 0;
        return;
    }
    *spins += 1;
    if *spins < 32 {
        std::hint::spin_loop();
    } else if *spins < 256 {
        std::thread::yield_now();
    } else {
        std::thread::sleep(Duration::from_micros(20));
    }
}
