//! Scheduler counters — the observability surface of the AMT substrate.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters, all relaxed: observational only.
#[derive(Default)]
pub struct Metrics {
    pub spawned: AtomicU64,
    pub executed: AtomicU64,
    /// Tasks dropped unrun at dispatch because their cancel token had
    /// fired (ISSUE 6) — disjoint from `executed`.
    pub cancelled: AtomicU64,
    /// Steal *sweeps*: one per `Queues::steal` call by a worker with an
    /// empty local queue (a sweep probes victims in locality order).
    /// `steals_success / steals_attempted` is the hit rate the
    /// locality-aware victim ordering optimizes.
    pub steals_attempted: AtomicU64,
    /// Steal visits that yielded at least one task (was `stolen` before
    /// steal-half batching landed).
    pub steals_success: AtomicU64,
    /// Total tasks moved by steals — `steal_batch_tasks /
    /// steals_success` is the mean batch size (1.0 means every steal
    /// moved a single task, i.e. the `HPXMP_STEAL_ONE=1` behavior).
    pub steal_batch_tasks: AtomicU64,
    /// Continuations run inline on the fulfilling worker instead of
    /// round-tripping through `Scheduler::spawn` (`HPXMP_INLINE_CONT`).
    /// Inlined continuations never enter `spawned`/`executed`, so the
    /// task-conservation identity is untouched.
    pub continuations_inlined: AtomicU64,
    pub overflowed: AtomicU64,
    /// Worker main-loop park *descents* (idle, nothing runnable): counted
    /// at the idle-set announce, i.e. including descents cancelled by the
    /// post-announce queue re-check.  Deliberate: every claimable idle bit
    /// is covered by exactly one increment, which is what makes the
    /// conservation check `wakes_targeted + wakes_any <= parked +
    /// wait_parks` exact (a counter of only-completed sleeps would
    /// undercount the claim windows).
    pub parked: AtomicU64,
    pub helped: AtomicU64,
    /// Parks taken *inside* blocking constructs (the `WaitState` engine:
    /// barriers, joins, taskwaits, future waits).
    pub wait_parks: AtomicU64,
    /// Parks taken by `wait_quiescent`/`shutdown` waiters — the counter
    /// that proves the old 50µs sleep-poll loop is gone (ISSUE 4): a
    /// quiescence waiter now parks and is notified on retire, it never
    /// busy-sleeps.
    pub quiesce_parks: AtomicU64,
    /// Wake-ups delivered to the worker the placement hint targeted
    /// (its queue holds the task) — the targeted-wake fast path.
    pub wakes_targeted: AtomicU64,
    /// Wake-ups delivered to an arbitrary idle worker (hint target was
    /// awake or the task had no placement hint).
    pub wakes_any: AtomicU64,
}

impl Metrics {
    #[inline]
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Bulk increment (batch spawn: one update for n tasks).
    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            spawned: self.spawned.load(Ordering::Relaxed),
            executed: self.executed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            steals_attempted: self.steals_attempted.load(Ordering::Relaxed),
            steals_success: self.steals_success.load(Ordering::Relaxed),
            steal_batch_tasks: self.steal_batch_tasks.load(Ordering::Relaxed),
            continuations_inlined: self.continuations_inlined.load(Ordering::Relaxed),
            overflowed: self.overflowed.load(Ordering::Relaxed),
            parked: self.parked.load(Ordering::Relaxed),
            helped: self.helped.load(Ordering::Relaxed),
            wait_parks: self.wait_parks.load(Ordering::Relaxed),
            quiesce_parks: self.quiesce_parks.load(Ordering::Relaxed),
            wakes_targeted: self.wakes_targeted.load(Ordering::Relaxed),
            wakes_any: self.wakes_any.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy, cheap to print/compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub spawned: u64,
    pub executed: u64,
    pub cancelled: u64,
    pub steals_attempted: u64,
    pub steals_success: u64,
    pub steal_batch_tasks: u64,
    pub continuations_inlined: u64,
    pub overflowed: u64,
    pub parked: u64,
    pub helped: u64,
    pub wait_parks: u64,
    pub quiesce_parks: u64,
    pub wakes_targeted: u64,
    pub wakes_any: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "spawned={} executed={} cancelled={} steals_attempted={} steals_success={} \
             steal_batch_tasks={} continuations_inlined={} overflowed={} parked={} helped={} \
             wait_parks={} quiesce_parks={} wakes_targeted={} wakes_any={}",
            self.spawned,
            self.executed,
            self.cancelled,
            self.steals_attempted,
            self.steals_success,
            self.steal_batch_tasks,
            self.continuations_inlined,
            self.overflowed,
            self.parked,
            self.helped,
            self.wait_parks,
            self.quiesce_parks,
            self.wakes_targeted,
            self.wakes_any
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_increments() {
        let m = Metrics::default();
        Metrics::inc(&m.spawned);
        Metrics::inc(&m.spawned);
        Metrics::inc(&m.executed);
        let s = m.snapshot();
        assert_eq!(s.spawned, 2);
        assert_eq!(s.executed, 1);
        assert_eq!(s.steals_success, 0);
    }

    #[test]
    fn display_contains_all_fields() {
        let m = Metrics::default().snapshot();
        let s = format!("{m}");
        for key in [
            "spawned",
            "executed",
            "cancelled",
            "steals_attempted",
            "steals_success",
            "steal_batch_tasks",
            "continuations_inlined",
            "overflowed",
            "parked",
            "helped",
            "wait_parks",
            "quiesce_parks",
            "wakes_targeted",
            "wakes_any",
        ] {
            assert!(s.contains(key), "{key} missing from {s}");
        }
    }
}
