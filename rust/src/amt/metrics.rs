//! Scheduler counters — the observability surface of the AMT substrate.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters, all relaxed: observational only.
#[derive(Default)]
pub struct Metrics {
    pub spawned: AtomicU64,
    pub executed: AtomicU64,
    pub stolen: AtomicU64,
    pub overflowed: AtomicU64,
    pub parked: AtomicU64,
    pub helped: AtomicU64,
}

impl Metrics {
    #[inline]
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Bulk increment (batch spawn: one update for n tasks).
    #[inline]
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            spawned: self.spawned.load(Ordering::Relaxed),
            executed: self.executed.load(Ordering::Relaxed),
            stolen: self.stolen.load(Ordering::Relaxed),
            overflowed: self.overflowed.load(Ordering::Relaxed),
            parked: self.parked.load(Ordering::Relaxed),
            helped: self.helped.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy, cheap to print/compare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub spawned: u64,
    pub executed: u64,
    pub stolen: u64,
    pub overflowed: u64,
    pub parked: u64,
    pub helped: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "spawned={} executed={} stolen={} overflowed={} parked={} helped={}",
            self.spawned, self.executed, self.stolen, self.overflowed, self.parked, self.helped
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_increments() {
        let m = Metrics::default();
        Metrics::inc(&m.spawned);
        Metrics::inc(&m.spawned);
        Metrics::inc(&m.executed);
        let s = m.snapshot();
        assert_eq!(s.spawned, 2);
        assert_eq!(s.executed, 1);
        assert_eq!(s.stolen, 0);
    }

    #[test]
    fn display_contains_all_fields() {
        let m = Metrics::default().snapshot();
        let s = format!("{m}");
        for key in ["spawned", "executed", "stolen", "overflowed", "parked", "helped"] {
            assert!(s.contains(key), "{key} missing from {s}");
        }
    }
}
