//! Futures and continuations — the `hpx::future`/`hpx::promise` analog
//! (ISSUE 2; DESIGN.md §7, error channel from ISSUE 6 / §11).
//!
//! The paper's closing argument is that an OpenMP-over-AMT runtime only
//! pays off once applications can leave fork/join behind for a *task-based
//! dataflow* model — exactly what HPX's `future`/`when_all`/`then` triple
//! provides.  This module is that missing subsystem:
//!
//! * [`Promise<T>`] — the write end: fulfilled exactly once, with a value
//!   or an error ([`Outcome`]).
//! * [`Future<T>`]  — the (shared, clonable) read end: `hpx::shared_future`
//!   semantics — continuations observe the value by reference, any number
//!   of continuations may attach, before or after fulfilment.
//! * [`Future::then`] — attaches a continuation that is **scheduled as an
//!   AMT task** on the fulfilling thread's `Scheduler` handle: no new OS
//!   threads, no blocking, just a `Scheduler::spawn` at fulfilment (or
//!   immediately if the value is already there).  When fulfilment happens
//!   *on a worker* of that scheduler, short chains skip the spawn and run
//!   the continuation inline on the fulfilling worker (ISSUE 8; bounded
//!   by `MAX_INLINE_DEPTH`, disabled via `HPXMP_INLINE_CONT=0`).
//! * [`when_all`] — joins N futures into one `Future<()>` with inline
//!   countdown hooks (no task spawned per input; the combined future's own
//!   continuations are where work hangs).
//! * [`Future::wait`] — a **help-first** wait for the blocking edges of
//!   the system: a worker that waits runs pending tasks via the unified
//!   [`worker::wait_until`] engine instead of burning its core, exactly
//!   like the OpenMP layer's barriers, and fulfilment wakes parked
//!   waiters explicitly.
//!
//! The state machine of one future (§7/§11 of DESIGN.md):
//!
//! ```text
//! Pending{conts} --set_value / set_cancelled / set_panicked / Drop-->
//!     Ready(Outcome) ; conts drained:
//!     Spawned  -> Scheduler::spawn(move || f(&outcome))  (on a worker)
//!     Inline   -> f(&outcome) on the fulfilling thread   (cheap hooks)
//! attach after Ready -> dispatched immediately (same two flavors)
//! ```
//!
//! **Error propagation (ISSUE 6).**  A future completes with one of
//! [`Outcome::Value`], [`Outcome::Cancelled`], or [`Outcome::Panicked`].
//! `then` continuations run only on `Value`; on an error outcome the
//! continuation body is *skipped* and the error is forwarded to the
//! result future, so a whole chain short-circuits in O(chain) inline
//! work.  A `then` body that panics drops its result promise mid-unwind,
//! and a [`Promise`] dropped unfulfilled completes its future with
//! `Panicked` — the "broken promise" of the old design now *fails fast*
//! instead of hanging every downstream `wait`.  [`when_all`] propagates
//! the worst outcome among its inputs (`Panicked` > `Cancelled` >
//! `Value`).  Unwinding itself still stops at the worker boundary
//! (`worker::execute` catches it); the outcome is the cross-task signal.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use once_cell::sync::OnceCell;

use super::park::WakeList;
use super::scheduler::Scheduler;
use super::task::{Hint, Priority};
use super::worker;

/// How a future completed.  Ordered by severity: a combinator joining
/// several outcomes reports the worst one (`Panicked` > `Cancelled` >
/// `Value`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome<T> {
    /// Normal completion.
    Value(T),
    /// Completed without a value because the work was cancelled (token
    /// fired or deadline passed) before it ran.
    Cancelled,
    /// The producer panicked (or its promise was dropped unfulfilled —
    /// indistinguishable from the outside, and in practice caused by an
    /// unwind through the producer).
    Panicked,
}

impl<T> Outcome<T> {
    /// The value, if this is a normal completion.
    pub fn value(&self) -> Option<&T> {
        match self {
            Outcome::Value(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_value(&self) -> bool {
        matches!(self, Outcome::Value(_))
    }

    pub fn is_cancelled(&self) -> bool {
        matches!(self, Outcome::Cancelled)
    }

    pub fn is_panicked(&self) -> bool {
        matches!(self, Outcome::Panicked)
    }

    /// Severity rank used by joining combinators (0 value, 1 cancelled,
    /// 2 panicked).
    fn severity(&self) -> usize {
        match self {
            Outcome::Value(_) => 0,
            Outcome::Cancelled => 1,
            Outcome::Panicked => 2,
        }
    }

    /// The error half with the value type erased (what a combinator
    /// forwards downstream).
    fn as_error<U>(&self) -> Option<Outcome<U>> {
        match self {
            Outcome::Value(_) => None,
            Outcome::Cancelled => Some(Outcome::Cancelled),
            Outcome::Panicked => Some(Outcome::Panicked),
        }
    }
}

/// Lock a continuation list, recovering from poisoning.  A panic while
/// holding `conts` can only happen inside an *inline* hook (user `then`
/// bodies run as spawned tasks, outside the lock); the list itself — a
/// `Vec` mutated only by `push` and `mem::take`, both panic-free — is
/// structurally valid at every unlock point, so the poison flag carries
/// no information and clearing it is sound.
fn lock_conts<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One registered continuation.
enum Cont<T> {
    /// Scheduled as an AMT task at fulfilment — the `future::then` path.
    Spawned {
        sched: Arc<Scheduler>,
        desc: &'static str,
        f: Box<dyn FnOnce(&Outcome<T>) + Send>,
    },
    /// Run inline on the fulfilling thread.  Reserved for cheap,
    /// non-blocking bookkeeping (the [`when_all`] countdown): user code
    /// never runs inline, so fulfilment cannot block on it.
    Inline(Box<dyn FnOnce(&Outcome<T>) + Send>),
}

/// Shared state of one promise/future pair.
struct SharedState<T> {
    /// Write-once outcome cell; `get().is_some()` is the ready flag (the
    /// cell's internal ordering publishes the outcome to readers).
    value: OnceCell<Outcome<T>>,
    /// Continuations registered while pending; drained at fulfilment.
    conts: Mutex<Vec<Cont<T>>>,
    /// Parked [`Future::wait`]ers; notified right after the value lands
    /// (the unified wait engine's explicit wake channel — DESIGN.md §9).
    wakers: WakeList,
}

fn dispatch<T: Send + Sync + 'static>(state: Arc<SharedState<T>>, cont: Cont<T>) {
    match cont {
        Cont::Inline(f) => f(state.value.get().expect("dispatch before fulfilment")),
        Cont::Spawned { sched, desc, f } => {
            // Continuation inlining (ISSUE 8): when the fulfilling thread
            // is a worker of the target scheduler and the per-worker depth
            // bound allows, run the continuation right here — the operand
            // is hot in this core's cache and the queue round-trip (push,
            // wake, steal) per `then` link disappears.  Past the bound the
            // chain falls back to `spawn` (fresh task, depth 0), so deep
            // chains can neither overflow the worker stack nor keep one
            // worker from its queues indefinitely.  `HPXMP_INLINE_CONT=0`
            // (or `Tuning { inline_cont: false, .. }`) kills the path.
            //
            // Panic containment mirrors `worker::execute`: the unwind is
            // caught and counted, the continuation's own result promise is
            // dropped mid-unwind (publishing `Panicked` downstream), and
            // the fulfilment drain loop keeps dispatching its remaining
            // continuations.
            if sched.try_begin_inline() {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f(state.value.get().expect("dispatch before fulfilment"));
                }));
                sched.end_inline();
                if result.is_err() {
                    sched.note_inline_panic();
                }
                return;
            }
            sched.spawn(Priority::Normal, Hint::Any, desc, move || {
                f(state.value.get().expect("dispatch before fulfilment"));
            });
        }
    }
}

/// Publish `outcome` and drain the pending continuations.  Idempotent:
/// the first caller wins (needed because `Promise::drop` races with
/// nothing but runs unconditionally after the consuming setters).
fn fulfil<T: Send + Sync + 'static>(state: &Arc<SharedState<T>>, outcome: Outcome<T>) {
    if state.value.set(outcome).is_err() {
        return;
    }
    // Wake parked `wait`ers first — they only need the ready flag,
    // which is already published — then dispatch continuations.
    state.wakers.notify_all();
    // Continuations registered from here on observe the outcome under the
    // lock and dispatch themselves; we drain only what was pending.
    let pending = std::mem::take(&mut *lock_conts(&state.conts));
    for cont in pending {
        dispatch(state.clone(), cont);
    }
}

/// The write end: fulfil with [`Promise::set_value`] (or an error setter)
/// exactly once.  Dropping a promise unfulfilled completes its future
/// with [`Outcome::Panicked`] — downstream waits fail fast instead of
/// hanging (the panicking-`then`-body path relies on exactly this).
pub struct Promise<T: Send + Sync + 'static> {
    state: Arc<SharedState<T>>,
}

impl<T: Send + Sync + 'static> Promise<T> {
    pub fn new() -> Self {
        Self {
            state: Arc::new(SharedState {
                value: OnceCell::new(),
                conts: Mutex::new(Vec::new()),
                wakers: WakeList::new(),
            }),
        }
    }

    /// The read end (`hpx::promise::get_future`); callable any number of
    /// times — futures are shared handles.
    pub fn get_future(&self) -> Future<T> {
        Future {
            state: self.state.clone(),
        }
    }

    /// Fulfil the promise: publish the value, then dispatch every
    /// registered continuation (inline hooks on this thread, `then`
    /// continuations as AMT tasks).  Consumes the promise — a future is
    /// fulfilled at most once.
    pub fn set_value(self, value: T) {
        fulfil(&self.state, Outcome::Value(value));
    }

    /// Complete with [`Outcome::Cancelled`] — the work was abandoned
    /// before producing a value.
    pub fn set_cancelled(self) {
        fulfil(&self.state, Outcome::Cancelled);
    }

    /// Complete with [`Outcome::Panicked`] — the producer failed.
    pub fn set_panicked(self) {
        fulfil(&self.state, Outcome::Panicked);
    }

    /// Complete with an arbitrary pre-built outcome (combinators
    /// forwarding a joined error).
    pub fn set_outcome(self, outcome: Outcome<T>) {
        fulfil(&self.state, outcome);
    }
}

impl<T: Send + Sync + 'static> Drop for Promise<T> {
    /// Broken-promise backstop: if the promise dies unfulfilled (producer
    /// panicked mid-unwind, or a combinator dropped it on an error path),
    /// fail the future instead of leaving every waiter pending forever.
    /// After any `set_*` (which consume `self` and run this drop on the
    /// way out) the cell is already occupied and this is a no-op.
    fn drop(&mut self) {
        fulfil(&self.state, Outcome::Panicked);
    }
}

impl<T: Send + Sync + 'static> Default for Promise<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// The read end: a shared handle to an eventually-available value.
pub struct Future<T> {
    state: Arc<SharedState<T>>,
}

impl<T> Clone for Future<T> {
    fn clone(&self) -> Self {
        Self {
            state: self.state.clone(),
        }
    }
}

impl<T: Send + Sync + 'static> Future<T> {
    /// An already-fulfilled future (`hpx::make_ready_future`).
    pub fn ready(value: T) -> Self {
        Self::with_outcome(Outcome::Value(value))
    }

    /// An already-completed future carrying an arbitrary outcome (ready
    /// errors for short-circuit paths).
    pub fn with_outcome(outcome: Outcome<T>) -> Self {
        let state = Arc::new(SharedState {
            value: OnceCell::new(),
            conts: Mutex::new(Vec::new()),
            wakers: WakeList::new(),
        });
        let _ = state.value.set(outcome);
        Self { state }
    }

    /// Whether the future has completed — with *any* outcome (never
    /// blocks).
    pub fn is_ready(&self) -> bool {
        self.state.value.get().is_some()
    }

    /// Whether two handles share one underlying promise/future state —
    /// identity, not value, equality (what a dependence engine needs to
    /// avoid registering a task as its own predecessor).
    pub fn ptr_eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.state, &other.state)
    }

    /// Help-first wait through the unified engine ([`worker::wait_until`],
    /// DESIGN.md §9): if the calling thread is an AMT worker it runs
    /// pending tasks while the value is not ready (so the producer chain
    /// can make progress *through* the waiter — no deadlock, no burnt
    /// core); otherwise it escalates spin → yield → timed-park, and
    /// fulfilment delivers an explicit wake to parked waiters.  Returns
    /// on any outcome, error or value.
    pub fn wait(&self) {
        worker::wait_until(Some(&self.state.wakers), || self.is_ready());
    }

    /// The outcome, if completed (never blocks).
    pub fn try_outcome(&self) -> Option<&Outcome<T>> {
        self.state.value.get()
    }

    /// Wait, then return the outcome by reference.  The error-aware
    /// sibling of [`Future::get`].
    pub fn wait_outcome(&self) -> &Outcome<T> {
        self.wait();
        self.state.value.get().expect("ready after wait")
    }

    /// Wait, then clone the value out.
    ///
    /// # Panics
    /// On an error outcome — `get` is the infallible convenience accessor
    /// for chains known to succeed; error-tolerant callers use
    /// [`Future::wait_outcome`].
    pub fn get(&self) -> T
    where
        T: Clone,
    {
        match self.wait_outcome() {
            Outcome::Value(v) => v.clone(),
            Outcome::Cancelled => panic!("Future::get on a cancelled future"),
            Outcome::Panicked => panic!("Future::get on a panicked future (producer failed)"),
        }
    }

    /// Attach a continuation scheduled as an AMT task on `sched` once the
    /// value is ready (immediately if it already is).  Returns the future
    /// of the continuation's own result — chains compose.  On an error
    /// outcome `f` is skipped and the error is forwarded to the result
    /// future (short-circuit); if `f` panics the result future completes
    /// as [`Outcome::Panicked`] via the promise-drop backstop.
    pub fn then<R: Send + Sync + 'static>(
        &self,
        sched: &Arc<Scheduler>,
        f: impl FnOnce(&T) -> R + Send + 'static,
    ) -> Future<R> {
        self.then_named(sched, "future_continuation", f)
    }

    /// [`Future::then`] with an explicit task description (what the
    /// metrics/tracing layer shows — the OpenMP layer passes
    /// `"omp_explicit_task"` so dependent tasks are indistinguishable
    /// from undeferred ones).
    pub fn then_named<R: Send + Sync + 'static>(
        &self,
        sched: &Arc<Scheduler>,
        desc: &'static str,
        f: impl FnOnce(&T) -> R + Send + 'static,
    ) -> Future<R> {
        let promise = Promise::new();
        let result = promise.get_future();
        let body: Box<dyn FnOnce(&Outcome<T>) + Send> = Box::new(move |out: &Outcome<T>| {
            crate::util::fault::inject(crate::util::fault::Site::Continuation);
            match out {
                // A panic in `f` unwinds through here dropping `promise`
                // unfulfilled -> the drop backstop publishes `Panicked`.
                Outcome::Value(v) => promise.set_value(f(v)),
                Outcome::Cancelled => promise.set_cancelled(),
                Outcome::Panicked => promise.set_panicked(),
            }
        });
        self.attach(Cont::Spawned {
            sched: sched.clone(),
            desc,
            f: body,
        });
        result
    }

    /// Inline hook run on the fulfilling thread (or right here if already
    /// ready).  Crate-internal: hooks must be cheap and non-blocking —
    /// they execute inside the fulfilment path.
    pub(crate) fn on_ready(&self, f: impl FnOnce(&Outcome<T>) + Send + 'static) {
        self.attach(Cont::Inline(Box::new(f)));
    }

    fn attach(&self, cont: Cont<T>) {
        {
            let mut pending = lock_conts(&self.state.conts);
            // Checked under the lock: `fulfil` publishes the outcome
            // *before* draining under this same lock, so either we see the
            // outcome (dispatch ourselves, below) or our push is in the vec
            // the drain takes.  No continuation is lost or run twice.
            if self.state.value.get().is_none() {
                pending.push(cont);
                return;
            }
        }
        dispatch(self.state.clone(), cont);
    }
}

/// Registry of promises whose producer lives in **another address
/// space** (ISSUE 10): the coordinator registers an entry per task it
/// ships to a worker process, hands the `Future<T>` to the waiter, and
/// fulfils the entry when the completion frame arrives.  The entry `tag`
/// identifies the producer (the dist layer packs `shard slot` and link
/// generation into it) so that when a worker dies, [`fail_tag`] resolves
/// exactly its in-flight futures `Panicked` — a dead producer can never
/// hang a waiter, and a respawned worker (new generation, new tag) is
/// unaffected.  Dropping the registry itself resolves the remainder via
/// the `Promise` drop backstop, so there is no leak path.
///
/// [`fail_tag`]: RemoteRegistry::fail_tag
pub struct RemoteRegistry<T: Send + Sync + 'static> {
    next: AtomicUsize,
    entries: Mutex<std::collections::HashMap<u64, RemoteEntry<T>>>,
}

struct RemoteEntry<T: Send + Sync + 'static> {
    tag: u64,
    promise: Promise<T>,
}

impl<T: Send + Sync + 'static> Default for RemoteRegistry<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send + Sync + 'static> RemoteRegistry<T> {
    pub fn new() -> Self {
        Self {
            next: AtomicUsize::new(0),
            entries: Mutex::new(std::collections::HashMap::new()),
        }
    }

    /// Create one remote promise under `tag`; returns the wire id a
    /// completion must quote and the future the waiter holds.  Ids start
    /// at 1 and never repeat (0 stays free as a wire sentinel).
    pub fn register(&self, tag: u64) -> (u64, Future<T>) {
        let id = self.next.fetch_add(1, Ordering::Relaxed) as u64 + 1;
        let promise = Promise::new();
        let future = promise.get_future();
        lock_conts(&self.entries).insert(id, RemoteEntry { tag, promise });
        (id, future)
    }

    /// Resolve entry `id` with `outcome`.  Returns whether the entry was
    /// live — `false` for ids already resolved (e.g. failed by
    /// [`RemoteRegistry::fail_tag`] racing a late completion frame),
    /// which callers treat as a benign duplicate.
    pub fn fulfil(&self, id: u64, outcome: Outcome<T>) -> bool {
        let entry = lock_conts(&self.entries).remove(&id);
        match entry {
            // Outside the lock: fulfilment runs inline hooks.
            Some(e) => {
                e.promise.set_outcome(outcome);
                true
            }
            None => false,
        }
    }

    /// Resolve every entry registered under `tag` as `Panicked` — the
    /// producer process died.  Returns how many futures were failed.
    pub fn fail_tag(&self, tag: u64) -> usize {
        let drained: Vec<Promise<T>> = {
            let mut map = lock_conts(&self.entries);
            let ids: Vec<u64> = map
                .iter()
                .filter(|(_, e)| e.tag == tag)
                .map(|(id, _)| *id)
                .collect();
            ids.iter().filter_map(|id| map.remove(id)).map(|e| e.promise).collect()
        };
        let n = drained.len();
        for p in drained {
            p.set_panicked();
        }
        n
    }

    /// Resolve every live entry as `Cancelled` — orderly shutdown with
    /// work still in flight.  Returns how many futures were cancelled.
    pub fn cancel_all(&self) -> usize {
        let drained: Vec<Promise<T>> =
            lock_conts(&self.entries).drain().map(|(_, e)| e.promise).collect();
        let n = drained.len();
        for p in drained {
            p.set_cancelled();
        }
        n
    }

    /// Live (registered, unresolved) entries — the coordinator-side leak
    /// gauge `tests/dist.rs` asserts returns to 0.
    pub fn pending(&self) -> usize {
        lock_conts(&self.entries).len()
    }
}

/// Join N futures into one `Future<()>` that becomes ready when every
/// input has (`hpx::when_all` shape, completion-only: inputs are shared
/// futures, so values stay retrievable from the inputs themselves).
///
/// The countdown runs as inline hooks on the fulfilling threads — no task
/// is spawned per input; downstream work attaches to the returned future
/// with [`Future::then`].  An empty set yields an already-ready future.
///
/// The join reports the **worst** input outcome: all-`Value` → `Value(())`,
/// any `Cancelled` → `Cancelled`, any `Panicked` → `Panicked` — so one
/// failed input fails (not hangs) every continuation hung off the join.
/// It still waits for *all* inputs before completing (sibling work is
/// not abandoned mid-flight; cancellation of unstarted work is the
/// token layer's job).
pub fn when_all<T: Send + Sync + 'static>(futures: &[Future<T>]) -> Future<()> {
    let promise = Promise::new();
    let joined = promise.get_future();
    if futures.is_empty() {
        promise.set_value(());
        return joined;
    }
    let remaining = Arc::new(AtomicUsize::new(futures.len()));
    let worst = Arc::new(AtomicUsize::new(0));
    let promise = Arc::new(Mutex::new(Some(promise)));
    for fut in futures {
        let remaining = remaining.clone();
        let worst = worst.clone();
        let promise = promise.clone();
        fut.on_ready(move |out| {
            worst.fetch_max(out.severity(), Ordering::AcqRel);
            if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let p = promise
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .take()
                    .expect("when_all countdown reached zero twice");
                match worst.load(Ordering::Acquire) {
                    0 => p.set_value(()),
                    1 => p.set_cancelled(),
                    _ => p.set_panicked(),
                }
            }
        });
    }
    joined
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::PolicyKind;
    use std::sync::atomic::AtomicUsize as AU;

    #[test]
    fn ready_future_is_ready_and_gets() {
        let f = Future::ready(41usize);
        assert!(f.is_ready());
        assert_eq!(f.get(), 41);
    }

    #[test]
    fn set_value_fulfils_and_wait_returns() {
        let p = Promise::new();
        let f = p.get_future();
        assert!(!f.is_ready());
        p.set_value(7i64);
        f.wait();
        assert_eq!(f.get(), 7);
    }

    #[test]
    fn then_runs_as_task_after_fulfilment() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let p = Promise::new();
        let f = p.get_future();
        let g = f.then(&s, |v: &usize| v * 2);
        p.set_value(21);
        assert_eq!(g.get(), 42);
        s.shutdown();
    }

    #[test]
    fn then_on_already_ready_future_still_runs() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        let f = Future::ready(5usize);
        let g = f.then(&s, |v: &usize| v + 1);
        assert_eq!(g.get(), 6);
        s.shutdown();
    }

    #[test]
    fn multiple_continuations_all_observe_the_value() {
        let s = Scheduler::new(2, PolicyKind::Abp);
        let p = Promise::new();
        let f = p.get_future();
        let sum = Arc::new(AU::new(0));
        let outs: Vec<Future<()>> = (0..8)
            .map(|_| {
                let sum = sum.clone();
                f.then(&s, move |v: &usize| {
                    sum.fetch_add(*v, Ordering::SeqCst);
                })
            })
            .collect();
        p.set_value(3);
        when_all(&outs).wait();
        assert_eq!(sum.load(Ordering::SeqCst), 24);
        s.shutdown();
    }

    #[test]
    fn when_all_empty_set_is_immediately_ready() {
        let futures: Vec<Future<usize>> = Vec::new();
        let joined = when_all(&futures);
        assert!(joined.is_ready());
        joined.wait(); // must not block
    }

    #[test]
    fn when_all_waits_for_every_input() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let promises: Vec<Promise<usize>> = (0..10).map(|_| Promise::new()).collect();
        let futures: Vec<Future<usize>> = promises.iter().map(|p| p.get_future()).collect();
        let joined = when_all(&futures);
        assert!(!joined.is_ready());
        for (i, p) in promises.into_iter().enumerate() {
            assert!(!joined.is_ready(), "ready after only {i} inputs");
            p.set_value(i);
        }
        joined.wait();
        assert!(futures.iter().all(|f| f.is_ready()));
        s.shutdown();
    }

    #[test]
    fn continuation_chain_preserves_order_under_all_policies() {
        for policy in PolicyKind::ALL {
            let s = Scheduler::new(2, policy);
            let trace = Arc::new(Mutex::new(Vec::new()));
            let p = Promise::new();
            let mut f: Future<()> = p.get_future();
            for step in 0..16usize {
                let trace = trace.clone();
                f = f.then(&s, move |_| {
                    trace.lock().unwrap().push(step);
                });
            }
            p.set_value(());
            f.wait();
            assert_eq!(
                *trace.lock().unwrap(),
                (0..16).collect::<Vec<_>>(),
                "policy {}",
                policy.name()
            );
            s.shutdown();
        }
    }

    #[test]
    fn dropped_promise_fails_fast_instead_of_hanging() {
        let p: Promise<usize> = Promise::new();
        let f = p.get_future();
        drop(p);
        f.wait(); // must return, not hang
        assert!(f.wait_outcome().is_panicked());
    }

    #[test]
    fn panicking_then_body_fails_downstream_chain() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let p = Promise::new();
        let f = p.get_future();
        let g = f.then(&s, |_: &usize| -> usize { panic!("continuation bomb") });
        let h = g.then(&s, |v: &usize| v + 1);
        p.set_value(1);
        assert!(h.wait_outcome().is_panicked(), "error must propagate, not hang");
        assert_eq!(s.task_panics(), 1);
        s.shutdown();
    }

    #[test]
    fn cancelled_outcome_short_circuits_then_chain() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        let p: Promise<usize> = Promise::new();
        let f = p.get_future();
        let ran = Arc::new(AU::new(0));
        let ran2 = ran.clone();
        let g = f.then(&s, move |_| {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        p.set_cancelled();
        assert!(g.wait_outcome().is_cancelled());
        assert_eq!(ran.load(Ordering::SeqCst), 0, "skipped body must not run");
        s.shutdown();
    }

    #[test]
    fn when_all_propagates_worst_outcome() {
        let a = Future::ready(1usize);
        let p: Promise<usize> = Promise::new();
        let b = p.get_future();
        let q: Promise<usize> = Promise::new();
        let c = q.get_future();
        let joined = when_all(&[a, b, c]);
        p.set_cancelled();
        assert!(!joined.is_ready(), "join waits for every input");
        drop(q); // -> Panicked
        assert!(joined.wait_outcome().is_panicked(), "worst outcome wins");
    }

    #[test]
    fn remote_registry_fulfils_by_id() {
        let reg: RemoteRegistry<usize> = RemoteRegistry::new();
        let (id, fut) = reg.register(1);
        assert!(id > 0);
        assert_eq!(reg.pending(), 1);
        assert!(reg.fulfil(id, Outcome::Value(99)));
        assert_eq!(fut.get(), 99);
        assert_eq!(reg.pending(), 0);
        // A late duplicate (or unknown id) is a benign no-op.
        assert!(!reg.fulfil(id, Outcome::Value(1)));
        assert!(!reg.fulfil(12345, Outcome::Cancelled));
    }

    #[test]
    fn remote_registry_fail_tag_kills_only_that_producer() {
        let reg: RemoteRegistry<usize> = RemoteRegistry::new();
        let (_, dead_a) = reg.register(7);
        let (_, dead_b) = reg.register(7);
        let (live_id, live) = reg.register(8);
        assert_eq!(reg.fail_tag(7), 2);
        assert!(dead_a.wait_outcome().is_panicked());
        assert!(dead_b.wait_outcome().is_panicked());
        assert!(!live.is_ready(), "other producer's entries must survive");
        assert_eq!(reg.pending(), 1);
        assert!(reg.fulfil(live_id, Outcome::Value(3)));
        assert_eq!(live.get(), 3);
    }

    #[test]
    fn remote_registry_cancel_all_and_drop_backstop() {
        let reg: RemoteRegistry<usize> = RemoteRegistry::new();
        let (_, a) = reg.register(1);
        assert_eq!(reg.cancel_all(), 1);
        assert!(a.wait_outcome().is_cancelled());
        assert_eq!(reg.pending(), 0);

        // Dropping the registry with live entries must fail them fast
        // (promise-drop backstop), never leave a waiter hanging.
        let reg: RemoteRegistry<usize> = RemoteRegistry::new();
        let (_, orphan) = reg.register(1);
        drop(reg);
        assert!(orphan.wait_outcome().is_panicked());
    }

    #[test]
    fn get_panics_descriptively_on_error_outcome() {
        let f: Future<usize> = Future::with_outcome(Outcome::Cancelled);
        let err =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.get())).unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("cancelled"), "got: {msg}");
    }
}
