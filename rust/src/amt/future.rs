//! Futures and continuations — the `hpx::future`/`hpx::promise` analog
//! (ISSUE 2; DESIGN.md §7).
//!
//! The paper's closing argument is that an OpenMP-over-AMT runtime only
//! pays off once applications can leave fork/join behind for a *task-based
//! dataflow* model — exactly what HPX's `future`/`when_all`/`then` triple
//! provides.  This module is that missing subsystem:
//!
//! * [`Promise<T>`] — the write end: fulfilled exactly once.
//! * [`Future<T>`]  — the (shared, clonable) read end: `hpx::shared_future`
//!   semantics — continuations observe the value by reference, any number
//!   of continuations may attach, before or after fulfilment.
//! * [`Future::then`] — attaches a continuation that is **scheduled as an
//!   AMT task** on the fulfilling thread's `Scheduler` handle: no new OS
//!   threads, no blocking, just a `Scheduler::spawn` at fulfilment (or
//!   immediately if the value is already there).
//! * [`when_all`] — joins N futures into one `Future<()>` with inline
//!   countdown hooks (no task spawned per input; the combined future's own
//!   continuations are where work hangs).
//! * [`Future::wait`] — a **help-first** wait for the blocking edges of
//!   the system: a worker that waits runs pending tasks via the unified
//!   [`worker::wait_until`] engine instead of burning its core, exactly
//!   like the OpenMP layer's barriers, and fulfilment wakes parked
//!   waiters explicitly.
//!
//! The state machine of one future (§7 of DESIGN.md):
//!
//! ```text
//! Pending{conts} --set_value--> Ready(v) ; conts drained:
//!     Spawned  -> Scheduler::spawn(move || f(&v))   (runs on a worker)
//!     Inline   -> f(&v) on the fulfilling thread    (cheap hooks only)
//! attach after Ready -> dispatched immediately (same two flavors)
//! ```
//!
//! Dropping a [`Promise`] without fulfilling it leaks its pending
//! continuations (they never run) — a "broken promise".  The OpenMP
//! tasking layer fulfils on every path (completion promises are set via
//! an RAII retire guard, so even a panicking task body releases its
//! dependents).  A raw [`Future::then`] continuation that panics, by
//! contrast, leaves its *result* future forever pending — there is no
//! value to fulfil it with and no error channel; the panic itself is
//! still isolated and counted by the worker layer.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use once_cell::sync::OnceCell;

use super::park::WakeList;
use super::scheduler::Scheduler;
use super::task::{Hint, Priority};
use super::worker;

/// One registered continuation.
enum Cont<T> {
    /// Scheduled as an AMT task at fulfilment — the `future::then` path.
    Spawned {
        sched: Arc<Scheduler>,
        desc: &'static str,
        f: Box<dyn FnOnce(&T) + Send>,
    },
    /// Run inline on the fulfilling thread.  Reserved for cheap,
    /// non-blocking bookkeeping (the [`when_all`] countdown): user code
    /// never runs inline, so fulfilment cannot block on it.
    Inline(Box<dyn FnOnce(&T) + Send>),
}

/// Shared state of one promise/future pair.
struct SharedState<T> {
    /// Write-once value cell; `get().is_some()` is the ready flag (the
    /// cell's internal ordering publishes the value to readers).
    value: OnceCell<T>,
    /// Continuations registered while pending; drained at fulfilment.
    conts: Mutex<Vec<Cont<T>>>,
    /// Parked [`Future::wait`]ers; notified right after the value lands
    /// (the unified wait engine's explicit wake channel — DESIGN.md §9).
    wakers: WakeList,
}

fn dispatch<T: Send + Sync + 'static>(state: Arc<SharedState<T>>, cont: Cont<T>) {
    match cont {
        Cont::Inline(f) => f(state.value.get().expect("dispatch before fulfilment")),
        Cont::Spawned { sched, desc, f } => {
            sched.spawn(Priority::Normal, Hint::Any, desc, move || {
                f(state.value.get().expect("dispatch before fulfilment"));
            });
        }
    }
}

/// The write end: fulfil with [`Promise::set_value`] exactly once.
pub struct Promise<T> {
    state: Arc<SharedState<T>>,
}

impl<T: Send + Sync + 'static> Promise<T> {
    pub fn new() -> Self {
        Self {
            state: Arc::new(SharedState {
                value: OnceCell::new(),
                conts: Mutex::new(Vec::new()),
                wakers: WakeList::new(),
            }),
        }
    }

    /// The read end (`hpx::promise::get_future`); callable any number of
    /// times — futures are shared handles.
    pub fn get_future(&self) -> Future<T> {
        Future {
            state: self.state.clone(),
        }
    }

    /// Fulfil the promise: publish the value, then dispatch every
    /// registered continuation (inline hooks on this thread, `then`
    /// continuations as AMT tasks).  Consumes the promise — a future is
    /// fulfilled at most once.
    pub fn set_value(self, value: T) {
        if self.state.value.set(value).is_err() {
            unreachable!("Promise::set_value consumes self; double-fulfil is unconstructible");
        }
        // Wake parked `wait`ers first — they only need the ready flag,
        // which is already published — then dispatch continuations.
        self.state.wakers.notify_all();
        // Continuations registered from here on observe the value under the
        // lock and dispatch themselves; we drain only what was pending.
        let pending = std::mem::take(&mut *self.state.conts.lock().unwrap());
        for cont in pending {
            dispatch(self.state.clone(), cont);
        }
    }
}

impl<T: Send + Sync + 'static> Default for Promise<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// The read end: a shared handle to an eventually-available value.
pub struct Future<T> {
    state: Arc<SharedState<T>>,
}

impl<T> Clone for Future<T> {
    fn clone(&self) -> Self {
        Self {
            state: self.state.clone(),
        }
    }
}

impl<T: Send + Sync + 'static> Future<T> {
    /// An already-fulfilled future (`hpx::make_ready_future`).
    pub fn ready(value: T) -> Self {
        let state = Arc::new(SharedState {
            value: OnceCell::new(),
            conts: Mutex::new(Vec::new()),
            wakers: WakeList::new(),
        });
        let _ = state.value.set(value);
        Self { state }
    }

    /// Whether the value is available (never blocks).
    pub fn is_ready(&self) -> bool {
        self.state.value.get().is_some()
    }

    /// Whether two handles share one underlying promise/future state —
    /// identity, not value, equality (what a dependence engine needs to
    /// avoid registering a task as its own predecessor).
    pub fn ptr_eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.state, &other.state)
    }

    /// Help-first wait through the unified engine ([`worker::wait_until`],
    /// DESIGN.md §9): if the calling thread is an AMT worker it runs
    /// pending tasks while the value is not ready (so the producer chain
    /// can make progress *through* the waiter — no deadlock, no burnt
    /// core); otherwise it escalates spin → yield → timed-park, and
    /// fulfilment delivers an explicit wake to parked waiters.
    pub fn wait(&self) {
        worker::wait_until(Some(&self.state.wakers), || self.is_ready());
    }

    /// Wait, then clone the value out.
    pub fn get(&self) -> T
    where
        T: Clone,
    {
        self.wait();
        self.state.value.get().expect("ready after wait").clone()
    }

    /// Attach a continuation scheduled as an AMT task on `sched` once the
    /// value is ready (immediately if it already is).  Returns the future
    /// of the continuation's own result — chains compose.
    pub fn then<R: Send + Sync + 'static>(
        &self,
        sched: &Arc<Scheduler>,
        f: impl FnOnce(&T) -> R + Send + 'static,
    ) -> Future<R> {
        self.then_named(sched, "future_continuation", f)
    }

    /// [`Future::then`] with an explicit task description (what the
    /// metrics/tracing layer shows — the OpenMP layer passes
    /// `"omp_explicit_task"` so dependent tasks are indistinguishable
    /// from undeferred ones).
    pub fn then_named<R: Send + Sync + 'static>(
        &self,
        sched: &Arc<Scheduler>,
        desc: &'static str,
        f: impl FnOnce(&T) -> R + Send + 'static,
    ) -> Future<R> {
        let promise = Promise::new();
        let result = promise.get_future();
        let body: Box<dyn FnOnce(&T) + Send> = Box::new(move |v: &T| {
            promise.set_value(f(v));
        });
        self.attach(Cont::Spawned {
            sched: sched.clone(),
            desc,
            f: body,
        });
        result
    }

    /// Inline hook run on the fulfilling thread (or right here if already
    /// ready).  Crate-internal: hooks must be cheap and non-blocking —
    /// they execute inside `set_value`.
    pub(crate) fn on_ready(&self, f: impl FnOnce(&T) + Send + 'static) {
        self.attach(Cont::Inline(Box::new(f)));
    }

    fn attach(&self, cont: Cont<T>) {
        {
            let mut pending = self.state.conts.lock().unwrap();
            // Checked under the lock: `set_value` publishes the value
            // *before* draining under this same lock, so either we see the
            // value (dispatch ourselves, below) or our push is in the vec
            // the drain takes.  No continuation is lost or run twice.
            if self.state.value.get().is_none() {
                pending.push(cont);
                return;
            }
        }
        dispatch(self.state.clone(), cont);
    }
}

/// Join N futures into one `Future<()>` that becomes ready when every
/// input has (`hpx::when_all` shape, completion-only: inputs are shared
/// futures, so values stay retrievable from the inputs themselves).
///
/// The countdown runs as inline hooks on the fulfilling threads — no task
/// is spawned per input; downstream work attaches to the returned future
/// with [`Future::then`].  An empty set yields an already-ready future.
pub fn when_all<T: Send + Sync + 'static>(futures: &[Future<T>]) -> Future<()> {
    let promise = Promise::new();
    let joined = promise.get_future();
    if futures.is_empty() {
        promise.set_value(());
        return joined;
    }
    let remaining = Arc::new(AtomicUsize::new(futures.len()));
    let promise = Arc::new(Mutex::new(Some(promise)));
    for fut in futures {
        let remaining = remaining.clone();
        let promise = promise.clone();
        fut.on_ready(move |_| {
            if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let p = promise
                    .lock()
                    .unwrap()
                    .take()
                    .expect("when_all countdown reached zero twice");
                p.set_value(());
            }
        });
    }
    joined
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amt::PolicyKind;
    use std::sync::atomic::AtomicUsize as AU;

    #[test]
    fn ready_future_is_ready_and_gets() {
        let f = Future::ready(41usize);
        assert!(f.is_ready());
        assert_eq!(f.get(), 41);
    }

    #[test]
    fn set_value_fulfils_and_wait_returns() {
        let p = Promise::new();
        let f = p.get_future();
        assert!(!f.is_ready());
        p.set_value(7i64);
        f.wait();
        assert_eq!(f.get(), 7);
    }

    #[test]
    fn then_runs_as_task_after_fulfilment() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let p = Promise::new();
        let f = p.get_future();
        let g = f.then(&s, |v: &usize| v * 2);
        p.set_value(21);
        assert_eq!(g.get(), 42);
        s.shutdown();
    }

    #[test]
    fn then_on_already_ready_future_still_runs() {
        let s = Scheduler::new(1, PolicyKind::PriorityLocal);
        let f = Future::ready(5usize);
        let g = f.then(&s, |v: &usize| v + 1);
        assert_eq!(g.get(), 6);
        s.shutdown();
    }

    #[test]
    fn multiple_continuations_all_observe_the_value() {
        let s = Scheduler::new(2, PolicyKind::Abp);
        let p = Promise::new();
        let f = p.get_future();
        let sum = Arc::new(AU::new(0));
        let outs: Vec<Future<()>> = (0..8)
            .map(|_| {
                let sum = sum.clone();
                f.then(&s, move |v: &usize| {
                    sum.fetch_add(*v, Ordering::SeqCst);
                })
            })
            .collect();
        p.set_value(3);
        when_all(&outs).wait();
        assert_eq!(sum.load(Ordering::SeqCst), 24);
        s.shutdown();
    }

    #[test]
    fn when_all_empty_set_is_immediately_ready() {
        let futures: Vec<Future<usize>> = Vec::new();
        let joined = when_all(&futures);
        assert!(joined.is_ready());
        joined.wait(); // must not block
    }

    #[test]
    fn when_all_waits_for_every_input() {
        let s = Scheduler::new(2, PolicyKind::PriorityLocal);
        let promises: Vec<Promise<usize>> = (0..10).map(|_| Promise::new()).collect();
        let futures: Vec<Future<usize>> = promises.iter().map(|p| p.get_future()).collect();
        let joined = when_all(&futures);
        assert!(!joined.is_ready());
        for (i, p) in promises.into_iter().enumerate() {
            assert!(!joined.is_ready(), "ready after only {i} inputs");
            p.set_value(i);
        }
        joined.wait();
        assert!(futures.iter().all(|f| f.is_ready()));
        s.shutdown();
    }

    #[test]
    fn continuation_chain_preserves_order_under_all_policies() {
        for policy in PolicyKind::ALL {
            let s = Scheduler::new(2, policy);
            let trace = Arc::new(Mutex::new(Vec::new()));
            let p = Promise::new();
            let mut f: Future<()> = p.get_future();
            for step in 0..16usize {
                let trace = trace.clone();
                f = f.then(&s, move |_| {
                    trace.lock().unwrap().push(step);
                });
            }
            p.set_value(());
            f.wait();
            assert_eq!(
                *trace.lock().unwrap(),
                (0..16).collect::<Vec<_>>(),
                "policy {}",
                policy.name()
            );
            s.shutdown();
        }
    }
}
