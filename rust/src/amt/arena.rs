//! Per-worker arena for task payload allocation (ISSUE 7).
//!
//! Every spawned task used to pay one `Box::new` on the submitting
//! thread and one `drop` on the executing worker — for fork-heavy
//! Blazemark loops that is two trips through the global allocator per
//! chunk, on the spawn fast path.  This module recycles task payload
//! blocks through a Bonwick-style **magazine/depot** hierarchy instead:
//!
//! * a thread-local *magazine* (plain `Vec` freelist) per size class —
//!   the common case is a same-thread pop/push with no atomics at all;
//! * a global mutex-guarded *depot* per class that magazines refill
//!   from in batches and overflow into, so blocks freed on one worker
//!   are reused by another instead of accumulating;
//! * a hard fallback to `Box` for payloads that are too big, too
//!   aligned, or zero-sized (a boxed ZST closure never allocates).
//!
//! [`Payload`] is the task-body representation: either a classic boxed
//! closure or an [`ArenaFn`] whose closure lives in a recycled block.
//! Invocation moves the closure out of the block *first*, so the block
//! is recyclable even if the closure panics; dropping an un-invoked
//! payload (a cancelled task) drops the closure in place and recycles
//! the block too — no leak on any path, which `loom`-free code has to
//! get right by construction.
//!
//! Workers call [`trim_thread`] on exit to flush their magazines back
//! to the depot; the depot itself is capped, beyond which blocks return
//! to the system allocator.  [`stats`] exposes global counters for
//! `hpxmp info` and tests.

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::cell::RefCell;
use std::mem;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use once_cell::sync::Lazy;

/// Block size classes (bytes).  Loop-chunk closures capture a handful
/// of `Range`/pointer/`Arc` words (~48–112 bytes); 256 covers every
/// closure the runtime itself spawns today.
pub const CLASS_SIZES: [usize; 3] = [64, 128, 256];

/// Alignment of every block — enough for any word/pointer/f64 capture.
/// Closures needing more fall back to `Box`.
pub const ALIGN: usize = 16;

/// Per-thread magazine capacity per class; overflow drains to the depot.
const FREELIST_CAP: usize = 128;

/// Blocks grabbed from the depot per refill (amortizes the lock).
const REFILL_BATCH: usize = 32;

/// Depot capacity per class; overflow returns to the system allocator.
const DEPOT_CAP: usize = 1024;

/// An owned raw block of `CLASS_SIZES[class]` bytes at [`ALIGN`].
/// Dropping a `Block` returns the memory to the system allocator, so a
/// magazine or depot torn down without [`trim_thread`] cannot leak.
struct Block {
    ptr: NonNull<u8>,
    class: usize,
}

// SAFETY: a Block is exclusively-owned raw memory with no thread
// affinity; moving it between threads moves ownership of the bytes.
unsafe impl Send for Block {}

impl Drop for Block {
    fn drop(&mut self) {
        FREED.fetch_add(1, Ordering::Relaxed);
        // SAFETY: ptr was allocated by `alloc_block` with this layout.
        unsafe { dealloc(self.ptr.as_ptr(), class_layout(self.class)) };
    }
}

thread_local! {
    static MAGAZINES: RefCell<[Vec<Block>; 3]> =
        RefCell::new([Vec::new(), Vec::new(), Vec::new()]);
}

static DEPOT: Lazy<[Mutex<Vec<Block>>; 3]> =
    Lazy::new(|| std::array::from_fn(|_| Mutex::new(Vec::new())));

static FRESH_ALLOCS: AtomicU64 = AtomicU64::new(0);
static REUSES: AtomicU64 = AtomicU64::new(0);
static FALLBACKS: AtomicU64 = AtomicU64::new(0);
static RECYCLED: AtomicU64 = AtomicU64::new(0);
static FREED: AtomicU64 = AtomicU64::new(0);

/// Global arena counters (monotonic since process start).
#[derive(Clone, Copy, Debug, Default)]
pub struct ArenaStats {
    /// Blocks carved fresh from the system allocator.
    pub fresh_allocs: u64,
    /// Payload allocations served from a magazine or the depot.
    pub reuses: u64,
    /// Payloads that fell back to `Box` (size/align/ZST).
    pub fallbacks: u64,
    /// Blocks returned to a magazine or the depot.
    pub recycled: u64,
    /// Blocks released back to the system (depot overflow / trim).
    pub freed: u64,
}

/// Snapshot the global arena counters.
pub fn stats() -> ArenaStats {
    ArenaStats {
        fresh_allocs: FRESH_ALLOCS.load(Ordering::Relaxed),
        reuses: REUSES.load(Ordering::Relaxed),
        fallbacks: FALLBACKS.load(Ordering::Relaxed),
        recycled: RECYCLED.load(Ordering::Relaxed),
        freed: FREED.load(Ordering::Relaxed),
    }
}

/// Blocks currently cached in *this thread's* magazines, per class —
/// deterministic observability for tests.
pub fn local_cached() -> [usize; 3] {
    MAGAZINES
        .try_with(|m| {
            let m = m.borrow();
            [m[0].len(), m[1].len(), m[2].len()]
        })
        .unwrap_or([0; 3])
}

fn class_layout(class: usize) -> Layout {
    // Infallible: every (CLASS_SIZES[i], ALIGN) pair is valid.
    Layout::from_size_align(CLASS_SIZES[class], ALIGN).unwrap()
}

/// Smallest class that fits `(size, align)`, or `None` for the `Box`
/// fallback.  ZSTs go to `Box` deliberately: boxing a zero-sized
/// closure performs no allocation at all.
fn class_for(size: usize, align: usize) -> Option<usize> {
    if size == 0 || align > ALIGN {
        return None;
    }
    CLASS_SIZES.iter().position(|&c| size <= c)
}

fn alloc_block(class: usize) -> NonNull<u8> {
    let from_cache = MAGAZINES
        .try_with(|m| {
            let mut mags = m.borrow_mut();
            if let Some(b) = mags[class].pop() {
                return Some(b);
            }
            // Magazine empty: refill a batch from the depot under one
            // lock acquisition.
            let mut depot = DEPOT[class].lock().unwrap();
            let take = REFILL_BATCH.min(depot.len());
            if take == 0 {
                return None;
            }
            let at = depot.len() - take;
            mags[class].extend(depot.drain(at..));
            drop(depot);
            mags[class].pop()
        })
        .unwrap_or(None);
    if let Some(b) = from_cache {
        REUSES.fetch_add(1, Ordering::Relaxed);
        let p = b.ptr;
        mem::forget(b); // ownership transfers to the caller's ArenaFn
        return p;
    }
    FRESH_ALLOCS.fetch_add(1, Ordering::Relaxed);
    let layout = class_layout(class);
    // SAFETY: layout has non-zero size.
    let p = unsafe { alloc(layout) };
    NonNull::new(p).unwrap_or_else(|| handle_alloc_error(layout))
}

fn recycle(ptr: NonNull<u8>, class: usize) {
    let block = Block { ptr, class };
    let kept = MAGAZINES
        .try_with(|m| {
            let mut mags = m.borrow_mut();
            if mags[class].len() < FREELIST_CAP {
                mags[class].push(Block { ptr, class });
                return true;
            }
            let mut depot = DEPOT[class].lock().unwrap();
            if depot.len() < DEPOT_CAP {
                depot.push(Block { ptr, class });
                return true;
            }
            false
        })
        .unwrap_or_else(|_| {
            // TLS already torn down (thread exit path): go via the depot.
            let mut depot = DEPOT[class].lock().unwrap();
            if depot.len() < DEPOT_CAP {
                depot.push(Block { ptr, class });
                true
            } else {
                false
            }
        });
    if kept {
        RECYCLED.fetch_add(1, Ordering::Relaxed);
        mem::forget(block); // ownership moved into the cache
    } else {
        drop(block); // depot full: back to the system allocator
    }
}

/// Flush this thread's magazines back to the depot (worker exit).
/// Depot overflow is released to the system allocator.
pub fn trim_thread() {
    let _ = MAGAZINES.try_with(|m| {
        let mut mags = m.borrow_mut();
        for (class, mag) in mags.iter_mut().enumerate() {
            if mag.is_empty() {
                continue;
            }
            let mut overflow = Vec::new();
            {
                let mut depot = DEPOT[class].lock().unwrap();
                while let Some(b) = mag.pop() {
                    if depot.len() < DEPOT_CAP {
                        depot.push(b);
                    } else {
                        overflow.push(b);
                    }
                }
            }
            drop(overflow); // dealloc outside the depot lock
        }
    });
}

type CallFn = unsafe fn(*mut u8);

/// A closure stored inside a recycled arena block: a hand-rolled
/// `Box<dyn FnOnce()>` whose storage comes from the magazine layer.
pub struct ArenaFn {
    ptr: NonNull<u8>,
    class: usize,
    call: CallFn,
    drop_fn: CallFn,
}

// SAFETY: the stored closure is `F: FnOnce() + Send + 'static` (the
// only constructor bound), and the block is exclusively owned.
unsafe impl Send for ArenaFn {}

unsafe fn call_fn<F: FnOnce()>(p: *mut u8) {
    // Move the closure out *before* running it: the block holds dead
    // bytes from here on, so the caller may recycle it even if `f`
    // panics (the moved-out `f` unwinds and drops normally).
    let f = std::ptr::read(p.cast::<F>());
    f();
}

unsafe fn drop_fn<F>(p: *mut u8) {
    std::ptr::drop_in_place(p.cast::<F>());
}

impl ArenaFn {
    /// Store `f` in an arena block, or hand it back if no class fits.
    fn new<F: FnOnce() + Send + 'static>(f: F) -> Result<Self, F> {
        let Some(class) = class_for(mem::size_of::<F>(), mem::align_of::<F>()) else {
            return Err(f);
        };
        let ptr = alloc_block(class);
        // SAFETY: the block is at least size_of::<F>() bytes at ALIGN ≥
        // align_of::<F>() (checked by class_for) and exclusively ours.
        unsafe { std::ptr::write(ptr.as_ptr().cast::<F>(), f) };
        Ok(Self {
            ptr,
            class,
            call: call_fn::<F>,
            drop_fn: drop_fn::<F>,
        })
    }

    /// Run the stored closure and recycle the block (even on panic —
    /// the closure is moved out of the block before it runs).
    pub fn invoke(self) {
        let (ptr, class, call) = (self.ptr, self.class, self.call);
        mem::forget(self);
        struct Recycle(NonNull<u8>, usize);
        impl Drop for Recycle {
            fn drop(&mut self) {
                recycle(self.0, self.1);
            }
        }
        let _recycle = Recycle(ptr, class);
        // SAFETY: ptr holds a valid F (written in `new`, not yet
        // consumed); `call` reads it out immediately.
        unsafe { call(ptr.as_ptr()) };
    }
}

impl Drop for ArenaFn {
    /// An un-invoked payload (cancelled task): drop the closure in
    /// place, then recycle the block.
    fn drop(&mut self) {
        // SAFETY: the closure was written in `new` and never consumed
        // (invoke() forgets self before reading it out).
        unsafe { (self.drop_fn)(self.ptr.as_ptr()) };
        recycle(self.ptr, self.class);
    }
}

/// A task body: boxed (the classic path, and the fallback for payloads
/// no arena class fits) or arena-resident.
pub enum Payload {
    /// Heap-boxed closure.
    Boxed(Box<dyn FnOnce() + Send + 'static>),
    /// Closure stored in a recycled arena block.
    Arena(ArenaFn),
}

impl Payload {
    /// Wrap `f`, preferring an arena block over a fresh heap box.
    pub fn new<F: FnOnce() + Send + 'static>(f: F) -> Self {
        match ArenaFn::new(f) {
            Ok(a) => Payload::Arena(a),
            Err(f) => {
                FALLBACKS.fetch_add(1, Ordering::Relaxed);
                Payload::Boxed(Box::new(f))
            }
        }
    }

    /// Consume and run the body.
    pub fn invoke(self) {
        match self {
            Payload::Boxed(f) => f(),
            Payload::Arena(a) => a.invoke(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn class_selection_covers_sizes_and_rejects_misfits() {
        assert_eq!(class_for(1, 8), Some(0));
        assert_eq!(class_for(64, 8), Some(0));
        assert_eq!(class_for(65, 8), Some(1));
        assert_eq!(class_for(128, 16), Some(1));
        assert_eq!(class_for(256, 16), Some(2));
        assert_eq!(class_for(257, 8), None, "oversized → Box");
        assert_eq!(class_for(0, 1), None, "ZST → Box (free)");
        assert_eq!(class_for(32, 32), None, "over-aligned → Box");
    }

    #[test]
    fn payload_invokes_exactly_once_and_recycles() {
        let n = Arc::new(AtomicUsize::new(0));
        let before = local_cached();
        let n2 = n.clone();
        let p = Payload::new(move || {
            n2.fetch_add(1, Ordering::SeqCst);
        });
        assert!(
            matches!(p, Payload::Arena(_)),
            "small closure should be arena-resident"
        );
        p.invoke();
        assert_eq!(n.load(Ordering::SeqCst), 1);
        let after = local_cached();
        // The block came back to this thread's magazine.
        assert!(after.iter().sum::<usize>() >= before.iter().sum::<usize>());
    }

    #[test]
    fn same_thread_reuse_hits_the_magazine() {
        // Warm the magazine, then check a spin of alloc/invoke cycles
        // raises reuses without raising fresh allocs by the same amount.
        for _ in 0..8 {
            Payload::new(|| {}).invoke();
        }
        let s0 = stats();
        for _ in 0..32 {
            Payload::new(|| {}).invoke();
        }
        let s1 = stats();
        assert!(
            s1.reuses > s0.reuses,
            "repeated same-class payloads must recycle ({s0:?} → {s1:?})"
        );
    }

    #[test]
    fn dropping_uninvoked_payload_drops_captures() {
        struct Canary(Arc<AtomicUsize>);
        impl Drop for Canary {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let canary = Canary(drops.clone());
        let p = Payload::new(move || {
            // Never runs; the capture must still drop exactly once.
            let _keep = &canary;
        });
        drop(p);
        assert_eq!(drops.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn panicking_payload_still_recycles_and_unwinds() {
        let r = std::panic::catch_unwind(|| {
            Payload::new(|| panic!("boom")).invoke();
        });
        assert!(r.is_err());
        // A fresh payload after the panic must still work.
        let n = Arc::new(AtomicUsize::new(0));
        let n2 = n.clone();
        Payload::new(move || {
            n2.fetch_add(1, Ordering::SeqCst);
        })
        .invoke();
        assert_eq!(n.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn oversized_payload_falls_back_to_box() {
        let big = [0u8; 512];
        let s0 = stats();
        let p = Payload::new(move || {
            std::hint::black_box(&big);
        });
        assert!(matches!(p, Payload::Boxed(_)));
        p.invoke();
        assert!(stats().fallbacks > s0.fallbacks);
    }

    #[test]
    fn trim_flushes_local_magazines() {
        for _ in 0..4 {
            Payload::new(|| {}).invoke();
        }
        assert!(local_cached().iter().sum::<usize>() > 0);
        trim_thread();
        assert_eq!(local_cached(), [0, 0, 0]);
        // And allocation still works afterwards (refills from depot).
        Payload::new(|| {}).invoke();
    }

    #[test]
    fn cross_thread_recycling_via_depot() {
        // Allocate on this thread, invoke (and thus recycle) on another:
        // the block must land in *that* thread's magazine or the depot,
        // and both threads stay functional.
        let p = Payload::new(|| {});
        std::thread::spawn(move || {
            p.invoke();
            trim_thread();
        })
        .join()
        .unwrap();
        Payload::new(|| {}).invoke();
    }
}
