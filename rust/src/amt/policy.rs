//! The seven HPX thread-scheduling policies (paper §3.2) behind one trait.
//!
//! | Paper policy                | Type here          | Structure |
//! |-----------------------------|--------------------|-----------|
//! | priority local (default)    | [`PriorityLocal`]  | per-worker high-prio queue + Chase–Lev deque + global injector, stealing |
//! | static priority             | [`StaticPriority`] | per-worker priority queues, round-robin placement, **no stealing** |
//! | local                       | [`Local`]          | per-worker deque + injector, stealing |
//! | global                      | [`Global`]         | one shared queue |
//! | ABP                         | [`Abp`]            | lock-free deque per worker, steal from the opposite end |
//! | hierarchy                   | [`Hierarchical`]   | binary tree of queues, workers traverse leaf→root |
//! | periodic priority           | [`PeriodicPriority`]| per-worker queue + shared high + shared low queues |
//!
//! Every policy upholds the conservation invariant (no task lost, none
//! duplicated), which `rust/tests/prop_invariants.rs` checks property-style
//! across all seven.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crossbeam_utils::CachePadded;

use super::deque::{ChaseLev, Steal};
use super::task::{Hint, Priority, Task};

/// Which policy to instantiate (CLI/env-selectable: `HPXMP_POLICY`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    PriorityLocal,
    StaticPriority,
    Local,
    Global,
    Abp,
    Hierarchical,
    PeriodicPriority,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 7] = [
        PolicyKind::PriorityLocal,
        PolicyKind::StaticPriority,
        PolicyKind::Local,
        PolicyKind::Global,
        PolicyKind::Abp,
        PolicyKind::Hierarchical,
        PolicyKind::PeriodicPriority,
    ];

    /// Accepted spellings (canonical names first, aliases after) — the
    /// table both [`PolicyKind::parse`] and [`PolicyKind::parse_or_list`]
    /// resolve through via [`crate::util::cli::lookup_choice`], the same
    /// helper behind the CLI's `--exec` selector.
    pub const CHOICES: &[(&str, PolicyKind)] = &[
        ("priority-local", PolicyKind::PriorityLocal),
        ("static-priority", PolicyKind::StaticPriority),
        ("local", PolicyKind::Local),
        ("global", PolicyKind::Global),
        ("abp", PolicyKind::Abp),
        ("hierarchical", PolicyKind::Hierarchical),
        ("periodic-priority", PolicyKind::PeriodicPriority),
        ("priority_local", PolicyKind::PriorityLocal),
        ("default", PolicyKind::PriorityLocal),
        ("static", PolicyKind::StaticPriority),
        ("hierarchy", PolicyKind::Hierarchical),
        ("periodic", PolicyKind::PeriodicPriority),
    ];

    pub fn parse(s: &str) -> Option<Self> {
        crate::util::cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for CLI flags / env vars: an unknown value reports
    /// the full valid set instead of silently defaulting.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        crate::util::cli::parse_choice("policy", s, Self::CHOICES)
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::PriorityLocal => "priority-local",
            PolicyKind::StaticPriority => "static-priority",
            PolicyKind::Local => "local",
            PolicyKind::Global => "global",
            PolicyKind::Abp => "abp",
            PolicyKind::Hierarchical => "hierarchical",
            PolicyKind::PeriodicPriority => "periodic-priority",
        }
    }

    pub fn build(&self, workers: usize) -> Box<dyn Queues> {
        match self {
            PolicyKind::PriorityLocal => Box::new(PriorityLocal::new(workers)),
            PolicyKind::StaticPriority => Box::new(StaticPriority::new(workers)),
            PolicyKind::Local => Box::new(Local::new(workers)),
            PolicyKind::Global => Box::new(Global::new(workers)),
            PolicyKind::Abp => Box::new(Abp::new(workers)),
            PolicyKind::Hierarchical => Box::new(Hierarchical::new(workers)),
            PolicyKind::PeriodicPriority => Box::new(PeriodicPriority::new(workers)),
        }
    }
}

/// The queue discipline a scheduler instance runs on.
///
/// `submitter` is `Some(w)` when the pushing thread *is* worker `w` (deque
/// owners may use their lock-free push path); `None` for external threads.
pub trait Queues: Send + Sync {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>);
    /// Fast local acquisition for worker `w`.
    fn pop(&self, worker: usize) -> Option<Task>;
    /// Cross-queue acquisition (work stealing / shared-queue fallback).
    /// `spin` differentiates steal attempts so victims rotate; `limit`
    /// bounds how many tasks one visit may claim (steal-half batching —
    /// `limit == 1` reproduces the classic single steal).  Returns the
    /// first claimed task plus the total number claimed this visit;
    /// extras beyond the first are requeued onto worker `w`'s *own*
    /// queues, never a private stash, so help-first waiters (`help_one`)
    /// always see every runnable task.
    ///
    /// Contract: call only from the thread that owns worker slot `w` —
    /// the requeue uses the owner-side deque push.
    fn steal(&self, worker: usize, spin: usize, limit: usize) -> Option<(Task, usize)>;
    /// Racy occupancy estimate for idle heuristics.
    fn approx_len(&self) -> usize;
    fn workers(&self) -> usize;
}

/// The one victim-rotation helper every stealing policy shares (ISSUE 8
/// satellite: previously each policy hand-rolled `(w + k + spin) % n`,
/// which skips a victim whenever `(k + spin) % n == 0` lands the probe on
/// the thief itself — and two policies forgot the self-check entirely).
/// Yields every worker except `w` exactly once, starting at an offset
/// rotated by `spin`.
pub(crate) fn rotation(w: usize, n: usize, spin: usize) -> impl Iterator<Item = usize> {
    let m = n.saturating_sub(1);
    (0..m).map(move |j| (w + 1 + (spin + j) % m) % n)
}

/// Per-thief victim ordering (ISSUE 8: locality-aware victim selection).
///
/// Probe order for thief `w`: (1) the last victim `w` stole from
/// successfully — task graphs exhibit producer/consumer affinity, so the
/// queue that fed us once likely still has work; (2) `w`'s locality group
/// (contiguous blocks of [`VictimTable::GROUP`] workers — the same
/// block-of-neighbors shape the PR 7 first-touch arena layer assumes, so
/// group-mates share cache/NUMA locality); (3) full [`rotation`] over the
/// remaining workers.  A remembered victim that misses
/// [`VictimTable::MAX_FAILS`] visits in a row is forgotten.
pub(crate) struct VictimTable {
    slots: Vec<CachePadded<VictimSlot>>,
}

struct VictimSlot {
    /// Last successful victim + 1 (0 = none remembered).
    last: AtomicUsize,
    /// Consecutive fully-failed steal visits since the last hit.
    fails: AtomicUsize,
}

impl VictimTable {
    /// Locality-group width: neighbors within the same block share the
    /// arena/NUMA placement from the first-touch layer.
    const GROUP: usize = 4;
    /// Failed visits before a remembered victim is forgotten.
    const MAX_FAILS: usize = 3;

    pub(crate) fn new(workers: usize) -> Self {
        Self {
            slots: (0..workers)
                .map(|_| {
                    CachePadded::new(VictimSlot {
                        last: AtomicUsize::new(0),
                        fails: AtomicUsize::new(0),
                    })
                })
                .collect(),
        }
    }

    /// Victim order for thief `w`: last hit, then locality group, then the
    /// rotation over everyone else.  Every non-self worker appears exactly
    /// once.
    pub(crate) fn order(&self, w: usize, spin: usize) -> impl Iterator<Item = usize> + '_ {
        let n = self.slots.len();
        let last = self.slots[w]
            .last
            .load(Ordering::Relaxed)
            .checked_sub(1)
            .filter(|&v| v != w && v < n);
        let g0 = (w / Self::GROUP) * Self::GROUP;
        let g1 = (g0 + Self::GROUP).min(n);
        let group = (g0..g1).filter(move |&v| v != w && Some(v) != last);
        let rest = rotation(w, n, spin).filter(move |&v| !(g0..g1).contains(&v) && Some(v) != last);
        last.into_iter().chain(group).chain(rest)
    }

    /// Record a successful steal from victim `v`.
    pub(crate) fn note_hit(&self, w: usize, v: usize) {
        self.slots[w].last.store(v + 1, Ordering::Relaxed);
        self.slots[w].fails.store(0, Ordering::Relaxed);
    }

    /// Record a fully-failed steal visit; forget a cold remembered victim.
    pub(crate) fn note_miss(&self, w: usize) {
        let f = self.slots[w].fails.fetch_add(1, Ordering::Relaxed) + 1;
        if f >= Self::MAX_FAILS {
            self.slots[w].last.store(0, Ordering::Relaxed);
            self.slots[w].fails.store(0, Ordering::Relaxed);
        }
    }
}

/// Mutex-guarded FIFO used as inbox/injector/overflow in several policies.
#[derive(Default)]
struct MutexQueue {
    q: Mutex<VecDeque<Task>>,
}

impl MutexQueue {
    fn push_back(&self, t: Task) {
        self.q.lock().unwrap().push_back(t);
    }
    fn pop_front(&self) -> Option<Task> {
        self.q.lock().unwrap().pop_front()
    }
    fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------------
// priority local — the HPX default
// ---------------------------------------------------------------------------

struct PlWorker {
    high: MutexQueue,
    deque: ChaseLev,
    /// Spill + external-submission inbox (deque push is owner-only).
    inbox: MutexQueue,
}

/// One high-priority queue and one deque per worker plus a global injector;
/// stealing allowed (high queues first, then deques).
pub struct PriorityLocal {
    per: Vec<PlWorker>,
    injector: MutexQueue,
    rr: AtomicUsize,
    victims: VictimTable,
}

impl PriorityLocal {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| PlWorker {
                    high: MutexQueue::default(),
                    deque: ChaseLev::with_capacity(4096),
                    inbox: MutexQueue::default(),
                })
                .collect(),
            injector: MutexQueue::default(),
            rr: AtomicUsize::new(0),
            victims: VictimTable::new(workers),
        }
    }

    fn target(&self, hint: Hint, submitter: Option<usize>) -> usize {
        match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => submitter
                .unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len()),
        }
    }

    /// Batched-steal extras land on the thief's own deque (owner push —
    /// valid by the `Queues::steal` ownership contract), spilling to its
    /// inbox on ring-full.  Real queues, not a stash: `help_one` and
    /// sibling thieves must be able to see them.
    fn requeue_extras(&self, w: usize, extra: Vec<Task>) {
        for t in extra {
            if let Err(t) = self.per[w].deque.push(t) {
                self.per[w].inbox.push_back(t);
            }
        }
    }
}

impl Queues for PriorityLocal {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>) {
        let w = self.target(hint, submitter);
        match task.priority {
            Priority::High => self.per[w].high.push_back(task),
            _ => {
                if submitter == Some(w) {
                    if let Err(t) = self.per[w].deque.push(task) {
                        self.per[w].inbox.push_back(t);
                    }
                } else {
                    self.per[w].inbox.push_back(task);
                }
            }
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        let me = &self.per[w];
        me.high
            .pop_front()
            .or_else(|| me.deque.pop())
            .or_else(|| me.inbox.pop_front())
            .or_else(|| self.injector.pop_front())
    }

    fn steal(&self, w: usize, spin: usize, limit: usize) -> Option<(Task, usize)> {
        for v in self.victims.order(w, spin) {
            if let Some(t) = self.per[v].high.pop_front() {
                self.victims.note_hit(w, v);
                return Some((t, 1));
            }
            let mut extra = Vec::new();
            let first = match self.per[v].deque.steal_batch(limit, &mut extra) {
                Steal::Success(t) => Some(t),
                // One bounded retry on contention, then move on.
                Steal::Retry => match self.per[v].deque.steal_batch(limit, &mut extra) {
                    Steal::Success(t) => Some(t),
                    _ => None,
                },
                Steal::Empty => None,
            };
            if let Some(t) = first {
                let claimed = 1 + extra.len();
                self.requeue_extras(w, extra);
                self.victims.note_hit(w, v);
                return Some((t, claimed));
            }
            if let Some(t) = self.per[v].inbox.pop_front() {
                self.victims.note_hit(w, v);
                return Some((t, 1));
            }
        }
        self.victims.note_miss(w);
        self.injector.pop_front().map(|t| (t, 1))
    }

    fn approx_len(&self) -> usize {
        self.injector.len()
            + self
                .per
                .iter()
                .map(|p| p.high.len() + p.deque.len_estimate() + p.inbox.len())
                .sum::<usize>()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// static priority — round-robin placement, no stealing
// ---------------------------------------------------------------------------

struct SpWorker {
    high: MutexQueue,
    normal: MutexQueue,
}

/// Round-robin placement at spawn time; workers only ever touch their own
/// queues (the paper: "thread stealing is not allowed in this policy").
pub struct StaticPriority {
    per: Vec<SpWorker>,
    rr: AtomicUsize,
}

impl StaticPriority {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| SpWorker {
                    high: MutexQueue::default(),
                    normal: MutexQueue::default(),
                })
                .collect(),
            rr: AtomicUsize::new(0),
        }
    }
}

impl Queues for StaticPriority {
    fn push(&self, task: Task, hint: Hint, _submitter: Option<usize>) {
        let w = match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len(),
        };
        match task.priority {
            Priority::High => self.per[w].high.push_back(task),
            _ => self.per[w].normal.push_back(task),
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        self.per[w]
            .high
            .pop_front()
            .or_else(|| self.per[w].normal.pop_front())
    }

    fn steal(&self, _w: usize, _spin: usize, _limit: usize) -> Option<(Task, usize)> {
        None // no stealing by definition
    }

    fn approx_len(&self) -> usize {
        self.per.iter().map(|p| p.high.len() + p.normal.len()).sum()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// local — per-worker deques + injector, stealing, no priority lanes
// ---------------------------------------------------------------------------

struct LWorker {
    deque: ChaseLev,
    inbox: MutexQueue,
}

pub struct Local {
    per: Vec<LWorker>,
    injector: MutexQueue,
    rr: AtomicUsize,
    victims: VictimTable,
}

impl Local {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| LWorker {
                    deque: ChaseLev::with_capacity(4096),
                    inbox: MutexQueue::default(),
                })
                .collect(),
            injector: MutexQueue::default(),
            rr: AtomicUsize::new(0),
            victims: VictimTable::new(workers),
        }
    }

    fn requeue_extras(&self, w: usize, extra: Vec<Task>) {
        for t in extra {
            if let Err(t) = self.per[w].deque.push(t) {
                self.per[w].inbox.push_back(t);
            }
        }
    }
}

impl Queues for Local {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>) {
        let w = match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => submitter
                .unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len()),
        };
        if submitter == Some(w) {
            if let Err(t) = self.per[w].deque.push(task) {
                self.per[w].inbox.push_back(t);
            }
        } else {
            self.per[w].inbox.push_back(task);
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        self.per[w]
            .deque
            .pop()
            .or_else(|| self.per[w].inbox.pop_front())
            .or_else(|| self.injector.pop_front())
    }

    fn steal(&self, w: usize, spin: usize, limit: usize) -> Option<(Task, usize)> {
        for v in self.victims.order(w, spin) {
            let mut extra = Vec::new();
            if let Steal::Success(t) = self.per[v].deque.steal_batch(limit, &mut extra) {
                let claimed = 1 + extra.len();
                self.requeue_extras(w, extra);
                self.victims.note_hit(w, v);
                return Some((t, claimed));
            }
            if let Some(t) = self.per[v].inbox.pop_front() {
                self.victims.note_hit(w, v);
                return Some((t, 1));
            }
        }
        self.victims.note_miss(w);
        self.injector.pop_front().map(|t| (t, 1))
    }

    fn approx_len(&self) -> usize {
        self.injector.len()
            + self
                .per
                .iter()
                .map(|p| p.deque.len_estimate() + p.inbox.len())
                .sum::<usize>()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// global — one shared queue all workers pull from
// ---------------------------------------------------------------------------

pub struct Global {
    high: MutexQueue,
    shared: MutexQueue,
    n: usize,
}

impl Global {
    pub fn new(workers: usize) -> Self {
        Self {
            high: MutexQueue::default(),
            shared: MutexQueue::default(),
            n: workers,
        }
    }
}

impl Queues for Global {
    fn push(&self, task: Task, _hint: Hint, _submitter: Option<usize>) {
        match task.priority {
            Priority::High => self.high.push_back(task),
            _ => self.shared.push_back(task),
        }
    }

    fn pop(&self, _w: usize) -> Option<Task> {
        self.high.pop_front().or_else(|| self.shared.pop_front())
    }

    fn steal(&self, _w: usize, _spin: usize, _limit: usize) -> Option<(Task, usize)> {
        None // pop already sees everything
    }

    fn approx_len(&self) -> usize {
        self.high.len() + self.shared.len()
    }

    fn workers(&self) -> usize {
        self.n
    }
}

// ---------------------------------------------------------------------------
// ABP — lock-free deque per worker, stealing from the opposite end
// ---------------------------------------------------------------------------

struct AbpWorker {
    deque: ChaseLev,
    inbox: MutexQueue,
}

pub struct Abp {
    per: Vec<AbpWorker>,
    rr: AtomicUsize,
    victims: VictimTable,
}

impl Abp {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| AbpWorker {
                    deque: ChaseLev::with_capacity(4096),
                    inbox: MutexQueue::default(),
                })
                .collect(),
            rr: AtomicUsize::new(0),
            victims: VictimTable::new(workers),
        }
    }

    fn requeue_extras(&self, w: usize, extra: Vec<Task>) {
        for t in extra {
            if let Err(t) = self.per[w].deque.push(t) {
                self.per[w].inbox.push_back(t);
            }
        }
    }
}

impl Queues for Abp {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>) {
        let w = match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => submitter
                .unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len()),
        };
        if submitter == Some(w) {
            if let Err(t) = self.per[w].deque.push(task) {
                self.per[w].inbox.push_back(t);
            }
        } else {
            self.per[w].inbox.push_back(task);
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        self.per[w]
            .deque
            .pop()
            .or_else(|| self.per[w].inbox.pop_front())
    }

    fn steal(&self, w: usize, spin: usize, limit: usize) -> Option<(Task, usize)> {
        for v in self.victims.order(w, spin) {
            let mut extra = Vec::new();
            loop {
                match self.per[v].deque.steal_batch(limit, &mut extra) {
                    Steal::Success(t) => {
                        let claimed = 1 + extra.len();
                        self.requeue_extras(w, extra);
                        self.victims.note_hit(w, v);
                        return Some((t, claimed));
                    }
                    Steal::Retry => continue,
                    Steal::Empty => break,
                }
            }
            if let Some(t) = self.per[v].inbox.pop_front() {
                self.victims.note_hit(w, v);
                return Some((t, 1));
            }
        }
        self.victims.note_miss(w);
        None
    }

    fn approx_len(&self) -> usize {
        self.per
            .iter()
            .map(|p| p.deque.len_estimate() + p.inbox.len())
            .sum()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// hierarchical — binary tree of queues, workers traverse leaf→root
// ---------------------------------------------------------------------------

/// Level 0 holds one leaf queue per worker; each level up halves the queue
/// count; pushes land at the root; a worker popping from an upper level
/// pulls a batch down toward its leaf (the paper: "constructs a tree of
/// task items, and each OS thread traverses through the tree to obtain new
/// task item").
pub struct Hierarchical {
    levels: Vec<Vec<MutexQueue>>, // levels[0] = leaves ... last = root
    batch: usize,
}

impl Hierarchical {
    pub fn new(workers: usize) -> Self {
        let mut levels = Vec::new();
        let mut n = workers.max(1);
        levels.push((0..n).map(|_| MutexQueue::default()).collect::<Vec<_>>());
        while n > 1 {
            n = n.div_ceil(2);
            levels.push((0..n).map(|_| MutexQueue::default()).collect());
        }
        Self { levels, batch: 8 }
    }

    fn root(&self) -> &MutexQueue {
        &self.levels.last().unwrap()[0]
    }
}

impl Queues for Hierarchical {
    fn push(&self, task: Task, hint: Hint, _submitter: Option<usize>) {
        match hint {
            // Targeted work lands directly in the leaf so affinity holds.
            Hint::Worker(w) => self.levels[0][w % self.levels[0].len()].push_back(task),
            Hint::Any => self.root().push_back(task),
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        // Leaf first.
        if let Some(t) = self.levels[0][w].pop_front() {
            return Some(t);
        }
        // Traverse up; on a hit, migrate a batch down to our leaf.
        let mut idx = w;
        for lvl in 1..self.levels.len() {
            idx /= 2;
            let q = &self.levels[lvl][idx];
            if let Some(t) = q.pop_front() {
                for _ in 1..self.batch {
                    match q.pop_front() {
                        Some(extra) => self.levels[0][w].push_back(extra),
                        None => break,
                    }
                }
                return Some(t);
            }
        }
        None
    }

    fn steal(&self, w: usize, spin: usize, limit: usize) -> Option<(Task, usize)> {
        // Sibling-leaf scan (tree-local stealing), on the shared rotation
        // (previously this policy's hand-rolled loop never skipped the
        // thief's own leaf, wasting one probe per sweep).  Batch extras
        // migrate to our own leaf — the same move `pop` does root→leaf.
        let n = self.levels[0].len();
        for v in rotation(w, n, spin) {
            if let Some(t) = self.levels[0][v].pop_front() {
                let mut claimed = 1;
                for _ in 1..limit.min(self.batch) {
                    match self.levels[0][v].pop_front() {
                        Some(extra) => {
                            self.levels[0][w].push_back(extra);
                            claimed += 1;
                        }
                        None => break,
                    }
                }
                return Some((t, claimed));
            }
        }
        None
    }

    fn approx_len(&self) -> usize {
        self.levels
            .iter()
            .map(|lvl| lvl.iter().map(MutexQueue::len).sum::<usize>())
            .sum()
    }

    fn workers(&self) -> usize {
        self.levels[0].len()
    }
}

// ---------------------------------------------------------------------------
// periodic priority — per-worker queue + shared high + shared low
// ---------------------------------------------------------------------------

/// "one queue of task items per OS thread, a couple of high priority queues
/// and one low priority queue"; high work preempts local, low work is
/// drained last.
pub struct PeriodicPriority {
    per: Vec<MutexQueue>,
    high: Vec<MutexQueue>,
    low: MutexQueue,
    rr: AtomicUsize,
}

impl PeriodicPriority {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers).map(|_| MutexQueue::default()).collect(),
            high: (0..2).map(|_| MutexQueue::default()).collect(),
            low: MutexQueue::default(),
            rr: AtomicUsize::new(0),
        }
    }
}

impl Queues for PeriodicPriority {
    fn push(&self, task: Task, hint: Hint, _submitter: Option<usize>) {
        match task.priority {
            Priority::High => {
                let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.high.len();
                self.high[i].push_back(task);
            }
            Priority::Low => self.low.push_back(task),
            Priority::Normal => {
                let w = match hint {
                    Hint::Worker(w) => w % self.per.len(),
                    Hint::Any => self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len(),
                };
                self.per[w].push_back(task);
            }
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        for h in &self.high {
            if let Some(t) = h.pop_front() {
                return Some(t);
            }
        }
        self.per[w].pop_front().or_else(|| self.low.pop_front())
    }

    fn steal(&self, w: usize, spin: usize, _limit: usize) -> Option<(Task, usize)> {
        // Periodic rebalancing: idle workers sweep sibling queues on the
        // shared rotation (previously the hand-rolled loop could probe the
        // thief's own queue — redundant with `pop` — and skip a sibling).
        let n = self.per.len();
        for v in rotation(w, n, spin) {
            if let Some(t) = self.per[v].pop_front() {
                return Some((t, 1));
            }
        }
        self.low.pop_front().map(|t| (t, 1))
    }

    fn approx_len(&self) -> usize {
        self.per.iter().map(MutexQueue::len).sum::<usize>()
            + self.high.iter().map(MutexQueue::len).sum::<usize>()
            + self.low.len()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as AU;
    use std::sync::Arc;

    fn mk(c: &Arc<AU>, prio: Priority) -> Task {
        let c = c.clone();
        Task::new(prio, "t", move || {
            c.fetch_add(1, Ordering::SeqCst);
        })
    }

    /// Push N tasks with mixed hints/priorities, then drain via pop+steal
    /// from every worker: all tasks must come back exactly once.
    fn drain_all(policy: &dyn Queues, n_tasks: usize) -> usize {
        let c = Arc::new(AU::new(0));
        for i in 0..n_tasks {
            let prio = match i % 3 {
                0 => Priority::Low,
                1 => Priority::Normal,
                _ => Priority::High,
            };
            let hint = if i % 2 == 0 {
                Hint::Any
            } else {
                Hint::Worker(i % 7)
            };
            policy.push(mk(&c, prio), hint, None);
        }
        let mut got = 0;
        loop {
            let mut any = false;
            for w in 0..policy.workers() {
                while let Some(t) = policy.pop(w) {
                    t.run();
                    got += 1;
                    any = true;
                }
                while let Some((t, _claimed)) = policy.steal(w, 0, 8) {
                    t.run();
                    got += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(c.load(Ordering::SeqCst), got);
        got
    }

    #[test]
    fn all_policies_conserve_tasks() {
        for kind in PolicyKind::ALL {
            let q = kind.build(4);
            let got = drain_all(q.as_ref(), 500);
            assert_eq!(got, 500, "policy {} lost/duplicated tasks", kind.name());
            assert_eq!(q.approx_len(), 0, "policy {} not drained", kind.name());
        }
    }

    #[test]
    fn static_priority_never_steals() {
        let q = StaticPriority::new(4);
        let c = Arc::new(AU::new(0));
        q.push(mk(&c, Priority::Normal), Hint::Worker(2), None);
        assert!(q.steal(0, 0, 8).is_none());
        assert!(q.pop(0).is_none());
        assert!(q.pop(2).is_some());
    }

    #[test]
    fn priority_local_serves_high_first() {
        let q = PriorityLocal::new(1);
        let c = Arc::new(AU::new(0));
        q.push(mk(&c, Priority::Normal), Hint::Worker(0), None);
        let high = mk(&c, Priority::High);
        let high_id = high.id;
        q.push(high, Hint::Worker(0), None);
        assert_eq!(q.pop(0).unwrap().id, high_id);
    }

    #[test]
    fn global_policy_shares_one_queue() {
        let q = Global::new(4);
        let c = Arc::new(AU::new(0));
        q.push(mk(&c, Priority::Normal), Hint::Any, None);
        // Any worker can pop it.
        assert!(q.pop(3).is_some());
    }

    #[test]
    fn hierarchical_migrates_batches_to_leaf() {
        let q = Hierarchical::new(4);
        let c = Arc::new(AU::new(0));
        for _ in 0..20 {
            q.push(mk(&c, Priority::Normal), Hint::Any, None);
        }
        // First pop on worker 0 pulls a batch from the root toward leaf 0.
        assert!(q.pop(0).is_some());
        assert!(
            q.levels[0][0].len() > 0,
            "batch was not migrated to the leaf"
        );
    }

    #[test]
    fn rotation_covers_every_non_self_victim_for_any_spin() {
        for n in [1usize, 2, 3, 4, 7, 16] {
            for w in 0..n {
                for spin in [0usize, 1, 2, 5, n, 3 * n + 1] {
                    let seen: Vec<usize> = rotation(w, n, spin).collect();
                    assert_eq!(seen.len(), n - 1, "n={n} w={w} spin={spin}");
                    let mut sorted = seen.clone();
                    sorted.sort_unstable();
                    sorted.dedup();
                    assert_eq!(sorted.len(), n - 1, "dup victim: n={n} w={w} spin={spin} {seen:?}");
                    assert!(!seen.contains(&w), "self-probe: n={n} w={w} spin={spin} {seen:?}");
                }
            }
        }
    }

    #[test]
    fn victim_table_orders_last_hit_first_and_covers_all() {
        let vt = VictimTable::new(8);
        // No history: order still covers all 7 non-self victims once.
        let base: Vec<usize> = vt.order(1, 0).collect();
        assert_eq!(base.len(), 7);
        assert!(!base.contains(&1));
        // After a hit on a far victim, it jumps to the front.
        vt.note_hit(1, 6);
        let after: Vec<usize> = vt.order(1, 0).collect();
        assert_eq!(after[0], 6);
        assert_eq!(after.len(), 7);
        let mut sorted = after.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 2, 3, 4, 5, 6, 7]);
        // Locality group (block of 4 containing worker 1) comes right after.
        assert_eq!(&after[1..4], &[0, 2, 3]);
        // Enough consecutive misses forget the remembered victim.
        for _ in 0..VictimTable::MAX_FAILS {
            vt.note_miss(1);
        }
        let forgot: Vec<usize> = vt.order(1, 0).collect();
        assert_eq!(&forgot[..3], &[0, 2, 3], "group first once last is forgotten");
    }

    #[test]
    fn steal_batch_requeues_extras_on_thief_queues() {
        // Worker 0 self-pushes 8 deque tasks; thief 1 steals with a wide
        // limit: it gets one task back and the extras appear in *visible*
        // queues on worker 1 (deque/inbox), where pop can serve them.
        let q = PriorityLocal::new(4);
        let c = Arc::new(AU::new(0));
        for _ in 0..8 {
            q.push(mk(&c, Priority::Normal), Hint::Worker(0), Some(0));
        }
        let (t, claimed) = q.steal(1, 0, 32).expect("steal hits worker 0");
        t.run();
        assert!(claimed > 1, "wide limit should batch, got {claimed}");
        let mut local = 0;
        while let Some(t) = q.pop(1) {
            t.run();
            local += 1;
        }
        assert_eq!(local, claimed - 1, "extras must be poppable on the thief");
        // Victim keeps the rest; nothing lost.
        let mut rest = 0;
        while let Some(t) = q.pop(0) {
            t.run();
            rest += 1;
        }
        assert_eq!(claimed + rest, 8);
        assert_eq!(c.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn steal_limit_one_is_single_steal_everywhere() {
        // HPXMP_STEAL_ONE=1 maps to limit 1: every policy that steals must
        // then claim exactly one task per visit.
        for kind in PolicyKind::ALL {
            let q = kind.build(4);
            let c = Arc::new(AU::new(0));
            for _ in 0..16 {
                q.push(mk(&c, Priority::Normal), Hint::Worker(0), Some(0));
            }
            while let Some((t, claimed)) = q.steal(1, 0, 1) {
                t.run();
                assert_eq!(claimed, 1, "policy {} batched at limit 1", kind.name());
            }
        }
    }

    #[test]
    fn parse_names_roundtrip() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(PolicyKind::parse("default"), Some(PolicyKind::PriorityLocal));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn strict_parse_lists_valid_set() {
        let err = PolicyKind::parse_or_list("nope").unwrap_err();
        assert!(err.contains("unknown policy 'nope'"), "{err}");
        for kind in PolicyKind::ALL {
            assert!(err.contains(kind.name()), "{err} missing {}", kind.name());
            assert_eq!(PolicyKind::parse_or_list(kind.name()), Ok(kind));
        }
    }
}
