//! The seven HPX thread-scheduling policies (paper §3.2) behind one trait.
//!
//! | Paper policy                | Type here          | Structure |
//! |-----------------------------|--------------------|-----------|
//! | priority local (default)    | [`PriorityLocal`]  | per-worker high-prio queue + Chase–Lev deque + global injector, stealing |
//! | static priority             | [`StaticPriority`] | per-worker priority queues, round-robin placement, **no stealing** |
//! | local                       | [`Local`]          | per-worker deque + injector, stealing |
//! | global                      | [`Global`]         | one shared queue |
//! | ABP                         | [`Abp`]            | lock-free deque per worker, steal from the opposite end |
//! | hierarchy                   | [`Hierarchical`]   | binary tree of queues, workers traverse leaf→root |
//! | periodic priority           | [`PeriodicPriority`]| per-worker queue + shared high + shared low queues |
//!
//! Every policy upholds the conservation invariant (no task lost, none
//! duplicated), which `rust/tests/prop_invariants.rs` checks property-style
//! across all seven.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::deque::{ChaseLev, Steal};
use super::task::{Hint, Priority, Task};

/// Which policy to instantiate (CLI/env-selectable: `HPXMP_POLICY`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    PriorityLocal,
    StaticPriority,
    Local,
    Global,
    Abp,
    Hierarchical,
    PeriodicPriority,
}

impl PolicyKind {
    pub const ALL: [PolicyKind; 7] = [
        PolicyKind::PriorityLocal,
        PolicyKind::StaticPriority,
        PolicyKind::Local,
        PolicyKind::Global,
        PolicyKind::Abp,
        PolicyKind::Hierarchical,
        PolicyKind::PeriodicPriority,
    ];

    /// Accepted spellings (canonical names first, aliases after) — the
    /// table both [`PolicyKind::parse`] and [`PolicyKind::parse_or_list`]
    /// resolve through via [`crate::util::cli::lookup_choice`], the same
    /// helper behind the CLI's `--exec` selector.
    pub const CHOICES: &[(&str, PolicyKind)] = &[
        ("priority-local", PolicyKind::PriorityLocal),
        ("static-priority", PolicyKind::StaticPriority),
        ("local", PolicyKind::Local),
        ("global", PolicyKind::Global),
        ("abp", PolicyKind::Abp),
        ("hierarchical", PolicyKind::Hierarchical),
        ("periodic-priority", PolicyKind::PeriodicPriority),
        ("priority_local", PolicyKind::PriorityLocal),
        ("default", PolicyKind::PriorityLocal),
        ("static", PolicyKind::StaticPriority),
        ("hierarchy", PolicyKind::Hierarchical),
        ("periodic", PolicyKind::PeriodicPriority),
    ];

    pub fn parse(s: &str) -> Option<Self> {
        crate::util::cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for CLI flags / env vars: an unknown value reports
    /// the full valid set instead of silently defaulting.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        crate::util::cli::parse_choice("policy", s, Self::CHOICES)
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::PriorityLocal => "priority-local",
            PolicyKind::StaticPriority => "static-priority",
            PolicyKind::Local => "local",
            PolicyKind::Global => "global",
            PolicyKind::Abp => "abp",
            PolicyKind::Hierarchical => "hierarchical",
            PolicyKind::PeriodicPriority => "periodic-priority",
        }
    }

    pub fn build(&self, workers: usize) -> Box<dyn Queues> {
        match self {
            PolicyKind::PriorityLocal => Box::new(PriorityLocal::new(workers)),
            PolicyKind::StaticPriority => Box::new(StaticPriority::new(workers)),
            PolicyKind::Local => Box::new(Local::new(workers)),
            PolicyKind::Global => Box::new(Global::new(workers)),
            PolicyKind::Abp => Box::new(Abp::new(workers)),
            PolicyKind::Hierarchical => Box::new(Hierarchical::new(workers)),
            PolicyKind::PeriodicPriority => Box::new(PeriodicPriority::new(workers)),
        }
    }
}

/// The queue discipline a scheduler instance runs on.
///
/// `submitter` is `Some(w)` when the pushing thread *is* worker `w` (deque
/// owners may use their lock-free push path); `None` for external threads.
pub trait Queues: Send + Sync {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>);
    /// Fast local acquisition for worker `w`.
    fn pop(&self, worker: usize) -> Option<Task>;
    /// Cross-queue acquisition (work stealing / shared-queue fallback).
    /// `spin` differentiates steal attempts so victims rotate.
    fn steal(&self, worker: usize, spin: usize) -> Option<Task>;
    /// Racy occupancy estimate for idle heuristics.
    fn approx_len(&self) -> usize;
    fn workers(&self) -> usize;
}

/// Mutex-guarded FIFO used as inbox/injector/overflow in several policies.
#[derive(Default)]
struct MutexQueue {
    q: Mutex<VecDeque<Task>>,
}

impl MutexQueue {
    fn push_back(&self, t: Task) {
        self.q.lock().unwrap().push_back(t);
    }
    fn pop_front(&self) -> Option<Task> {
        self.q.lock().unwrap().pop_front()
    }
    fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------------
// priority local — the HPX default
// ---------------------------------------------------------------------------

struct PlWorker {
    high: MutexQueue,
    deque: ChaseLev,
    /// Spill + external-submission inbox (deque push is owner-only).
    inbox: MutexQueue,
}

/// One high-priority queue and one deque per worker plus a global injector;
/// stealing allowed (high queues first, then deques).
pub struct PriorityLocal {
    per: Vec<PlWorker>,
    injector: MutexQueue,
    rr: AtomicUsize,
}

impl PriorityLocal {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| PlWorker {
                    high: MutexQueue::default(),
                    deque: ChaseLev::with_capacity(4096),
                    inbox: MutexQueue::default(),
                })
                .collect(),
            injector: MutexQueue::default(),
            rr: AtomicUsize::new(0),
        }
    }

    fn target(&self, hint: Hint, submitter: Option<usize>) -> usize {
        match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => submitter
                .unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len()),
        }
    }
}

impl Queues for PriorityLocal {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>) {
        let w = self.target(hint, submitter);
        match task.priority {
            Priority::High => self.per[w].high.push_back(task),
            _ => {
                if submitter == Some(w) {
                    if let Err(t) = self.per[w].deque.push(task) {
                        self.per[w].inbox.push_back(t);
                    }
                } else {
                    self.per[w].inbox.push_back(task);
                }
            }
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        let me = &self.per[w];
        me.high
            .pop_front()
            .or_else(|| me.deque.pop())
            .or_else(|| me.inbox.pop_front())
            .or_else(|| self.injector.pop_front())
    }

    fn steal(&self, w: usize, spin: usize) -> Option<Task> {
        let n = self.per.len();
        for k in 1..n {
            let v = (w + k + spin) % n;
            if v == w {
                continue;
            }
            if let Some(t) = self.per[v].high.pop_front() {
                return Some(t);
            }
            match self.per[v].deque.steal() {
                Steal::Success(t) => return Some(t),
                Steal::Retry => {
                    if let Steal::Success(t) = self.per[v].deque.steal() {
                        return Some(t);
                    }
                }
                Steal::Empty => {}
            }
            if let Some(t) = self.per[v].inbox.pop_front() {
                return Some(t);
            }
        }
        self.injector.pop_front()
    }

    fn approx_len(&self) -> usize {
        self.injector.len()
            + self
                .per
                .iter()
                .map(|p| p.high.len() + p.deque.len_estimate() + p.inbox.len())
                .sum::<usize>()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// static priority — round-robin placement, no stealing
// ---------------------------------------------------------------------------

struct SpWorker {
    high: MutexQueue,
    normal: MutexQueue,
}

/// Round-robin placement at spawn time; workers only ever touch their own
/// queues (the paper: "thread stealing is not allowed in this policy").
pub struct StaticPriority {
    per: Vec<SpWorker>,
    rr: AtomicUsize,
}

impl StaticPriority {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| SpWorker {
                    high: MutexQueue::default(),
                    normal: MutexQueue::default(),
                })
                .collect(),
            rr: AtomicUsize::new(0),
        }
    }
}

impl Queues for StaticPriority {
    fn push(&self, task: Task, hint: Hint, _submitter: Option<usize>) {
        let w = match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len(),
        };
        match task.priority {
            Priority::High => self.per[w].high.push_back(task),
            _ => self.per[w].normal.push_back(task),
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        self.per[w]
            .high
            .pop_front()
            .or_else(|| self.per[w].normal.pop_front())
    }

    fn steal(&self, _w: usize, _spin: usize) -> Option<Task> {
        None // no stealing by definition
    }

    fn approx_len(&self) -> usize {
        self.per.iter().map(|p| p.high.len() + p.normal.len()).sum()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// local — per-worker deques + injector, stealing, no priority lanes
// ---------------------------------------------------------------------------

struct LWorker {
    deque: ChaseLev,
    inbox: MutexQueue,
}

pub struct Local {
    per: Vec<LWorker>,
    injector: MutexQueue,
    rr: AtomicUsize,
}

impl Local {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| LWorker {
                    deque: ChaseLev::with_capacity(4096),
                    inbox: MutexQueue::default(),
                })
                .collect(),
            injector: MutexQueue::default(),
            rr: AtomicUsize::new(0),
        }
    }
}

impl Queues for Local {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>) {
        let w = match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => submitter
                .unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len()),
        };
        if submitter == Some(w) {
            if let Err(t) = self.per[w].deque.push(task) {
                self.per[w].inbox.push_back(t);
            }
        } else {
            self.per[w].inbox.push_back(task);
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        self.per[w]
            .deque
            .pop()
            .or_else(|| self.per[w].inbox.pop_front())
            .or_else(|| self.injector.pop_front())
    }

    fn steal(&self, w: usize, spin: usize) -> Option<Task> {
        let n = self.per.len();
        for k in 1..n {
            let v = (w + k + spin) % n;
            if v == w {
                continue;
            }
            if let Steal::Success(t) = self.per[v].deque.steal() {
                return Some(t);
            }
            if let Some(t) = self.per[v].inbox.pop_front() {
                return Some(t);
            }
        }
        self.injector.pop_front()
    }

    fn approx_len(&self) -> usize {
        self.injector.len()
            + self
                .per
                .iter()
                .map(|p| p.deque.len_estimate() + p.inbox.len())
                .sum::<usize>()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// global — one shared queue all workers pull from
// ---------------------------------------------------------------------------

pub struct Global {
    high: MutexQueue,
    shared: MutexQueue,
    n: usize,
}

impl Global {
    pub fn new(workers: usize) -> Self {
        Self {
            high: MutexQueue::default(),
            shared: MutexQueue::default(),
            n: workers,
        }
    }
}

impl Queues for Global {
    fn push(&self, task: Task, _hint: Hint, _submitter: Option<usize>) {
        match task.priority {
            Priority::High => self.high.push_back(task),
            _ => self.shared.push_back(task),
        }
    }

    fn pop(&self, _w: usize) -> Option<Task> {
        self.high.pop_front().or_else(|| self.shared.pop_front())
    }

    fn steal(&self, _w: usize, _spin: usize) -> Option<Task> {
        None // pop already sees everything
    }

    fn approx_len(&self) -> usize {
        self.high.len() + self.shared.len()
    }

    fn workers(&self) -> usize {
        self.n
    }
}

// ---------------------------------------------------------------------------
// ABP — lock-free deque per worker, stealing from the opposite end
// ---------------------------------------------------------------------------

struct AbpWorker {
    deque: ChaseLev,
    inbox: MutexQueue,
}

pub struct Abp {
    per: Vec<AbpWorker>,
    rr: AtomicUsize,
}

impl Abp {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers)
                .map(|_| AbpWorker {
                    deque: ChaseLev::with_capacity(4096),
                    inbox: MutexQueue::default(),
                })
                .collect(),
            rr: AtomicUsize::new(0),
        }
    }
}

impl Queues for Abp {
    fn push(&self, task: Task, hint: Hint, submitter: Option<usize>) {
        let w = match hint {
            Hint::Worker(w) => w % self.per.len(),
            Hint::Any => submitter
                .unwrap_or_else(|| self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len()),
        };
        if submitter == Some(w) {
            if let Err(t) = self.per[w].deque.push(task) {
                self.per[w].inbox.push_back(t);
            }
        } else {
            self.per[w].inbox.push_back(task);
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        self.per[w]
            .deque
            .pop()
            .or_else(|| self.per[w].inbox.pop_front())
    }

    fn steal(&self, w: usize, spin: usize) -> Option<Task> {
        let n = self.per.len();
        for k in 1..n {
            let v = (w + k + spin) % n;
            loop {
                match self.per[v].deque.steal() {
                    Steal::Success(t) => return Some(t),
                    Steal::Retry => continue,
                    Steal::Empty => break,
                }
            }
            if let Some(t) = self.per[v].inbox.pop_front() {
                return Some(t);
            }
        }
        None
    }

    fn approx_len(&self) -> usize {
        self.per
            .iter()
            .map(|p| p.deque.len_estimate() + p.inbox.len())
            .sum()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

// ---------------------------------------------------------------------------
// hierarchical — binary tree of queues, workers traverse leaf→root
// ---------------------------------------------------------------------------

/// Level 0 holds one leaf queue per worker; each level up halves the queue
/// count; pushes land at the root; a worker popping from an upper level
/// pulls a batch down toward its leaf (the paper: "constructs a tree of
/// task items, and each OS thread traverses through the tree to obtain new
/// task item").
pub struct Hierarchical {
    levels: Vec<Vec<MutexQueue>>, // levels[0] = leaves ... last = root
    batch: usize,
}

impl Hierarchical {
    pub fn new(workers: usize) -> Self {
        let mut levels = Vec::new();
        let mut n = workers.max(1);
        levels.push((0..n).map(|_| MutexQueue::default()).collect::<Vec<_>>());
        while n > 1 {
            n = n.div_ceil(2);
            levels.push((0..n).map(|_| MutexQueue::default()).collect());
        }
        Self { levels, batch: 8 }
    }

    fn root(&self) -> &MutexQueue {
        &self.levels.last().unwrap()[0]
    }
}

impl Queues for Hierarchical {
    fn push(&self, task: Task, hint: Hint, _submitter: Option<usize>) {
        match hint {
            // Targeted work lands directly in the leaf so affinity holds.
            Hint::Worker(w) => self.levels[0][w % self.levels[0].len()].push_back(task),
            Hint::Any => self.root().push_back(task),
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        // Leaf first.
        if let Some(t) = self.levels[0][w].pop_front() {
            return Some(t);
        }
        // Traverse up; on a hit, migrate a batch down to our leaf.
        let mut idx = w;
        for lvl in 1..self.levels.len() {
            idx /= 2;
            let q = &self.levels[lvl][idx];
            if let Some(t) = q.pop_front() {
                for _ in 1..self.batch {
                    match q.pop_front() {
                        Some(extra) => self.levels[0][w].push_back(extra),
                        None => break,
                    }
                }
                return Some(t);
            }
        }
        None
    }

    fn steal(&self, w: usize, spin: usize) -> Option<Task> {
        // Sibling-leaf scan (tree-local stealing).
        let n = self.levels[0].len();
        for k in 1..n {
            let v = (w + k + spin) % n;
            if let Some(t) = self.levels[0][v].pop_front() {
                return Some(t);
            }
        }
        None
    }

    fn approx_len(&self) -> usize {
        self.levels
            .iter()
            .map(|lvl| lvl.iter().map(MutexQueue::len).sum::<usize>())
            .sum()
    }

    fn workers(&self) -> usize {
        self.levels[0].len()
    }
}

// ---------------------------------------------------------------------------
// periodic priority — per-worker queue + shared high + shared low
// ---------------------------------------------------------------------------

/// "one queue of task items per OS thread, a couple of high priority queues
/// and one low priority queue"; high work preempts local, low work is
/// drained last.
pub struct PeriodicPriority {
    per: Vec<MutexQueue>,
    high: Vec<MutexQueue>,
    low: MutexQueue,
    rr: AtomicUsize,
}

impl PeriodicPriority {
    pub fn new(workers: usize) -> Self {
        Self {
            per: (0..workers).map(|_| MutexQueue::default()).collect(),
            high: (0..2).map(|_| MutexQueue::default()).collect(),
            low: MutexQueue::default(),
            rr: AtomicUsize::new(0),
        }
    }
}

impl Queues for PeriodicPriority {
    fn push(&self, task: Task, hint: Hint, _submitter: Option<usize>) {
        match task.priority {
            Priority::High => {
                let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.high.len();
                self.high[i].push_back(task);
            }
            Priority::Low => self.low.push_back(task),
            Priority::Normal => {
                let w = match hint {
                    Hint::Worker(w) => w % self.per.len(),
                    Hint::Any => self.rr.fetch_add(1, Ordering::Relaxed) % self.per.len(),
                };
                self.per[w].push_back(task);
            }
        }
    }

    fn pop(&self, w: usize) -> Option<Task> {
        for h in &self.high {
            if let Some(t) = h.pop_front() {
                return Some(t);
            }
        }
        self.per[w].pop_front().or_else(|| self.low.pop_front())
    }

    fn steal(&self, w: usize, spin: usize) -> Option<Task> {
        // Periodic rebalancing: idle workers sweep sibling queues.
        let n = self.per.len();
        for k in 1..n {
            let v = (w + k + spin) % n;
            if let Some(t) = self.per[v].pop_front() {
                return Some(t);
            }
        }
        self.low.pop_front()
    }

    fn approx_len(&self) -> usize {
        self.per.iter().map(MutexQueue::len).sum::<usize>()
            + self.high.iter().map(MutexQueue::len).sum::<usize>()
            + self.low.len()
    }

    fn workers(&self) -> usize {
        self.per.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as AU;
    use std::sync::Arc;

    fn mk(c: &Arc<AU>, prio: Priority) -> Task {
        let c = c.clone();
        Task::new(prio, "t", move || {
            c.fetch_add(1, Ordering::SeqCst);
        })
    }

    /// Push N tasks with mixed hints/priorities, then drain via pop+steal
    /// from every worker: all tasks must come back exactly once.
    fn drain_all(policy: &dyn Queues, n_tasks: usize) -> usize {
        let c = Arc::new(AU::new(0));
        for i in 0..n_tasks {
            let prio = match i % 3 {
                0 => Priority::Low,
                1 => Priority::Normal,
                _ => Priority::High,
            };
            let hint = if i % 2 == 0 {
                Hint::Any
            } else {
                Hint::Worker(i % 7)
            };
            policy.push(mk(&c, prio), hint, None);
        }
        let mut got = 0;
        loop {
            let mut any = false;
            for w in 0..policy.workers() {
                while let Some(t) = policy.pop(w) {
                    t.run();
                    got += 1;
                    any = true;
                }
                while let Some(t) = policy.steal(w, 0) {
                    t.run();
                    got += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(c.load(Ordering::SeqCst), got);
        got
    }

    #[test]
    fn all_policies_conserve_tasks() {
        for kind in PolicyKind::ALL {
            let q = kind.build(4);
            let got = drain_all(q.as_ref(), 500);
            assert_eq!(got, 500, "policy {} lost/duplicated tasks", kind.name());
            assert_eq!(q.approx_len(), 0, "policy {} not drained", kind.name());
        }
    }

    #[test]
    fn static_priority_never_steals() {
        let q = StaticPriority::new(4);
        let c = Arc::new(AU::new(0));
        q.push(mk(&c, Priority::Normal), Hint::Worker(2), None);
        assert!(q.steal(0, 0).is_none());
        assert!(q.pop(0).is_none());
        assert!(q.pop(2).is_some());
    }

    #[test]
    fn priority_local_serves_high_first() {
        let q = PriorityLocal::new(1);
        let c = Arc::new(AU::new(0));
        q.push(mk(&c, Priority::Normal), Hint::Worker(0), None);
        let high = mk(&c, Priority::High);
        let high_id = high.id;
        q.push(high, Hint::Worker(0), None);
        assert_eq!(q.pop(0).unwrap().id, high_id);
    }

    #[test]
    fn global_policy_shares_one_queue() {
        let q = Global::new(4);
        let c = Arc::new(AU::new(0));
        q.push(mk(&c, Priority::Normal), Hint::Any, None);
        // Any worker can pop it.
        assert!(q.pop(3).is_some());
    }

    #[test]
    fn hierarchical_migrates_batches_to_leaf() {
        let q = Hierarchical::new(4);
        let c = Arc::new(AU::new(0));
        for _ in 0..20 {
            q.push(mk(&c, Priority::Normal), Hint::Any, None);
        }
        // First pop on worker 0 pulls a batch from the root toward leaf 0.
        assert!(q.pop(0).is_some());
        assert!(
            q.levels[0][0].len() > 0,
            "batch was not migrated to the leaf"
        );
    }

    #[test]
    fn parse_names_roundtrip() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(PolicyKind::parse("default"), Some(PolicyKind::PriorityLocal));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn strict_parse_lists_valid_set() {
        let err = PolicyKind::parse_or_list("nope").unwrap_err();
        assert!(err.contains("unknown policy 'nope'"), "{err}");
        for kind in PolicyKind::ALL {
            assert!(err.contains(kind.name()), "{err} missing {}", kind.name());
            assert_eq!(PolicyKind::parse_or_list(kind.name()), Ok(kind));
        }
    }
}
