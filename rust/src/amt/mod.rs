//! The AMT (asynchronous many-task) substrate — our stand-in for HPX.
//!
//! The paper (§3) relies on HPX's lightweight threading system: user-level
//! tasks multiplexed over OS worker threads under one of eight scheduling
//! policies.  This module rebuilds that substrate from scratch:
//!
//! * [`task`] — the task object (`register_thread_nullary` analog) with the
//!   three priorities the paper's Listing 3 uses.
//! * [`deque`] — a hand-built Chase–Lev work-stealing deque (the lock-free
//!   structure behind HPX's ABP/local policies).
//! * [`policy`] — the seven §3.2 scheduling policies behind one trait.
//! * [`park`] — the sleep/wake substrate (DESIGN.md §9): per-worker
//!   eventcount parkers, the lock-free idle-worker set behind targeted
//!   wakes, wake lists for event-driven waits, and the global-condvar
//!   ablation fallback (`HPXMP_GLOBAL_IDLE=1`).
//! * [`worker`] / [`scheduler`] — OS worker threads, parking, spawning,
//!   cooperative "help" execution, and the unified
//!   [`WaitState`](worker::WaitState) engine every blocking construct
//!   (barrier, join, taskwait, future wait, quiescence) ticks through.
//! * [`future`] — `hpx::future`/`promise` continuations: `then` scheduled
//!   as AMT tasks, `when_all` joins, help-first waits (DESIGN.md §7).
//! * [`metrics`] — counters for spawned/executed/parked tasks, the steal
//!   pipeline (attempts/hits/batch sizes, inlined continuations — ISSUE 8)
//!   and the targeted-wake observability surface.
//! * [`arena`] — per-worker magazine/depot allocator for task payloads
//!   (ISSUE 7): spawn-path closures recycle fixed-size blocks instead of
//!   round-tripping malloc.

pub mod arena;
pub mod cancel;
pub mod deque;
pub mod future;
pub mod metrics;
pub mod park;
pub mod policy;
pub mod scheduler;
pub mod task;
pub mod worker;

pub use arena::Payload;
pub use cancel::CancelToken;
pub use future::{when_all, Future, Outcome, Promise, RemoteRegistry};
pub use park::IdleMode;
pub use policy::PolicyKind;
pub use scheduler::{Scheduler, Tuning, MAX_INLINE_DEPTH};
pub use task::{Hint, Priority, Task};
