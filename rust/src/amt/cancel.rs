//! Hierarchical cancellation tokens (ISSUE 6; DESIGN.md §11).
//!
//! A [`CancelToken`] is a cheap, clonable handle to a shared cancellation
//! flag.  Tokens form a tree: [`CancelToken::child`] derives a token that
//! observes its parent's cancellation *in addition to* its own — cancelling
//! a parent fans out to every descendant with **no** per-child bookkeeping
//! on the parent (children walk up the chain on query and cache the answer
//! in their own flag, so a deep chain is paid at most once per token).
//!
//! Tokens optionally carry a **deadline** ([`CancelToken::with_deadline`]):
//! a token whose deadline has passed reports cancelled without anyone
//! calling [`CancelToken::cancel`].  This is how the policy layer's
//! `.deadline(..)` combinator and the serving layer's per-request deadlines
//! are expressed — one mechanism for both explicit and timed cancellation.
//!
//! Checking is always *cooperative*: nothing is interrupted; running code
//! polls [`CancelToken::is_cancelled`] at cancellation points (scheduler
//! dispatch, chunk starts, `omp cancellation point`) and unwinds its own
//! bookkeeping before returning.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Inner {
    /// Set by [`CancelToken::cancel`], or cached from an ancestor / an
    /// expired deadline on first observation (monotonic: never cleared).
    flag: AtomicBool,
    /// Passing this instant cancels the token implicitly.
    deadline: Option<Instant>,
    /// Parent link — the upward half of the fan-out tree.
    parent: Option<Arc<Inner>>,
}

impl Inner {
    fn is_cancelled(&self) -> bool {
        if self.flag.load(Ordering::Acquire) {
            return true;
        }
        let hit = self.deadline.is_some_and(|d| Instant::now() >= d)
            || self.parent.as_ref().is_some_and(|p| p.is_cancelled());
        if hit {
            // Cache: later checks on this token short-circuit without
            // re-walking the chain or re-reading the clock.
            self.flag.store(true, Ordering::Release);
        }
        hit
    }
}

/// A clonable handle to one node of a cancellation tree.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl CancelToken {
    /// A fresh root token (not cancelled, no deadline, no parent).
    pub fn new() -> Self {
        Self::build(None, None)
    }

    /// A root token that auto-cancels once `timeout` has elapsed.
    pub fn with_deadline(timeout: Duration) -> Self {
        Self::build(Some(Instant::now() + timeout), None)
    }

    /// A root token that auto-cancels at `at`.
    pub fn at_deadline(at: Instant) -> Self {
        Self::build(Some(at), None)
    }

    /// Derive a child: cancelled when *either* this token is cancelled or
    /// [`CancelToken::cancel`] is called on the child itself.
    pub fn child(&self) -> Self {
        Self::build(None, Some(self.inner.clone()))
    }

    /// Derive a child with its own deadline (parent cancellation still
    /// propagates; whichever fires first wins).
    pub fn child_with_deadline(&self, timeout: Duration) -> Self {
        Self::build(Some(Instant::now() + timeout), Some(self.inner.clone()))
    }

    fn build(deadline: Option<Instant>, parent: Option<Arc<Inner>>) -> Self {
        Self {
            inner: Arc::new(Inner {
                flag: AtomicBool::new(false),
                deadline,
                parent,
            }),
        }
    }

    /// Request cancellation of this token and (transitively) every child
    /// derived from it.  Idempotent; never blocks.
    pub fn cancel(&self) {
        self.inner.flag.store(true, Ordering::Release);
    }

    /// Whether this token — or any ancestor, or an expired deadline along
    /// the chain — has been cancelled.  The cancellation-point predicate.
    pub fn is_cancelled(&self) -> bool {
        self.inner.is_cancelled()
    }

    /// Identity equality (two handles to the same tree node).
    pub fn ptr_eq(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CancelToken")
            .field("cancelled", &self.is_cancelled())
            .field("deadline", &self.inner.deadline)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_live_and_cancel_is_sticky() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(t.is_cancelled());
        assert!(t.is_cancelled(), "cancellation is monotonic");
    }

    #[test]
    fn parent_cancel_fans_out_to_children_and_grandchildren() {
        let root = CancelToken::new();
        let child = root.child();
        let grandchild = child.child();
        assert!(!grandchild.is_cancelled());
        root.cancel();
        assert!(child.is_cancelled());
        assert!(grandchild.is_cancelled());
    }

    #[test]
    fn child_cancel_does_not_propagate_upward() {
        let root = CancelToken::new();
        let child = root.child();
        child.cancel();
        assert!(child.is_cancelled());
        assert!(!root.is_cancelled());
    }

    #[test]
    fn sibling_is_unaffected_by_other_childs_cancel() {
        let root = CancelToken::new();
        let a = root.child();
        let b = root.child();
        a.cancel();
        assert!(!b.is_cancelled());
    }

    #[test]
    fn expired_deadline_reads_as_cancelled() {
        let t = CancelToken::with_deadline(Duration::from_micros(0));
        std::thread::sleep(Duration::from_millis(1));
        assert!(t.is_cancelled());
    }

    #[test]
    fn future_deadline_is_still_live() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!t.is_cancelled());
    }

    #[test]
    fn child_deadline_expires_without_touching_parent() {
        let root = CancelToken::new();
        let child = root.child_with_deadline(Duration::from_micros(0));
        std::thread::sleep(Duration::from_millis(1));
        assert!(child.is_cancelled());
        assert!(!root.is_cancelled());
    }

    #[test]
    fn clones_share_state() {
        let a = CancelToken::new();
        let b = a.clone();
        assert!(a.ptr_eq(&b));
        b.cancel();
        assert!(a.is_cancelled());
    }
}
