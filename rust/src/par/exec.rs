//! One execution-policy API (ISSUE 5): HPX-style composable policies.
//!
//! HPX exposes *how* an algorithm executes as a first-class value —
//! `hpx::execution::seq`, `par`, `par.on(executor)` — so the same
//! algorithm runs serial, fork-join, or as a futurized task graph with a
//! one-line policy swap (Diehl et al. 2023, Heller et al. 2024).  This
//! module ports that shape onto the hpxMP stack:
//!
//! * [`Executor`] — the execution-resource trait.  Implemented by
//!   [`crate::par::HpxMpRuntime`] (OpenMP regions over the AMT
//!   scheduler), [`crate::baseline::BaselinePool`] /
//!   [`crate::baseline::BaselineRuntime`] (the warm libomp-style
//!   OS-thread pool), and the inline [`Serial`] executor.
//! * [`Policy`] — a `Copy` value bundling an execution mode
//!   ([`ExecMode`]) with an executor and tuning knobs, built from
//!   [`seq()`], [`par()`], [`task()`] and refined with the combinators
//!   [`Policy::on`], [`Policy::threads`], [`Policy::chunk`],
//!   [`Policy::tile`], [`Policy::hint`].
//! * Generic algorithms — [`for_each`] (blocking), [`for_each_async`]
//!   (returns a [`Future`] that composes with `then`/`when_all`),
//!   [`for_each_tile_async`] (2-D tiled dependence graph, the engine
//!   behind `task()`-mode `dmatdmatmult`), and
//!   [`for_each_tile_async_prepped`] (same graph with per-band
//!   preparation tasks as the band futures — the packing hook of the
//!   ISSUE 7 packed matmul).
//!
//! Every Blaze kernel is generic over `&Policy`, so each of the paper's
//! workloads is one call expressed three ways:
//!
//! ```ignore
//! blaze::daxpy(&exec::seq(), 3.0, &a, &mut b);                  // serial
//! blaze::daxpy(&exec::par().on(&hpx).threads(4), 3.0, &a, &mut b); // fork-join
//! blaze::daxpy(&exec::task().on(&hpx).threads(4), 3.0, &a, &mut b); // dataflow
//! ```
//!
//! This replaced the three disjoint pre-PR-5 entry points
//! (`ParallelRuntime::parallel_for`, `parallel_for_mono`,
//! `parallel_for_async`) and the bespoke `dmatdmatmult_dataflow_tiled`
//! kernel — see `DESIGN.md` §10 for the migration map.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::amt::cancel::CancelToken;
use crate::amt::future::{when_all, Future, Outcome, Promise};
use crate::amt::task::Hint;
use crate::amt::Scheduler;
use crate::par::LoopSched;
use crate::util::cli;

/// Default tile edge of [`for_each_tile_async`]'s decomposition: large
/// enough that one tile amortizes task scheduling, small enough that a
/// 150×150 product still yields a stealable graph.
pub const DEFAULT_TILE: usize = 64;

/// An execution resource a [`Policy`] can be placed `.on(..)`: something
/// that can run a chunked loop as a blocking fork-join region and — when
/// it owns an AMT substrate — as a graph of futurized tasks.
pub trait Executor: Send + Sync {
    /// Short human-readable name ("hpxMP", "OpenMP(baseline)", "serial")
    /// used in reports and bench rows.
    fn name(&self) -> &'static str;

    /// Largest useful team size / concurrency width.
    fn max_concurrency(&self) -> usize;

    /// Blocking fork-join bulk dispatch: partition `range` per `sched`
    /// across a team of `threads`, run `body` on each claimed sub-range,
    /// and return only after every iteration completed (implicit
    /// end-of-region barrier).
    fn bulk_sync(
        &self,
        threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    );

    /// The AMT scheduler behind this executor, when it has one.  `task()`
    /// algorithms build their future graphs on it; executors returning
    /// `None` (the warm OS-thread pool, [`Serial`]) degrade task-mode
    /// dispatch to eager inline execution with an already-ready join.
    fn scheduler(&self) -> Option<&Arc<Scheduler>> {
        None
    }

    /// Non-blocking bulk dispatch: run `body` over a static partition of
    /// `range` into `tasks` chunks and return a future fulfilled when
    /// every chunk retired.  `hint` seeds chunk placement
    /// ([`Hint::Any`] lets the scheduler interleave, `Hint::Worker(w)`
    /// pins the batch's first chunk to worker `w`).
    ///
    /// The default (for executors with no AMT substrate) executes
    /// eagerly through [`Executor::bulk_sync`] and returns
    /// [`Future::ready`] — same results, no asynchrony.
    fn bulk_async(
        &self,
        tasks: usize,
        hint: Hint,
        range: Range<i64>,
        body: Arc<dyn Fn(Range<i64>) + Send + Sync>,
    ) -> Future<()> {
        let _ = hint;
        let body_ref: &(dyn Fn(Range<i64>) + Sync) = &*body;
        self.bulk_sync(tasks, range, LoopSched::Static { chunk: None }, body_ref);
        Future::ready(())
    }

    /// Is the executor saturated *right now*?  Deadline-aware callers
    /// (the serving coordinator's load shedder) consult this before
    /// submitting work that would only queue behind already-admitted
    /// regions and blow its deadline anyway.  Executors without an
    /// admission budget (the OS-thread pool, [`Serial`]) are never
    /// overloaded — every submission starts immediately.
    fn overloaded(&self) -> bool {
        false
    }
}

/// Inline serial execution — the executor every mode can run on, and the
/// oracle the policy-equivalence tests compare against.  Below Blaze's
/// parallelization thresholds every policy collapses to this behaviour.
pub struct Serial;

impl Executor for Serial {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn max_concurrency(&self) -> usize {
        1
    }

    fn bulk_sync(
        &self,
        _threads: usize,
        range: Range<i64>,
        _sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        body(range);
    }
}

/// The three execution models a [`Policy`] can select — the axis the
/// `--exec` CLI flag, `HPXMP_EXEC`, and `benches/ablation_exec.rs` sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Serial: the whole range on the calling thread.
    Seq,
    /// Fork-join: an OpenMP-style team with an implicit end barrier.
    Par,
    /// Futurized task graph: chunks/tiles as dataflow tasks, joined
    /// through futures — no barriers.
    Task,
}

impl ExecMode {
    pub const ALL: [ExecMode; 3] = [ExecMode::Seq, ExecMode::Par, ExecMode::Task];

    /// Accepted spellings, resolved through the same
    /// [`cli::lookup_choice`] helper as [`crate::amt::PolicyKind`].
    pub const CHOICES: &[(&str, ExecMode)] = &[
        ("seq", ExecMode::Seq),
        ("par", ExecMode::Par),
        ("task", ExecMode::Task),
        ("serial", ExecMode::Seq),
        ("parallel", ExecMode::Par),
        ("dataflow", ExecMode::Task),
    ];

    pub fn parse(s: &str) -> Option<Self> {
        cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for `--exec` / `HPXMP_EXEC`: unknown values report
    /// the valid set instead of silently defaulting.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        cli::parse_choice("exec mode", s, Self::CHOICES)
    }

    /// Resolve the `HPXMP_EXEC` env binding, falling back to `default`
    /// when unset; a set-but-bad value fails loudly with the valid set.
    pub fn from_env(default: ExecMode) -> ExecMode {
        match std::env::var("HPXMP_EXEC") {
            Err(_) => default,
            Ok(v) => Self::parse_or_list(&v).unwrap_or_else(|e| panic!("HPXMP_EXEC: {e}")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Seq => "seq",
            ExecMode::Par => "par",
            ExecMode::Task => "task",
        }
    }
}

/// Which inner-loop implementation a Blaze kernel dispatches to — the
/// axis `benches/ablation_kernels.rs` and the `--kernel` CLI flag sweep
/// (ISSUE 7).  Selecting a variant never changes *where* work runs (the
/// [`ExecMode`] does that); it changes the per-chunk compute loop.
///
/// Numerics contract: [`KernelVariant::Auto`] is **numerics-preserving**
/// — it only picks an alternative implementation when the result is
/// bitwise-identical to the scalar loop (elementwise unrolling without
/// FMA) or when the operand is large enough that the repo-wide oracle
/// tests use tolerances anyway (packed matmul above
/// [`crate::blaze::thresholds::PACKED_MIN_DIM`]).  Explicitly requesting
/// `Unrolled`/`Packed` opts into reassociated sums and (with the `simd`
/// feature compiled and the CPU capable) fused multiply-add.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// Pick per (kernel, size): the fastest numerics-preserving path.
    Auto,
    /// The straightforward scalar loops in `blaze/serial.rs` — the
    /// oracle every other variant is tested against.
    Scalar,
    /// Explicitly 4-wide unrolled loops with split accumulators
    /// (`blaze/kernel.rs`); FMA when compiled+detected.
    Unrolled,
    /// Packed cache-blocked matmul micro-kernel (MR×NR register tile
    /// over KC-strip panels); non-matmul kernels fall back to
    /// [`KernelVariant::Unrolled`].
    Packed,
}

impl KernelVariant {
    pub const ALL: [KernelVariant; 4] = [
        KernelVariant::Auto,
        KernelVariant::Scalar,
        KernelVariant::Unrolled,
        KernelVariant::Packed,
    ];

    /// Accepted spellings, resolved through the same
    /// [`cli::lookup_choice`] helper as [`ExecMode`].
    pub const CHOICES: &[(&str, KernelVariant)] = &[
        ("auto", KernelVariant::Auto),
        ("scalar", KernelVariant::Scalar),
        ("unrolled", KernelVariant::Unrolled),
        ("packed", KernelVariant::Packed),
        ("simd", KernelVariant::Unrolled),
        ("blocked", KernelVariant::Packed),
    ];

    /// Lenient parse (None on unknown).
    pub fn parse(s: &str) -> Option<Self> {
        cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for `--kernel` / `HPXMP_KERNEL`: unknown values
    /// report the valid set instead of silently defaulting.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        cli::parse_choice("kernel variant", s, Self::CHOICES)
    }

    /// Resolve the `HPXMP_KERNEL` env binding, falling back to `default`
    /// when unset; a set-but-bad value fails loudly with the valid set.
    pub fn from_env(default: KernelVariant) -> KernelVariant {
        match std::env::var("HPXMP_KERNEL") {
            Err(_) => default,
            Ok(v) => Self::parse_or_list(&v).unwrap_or_else(|e| panic!("HPXMP_KERNEL: {e}")),
        }
    }

    /// Canonical name for reports and bench rows.
    pub fn name(&self) -> &'static str {
        match self {
            KernelVariant::Auto => "auto",
            KernelVariant::Scalar => "scalar",
            KernelVariant::Unrolled => "unrolled",
            KernelVariant::Packed => "packed",
        }
    }
}

/// A composable execution policy: *how* a generic algorithm or Blaze
/// kernel executes, as a value.
///
/// Built from [`seq()`] / [`par()`] / [`task()`] (or
/// [`Policy::with_mode`] when the mode is CLI-selected), then refined:
///
/// ```ignore
/// let pol = exec::task().on(&hpx).threads(8).tile(32).hint(Hint::Worker(2));
/// exec::for_each(&pol, 0..n, |r| ...);
/// ```
///
/// `Policy` is `Copy`; the executor is held by reference, so policies
/// are free to clone per benchmark cell (`pol.threads(t)`).  A policy
/// whose executor was never set runs on [`Serial`] — `seq()` is the only
/// constructor for which that is the natural resource, so attach `.on`
/// before running `par()`/`task()` policies on real hardware.
#[derive(Clone, Copy)]
pub struct Policy<'e> {
    mode: ExecMode,
    exec: &'e dyn Executor,
    threads: Option<usize>,
    sched: LoopSched,
    tile: usize,
    hint: Hint,
    /// Wall-clock budget measured from algorithm entry; expired → the
    /// algorithm abandons un-started chunks (ISSUE 6).
    deadline: Option<Duration>,
    /// *Absolute* deadline instant (ISSUE 9: wire requests carry their
    /// deadline from arrival, not from algorithm entry — a request that
    /// queued in the coalescing window has already spent budget).
    /// Composes with `deadline`/`token`: whichever source fires first
    /// abandons the tail.
    deadline_at: Option<Instant>,
    /// External cancellation token the algorithm observes at chunk
    /// boundaries.  Borrowed so `Policy` stays `Copy`.
    token: Option<&'e CancelToken>,
    /// Inner-loop implementation Blaze kernels dispatch to (ISSUE 7).
    kernel: KernelVariant,
    /// Override for the kernel's serial→parallel crossover element
    /// count; `None` keeps the per-kernel default from
    /// `blaze/thresholds.rs`.
    threshold: Option<usize>,
}

/// How a cancellable algorithm run ended (ISSUE 6): returned by
/// [`for_each`] so callers can distinguish full completion from an
/// abandoned tail or an isolated chunk failure without inventing
/// side-channels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecResult {
    /// Every chunk executed.
    Done,
    /// The policy's token fired or its deadline expired mid-run:
    /// `chunks_skipped` dispatched sub-ranges were abandoned un-run
    /// (already-started chunk bodies always finish).
    Cancelled { chunks_skipped: usize },
    /// At least one chunk body panicked (task mode; the panic stays
    /// isolated in the worker layer) — surviving chunks still completed
    /// and the join resolved.
    Failed,
}

impl ExecResult {
    pub fn is_done(&self) -> bool {
        matches!(self, ExecResult::Done)
    }
}

/// Serial execution policy (`hpx::execution::seq` analog).
pub fn seq() -> Policy<'static> {
    Policy::with_mode(ExecMode::Seq)
}

/// Fork-join team execution policy (`hpx::execution::par` analog).
pub fn par() -> Policy<'static> {
    Policy::with_mode(ExecMode::Par)
}

/// Futurized task-graph execution policy (the `hpx::execution::task`
/// composition the paper's conclusion points OpenMP toward).
pub fn task() -> Policy<'static> {
    Policy::with_mode(ExecMode::Task)
}

impl Policy<'static> {
    /// Constructor from a runtime-selected mode (the `--exec` /
    /// `HPXMP_EXEC` path); `seq()`/`par()`/`task()` are the literal
    /// spellings.
    pub fn with_mode(mode: ExecMode) -> Policy<'static> {
        Policy {
            mode,
            exec: &Serial,
            threads: None,
            sched: LoopSched::Static { chunk: None },
            tile: DEFAULT_TILE,
            hint: Hint::Any,
            deadline: None,
            deadline_at: None,
            token: None,
            kernel: KernelVariant::Auto,
            threshold: None,
        }
    }
}

impl<'e> Policy<'e> {
    /// Place the policy on an executor (`hpx`'s `.on(executor)`).
    pub fn on<'n>(self, exec: &'n dyn Executor) -> Policy<'n>
    where
        'e: 'n,
    {
        Policy {
            mode: self.mode,
            exec,
            threads: self.threads,
            sched: self.sched,
            tile: self.tile,
            hint: self.hint,
            deadline: self.deadline,
            deadline_at: self.deadline_at,
            token: self.token,
            kernel: self.kernel,
            threshold: self.threshold,
        }
    }

    /// Team size (fork-join) / chunk-task count (task mode).  Defaults
    /// to the executor's [`Executor::max_concurrency`].
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Loop schedule for fork-join dispatch (`schedule(static|dynamic|guided)`).
    pub fn chunk(mut self, sched: LoopSched) -> Self {
        self.sched = sched;
        self
    }

    /// Tile edge for 2-D task-graph decomposition
    /// ([`for_each_tile_async`]); default [`DEFAULT_TILE`].
    pub fn tile(mut self, tile: usize) -> Self {
        self.tile = tile.max(1);
        self
    }

    /// Placement hint seeding task-mode chunk distribution.
    pub fn hint(mut self, hint: Hint) -> Self {
        self.hint = hint;
        self
    }

    /// Wall-clock budget for the algorithm, measured from its entry:
    /// once `d` elapses, chunks that have not started are abandoned and
    /// the run reports [`ExecResult::Cancelled`].  Already-running chunk
    /// bodies finish (cooperative cancellation — nothing is torn down
    /// mid-iteration).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// *Absolute* wall-clock deadline: the algorithm abandons un-started
    /// chunks once `Instant::now()` passes `at`.  Unlike
    /// [`Policy::deadline`] the budget is not re-armed at algorithm
    /// entry, so callers that queued the work earlier (the wire
    /// front-end's coalescing window) charge the queueing delay against
    /// the same budget.  Composes with `deadline` and `token`: the
    /// earliest-firing source wins.
    pub fn deadline_at(mut self, at: Instant) -> Self {
        self.deadline_at = Some(at);
        self
    }

    /// Select the inner-loop implementation Blaze kernels dispatch to
    /// (ISSUE 7); default [`KernelVariant::Auto`].
    pub fn kernel(mut self, v: KernelVariant) -> Self {
        self.kernel = v;
        self
    }

    /// Override the serial→parallel crossover element count for Blaze
    /// kernels: operands with at least this many elements (kernel FLOPs
    /// for the compute-bound ops) parallelize; smaller ones run the
    /// serial path regardless of mode.  `None` (the default) keeps the
    /// per-kernel Blazemark-calibrated constants in
    /// `blaze/thresholds.rs`.
    pub fn threshold(mut self, elements: usize) -> Self {
        self.threshold = Some(elements);
        self
    }

    /// Observe an external cancellation token at every chunk boundary —
    /// composes with [`Policy::deadline`] (the deadline becomes a child
    /// of `token`, so either firing abandons the tail).
    pub fn token(mut self, token: &'e CancelToken) -> Self {
        self.token = Some(token);
        self
    }

    /// The configured wall-clock budget, if any.
    pub fn deadline_limit(&self) -> Option<Duration> {
        self.deadline
    }

    /// The configured external cancellation token, if any.
    pub fn cancel_token(&self) -> Option<&'e CancelToken> {
        self.token
    }

    /// Resolve the policy's cancellation sources into one token for this
    /// run: the external token, a fresh deadline token, or a
    /// deadline-bearing child of the external token — `None` when the
    /// policy is not cancellable (the hot path stays check-free).
    /// Deadlines are armed *now* (algorithm entry).
    pub fn effective_token(&self) -> Option<CancelToken> {
        let mut tok = match (self.token, self.deadline) {
            (None, None) => None,
            (Some(t), None) => Some(t.clone()),
            (Some(t), Some(d)) => Some(t.child_with_deadline(d)),
            (None, Some(d)) => Some(CancelToken::with_deadline(d)),
        };
        if let Some(at) = self.deadline_at {
            // Absolute deadline: the remaining budget (possibly zero —
            // already expired) hangs as a child off whatever the relative
            // sources produced, so the earliest source still wins.
            let remaining = at.saturating_duration_since(Instant::now());
            tok = Some(match tok {
                Some(t) => t.child_with_deadline(remaining),
                None => CancelToken::with_deadline(remaining),
            });
        }
        tok
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn executor(&self) -> &'e dyn Executor {
        self.exec
    }

    /// Resolved team size: the explicit `.threads(..)` override or the
    /// executor's maximum.
    pub fn num_threads(&self) -> usize {
        self.threads
            .unwrap_or_else(|| self.exec.max_concurrency())
            .max(1)
    }

    pub fn sched(&self) -> LoopSched {
        self.sched
    }

    pub fn tile_size(&self) -> usize {
        self.tile
    }

    pub fn placement(&self) -> Hint {
        self.hint
    }

    /// The selected inner-loop implementation ([`Policy::kernel`]).
    pub fn kernel_variant(&self) -> KernelVariant {
        self.kernel
    }

    /// Resolve the parallelization threshold for a kernel whose default
    /// crossover is `default` elements: the explicit
    /// [`Policy::threshold`] override wins, else the per-kernel constant.
    pub fn par_threshold(&self, default: usize) -> usize {
        self.threshold.unwrap_or(default)
    }

    /// Does this policy execute serially?  True for `seq()` and for any
    /// policy resolved to a single thread — the predicate Blaze kernels
    /// combine with their size thresholds to pick the serial kernel.
    pub fn is_serial(&self) -> bool {
        self.mode == ExecMode::Seq || self.num_threads() <= 1
    }

    /// Report label: `"par(hpxMP)"`, `"task(serial)"`, ...
    pub fn label(&self) -> String {
        format!("{}({})", self.mode.name(), self.exec.name())
    }
}

impl std::fmt::Debug for Policy<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Policy")
            .field("mode", &self.mode)
            .field("exec", &self.exec.name())
            .field("threads", &self.threads)
            .field("sched", &self.sched)
            .field("tile", &self.tile)
            .field("hint", &self.hint)
            .field("deadline", &self.deadline)
            .field("deadline_at", &self.deadline_at.is_some())
            .field("token", &self.token.is_some())
            .field("kernel", &self.kernel)
            .field("threshold", &self.threshold)
            .finish()
    }
}

/// Run `body` over a partition of `range` under `pol` and return when
/// every iteration completed — the one generic loop algorithm behind
/// every Blaze kernel and the legacy `parallel_for*` wrappers.
///
/// * `seq()` (or one resolved thread): `body(range)` on the caller.
/// * `par()`: a fork-join region via [`Executor::bulk_sync`].
/// * `task()`: chunk tasks via [`Executor::bulk_async`], helping /
///   parking until the join future fulfils.
///
/// With a [`Policy::deadline`] / [`Policy::token`] attached, chunks that
/// have not started when the token fires are abandoned and the run
/// reports [`ExecResult::Cancelled`]; otherwise the result is
/// [`ExecResult::Done`] (or [`ExecResult::Failed`] when a task-mode
/// chunk panicked — the join still resolves).
pub fn for_each<F>(pol: &Policy<'_>, range: Range<i64>, body: F) -> ExecResult
where
    F: Fn(Range<i64>) + Sync,
{
    if range.start >= range.end {
        return ExecResult::Done;
    }
    let cancel = pol.effective_token();
    if pol.is_serial() {
        // The one serial spelling: covers seq() and single-thread policies.
        if cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
            return ExecResult::Cancelled { chunks_skipped: 1 };
        }
        body(range);
        return ExecResult::Done;
    }
    if pol.mode() == ExecMode::Task {
        // The join below blocks until every chunk retired, so
        // re-borrowing the non-'static `body` for the dispatch is
        // sound: smuggle the thin pointer as an address and
        // re-materialize inside each chunk task (`F: Sync` makes the
        // shared re-borrow across workers sound).
        let skipped = Arc::new(AtomicUsize::new(0));
        let body_addr = &body as *const F as usize;
        let sk = skipped.clone();
        let tok = cancel.clone();
        let chunk: Arc<dyn Fn(Range<i64>) + Send + Sync> = Arc::new(move |r| {
            if tok.as_ref().is_some_and(|t| t.is_cancelled()) {
                sk.fetch_add(1, Ordering::Relaxed);
                return;
            }
            // SAFETY: see above — the blocking join below keeps `body`
            // alive past every use, and `F: Sync` permits the shared
            // re-borrow.
            let body: &F = unsafe { &*(body_addr as *const F) };
            body(r);
        });
        let join = pol
            .executor()
            .bulk_async(pol.num_threads(), pol.placement(), range, chunk);
        let outcome = join.wait_outcome();
        let n_skipped = skipped.load(Ordering::Relaxed);
        return match outcome {
            Outcome::Panicked => ExecResult::Failed,
            _ if n_skipped > 0 => ExecResult::Cancelled {
                chunks_skipped: n_skipped,
            },
            Outcome::Cancelled => ExecResult::Cancelled { chunks_skipped: 0 },
            Outcome::Value(_) => ExecResult::Done,
        };
    }
    // Par (Seq never reaches here: seq() is always serial).
    match cancel {
        None => {
            pol.executor()
                .bulk_sync(pol.num_threads(), range, pol.sched(), &body);
            ExecResult::Done
        }
        Some(tok) => {
            let skipped = AtomicUsize::new(0);
            let run = |r: Range<i64>| {
                if tok.is_cancelled() {
                    skipped.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                body(r);
            };
            pol.executor()
                .bulk_sync(pol.num_threads(), range, pol.sched(), &run);
            match skipped.load(Ordering::Relaxed) {
                0 => ExecResult::Done,
                s => ExecResult::Cancelled { chunks_skipped: s },
            }
        }
    }
}

/// Non-blocking [`for_each`]: returns a [`Future`] fulfilled when every
/// iteration completed, composing with `then`/`when_all` into dataflow
/// graphs without intermediate barriers.
///
/// Only `task()` policies are genuinely asynchronous; `seq()`/`par()`
/// (and executors without an AMT substrate) execute eagerly and return
/// an already-ready future — identical results, no overlap.  `body` is
/// shared (`Arc`) because task mode outlives the caller's stack frame;
/// chunk panics are isolated in the worker layer and the join future
/// still fulfils (arrival is a drop guard).
/// A cancellable policy reports through the returned future's *outcome*:
/// [`Outcome::Cancelled`] when any chunk was abandoned (`wait()` still
/// returns; error-tolerant callers read [`Future::wait_outcome`]).
pub fn for_each_async(
    pol: &Policy<'_>,
    range: Range<i64>,
    body: Arc<dyn Fn(Range<i64>) + Send + Sync>,
) -> Future<()> {
    if range.start >= range.end {
        return Future::ready(());
    }
    let cancel = pol.effective_token();
    match pol.mode() {
        ExecMode::Seq => {
            if cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
                return Future::with_outcome(Outcome::Cancelled);
            }
            body(range);
            Future::ready(())
        }
        ExecMode::Par => {
            if cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
                return Future::with_outcome(Outcome::Cancelled);
            }
            let body_ref: &(dyn Fn(Range<i64>) + Sync) = &*body;
            pol.executor()
                .bulk_sync(pol.num_threads(), range, pol.sched(), body_ref);
            Future::ready(())
        }
        // Even a single-chunk task() stays asynchronous: the caller may
        // rely on the future, not on inline completion.
        ExecMode::Task => match cancel {
            None => pol
                .executor()
                .bulk_async(pol.num_threads(), pol.placement(), range, body),
            Some(tok) => {
                let skipped = Arc::new(AtomicUsize::new(0));
                let sk = skipped.clone();
                let wrapped: Arc<dyn Fn(Range<i64>) + Send + Sync> = Arc::new(move |r| {
                    if tok.is_cancelled() {
                        sk.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    body(r);
                });
                let join =
                    pol.executor()
                        .bulk_async(pol.num_threads(), pol.placement(), range, wrapped);
                // Re-join through a fresh promise so an abandoned tail
                // surfaces as a Cancelled outcome instead of a silent
                // Value — downstream `then` chains short-circuit on it.
                let promise = Promise::new();
                let fut = promise.get_future();
                join.on_ready(move |out: &Outcome<()>| match out {
                    Outcome::Panicked => promise.set_panicked(),
                    _ if skipped.load(Ordering::Relaxed) > 0 => promise.set_cancelled(),
                    Outcome::Cancelled => promise.set_cancelled(),
                    Outcome::Value(_) => promise.set_value(()),
                });
                fut
            }
        },
    }
}

/// 2-D tiled task-graph execution: partition `rows × cols` into
/// [`Policy::tile`]-edged tiles, run `body(row_range, col_range)` per
/// tile as a continuation hung off `when_all` of the tile's *input-band
/// futures* (its row band and column band), and return the single
/// `when_all` join of all tiles — the generic engine that replaced the
/// bespoke `dmatdmatmult_dataflow_tiled` kernel.
///
/// The band futures are materialized ready here (the operands exist),
/// but the graph shape is exactly what lets an upstream producer chain
/// results without joins: hang the band futures off producer tasks
/// instead and nothing else changes.
///
/// On an executor without an AMT scheduler (or a serial policy) the
/// tile sweep degrades like [`Executor::bulk_async`]'s default — eager,
/// but still parallel: row-tile bands are partitioned through
/// [`Executor::bulk_sync`] (each band's tiles run left-to-right by one
/// claimant, bands are disjoint in the output), returning a ready join.
/// Same per-tile bodies either way, so the algorithm stays
/// policy-generic *and* the comparator keeps its parallelism.
pub fn for_each_tile_async(
    pol: &Policy<'_>,
    rows: usize,
    cols: usize,
    body: Arc<dyn Fn(Range<usize>, Range<usize>) + Send + Sync>,
) -> Future<()> {
    tile_graph(pol, rows, cols, None, body)
}

/// A band-preparation hook for [`for_each_tile_async_prepped`]: called
/// once per row (or column) tile band with `(band_index, band_range)`
/// before any tile of that band runs.
pub type BandPrep = Arc<dyn Fn(usize, Range<usize>) + Send + Sync>;

/// [`for_each_tile_async`] with *band futures that do work*: `row_prep`
/// runs once per row-tile band and `col_prep` once per column-tile band
/// as real tasks on the graph, and every tile's `when_all` input edge is
/// its two bands' prep futures — so per-band preparation (packing a
/// matrix panel into a contiguous buffer, ISSUE 7) overlaps tile compute
/// and is shared across all tiles of the band instead of being redone
/// per tile.
///
/// Ordering contract: `body(ri, rj)` observes the completed
/// `row_prep(bi, ri)` and `col_prep(bj, rj)` for its own bands (the
/// `when_all` edge), but bands are otherwise unordered against each
/// other.  On an executor without an AMT scheduler (or a serial policy)
/// all preps run before the eager tile sweep — parallel via
/// [`Executor::bulk_sync`] when the policy is.  Cancellation skips tile
/// bodies (as in [`for_each_tile_async`]) but never preps: a pack buffer
/// must be consistent for the tiles that already started.
pub fn for_each_tile_async_prepped(
    pol: &Policy<'_>,
    rows: usize,
    cols: usize,
    row_prep: BandPrep,
    col_prep: BandPrep,
    body: Arc<dyn Fn(Range<usize>, Range<usize>) + Send + Sync>,
) -> Future<()> {
    tile_graph(pol, rows, cols, Some((row_prep, col_prep)), body)
}

/// Shared engine behind [`for_each_tile_async`] and
/// [`for_each_tile_async_prepped`] — identical graph shape, with band
/// futures either materialized ready (`preps: None`) or hung off
/// spawned preparation tasks.
fn tile_graph(
    pol: &Policy<'_>,
    rows: usize,
    cols: usize,
    preps: Option<(BandPrep, BandPrep)>,
    body: Arc<dyn Fn(Range<usize>, Range<usize>) + Send + Sync>,
) -> Future<()> {
    if rows == 0 || cols == 0 {
        return Future::ready(());
    }
    // Cancellable policy: every tile checks the resolved token before
    // running; abandoned tiles are counted and surface as a Cancelled
    // outcome on the join.
    let cancel = pol.effective_token();
    let skipped = Arc::new(AtomicUsize::new(0));
    let body: Arc<dyn Fn(Range<usize>, Range<usize>) + Send + Sync> = match &cancel {
        None => body,
        Some(tok) => {
            let tok = tok.clone();
            let sk = skipped.clone();
            let inner = body;
            Arc::new(move |ri: Range<usize>, rj: Range<usize>| {
                if tok.is_cancelled() {
                    sk.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                inner(ri, rj);
            })
        }
    };
    let tile = pol.tile_size().max(8);
    let row_tiles = rows.div_ceil(tile);
    let col_tiles = cols.div_ceil(tile);
    let sched = match pol.executor().scheduler() {
        Some(s) if pol.mode() == ExecMode::Task && !pol.is_serial() => s.clone(),
        _ => {
            if let Some((rp, cp)) = &preps {
                // Eager fallback: every band prep completes before any
                // tile runs.  One fused index space (row bands first,
                // then column bands) so a parallel policy overlaps them.
                let prep_band = |r: Range<i64>| {
                    for b in r.start as usize..r.end as usize {
                        if b < row_tiles {
                            rp(b, b * tile..((b + 1) * tile).min(rows));
                        } else {
                            let bj = b - row_tiles;
                            cp(bj, bj * tile..((bj + 1) * tile).min(cols));
                        }
                    }
                };
                let total = (row_tiles + col_tiles) as i64;
                if pol.is_serial() {
                    prep_band(0..total);
                } else {
                    pol.executor().bulk_sync(
                        pol.num_threads(),
                        0..total,
                        LoopSched::Static { chunk: None },
                        &prep_band,
                    );
                }
            }
            let band = |r: Range<i64>| {
                for bi in r.start as usize..r.end as usize {
                    let (i0, i1) = (bi * tile, ((bi + 1) * tile).min(rows));
                    for j0 in (0..cols).step_by(tile) {
                        let j1 = (j0 + tile).min(cols);
                        body(i0..i1, j0..j1);
                    }
                }
            };
            if pol.is_serial() {
                band(0..row_tiles as i64);
            } else {
                pol.executor().bulk_sync(
                    pol.num_threads(),
                    0..row_tiles as i64,
                    LoopSched::Static { chunk: None },
                    &band,
                );
            }
            return if skipped.load(Ordering::Relaxed) > 0 {
                Future::with_outcome(Outcome::Cancelled)
            } else {
                Future::ready(())
            };
        }
    };

    // The input tiles of the graph: rows banded by tile, columns by
    // tile, one future each.  With preps attached the band future IS the
    // spawned preparation task; without, it is materialized ready (the
    // operands exist as-is).
    let (row_bands, col_bands): (Vec<Future<()>>, Vec<Future<()>>) = match &preps {
        None => (
            (0..row_tiles).map(|_| Future::ready(())).collect(),
            (0..col_tiles).map(|_| Future::ready(())).collect(),
        ),
        Some((rp, cp)) => (
            (0..row_tiles)
                .map(|bi| {
                    let rp = rp.clone();
                    let (i0, i1) = (bi * tile, ((bi + 1) * tile).min(rows));
                    Future::ready(())
                        .then_named(&sched, "exec_pack_row_band", move |_| rp(bi, i0..i1))
                })
                .collect(),
            (0..col_tiles)
                .map(|bj| {
                    let cp = cp.clone();
                    let (j0, j1) = (bj * tile, ((bj + 1) * tile).min(cols));
                    Future::ready(())
                        .then_named(&sched, "exec_pack_col_band", move |_| cp(bj, j0..j1))
                })
                .collect(),
        ),
    };

    let mut tiles: Vec<Future<()>> = Vec::with_capacity(row_tiles * col_tiles);
    for bi in 0..row_tiles {
        let (i0, i1) = (bi * tile, ((bi + 1) * tile).min(rows));
        for bj in 0..col_tiles {
            let (j0, j1) = (bj * tile, ((bj + 1) * tile).min(cols));
            let inputs = [row_bands[bi].clone(), col_bands[bj].clone()];
            let body = body.clone();
            let tile_task = when_all(&inputs)
                .then_named(&sched, "exec_tile", move |_| body(i0..i1, j0..j1));
            tiles.push(tile_task);
        }
    }
    let join = when_all(&tiles);
    match cancel {
        None => join,
        Some(_) => {
            let promise = Promise::new();
            let fut = promise.get_future();
            join.on_ready(move |out: &Outcome<()>| match out {
                Outcome::Panicked => promise.set_panicked(),
                _ if skipped.load(Ordering::Relaxed) > 0 => promise.set_cancelled(),
                Outcome::Cancelled => promise.set_cancelled(),
                Outcome::Value(_) => promise.set_value(()),
            });
            fut
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::OmpRuntime;
    use crate::par::HpxMpRuntime;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn coverage(pol: &Policy<'_>, n: i64) {
        let seen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        for_each(pol, 0..n, |r| {
            for i in r {
                seen[i as usize].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(
            seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
            "{} missed/duplicated iterations (n={n})",
            pol.label()
        );
    }

    #[test]
    fn seq_policy_covers_inline() {
        coverage(&seq(), 1000);
    }

    #[test]
    fn policies_cover_on_hpxmp() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for mode in ExecMode::ALL {
            for threads in [1, 2, 4] {
                let pol = Policy::with_mode(mode).on(&hpx).threads(threads);
                coverage(&pol, 777);
            }
        }
    }

    #[test]
    fn combinators_compose_and_accessors_resolve() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        let pol = task()
            .on(&hpx)
            .threads(3)
            .chunk(LoopSched::Dynamic { chunk: 8 })
            .tile(32)
            .hint(Hint::Worker(1));
        assert_eq!(pol.mode(), ExecMode::Task);
        assert_eq!(pol.num_threads(), 3);
        assert_eq!(pol.sched(), LoopSched::Dynamic { chunk: 8 });
        assert_eq!(pol.tile_size(), 32);
        assert_eq!(pol.placement(), Hint::Worker(1));
        assert_eq!(pol.label(), "task(hpxMP)");
        // Defaults resolve from the executor.
        assert_eq!(par().on(&hpx).num_threads(), 2);
        assert!(seq().is_serial());
        assert!(par().on(&hpx).threads(1).is_serial());
        // Cancellation combinators (ISSUE 6).
        let tok = CancelToken::new();
        let pol2 = par()
            .on(&hpx)
            .deadline(Duration::from_millis(5))
            .token(&tok);
        assert_eq!(pol2.deadline_limit(), Some(Duration::from_millis(5)));
        assert!(pol2.cancel_token().is_some());
        assert!(pol2.effective_token().is_some());
        assert!(seq().effective_token().is_none(), "hot path stays check-free");
        // Kernel-variant / threshold combinators (ISSUE 7).
        assert_eq!(seq().kernel_variant(), KernelVariant::Auto);
        let pol3 = par()
            .on(&hpx)
            .kernel(KernelVariant::Packed)
            .threshold(1234);
        assert_eq!(pol3.kernel_variant(), KernelVariant::Packed);
        assert_eq!(pol3.par_threshold(99), 1234, "override wins");
        assert_eq!(par().par_threshold(99), 99, "default flows through");
        // `.on()` preserves the new knobs.
        assert_eq!(pol3.on(&hpx).kernel_variant(), KernelVariant::Packed);
        assert_eq!(pol3.on(&hpx).par_threshold(99), 1234);
    }

    #[test]
    fn kernel_variant_parse_roundtrip_and_listing() {
        for v in KernelVariant::ALL {
            assert_eq!(KernelVariant::parse(v.name()), Some(v));
        }
        assert_eq!(KernelVariant::parse("simd"), Some(KernelVariant::Unrolled));
        assert_eq!(KernelVariant::parse("blocked"), Some(KernelVariant::Packed));
        let err = KernelVariant::parse_or_list("bogus").unwrap_err();
        assert!(err.contains("auto|scalar|unrolled|packed"), "{err}");
    }

    #[test]
    fn cancelled_token_abandons_unstarted_chunks_in_every_mode() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let tok = CancelToken::new();
        tok.cancel();
        for mode in ExecMode::ALL {
            let ran = AtomicU32::new(0);
            let pol = Policy::with_mode(mode).on(&hpx).threads(4).token(&tok);
            let res = for_each(&pol, 0..1000, |r| {
                ran.fetch_add((r.end - r.start) as u32, Ordering::SeqCst);
            });
            assert_eq!(ran.load(Ordering::SeqCst), 0, "{mode:?} ran cancelled work");
            assert!(
                matches!(res, ExecResult::Cancelled { .. }),
                "{mode:?} reported {res:?}"
            );
        }
    }

    #[test]
    fn expired_deadline_reports_cancelled() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        // Zero budget: expired at algorithm entry.
        let pol = par().on(&hpx).threads(2).deadline(Duration::from_secs(0));
        let ran = AtomicU32::new(0);
        let res = for_each(&pol, 0..100, |r| {
            ran.fetch_add((r.end - r.start) as u32, Ordering::SeqCst);
        });
        assert!(matches!(res, ExecResult::Cancelled { .. }), "{res:?}");
        assert_eq!(ran.load(Ordering::SeqCst), 0);
        // Without a budget the same run completes.
        assert_eq!(
            for_each(&par().on(&hpx).threads(2), 0..100, |_r| {}),
            ExecResult::Done
        );
    }

    #[test]
    fn absolute_deadline_expired_on_arrival_reports_cancelled() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        // A deadline instant already in the past (the wire path's "spent
        // its whole budget queueing" case): nothing may run.
        let pol = par().on(&hpx).threads(2).deadline_at(Instant::now());
        let ran = AtomicU32::new(0);
        let res = for_each(&pol, 0..100, |r| {
            ran.fetch_add((r.end - r.start) as u32, Ordering::SeqCst);
        });
        assert!(matches!(res, ExecResult::Cancelled { .. }), "{res:?}");
        assert_eq!(ran.load(Ordering::SeqCst), 0);
        // A generous absolute deadline completes normally.
        let pol = par()
            .on(&hpx)
            .threads(2)
            .deadline_at(Instant::now() + Duration::from_secs(60));
        assert_eq!(for_each(&pol, 0..100, |_r| {}), ExecResult::Done);
    }

    #[test]
    fn token_fired_mid_run_abandons_the_tail() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        let tok = CancelToken::new();
        let pol = par()
            .on(&hpx)
            .threads(2)
            .chunk(LoopSched::Dynamic { chunk: 1 })
            .token(&tok);
        let ran = AtomicU32::new(0);
        let res = for_each(&pol, 0..1000, |r| {
            if r.start == 0 {
                tok.cancel();
            }
            crate::util::timing::spin_wait(std::time::Duration::from_micros(50));
            ran.fetch_add((r.end - r.start) as u32, Ordering::SeqCst);
        });
        assert!(matches!(res, ExecResult::Cancelled { .. }), "{res:?}");
        assert!(
            ran.load(Ordering::SeqCst) < 1000,
            "no chunks were abandoned after the token fired"
        );
    }

    #[test]
    fn async_cancelled_policy_reports_cancelled_outcome() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        let tok = CancelToken::new();
        tok.cancel();
        let pol = task().on(&hpx).threads(4).token(&tok);
        let fut = for_each_async(&pol, 0..100, Arc::new(|_r| panic!("must not run")));
        assert!(
            matches!(fut.wait_outcome(), Outcome::Cancelled),
            "abandoned run must surface as a Cancelled outcome"
        );
        // Tiled variant: same contract.
        let tiled = for_each_tile_async(
            &task().on(&hpx).threads(2).tile(16).token(&tok),
            64,
            64,
            Arc::new(|_ri, _rj| panic!("must not run")),
        );
        assert!(matches!(tiled.wait_outcome(), Outcome::Cancelled));
    }

    #[test]
    fn exec_mode_parse_roundtrip_and_listing() {
        for mode in ExecMode::ALL {
            assert_eq!(ExecMode::parse(mode.name()), Some(mode));
        }
        assert_eq!(ExecMode::parse("dataflow"), Some(ExecMode::Task));
        let err = ExecMode::parse_or_list("bogus").unwrap_err();
        assert!(err.contains("seq|par|task"), "{err}");
    }

    #[test]
    fn for_each_async_task_composes_with_then() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let n = 512i64;
        let data: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let d = data.clone();
        let pol = task().on(&hpx).threads(4);
        let phase1 = for_each_async(
            &pol,
            0..n,
            Arc::new(move |r: Range<i64>| {
                for i in r {
                    d[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            }),
        );
        let sched = hpx.rt.sched.clone();
        let d = data.clone();
        let total = phase1.then(&sched, move |_| {
            d.iter().map(|v| v.load(Ordering::SeqCst)).sum::<u32>()
        });
        assert_eq!(total.get(), n as u32);
    }

    #[test]
    fn tiled_graph_covers_every_cell_exactly_once() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for (rows, cols, tile) in [(64usize, 64usize, 16usize), (57, 83, 16), (10, 200, 32)] {
            let cells: Arc<Vec<AtomicU32>> =
                Arc::new((0..rows * cols).map(|_| AtomicU32::new(0)).collect());
            let c = cells.clone();
            let pol = task().on(&hpx).threads(4).tile(tile);
            for_each_tile_async(
                &pol,
                rows,
                cols,
                Arc::new(move |ri: Range<usize>, rj: Range<usize>| {
                    for i in ri.clone() {
                        for j in rj.clone() {
                            c[i * cols + j].fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }),
            )
            .wait();
            assert!(
                cells.iter().all(|v| v.load(Ordering::SeqCst) == 1),
                "tiles missed/overlapped cells ({rows}x{cols}, tile {tile})"
            );
        }
    }

    #[test]
    fn tiled_fallback_without_scheduler_is_eager_and_complete() {
        // task() on the AMT-less baseline pool: the tile sweep degrades
        // through bulk_sync (row-tile bands forked across the team) and
        // returns an already-ready join — every cell exactly once.
        let base = crate::baseline::BaselineRuntime::new(3);
        let (rows, cols) = (40usize, 24usize);
        let cells: Arc<Vec<AtomicU32>> =
            Arc::new((0..rows * cols).map(|_| AtomicU32::new(0)).collect());
        let c = cells.clone();
        let fut = for_each_tile_async(
            &task().on(&base).threads(3).tile(8),
            rows,
            cols,
            Arc::new(move |ri: Range<usize>, rj: Range<usize>| {
                for i in ri.clone() {
                    for j in rj.clone() {
                        c[i * cols + j].fetch_add(1, Ordering::SeqCst);
                    }
                }
            }),
        );
        assert!(fut.is_ready(), "schedulerless tile dispatch must be eager");
        assert!(cells.iter().all(|v| v.load(Ordering::SeqCst) == 1));
    }

    /// Shared skeleton for the prepped-graph tests: every band prep must
    /// run exactly once and *before* any tile of its band, every cell
    /// exactly once.
    fn prepped_coverage(pol: &Policy<'_>, rows: usize, cols: usize, tile: usize) {
        let row_tiles = rows.div_ceil(tile);
        let col_tiles = cols.div_ceil(tile);
        let row_ready: Arc<Vec<AtomicU32>> =
            Arc::new((0..row_tiles).map(|_| AtomicU32::new(0)).collect());
        let col_ready: Arc<Vec<AtomicU32>> =
            Arc::new((0..col_tiles).map(|_| AtomicU32::new(0)).collect());
        let cells: Arc<Vec<AtomicU32>> =
            Arc::new((0..rows * cols).map(|_| AtomicU32::new(0)).collect());
        let (rr, cr, ce) = (row_ready.clone(), col_ready.clone(), cells.clone());
        let (rr2, cr2) = (row_ready.clone(), col_ready.clone());
        for_each_tile_async_prepped(
            &pol.tile(tile),
            rows,
            cols,
            Arc::new(move |bi, ri: Range<usize>| {
                assert_eq!(ri.start, bi * tile, "row band range mismatch");
                rr2[bi].fetch_add(1, Ordering::SeqCst);
            }),
            Arc::new(move |bj, rj: Range<usize>| {
                assert_eq!(rj.start, bj * tile, "col band range mismatch");
                cr2[bj].fetch_add(1, Ordering::SeqCst);
            }),
            Arc::new(move |ri: Range<usize>, rj: Range<usize>| {
                // The ordering contract: this tile's bands are prepped.
                assert_eq!(rr[ri.start / tile].load(Ordering::SeqCst), 1);
                assert_eq!(cr[rj.start / tile].load(Ordering::SeqCst), 1);
                for i in ri.clone() {
                    for j in rj.clone() {
                        ce[i * cols + j].fetch_add(1, Ordering::SeqCst);
                    }
                }
            }),
        )
        .wait();
        assert!(row_ready.iter().all(|v| v.load(Ordering::SeqCst) == 1));
        assert!(col_ready.iter().all(|v| v.load(Ordering::SeqCst) == 1));
        assert!(
            cells.iter().all(|v| v.load(Ordering::SeqCst) == 1),
            "{}: prepped tiles missed/overlapped cells ({rows}x{cols}, tile {tile})",
            pol.label()
        );
    }

    #[test]
    fn prepped_graph_runs_band_preps_before_tiles() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for (rows, cols, tile) in [(64usize, 64usize, 16usize), (57, 83, 16), (10, 200, 32)] {
            prepped_coverage(&task().on(&hpx).threads(4), rows, cols, tile);
        }
    }

    #[test]
    fn prepped_fallbacks_run_preps_first() {
        // Serial and schedulerless policies degrade to eager preps
        // followed by the eager tile sweep — same contract.
        prepped_coverage(&seq(), 40, 24, 8);
        let base = crate::baseline::BaselineRuntime::new(3);
        prepped_coverage(&task().on(&base).threads(3), 40, 24, 8);
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        prepped_coverage(&par().on(&hpx).threads(2), 40, 24, 8);
    }

    #[test]
    fn tiled_graph_serial_fallback_matches() {
        // No scheduler behind Serial: tiles run inline, join is ready.
        let cells: Arc<Vec<AtomicU32>> = Arc::new((0..30 * 20).map(|_| AtomicU32::new(0)).collect());
        let c = cells.clone();
        let fut = for_each_tile_async(
            &seq().tile(8),
            30,
            20,
            Arc::new(move |ri: Range<usize>, rj: Range<usize>| {
                for i in ri.clone() {
                    for j in rj.clone() {
                        c[i * 20 + j].fetch_add(1, Ordering::SeqCst);
                    }
                }
            }),
        );
        assert!(fut.is_ready());
        assert!(cells.iter().all(|v| v.load(Ordering::SeqCst) == 1));
    }
}
