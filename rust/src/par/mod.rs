//! The execution seam Blaze-lite parallelizes over.
//!
//! The paper's experiment is "same application (Blaze), two OpenMP
//! runtimes (hpxMP vs. the compiler-supplied one)".  Since PR 5 that
//! seam is the HPX-style [`exec`] policy API: [`HpxMpRuntime`] (hpxMP),
//! [`crate::baseline::BaselineRuntime`] (libomp-style) and
//! [`exec::Serial`] all implement [`exec::Executor`], and every kernel /
//! benchmark takes an [`exec::Policy`] — so serial, fork-join and
//! futurized-dataflow execution are a one-line policy swap
//! (`seq()` / `par().on(&rt)` / `task().on(&rt)`).
//!
//! The legacy entry points (`parallel_for`, `parallel_for_mono`,
//! `parallel_for_async`) survive as thin wrappers over
//! [`exec::for_each`] / [`exec::for_each_async`]; the old
//! `ParallelRuntime` trait and `SerialRuntime` struct are gone
//! (DESIGN.md §10 has the migration map).

pub mod exec;

pub use exec::{
    for_each, for_each_async, for_each_tile_async, par, seq, task, ExecMode, ExecResult, Executor,
    Policy, Serial,
};

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::amt::future::{Future, Promise};
use crate::util::lock_unpoisoned;
use crate::amt::task::Hint;
use crate::amt::{Payload, Priority, Scheduler};
use crate::omp::icv::Schedule;
use crate::omp::{fork_call, OmpRuntime};

/// Loop scheduling requested by the application (maps to
/// `#pragma omp for schedule(...)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopSched {
    /// `schedule(static[,chunk])`
    Static { chunk: Option<usize> },
    /// `schedule(dynamic,chunk)`
    Dynamic { chunk: usize },
    /// `schedule(guided,chunk)`
    Guided { chunk: usize },
}

impl Default for LoopSched {
    fn default() -> Self {
        LoopSched::Static { chunk: None }
    }
}

/// hpxMP as an [`Executor`] — the paper's system under test.
pub struct HpxMpRuntime {
    pub rt: Arc<OmpRuntime>,
}

impl HpxMpRuntime {
    pub fn new(rt: Arc<OmpRuntime>) -> Self {
        Self { rt }
    }

    /// The monomorphized fork-join engine behind
    /// [`Executor::bulk_sync`]: the per-chunk inner loop is compiled
    /// against the concrete `F`, so chunk dispatch is a static call
    /// (and inlinable); the trait path passes `F = &dyn Fn` — identical
    /// behavior, one indirection.
    fn bulk_sync_mono<F>(&self, num_threads: usize, range: Range<i64>, sched: LoopSched, body: &F)
    where
        F: Fn(Range<i64>) + Sync,
    {
        // fork_call requires 'static, but it joins before returning, so
        // re-borrowing `body` for the region is sound: smuggle the thin
        // pointer as an address and re-materialize inside the region.
        let body_addr = body as *const F as usize;
        fork_call(&self.rt, Some(num_threads), move |ctx| {
            // SAFETY: fork_call blocks until the region joins, so `body`
            // outlives every use here; `F: Sync` makes the shared
            // re-borrow across team members sound.
            let body: &F = unsafe { &*(body_addr as *const F) };
            match sched {
                LoopSched::Static { chunk } => {
                    ctx.for_static_chunks(range.clone(), chunk, |r| body(r));
                }
                LoopSched::Dynamic { chunk } => {
                    let desc = ctx.dispatch_init(
                        range.clone(),
                        Schedule::new(crate::omp::SchedKind::Dynamic, Some(chunk)),
                    );
                    while let Some(r) = ctx.dispatch_next(&desc, range.start) {
                        body(r);
                    }
                    ctx.dispatch_fini(&desc);
                }
                LoopSched::Guided { chunk } => {
                    let desc = ctx.dispatch_init(
                        range.clone(),
                        Schedule::new(crate::omp::SchedKind::Guided, Some(chunk)),
                    );
                    while let Some(r) = ctx.dispatch_next(&desc, range.start) {
                        body(r);
                    }
                    ctx.dispatch_fini(&desc);
                }
            }
            // implicit region-end barrier joins the loop
        });
    }

    /// Legacy fork-join entry point — a thin wrapper over
    /// [`exec::for_each`] with a `par().on(self)` policy.
    pub fn parallel_for(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        for_each(
            &par().on(self).threads(num_threads).chunk(sched),
            range,
            body,
        );
    }

    /// Legacy monomorphized fork-join entry point: delegates straight to
    /// the concrete engine (one static call per chunk).
    pub fn parallel_for_mono<F>(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &F,
    ) where
        F: Fn(Range<i64>) + Sync,
    {
        self.bulk_sync_mono(num_threads, range, sched, body);
    }

    /// Legacy async seam (ISSUE 2) — a thin wrapper over
    /// [`exec::for_each_async`] with a `task().on(self)` policy: chunks
    /// run as plain AMT tasks, the returned future fulfils when every
    /// chunk retired, and nothing blocks (regions compose through
    /// `then`/`when_all` without intermediate barriers).
    pub fn parallel_for_async(
        &self,
        num_tasks: usize,
        range: Range<i64>,
        body: Arc<dyn Fn(Range<i64>) + Send + Sync>,
    ) -> Future<()> {
        for_each_async(&task().on(self).threads(num_tasks), range, body)
    }
}

impl Executor for HpxMpRuntime {
    fn name(&self) -> &'static str {
        "hpxMP"
    }

    fn max_concurrency(&self) -> usize {
        self.rt.sched.workers()
    }

    fn bulk_sync(
        &self,
        threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        // `F = &dyn Fn`: the engine monomorphizes over the (thin)
        // reference, one indirect call per chunk.
        self.bulk_sync_mono(threads, range, sched, &body);
    }

    fn scheduler(&self) -> Option<&Arc<Scheduler>> {
        Some(&self.rt.sched)
    }

    /// Saturated when the admission budget has reserved every worker
    /// slot: a new top-level region would wait for a slot (DESIGN.md §8),
    /// so deadline-bound callers should shed or back off instead.
    fn overloaded(&self) -> bool {
        self.rt.reserved_workers() >= self.rt.sched.workers()
    }

    /// Task-mode bulk dispatch: `tasks` static chunks as raw dataflow
    /// tasks (no OpenMP team, so the body must not use team constructs —
    /// barriers, worksharing, `omp_get_thread_num`), joined by a future
    /// fulfilled when every chunk retired.
    ///
    /// Placement: an explicit `Hint::Worker(w)` pins the batch's chunks
    /// to workers `w, w+1, ...`; `Hint::Any` claims a rotating base from
    /// [`Scheduler::hint_base`] so concurrent task-mode clients
    /// interleave across worker queues instead of all pinning onto
    /// workers `0..tasks` (the multi-tenant fairness path, DESIGN.md §8).
    fn bulk_async(
        &self,
        tasks: usize,
        hint: Hint,
        range: Range<i64>,
        body: Arc<dyn Fn(Range<i64>) + Send + Sync>,
    ) -> Future<()> {
        let n = range.end - range.start;
        if n <= 0 {
            return Future::ready(());
        }
        let tasks = tasks.clamp(1, n as usize) as i64;
        let per = n / tasks + i64::from(n % tasks != 0);
        let chunks: Vec<Range<i64>> = (0..tasks)
            .map(|t| {
                let lo = (range.start + t * per).min(range.end);
                let hi = (lo + per).min(range.end);
                lo..hi
            })
            .filter(|r| r.start < r.end)
            .collect();

        let promise = Arc::new(Mutex::new(Some(Promise::new())));
        let joined = promise.lock().unwrap().as_ref().unwrap().get_future();
        let remaining = Arc::new(AtomicUsize::new(chunks.len()));
        let panicked = Arc::new(AtomicBool::new(false));

        /// Chunk arrival as a drop guard: a panicking body must still
        /// count down and (as last arriver) fulfil the joined promise —
        /// otherwise one crashed chunk would hang every waiter forever
        /// (the panic itself stays isolated in the worker layer).  A
        /// crashed chunk is *recorded* (`std::thread::panicking()` at
        /// drop), so the join resolves with a `Panicked` outcome instead
        /// of silently claiming success — `wait()` still returns, and
        /// error-aware callers ([`exec::for_each`]) map it to
        /// [`ExecResult::Failed`].
        struct Arrive {
            remaining: Arc<AtomicUsize>,
            panicked: Arc<AtomicBool>,
            promise: Arc<Mutex<Option<Promise<()>>>>,
        }
        impl Drop for Arrive {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.panicked.store(true, Ordering::Release);
                }
                if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    if let Some(p) = lock_unpoisoned(&self.promise).take() {
                        if self.panicked.load(Ordering::Acquire) {
                            p.set_panicked();
                        } else {
                            p.set_value(());
                        }
                    }
                }
            }
        }

        let base = match hint {
            Hint::Worker(w) => w,
            Hint::Any => self.rt.sched.hint_base(chunks.len()),
        };
        // Payload::new places each small chunk closure in a recycled
        // per-worker arena block (ISSUE 7) instead of a fresh Box —
        // malloc stays off the bulk-spawn fast path.
        let bodies: Vec<(Hint, Payload)> = chunks
            .into_iter()
            .enumerate()
            .map(|(t, r)| {
                let body = body.clone();
                let arrive = Arrive {
                    remaining: remaining.clone(),
                    panicked: panicked.clone(),
                    promise: promise.clone(),
                };
                let chunk = Payload::new(move || {
                    let _arrive = arrive;
                    body(r);
                });
                (Hint::Worker(base + t), chunk)
            })
            .collect();
        self.rt
            .sched
            .spawn_batch_payloads(Priority::Normal, "par_async_chunk", None, bodies);
        joined
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn check_covers(rt: &dyn Executor, threads: usize, n: i64, sched: LoopSched) {
        let seen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        rt.bulk_sync(threads, 0..n, sched, &|r| {
            for i in r {
                seen[i as usize].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(
            seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
            "{} missed/duplicated iterations (threads={threads}, n={n}, {sched:?})",
            rt.name()
        );
    }

    #[test]
    fn hpxmp_bulk_sync_covers_all_schedules() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for threads in [1, 2, 4] {
            for sched in [
                LoopSched::Static { chunk: None },
                LoopSched::Static { chunk: Some(7) },
                LoopSched::Dynamic { chunk: 16 },
                LoopSched::Guided { chunk: 8 },
            ] {
                check_covers(&rt, threads, 1000, sched);
            }
        }
    }

    #[test]
    fn serial_executor_runs_whole_range_once() {
        check_covers(&Serial, 1, 100, LoopSched::default());
    }

    #[test]
    fn parallel_for_async_covers_range_once() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for (tasks, n) in [(1usize, 100i64), (4, 1000), (16, 37), (8, 0)] {
            let seen: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
            let s = seen.clone();
            let fut = rt.parallel_for_async(
                tasks,
                0..n,
                Arc::new(move |r: std::ops::Range<i64>| {
                    for i in r {
                        s[i as usize].fetch_add(1, Ordering::SeqCst);
                    }
                }),
            );
            fut.wait();
            assert!(
                seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
                "async chunks missed/duplicated iterations (tasks={tasks}, n={n})"
            );
        }
    }

    #[test]
    fn parallel_for_async_panicking_chunk_still_fulfils_join() {
        // One crashed chunk must not hang the joined future: arrival runs
        // via a drop guard, the panic stays isolated in the worker layer.
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        let ran = Arc::new(AtomicU32::new(0));
        let r2 = ran.clone();
        let fut = rt.parallel_for_async(
            4,
            0..4,
            Arc::new(move |r: std::ops::Range<i64>| {
                if r.start == 0 {
                    panic!("chunk body panics");
                }
                r2.fetch_add(1, Ordering::SeqCst);
            }),
        );
        fut.wait();
        assert_eq!(ran.load(Ordering::SeqCst), 3, "surviving chunks ran");
        assert_eq!(rt.rt.sched.task_panics(), 1, "panic not isolated");
        // The join resolves — with an honest Panicked outcome, not a
        // silent success (ISSUE 6).
        assert!(matches!(
            fut.wait_outcome(),
            crate::amt::future::Outcome::Panicked
        ));
    }

    #[test]
    fn async_regions_compose_without_intermediate_joins() {
        // Phase 2 hangs off phase 1's future via `then` — the caller only
        // blocks once, at the very end.
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let n = 512i64;
        let data: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let d1 = data.clone();
        let phase1 = rt.parallel_for_async(
            4,
            0..n,
            Arc::new(move |r: std::ops::Range<i64>| {
                for i in r {
                    d1[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            }),
        );
        let sched = rt.rt.sched.clone();
        let d2 = data.clone();
        let rt2 = HpxMpRuntime::new(rt.rt.clone());
        let phase2 = phase1.then(&sched, move |_| {
            let inner = rt2.parallel_for_async(
                4,
                0..n,
                Arc::new(move |r: std::ops::Range<i64>| {
                    for i in r {
                        d2[i as usize].fetch_add(10, Ordering::SeqCst);
                    }
                }),
            );
            inner.wait();
        });
        phase2.wait();
        assert!(data.iter().all(|c| c.load(Ordering::SeqCst) == 11));
    }

    #[test]
    fn monomorphized_parallel_for_covers_all_schedules() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        for sched in [
            LoopSched::Static { chunk: Some(3) },
            LoopSched::Dynamic { chunk: 8 },
            LoopSched::Guided { chunk: 4 },
        ] {
            let seen: Vec<AtomicU32> = (0..500).map(|_| AtomicU32::new(0)).collect();
            let body = |r: Range<i64>| {
                for i in r {
                    seen[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            };
            rt.parallel_for_mono(2, 0..500, sched, &body);
            assert!(
                seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
                "mono path missed/duplicated iterations ({sched:?})"
            );
        }
    }

    #[test]
    fn explicit_hint_pins_async_batch_base() {
        // `.hint(Worker(w))` must reach the scheduler: chunks land on
        // workers w, w+1, ... — observable as coverage with any base.
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        let seen: Arc<Vec<AtomicU32>> = Arc::new((0..64).map(|_| AtomicU32::new(0)).collect());
        let s = seen.clone();
        for_each_async(
            &task().on(&rt).threads(4).hint(Hint::Worker(1)),
            0..64,
            Arc::new(move |r: std::ops::Range<i64>| {
                for i in r {
                    s[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            }),
        )
        .wait();
        assert!(seen.iter().all(|c| c.load(Ordering::SeqCst) == 1));
    }
}
