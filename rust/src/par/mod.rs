//! The `ParallelRuntime` abstraction: what Blaze-lite parallelizes over.
//!
//! The paper's experiment is "same application (Blaze), two OpenMP
//! runtimes (hpxMP vs. the compiler-supplied one)".  This trait is the
//! seam that makes that swap possible here: [`crate::omp`] (hpxMP) and
//! [`crate::baseline`] (libomp-style) both implement it, and every
//! benchmark/example takes `&dyn ParallelRuntime`.

use std::ops::Range;
use std::sync::Arc;

use crate::omp::icv::Schedule;
use crate::omp::{fork_call, OmpRuntime};

/// Loop scheduling requested by the application (maps to
/// `#pragma omp for schedule(...)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopSched {
    /// `schedule(static[,chunk])`
    Static { chunk: Option<usize> },
    /// `schedule(dynamic,chunk)`
    Dynamic { chunk: usize },
    /// `schedule(guided,chunk)`
    Guided { chunk: usize },
}

impl Default for LoopSched {
    fn default() -> Self {
        LoopSched::Static { chunk: None }
    }
}

/// A fork-join parallel runtime executing chunked loops.
///
/// `parallel_for` runs `body(sub_range)` over a partition of `range` using
/// `num_threads` OpenMP threads; it must not return before every
/// iteration completed (implicit end-of-region barrier).
pub trait ParallelRuntime: Send + Sync {
    fn name(&self) -> &'static str;

    /// Largest usable team size.
    fn max_threads(&self) -> usize;

    /// Fork a team of `num_threads`, partition `range` per `sched`, and
    /// run `body` on each claimed sub-range.
    fn parallel_for(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    );
}

/// hpxMP as a `ParallelRuntime` — the paper's system under test.
pub struct HpxMpRuntime {
    pub rt: Arc<OmpRuntime>,
}

impl HpxMpRuntime {
    pub fn new(rt: Arc<OmpRuntime>) -> Self {
        Self { rt }
    }

    /// Monomorphized `parallel_for`: the per-chunk inner loop is compiled
    /// against the concrete `F`, so chunk dispatch is a static call (and
    /// inlinable) instead of a `dyn Fn` indirect call per chunk.  The
    /// trait object path ([`ParallelRuntime::parallel_for`]) delegates
    /// here with `F = &dyn Fn` — identical behavior, one indirection —
    /// while concrete callers (kernels, the fork-overhead ablation) get
    /// the fully static loop.
    pub fn parallel_for_mono<F>(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &F,
    ) where
        F: Fn(Range<i64>) + Sync,
    {
        // fork_call requires 'static, but it joins before returning, so
        // re-borrowing `body` for the region is sound: smuggle the thin
        // pointer as an address and re-materialize inside the region.
        let body_addr = body as *const F as usize;
        fork_call(&self.rt, Some(num_threads), move |ctx| {
            // SAFETY: fork_call blocks until the region joins, so `body`
            // outlives every use here; `F: Sync` makes the shared
            // re-borrow across team members sound.
            let body: &F = unsafe { &*(body_addr as *const F) };
            match sched {
                LoopSched::Static { chunk } => {
                    ctx.for_static_chunks(range.clone(), chunk, |r| body(r));
                }
                LoopSched::Dynamic { chunk } => {
                    let desc = ctx.dispatch_init(
                        range.clone(),
                        Schedule::new(crate::omp::SchedKind::Dynamic, Some(chunk)),
                    );
                    while let Some(r) = ctx.dispatch_next(&desc, range.start) {
                        body(r);
                    }
                    ctx.dispatch_fini(&desc);
                }
                LoopSched::Guided { chunk } => {
                    let desc = ctx.dispatch_init(
                        range.clone(),
                        Schedule::new(crate::omp::SchedKind::Guided, Some(chunk)),
                    );
                    while let Some(r) = ctx.dispatch_next(&desc, range.start) {
                        body(r);
                    }
                    ctx.dispatch_fini(&desc);
                }
            }
            // implicit region-end barrier joins the loop
        });
    }
}

impl ParallelRuntime for HpxMpRuntime {
    fn name(&self) -> &'static str {
        "hpxMP"
    }

    fn max_threads(&self) -> usize {
        self.rt.sched.workers()
    }

    fn parallel_for(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        self.parallel_for_mono(num_threads, range, sched, &body)
    }
}

/// Serial execution (below Blaze's parallelization thresholds both
/// runtimes fall back to this).
pub struct SerialRuntime;

impl ParallelRuntime for SerialRuntime {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn max_threads(&self) -> usize {
        1
    }

    fn parallel_for(
        &self,
        _num_threads: usize,
        range: Range<i64>,
        _sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        body(range);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn check_covers(rt: &dyn ParallelRuntime, threads: usize, n: i64, sched: LoopSched) {
        let seen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        rt.parallel_for(threads, 0..n, sched, &|r| {
            for i in r {
                seen[i as usize].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(
            seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
            "{} missed/duplicated iterations (threads={threads}, n={n}, {sched:?})",
            rt.name()
        );
    }

    #[test]
    fn hpxmp_parallel_for_covers_all_schedules() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for threads in [1, 2, 4] {
            for sched in [
                LoopSched::Static { chunk: None },
                LoopSched::Static { chunk: Some(7) },
                LoopSched::Dynamic { chunk: 16 },
                LoopSched::Guided { chunk: 8 },
            ] {
                check_covers(&rt, threads, 1000, sched);
            }
        }
    }

    #[test]
    fn serial_runtime_runs_whole_range_once() {
        check_covers(&SerialRuntime, 1, 100, LoopSched::default());
    }

    #[test]
    fn monomorphized_parallel_for_covers_all_schedules() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        for sched in [
            LoopSched::Static { chunk: Some(3) },
            LoopSched::Dynamic { chunk: 8 },
            LoopSched::Guided { chunk: 4 },
        ] {
            let seen: Vec<AtomicU32> = (0..500).map(|_| AtomicU32::new(0)).collect();
            let body = |r: Range<i64>| {
                for i in r {
                    seen[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            };
            rt.parallel_for_mono(2, 0..500, sched, &body);
            assert!(
                seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
                "mono path missed/duplicated iterations ({sched:?})"
            );
        }
    }
}
