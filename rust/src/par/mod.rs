//! The `ParallelRuntime` abstraction: what Blaze-lite parallelizes over.
//!
//! The paper's experiment is "same application (Blaze), two OpenMP
//! runtimes (hpxMP vs. the compiler-supplied one)".  This trait is the
//! seam that makes that swap possible here: [`crate::omp`] (hpxMP) and
//! [`crate::baseline`] (libomp-style) both implement it, and every
//! benchmark/example takes `&dyn ParallelRuntime`.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::amt::future::{Future, Promise};
use crate::amt::task::Hint;
use crate::amt::Priority;
use crate::omp::icv::Schedule;
use crate::omp::{fork_call, OmpRuntime};

/// Loop scheduling requested by the application (maps to
/// `#pragma omp for schedule(...)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopSched {
    /// `schedule(static[,chunk])`
    Static { chunk: Option<usize> },
    /// `schedule(dynamic,chunk)`
    Dynamic { chunk: usize },
    /// `schedule(guided,chunk)`
    Guided { chunk: usize },
}

impl Default for LoopSched {
    fn default() -> Self {
        LoopSched::Static { chunk: None }
    }
}

/// A fork-join parallel runtime executing chunked loops.
///
/// `parallel_for` runs `body(sub_range)` over a partition of `range` using
/// `num_threads` OpenMP threads; it must not return before every
/// iteration completed (implicit end-of-region barrier).
pub trait ParallelRuntime: Send + Sync {
    fn name(&self) -> &'static str;

    /// Largest usable team size.
    fn max_threads(&self) -> usize;

    /// Fork a team of `num_threads`, partition `range` per `sched`, and
    /// run `body` on each claimed sub-range.
    fn parallel_for(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    );
}

/// hpxMP as a `ParallelRuntime` — the paper's system under test.
pub struct HpxMpRuntime {
    pub rt: Arc<OmpRuntime>,
}

impl HpxMpRuntime {
    pub fn new(rt: Arc<OmpRuntime>) -> Self {
        Self { rt }
    }

    /// Monomorphized `parallel_for`: the per-chunk inner loop is compiled
    /// against the concrete `F`, so chunk dispatch is a static call (and
    /// inlinable) instead of a `dyn Fn` indirect call per chunk.  The
    /// trait object path ([`ParallelRuntime::parallel_for`]) delegates
    /// here with `F = &dyn Fn` — identical behavior, one indirection —
    /// while concrete callers (kernels, the fork-overhead ablation) get
    /// the fully static loop.
    pub fn parallel_for_mono<F>(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &F,
    ) where
        F: Fn(Range<i64>) + Sync,
    {
        // fork_call requires 'static, but it joins before returning, so
        // re-borrowing `body` for the region is sound: smuggle the thin
        // pointer as an address and re-materialize inside the region.
        let body_addr = body as *const F as usize;
        fork_call(&self.rt, Some(num_threads), move |ctx| {
            // SAFETY: fork_call blocks until the region joins, so `body`
            // outlives every use here; `F: Sync` makes the shared
            // re-borrow across team members sound.
            let body: &F = unsafe { &*(body_addr as *const F) };
            match sched {
                LoopSched::Static { chunk } => {
                    ctx.for_static_chunks(range.clone(), chunk, |r| body(r));
                }
                LoopSched::Dynamic { chunk } => {
                    let desc = ctx.dispatch_init(
                        range.clone(),
                        Schedule::new(crate::omp::SchedKind::Dynamic, Some(chunk)),
                    );
                    while let Some(r) = ctx.dispatch_next(&desc, range.start) {
                        body(r);
                    }
                    ctx.dispatch_fini(&desc);
                }
                LoopSched::Guided { chunk } => {
                    let desc = ctx.dispatch_init(
                        range.clone(),
                        Schedule::new(crate::omp::SchedKind::Guided, Some(chunk)),
                    );
                    while let Some(r) = ctx.dispatch_next(&desc, range.start) {
                        body(r);
                    }
                    ctx.dispatch_fini(&desc);
                }
            }
            // implicit region-end barrier joins the loop
        });
    }

    /// The async seam (ISSUE 2): run `body` over a static partition of
    /// `range` as plain AMT tasks and return a [`Future<()>`] fulfilled
    /// when every chunk has retired — **no blocking join**, so regions
    /// compose into dataflow graphs (`then`/`when_all`) without
    /// intermediate barriers.
    ///
    /// Unlike [`ParallelRuntime::parallel_for`] this path forks no OpenMP
    /// team: chunks are raw dataflow tasks with no implicit-task context,
    /// so the body must not use team constructs (barriers, worksharing,
    /// `omp_get_thread_num`).  `body` is shared (`Arc`) because nothing
    /// blocks for it — it must outlive the caller's stack frame.
    pub fn parallel_for_async(
        &self,
        num_tasks: usize,
        range: Range<i64>,
        body: Arc<dyn Fn(Range<i64>) + Send + Sync>,
    ) -> Future<()> {
        let n = range.end - range.start;
        if n <= 0 {
            return Future::ready(());
        }
        let tasks = num_tasks.clamp(1, n as usize) as i64;
        let per = n / tasks + i64::from(n % tasks != 0);
        let chunks: Vec<Range<i64>> = (0..tasks)
            .map(|t| {
                let lo = (range.start + t * per).min(range.end);
                let hi = (lo + per).min(range.end);
                lo..hi
            })
            .filter(|r| r.start < r.end)
            .collect();

        let promise = Arc::new(Mutex::new(Some(Promise::new())));
        let joined = promise.lock().unwrap().as_ref().unwrap().get_future();
        let remaining = Arc::new(AtomicUsize::new(chunks.len()));

        /// Chunk arrival as a drop guard: a panicking body must still
        /// count down and (as last arriver) fulfil the joined promise —
        /// otherwise one crashed chunk would hang every waiter forever
        /// (the panic itself stays isolated in the worker layer).
        struct Arrive {
            remaining: Arc<AtomicUsize>,
            promise: Arc<Mutex<Option<Promise<()>>>>,
        }
        impl Drop for Arrive {
            fn drop(&mut self) {
                if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    if let Some(p) = self.promise.lock().unwrap().take() {
                        p.set_value(());
                    }
                }
            }
        }

        let bodies: Vec<(Hint, Box<dyn FnOnce() + Send>)> = chunks
            .into_iter()
            .enumerate()
            .map(|(t, r)| {
                let body = body.clone();
                let arrive = Arrive {
                    remaining: remaining.clone(),
                    promise: promise.clone(),
                };
                let chunk: Box<dyn FnOnce() + Send> = Box::new(move || {
                    let _arrive = arrive;
                    body(r);
                });
                (Hint::Worker(t), chunk)
            })
            .collect();
        self.rt
            .sched
            .spawn_batch(Priority::Normal, "par_async_chunk", bodies);
        joined
    }
}

impl ParallelRuntime for HpxMpRuntime {
    fn name(&self) -> &'static str {
        "hpxMP"
    }

    fn max_threads(&self) -> usize {
        self.rt.sched.workers()
    }

    fn parallel_for(
        &self,
        num_threads: usize,
        range: Range<i64>,
        sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        self.parallel_for_mono(num_threads, range, sched, &body)
    }
}

/// Serial execution (below Blaze's parallelization thresholds both
/// runtimes fall back to this).
pub struct SerialRuntime;

impl ParallelRuntime for SerialRuntime {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn max_threads(&self) -> usize {
        1
    }

    fn parallel_for(
        &self,
        _num_threads: usize,
        range: Range<i64>,
        _sched: LoopSched,
        body: &(dyn Fn(Range<i64>) + Sync),
    ) {
        body(range);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn check_covers(rt: &dyn ParallelRuntime, threads: usize, n: i64, sched: LoopSched) {
        let seen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        rt.parallel_for(threads, 0..n, sched, &|r| {
            for i in r {
                seen[i as usize].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(
            seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
            "{} missed/duplicated iterations (threads={threads}, n={n}, {sched:?})",
            rt.name()
        );
    }

    #[test]
    fn hpxmp_parallel_for_covers_all_schedules() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for threads in [1, 2, 4] {
            for sched in [
                LoopSched::Static { chunk: None },
                LoopSched::Static { chunk: Some(7) },
                LoopSched::Dynamic { chunk: 16 },
                LoopSched::Guided { chunk: 8 },
            ] {
                check_covers(&rt, threads, 1000, sched);
            }
        }
    }

    #[test]
    fn serial_runtime_runs_whole_range_once() {
        check_covers(&SerialRuntime, 1, 100, LoopSched::default());
    }

    #[test]
    fn parallel_for_async_covers_range_once() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        for (tasks, n) in [(1usize, 100i64), (4, 1000), (16, 37), (8, 0)] {
            let seen: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
            let s = seen.clone();
            let fut = rt.parallel_for_async(
                tasks,
                0..n,
                Arc::new(move |r: std::ops::Range<i64>| {
                    for i in r {
                        s[i as usize].fetch_add(1, Ordering::SeqCst);
                    }
                }),
            );
            fut.wait();
            assert!(
                seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
                "async chunks missed/duplicated iterations (tasks={tasks}, n={n})"
            );
        }
    }

    #[test]
    fn parallel_for_async_panicking_chunk_still_fulfils_join() {
        // One crashed chunk must not hang the joined future: arrival runs
        // via a drop guard, the panic stays isolated in the worker layer.
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        let ran = Arc::new(AtomicU32::new(0));
        let r2 = ran.clone();
        let fut = rt.parallel_for_async(
            4,
            0..4,
            Arc::new(move |r: std::ops::Range<i64>| {
                if r.start == 0 {
                    panic!("chunk body panics");
                }
                r2.fetch_add(1, Ordering::SeqCst);
            }),
        );
        fut.wait();
        assert_eq!(ran.load(Ordering::SeqCst), 3, "surviving chunks ran");
        assert_eq!(rt.rt.sched.task_panics(), 1, "panic not isolated");
    }

    #[test]
    fn async_regions_compose_without_intermediate_joins() {
        // Phase 2 hangs off phase 1's future via `then` — the caller only
        // blocks once, at the very end.
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let n = 512i64;
        let data: Arc<Vec<AtomicU32>> = Arc::new((0..n).map(|_| AtomicU32::new(0)).collect());
        let d1 = data.clone();
        let phase1 = rt.parallel_for_async(
            4,
            0..n,
            Arc::new(move |r: std::ops::Range<i64>| {
                for i in r {
                    d1[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            }),
        );
        let sched = rt.rt.sched.clone();
        let d2 = data.clone();
        let rt2 = HpxMpRuntime::new(rt.rt.clone());
        let phase2 = phase1.then(&sched, move |_| {
            let inner = rt2.parallel_for_async(
                4,
                0..n,
                Arc::new(move |r: std::ops::Range<i64>| {
                    for i in r {
                        d2[i as usize].fetch_add(10, Ordering::SeqCst);
                    }
                }),
            );
            inner.wait();
        });
        phase2.wait();
        assert!(data.iter().all(|c| c.load(Ordering::SeqCst) == 11));
    }

    #[test]
    fn monomorphized_parallel_for_covers_all_schedules() {
        let rt = HpxMpRuntime::new(OmpRuntime::for_tests(2));
        for sched in [
            LoopSched::Static { chunk: Some(3) },
            LoopSched::Dynamic { chunk: 8 },
            LoopSched::Guided { chunk: 4 },
        ] {
            let seen: Vec<AtomicU32> = (0..500).map(|_| AtomicU32::new(0)).collect();
            let body = |r: Range<i64>| {
                for i in r {
                    seen[i as usize].fetch_add(1, Ordering::SeqCst);
                }
            };
            rt.parallel_for_mono(2, 0..500, sched, &body);
            assert!(
                seen.iter().all(|c| c.load(Ordering::SeqCst) == 1),
                "mono path missed/duplicated iterations ({sched:?})"
            );
        }
    }
}
