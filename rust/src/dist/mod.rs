//! Distributed hpxMP (ISSUE 10): multi-process sharding with remote
//! futures over the wire layer.
//!
//! The paper's runtime futurizes work *within* one process; this module
//! extends the same futurized engine across process boundaries.  Three
//! layers (DESIGN.md §15):
//!
//! * [`proto`] — dist message frames riding the PR 9 wire layout
//!   (submit / broadcast / band / completion / stats / shutdown), plus
//!   [`DistLink`], the liveness-tracked write half both sides share.
//! * [`worker`] — the `hpxmp worker` process: an AMT runtime fed by a
//!   coordinator link, replying through the same [`Coalescer`] stack as
//!   the in-process server.
//! * [`shard`] — the coordinator: a supervised worker-process pool
//!   ([`ShardPool`]), the request [`Router`] behind
//!   `hpxmp serve --shards`, and the scatter/gather distributed
//!   [`dist_matmul`].
//!
//! The glue is the **remote future**: every task shipped to a worker is
//! an entry in a [`RemoteRegistry`](crate::amt::RemoteRegistry), and the
//! waiter's `Future<Response>` resolves through the ordinary
//! [`Outcome`](crate::amt::Outcome) channel — `Value` from a completion
//! frame, `Panicked` when the producer process died, `Cancelled` on
//! shutdown.  A dead worker can never hang a waiter.
//!
//! [`Coalescer`]: crate::net::batch::Coalescer

pub mod proto;
pub mod shard;
pub mod worker;

pub use proto::{DistLink, DistMsg, DIST_MMULT_MAX_N};
pub use shard::{dist_matmul, Router, ShardCfg, ShardPool};
pub use worker::{run_worker, WorkerCfg};

use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-global dist counters (coordinator side), mirroring the
/// arena/metrics pattern: cheap relaxed atomics bumped on the hot paths,
/// snapshotted by [`stats`] for `hpxmp info` and the serve status line.
pub(crate) struct Counters {
    pub routed: AtomicUsize,
    pub bands: AtomicUsize,
    pub fulfilled: AtomicUsize,
    pub failed: AtomicUsize,
    pub cancelled: AtomicUsize,
    pub reroutes: AtomicUsize,
    pub reconnects: AtomicUsize,
}

pub(crate) static COUNTERS: Counters = Counters {
    routed: AtomicUsize::new(0),
    bands: AtomicUsize::new(0),
    fulfilled: AtomicUsize::new(0),
    failed: AtomicUsize::new(0),
    cancelled: AtomicUsize::new(0),
    reroutes: AtomicUsize::new(0),
    reconnects: AtomicUsize::new(0),
};

/// Snapshot of the coordinator-side dist counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct DistStats {
    /// Serving tasks forwarded to workers (all shards).
    pub routed: usize,
    /// Matmul row bands scattered.
    pub bands: usize,
    /// Remote futures resolved by a completion frame.
    pub fulfilled: usize,
    /// Remote futures failed because their worker died.
    pub failed: usize,
    /// Remote futures cancelled by pool shutdown.
    pub cancelled: usize,
    /// Forwards that probed past a dead home shard.
    pub reroutes: usize,
    /// Worker processes respawned after a death.
    pub reconnects: usize,
}

/// Read the process-global dist counters (coordinator side).
pub fn stats() -> DistStats {
    DistStats {
        routed: COUNTERS.routed.load(Ordering::Relaxed),
        bands: COUNTERS.bands.load(Ordering::Relaxed),
        fulfilled: COUNTERS.fulfilled.load(Ordering::Relaxed),
        failed: COUNTERS.failed.load(Ordering::Relaxed),
        cancelled: COUNTERS.cancelled.load(Ordering::Relaxed),
        reroutes: COUNTERS.reroutes.load(Ordering::Relaxed),
        reconnects: COUNTERS.reconnects.load(Ordering::Relaxed),
    }
}
