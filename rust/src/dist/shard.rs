//! Coordinator side of dist (ISSUE 10): the worker-process pool, the
//! shard router, and the distributed matrix product.
//!
//! The coordinator owns a fleet of `hpxmp worker` child processes.  Each
//! worker dials back over TCP, says [`DistMsg::Hello`], and from then on
//! is addressed through a [`WorkerLink`] whose **tag** packs its shard
//! slot and a monotonically increasing link generation.  Every task
//! shipped to a worker is first registered in a
//! [`RemoteRegistry`]`<Response>` under that tag, so the failure story
//! is uniform (DESIGN.md §15):
//!
//! * completion frame arrives → `fulfil(id, Value)` resolves the future;
//! * the worker process dies → the reader thread's `fail_tag` resolves
//!   exactly its in-flight futures `Panicked` (a respawned worker gets a
//!   fresh generation, so its tag never collides with the corpse's);
//! * pool shutdown → `cancel_all` resolves the remainder `Cancelled`.
//!
//! A waiter therefore always gets *some* outcome — a dead worker can
//! never hang a remote future, and the registry's `pending()` gauge
//! returning to 0 is the coordinator-side leak check `tests/dist.rs`
//! asserts.
//!
//! [`Router`] implements the wire server's
//! [`RequestHandler`] so `hpxmp serve --shards N` reuses the whole PR 9
//! connection layer unchanged: decoded client requests are forwarded by
//! request key (`req_id >> 32`, i.e. the loadgen connection index) with
//! linear probing past dead shards, and each reply is written by the
//! remote future's completion hook.

use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::amt::{when_all, Future, Outcome, RemoteRegistry};
use crate::blaze::kernel::PACKED_ROW_BAND;
use crate::net::batch::{ReplySink, WireStats};
use crate::net::frame::{self, FrameBuf, Request, Response, Status};
use crate::net::server::{RequestHandler, WireStream};

use super::proto::{self, DistLink, DistMsg, DIST_MMULT_MAX_N};
use super::COUNTERS;

/// Configuration for a worker-process pool.
#[derive(Clone, Debug)]
pub struct ShardCfg {
    /// Worker processes (shard slots).
    pub shards: usize,
    /// AMT worker threads per process.
    pub threads_per: usize,
    /// Executable to spawn as `<program> worker --connect ...` — the
    /// `hpxmp` binary itself (tests pass `CARGO_BIN_EXE_hpxmp`).
    pub program: PathBuf,
    /// Respawn a worker whose process died (tests disable this to pin
    /// down the no-survivor path).
    pub respawn: bool,
    /// `--stall-us` forwarded to workers (tests use it to hold tasks in
    /// flight across a kill; 0 = none).
    pub stall_us: u64,
}

impl ShardCfg {
    /// Pool config spawning the current executable, with respawn on and
    /// no stall.
    pub fn new(shards: usize, threads_per: usize) -> std::io::Result<Self> {
        Ok(Self {
            shards,
            threads_per,
            program: std::env::current_exe()?,
            respawn: true,
            stall_us: 0,
        })
    }
}

/// One live coordinator→worker connection.  `tag` feeds the remote
/// registry: slot in the high half, link generation in the low half, so
/// a dead link's futures are failed without touching its replacement's.
struct WorkerLink {
    slot: usize,
    gen: u64,
    tx: Arc<DistLink>,
}

impl WorkerLink {
    fn tag(&self) -> u64 {
        ((self.slot as u64) << 32) | (self.gen & 0xFFFF_FFFF)
    }
}

/// Shared pool state: links, children, the remote-future registry.
struct PoolState {
    cfg: ShardCfg,
    /// Dial-back address handed to children (`tcp:127.0.0.1:port`).
    connect_addr: String,
    links: Mutex<Vec<Option<Arc<WorkerLink>>>>,
    children: Mutex<Vec<Option<Child>>>,
    gen: AtomicU64,
    registry: RemoteRegistry<Response>,
    shutdown: AtomicBool,
    reader_handles: Mutex<Vec<JoinHandle<()>>>,
    /// Tasks forwarded per shard slot (the `serve --shards` status line).
    routed: Vec<AtomicUsize>,
    /// One distributed mmult at a time: bands of concurrent products
    /// would interleave `BroadcastB` frames and corrupt the cached B.
    mmult_gate: Mutex<()>,
}

/// A running pool of worker processes; dropping it shuts the fleet down
/// (shutdown frames, then reaping) and resolves every in-flight remote
/// future.
pub struct ShardPool {
    state: Arc<PoolState>,
    accept: Option<JoinHandle<()>>,
}

impl ShardPool {
    /// Bind the dial-back listener, spawn `cfg.shards` worker processes,
    /// and start the accept/reader threads.  Workers connect
    /// asynchronously — gate on [`ShardPool::wait_ready`] before
    /// demanding full capacity.
    pub fn start(cfg: ShardCfg) -> std::io::Result<ShardPool> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let port = listener.local_addr()?.port();
        let shards = cfg.shards;
        let state = Arc::new(PoolState {
            connect_addr: format!("tcp:127.0.0.1:{port}"),
            links: Mutex::new((0..shards).map(|_| None).collect()),
            children: Mutex::new((0..shards).map(|_| None).collect()),
            gen: AtomicU64::new(0),
            registry: RemoteRegistry::new(),
            shutdown: AtomicBool::new(false),
            reader_handles: Mutex::new(Vec::new()),
            routed: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            mmult_gate: Mutex::new(()),
            cfg,
        });
        for slot in 0..shards {
            let child = spawn_child(&state, slot)?;
            state.children.lock().expect("children poisoned")[slot] = Some(child);
        }
        let accept = {
            let st = state.clone();
            std::thread::Builder::new()
                .name("hpxmp-dist-accept".into())
                .spawn(move || accept_loop(listener, &st))
                .expect("spawn dist acceptor")
        };
        Ok(ShardPool {
            state,
            accept: Some(accept),
        })
    }

    /// Block until every slot has a live link, up to `timeout`; returns
    /// whether the fleet came up in time.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if self.live() == self.state.cfg.shards {
                return true;
            }
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Slots with a live worker link right now.
    pub fn live(&self) -> usize {
        self.state
            .links
            .lock()
            .expect("links poisoned")
            .iter()
            .flatten()
            .filter(|w| w.tx.alive())
            .count()
    }

    /// Configured shard count.
    pub fn shards(&self) -> usize {
        self.state.cfg.shards
    }

    /// Remote futures registered but not yet resolved — the
    /// coordinator-side leak gauge (0 once drained).
    pub fn pending_remote(&self) -> usize {
        self.state.registry.pending()
    }

    /// Tasks forwarded per shard slot since start.
    pub fn routed_per_shard(&self) -> Vec<usize> {
        self.state
            .routed
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect()
    }

    /// Kill the worker process in `slot` (test hook for the
    /// worker-death paths).  The reader thread notices EOF, fails the
    /// slot's in-flight futures, and — when `cfg.respawn` — starts a
    /// replacement.
    pub fn kill_worker(&self, slot: usize) {
        let child = self.state.children.lock().expect("children poisoned")[slot].take();
        if let Some(mut ch) = child {
            let _ = ch.kill();
            let _ = ch.wait();
        }
    }

    /// Orderly shutdown: flag first (stops respawns and new forwards),
    /// shutdown frames to live workers, cancel every in-flight remote
    /// future, then reap children and join the pool threads.
    /// Idempotent; also runs from `Drop`.
    pub fn shutdown(&mut self) {
        if self.state.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        {
            let links = self.state.links.lock().expect("links poisoned");
            for wl in links.iter().flatten() {
                wl.tx.send(&DistMsg::Shutdown);
            }
        }
        let cancelled = self.state.registry.cancel_all();
        if cancelled > 0 {
            COUNTERS.cancelled.fetch_add(cancelled, Ordering::Relaxed);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Give workers a bounded window to drain and exit on their own
        // before the hard kill.
        let deadline = Instant::now() + Duration::from_secs(1);
        loop {
            let mut all_done = true;
            {
                let mut children = self.state.children.lock().expect("children poisoned");
                for slot in children.iter_mut() {
                    if let Some(ch) = slot {
                        match ch.try_wait() {
                            Ok(Some(_)) => *slot = None,
                            _ => all_done = false,
                        }
                    }
                }
            }
            if all_done || Instant::now() > deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        {
            let mut children = self.state.children.lock().expect("children poisoned");
            for slot in children.iter_mut() {
                if let Some(mut ch) = slot.take() {
                    let _ = ch.kill();
                    let _ = ch.wait();
                }
            }
        }
        let handles: Vec<JoinHandle<()>> = self
            .state
            .reader_handles
            .lock()
            .expect("reader handles poisoned")
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn spawn_child(state: &PoolState, slot: usize) -> std::io::Result<Child> {
    let mut cmd = Command::new(&state.cfg.program);
    cmd.arg("worker")
        .arg("--connect")
        .arg(&state.connect_addr)
        .arg("--threads")
        .arg(state.cfg.threads_per.to_string())
        .arg("--slot")
        .arg(slot.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null());
    if state.cfg.stall_us > 0 {
        cmd.arg("--stall-us").arg(state.cfg.stall_us.to_string());
    }
    cmd.spawn()
}

fn accept_loop(listener: TcpListener, state: &Arc<PoolState>) {
    let fd = listener.as_raw_fd();
    while !state.shutdown.load(Ordering::Acquire) {
        let mut pfd = libc::pollfd {
            fd,
            events: libc::POLLIN,
            revents: 0,
        };
        // SAFETY: polling one valid listener fd with a bounded timeout.
        let rc = unsafe { libc::poll(&mut pfd, 1, 100) };
        if rc <= 0 || pfd.revents & libc::POLLIN == 0 {
            continue;
        }
        match listener.accept() {
            Ok((s, _)) => {
                let _ = s.set_nodelay(true);
                let stream = WireStream::Tcp(s);
                let st = state.clone();
                let h = std::thread::Builder::new()
                    .name("hpxmp-dist-rd".into())
                    .spawn(move || reader_loop(stream, &st))
                    .expect("spawn dist reader");
                state
                    .reader_handles
                    .lock()
                    .expect("reader handles poisoned")
                    .push(h);
            }
            Err(_) => continue,
        }
    }
}

/// Per-connection reader: installs the link on `Hello`, fulfils remote
/// futures on `Complete`, and on EOF/desync runs the worker-death path.
fn reader_loop(mut stream: WireStream, state: &Arc<PoolState>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut fb = FrameBuf::new();
    let mut tmp = vec![0u8; 64 * 1024];
    let mut link: Option<Arc<WorkerLink>> = None;
    'conn: loop {
        if state.shutdown.load(Ordering::Acquire) {
            break;
        }
        loop {
            let msg = match fb.next_body() {
                Ok(Some(body)) => match proto::decode(body) {
                    Ok(m) => m,
                    // Addressable decode error: streams still in sync.
                    Err(e) if e.req_id().is_some() => continue,
                    Err(_) => break 'conn,
                },
                Ok(None) => break,
                Err(_) => break 'conn,
            };
            match msg {
                DistMsg::Hello { slot, .. } => {
                    if link.is_some() {
                        continue; // duplicate hello: ignore
                    }
                    let slot = slot as usize;
                    if slot >= state.cfg.shards {
                        break 'conn;
                    }
                    let write_half = match stream.try_clone() {
                        Ok(w) => w,
                        Err(_) => break 'conn,
                    };
                    let _ = write_half.set_write_timeout(Some(Duration::from_secs(5)));
                    let gen = state.gen.fetch_add(1, Ordering::AcqRel) + 1;
                    let wl = Arc::new(WorkerLink {
                        slot,
                        gen,
                        tx: Arc::new(DistLink::new(write_half)),
                    });
                    state.links.lock().expect("links poisoned")[slot] = Some(wl.clone());
                    link = Some(wl);
                }
                DistMsg::Complete {
                    task_id,
                    status,
                    deadline_missed,
                    n,
                    payload,
                } => {
                    let resolved = state.registry.fulfil(
                        task_id,
                        Outcome::Value(Response {
                            req_id: task_id,
                            status,
                            deadline_missed,
                            n,
                            payload,
                        }),
                    );
                    if resolved {
                        COUNTERS.fulfilled.fetch_add(1, Ordering::Relaxed);
                    }
                }
                // Worker→coordinator stats polling is driven by the
                // status loop when it wants numbers; everything else in
                // this direction is noise.
                _ => {}
            }
        }
        match frame::read_into(&mut stream, &mut fb, &mut tmp) {
            Ok(0) => break,
            Ok(_) => {}
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    if let Some(wl) = link {
        on_worker_death(state, &wl);
    }
}

/// The race-ordered death path: kill the link *first* (so no new send
/// succeeds), unlink the slot only if this link is still current, fail
/// the tag's in-flight futures, then reap and (optionally) respawn.
fn on_worker_death(state: &Arc<PoolState>, wl: &Arc<WorkerLink>) {
    wl.tx.kill();
    let was_current = {
        let mut links = state.links.lock().expect("links poisoned");
        match &links[wl.slot] {
            Some(cur) if cur.gen == wl.gen => {
                links[wl.slot] = None;
                true
            }
            _ => false,
        }
    };
    let failed = state.registry.fail_tag(wl.tag());
    if failed > 0 {
        COUNTERS.failed.fetch_add(failed, Ordering::Relaxed);
    }
    if was_current && !state.shutdown.load(Ordering::Acquire) {
        let child = state.children.lock().expect("children poisoned")[wl.slot].take();
        if let Some(mut ch) = child {
            let _ = ch.kill();
            let _ = ch.wait();
        }
        if state.cfg.respawn {
            if let Ok(ch) = spawn_child(state, wl.slot) {
                state.children.lock().expect("children poisoned")[wl.slot] = Some(ch);
                COUNTERS.reconnects.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl PoolState {
    /// Ship one serving request to the shard owning `key`, probing
    /// linearly past dead slots.  Registration happens *before* the
    /// send, so a worker dying mid-send is covered by `fail_tag`; a send
    /// that fails outright resolves its own entry `Panicked`.  All slots
    /// dead → an already-`Panicked` future (never a hang).
    fn forward(&self, key: u64, req: &Request) -> Future<Response> {
        if self.shutdown.load(Ordering::Acquire) {
            return Future::with_outcome(Outcome::Panicked);
        }
        let shards = self.cfg.shards;
        let home = (key % shards as u64) as usize;
        for attempt in 0..shards {
            let slot = (home + attempt) % shards;
            let Some(wl) = self.links.lock().expect("links poisoned")[slot].clone() else {
                continue;
            };
            if !wl.tx.alive() {
                continue;
            }
            let (id, fut) = self.registry.register(wl.tag());
            let sent = wl.tx.send(&DistMsg::Submit {
                task_id: id,
                op: req.op,
                deadline_us: req.deadline_us,
                n: req.n,
                payload: req.payload.clone(),
            });
            if !sent {
                // Entry is ours to resolve (registered after any
                // fail_tag that raced the death we just observed).
                let _ = self.registry.fulfil(id, Outcome::Panicked);
                continue;
            }
            if !wl.tx.alive() {
                // Link died between send and here: fail_tag may or may
                // not have drained the entry — either way this resolves
                // it (duplicate fulfil is a benign no-op).
                let _ = self.registry.fulfil(id, Outcome::Panicked);
            }
            self.routed[slot].fetch_add(1, Ordering::Relaxed);
            COUNTERS.routed.fetch_add(1, Ordering::Relaxed);
            if attempt > 0 {
                COUNTERS.reroutes.fetch_add(1, Ordering::Relaxed);
            }
            return fut;
        }
        Future::with_outcome(Outcome::Panicked)
    }
}

/// The dist front-end's [`RequestHandler`]: decoded client requests are
/// forwarded to the shard pool and answered from the remote future's
/// completion hook.  Plugging this into
/// [`WireServer::start_with`](crate::net::server::WireServer::start_with)
/// is the whole of `hpxmp serve --shards N`.
pub struct Router {
    pool: Arc<PoolState>,
    stats: Arc<WireStats>,
    max_pending: usize,
}

impl Router {
    /// Build a router over `pool`, accounting into `stats`, shedding
    /// beyond `max_pending` in-flight requests.
    pub fn new(pool: &ShardPool, stats: Arc<WireStats>, max_pending: usize) -> Arc<Router> {
        Arc::new(Router {
            pool: pool.state.clone(),
            stats,
            max_pending,
        })
    }
}

impl RequestHandler for Router {
    fn submit(&self, req: Request, sink: Arc<dyn ReplySink>) {
        if self.stats.pending.load(Ordering::Acquire) >= self.max_pending {
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            sink.send(&Response {
                req_id: req.req_id,
                status: Status::Shed,
                deadline_missed: false,
                n: req.n,
                payload: Vec::new(),
            });
            return;
        }
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.pending.fetch_add(1, Ordering::AcqRel);
        let client_id = req.req_id;
        let n = req.n;
        // Key on the connection half of the id (loadgen packs
        // `conn << 32 | seq`): one client connection's requests stay on
        // one shard, spreading connections across the fleet.
        let key = req.req_id >> 32;
        let fut = self.pool.forward(key, &req);
        let stats = self.stats.clone();
        // `on_ready` fires for every outcome — completion frame,
        // fail_tag, cancel_all, or the promise-drop backstop — so the
        // pending gauge decrement below runs exactly once per admitted
        // request (the dist leak-freedom invariant).
        fut.on_ready(move |out: &Outcome<Response>| {
            let resp = match out {
                Outcome::Value(r) => {
                    match r.status {
                        Status::Ok => {
                            stats.ok.fetch_add(1, Ordering::Relaxed);
                            if r.deadline_missed {
                                stats.deadline_misses.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Status::Shed => {
                            stats.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Status::Expired => {
                            stats.expired.fetch_add(1, Ordering::Relaxed);
                        }
                        Status::Error | Status::BadRequest => {
                            stats.errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Response {
                        req_id: client_id,
                        status: r.status,
                        deadline_missed: r.deadline_missed,
                        n: r.n,
                        payload: r.payload.clone(),
                    }
                }
                Outcome::Cancelled | Outcome::Panicked => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    Response {
                        req_id: client_id,
                        status: Status::Error,
                        deadline_missed: false,
                        n,
                        payload: Vec::new(),
                    }
                }
            };
            sink.send(&resp);
            stats.pending.fetch_sub(1, Ordering::AcqRel);
        });
    }
}

/// Distributed `C = A · B` (row-major n×n): broadcast B to every live
/// worker, scatter A in row bands round-robin, gather C through
/// [`when_all`] over the bands' remote futures.  Bands lost to a worker
/// death are re-scattered to survivors (or respawns) on later rounds.
///
/// Bitwise identical to [`crate::blaze::kernel::packed_matmul`] for any
/// row split: every path packs the *full* B once and accumulates each C
/// element over ascending-k strips, so the per-element operation order
/// is independent of where the rows land.
pub fn dist_matmul(pool: &ShardPool, a: &[f64], b: &[f64], n: usize) -> Result<Vec<f64>, String> {
    if n == 0 || n > DIST_MMULT_MAX_N {
        return Err(format!("dist mmult: n={n} outside 1..={DIST_MMULT_MAX_N}"));
    }
    assert_eq!(a.len(), n * n, "A must be n x n");
    assert_eq!(b.len(), n * n, "B must be n x n");
    let state = &pool.state;
    let _gate = state.mmult_gate.lock().expect("mmult gate poisoned");
    let mut c = vec![0.0f64; n * n];
    // Band size: ~2 bands per shard for load balance, rounded up to the
    // packed row band so splits are cheap (any split is bitwise-safe).
    let chunk = n
        .div_ceil(state.cfg.shards.max(1) * 2)
        .div_ceil(PACKED_ROW_BAND)
        .max(1)
        * PACKED_ROW_BAND;
    let mut todo: Vec<(usize, usize)> = (0..n)
        .step_by(chunk)
        .map(|r0| (r0, (r0 + chunk).min(n)))
        .collect();
    for round in 0..3 {
        if todo.is_empty() {
            break;
        }
        if round > 0 {
            // A lost band means a worker just died; give a respawn a
            // beat to dial back in before re-scattering.
            std::thread::sleep(Duration::from_millis(300));
        }
        let live: Vec<Arc<WorkerLink>> = state
            .links
            .lock()
            .expect("links poisoned")
            .iter()
            .flatten()
            .filter(|w| w.tx.alive())
            .cloned()
            .collect();
        if live.is_empty() {
            continue;
        }
        // (Re-)broadcast B: a respawned worker has no cached operand.
        let live: Vec<Arc<WorkerLink>> = live
            .into_iter()
            .filter(|w| {
                w.tx.send(&DistMsg::BroadcastB {
                    n: n as u32,
                    b: b.to_vec(),
                })
            })
            .collect();
        if live.is_empty() {
            continue;
        }
        let mut futs = Vec::with_capacity(todo.len());
        let mut meta = Vec::with_capacity(todo.len());
        for (i, &(r0, r1)) in todo.iter().enumerate() {
            let wl = &live[i % live.len()];
            let (id, fut) = state.registry.register(wl.tag());
            let sent = wl.tx.send(&DistMsg::SubmitBand {
                task_id: id,
                n: n as u32,
                row0: r0 as u32,
                a_rows: a[r0 * n..r1 * n].to_vec(),
            });
            if !sent || !wl.tx.alive() {
                let _ = state.registry.fulfil(id, Outcome::Panicked);
            }
            COUNTERS.bands.fetch_add(1, Ordering::Relaxed);
            futs.push(fut);
            meta.push((r0, r1));
        }
        when_all(&futs).wait();
        let mut next = Vec::new();
        for (fut, (r0, r1)) in futs.iter().zip(meta) {
            match fut.try_outcome() {
                Some(Outcome::Value(resp))
                    if resp.status == Status::Ok && resp.payload.len() == (r1 - r0) * n =>
                {
                    c[r0 * n..r1 * n].copy_from_slice(&resp.payload);
                }
                _ => next.push((r0, r1)),
            }
        }
        todo = next;
    }
    if !todo.is_empty() {
        return Err(format!(
            "dist mmult: {} row bands unserved after retries",
            todo.len()
        ));
    }
    Ok(c)
}
