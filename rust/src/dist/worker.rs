//! The worker-process side of dist (ISSUE 10): `hpxmp worker --connect`.
//!
//! A worker is one OS process running its own AMT runtime.  It dials
//! the coordinator, announces itself with [`DistMsg::Hello`], and then
//! serves two kinds of work off one blocking read loop:
//!
//! * [`DistMsg::Submit`] — a serving-kernel task.  It goes straight
//!   into the PR 9 [`Coalescer`]/[`Engine`] stack (same batching,
//!   backpressure, and deadline machinery as the in-process server);
//!   the engine's reply sink is the [`DistLink`] back to the
//!   coordinator, so every outcome — Ok, Shed, Expired, Error — leaves
//!   as a [`DistMsg::Complete`] frame with no dist-specific branches in
//!   the engine.
//! * [`DistMsg::BroadcastB`] + [`DistMsg::SubmitBand`] — the
//!   distributed `dmatdmatmult`.  B is packed once per broadcast; each
//!   band is futurized over the local runtime with the same
//!   packed-band kernel the single-process path uses, so the scattered
//!   product is bitwise identical to the serial oracle for *any* row
//!   split (per-element accumulation order depends only on ascending-k
//!   strips, not on where the rows land).
//!
//! EOF or a framing error on the coordinator link is the worker's cue
//! to drain and exit: an orphaned worker never lingers past its
//! coordinator.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::amt::{Outcome, PolicyKind};
use crate::blaze::kernel::{self, pack_a_band, pack_b_band, packed_a_len, packed_b_len, PACKED_ROW_BAND};
use crate::blaze::ops::SendPtr;
use crate::net::batch::{BatchCfg, Coalescer, Engine, ReplySink, WireStats};
use crate::net::frame::{self, FrameBuf, Request, Status};
use crate::net::server::{WireAddr, WireStream};
use crate::omp::OmpRuntime;
use crate::par::{exec, ExecMode, HpxMpRuntime, Policy};

use super::proto::{self, DistLink, DistMsg};

/// Configuration for one worker process (`hpxmp worker`).
#[derive(Clone, Debug)]
pub struct WorkerCfg {
    /// Coordinator address to dial (`--connect`).
    pub connect: WireAddr,
    /// AMT worker threads for the in-process runtime (`--threads`).
    pub threads: usize,
    /// Shard slot this process fills, echoed in `Hello` (`--slot`).
    pub slot: u32,
    /// Artificial delay before handling each submit, µs (`--stall-us`;
    /// tests use it to hold tasks in flight across a kill).
    pub stall_us: u64,
}

/// The per-broadcast cached B operand: packed once, shared by every
/// band task until the next broadcast replaces it.
#[derive(Clone)]
struct Bcast {
    n: u32,
    b_pack: Arc<Vec<f64>>,
}

/// Run one worker process to completion: dial the coordinator, say
/// hello, serve submits until shutdown/EOF, drain, exit.  This is the
/// whole body of the `hpxmp worker` subcommand.
pub fn run_worker(cfg: &WorkerCfg) -> std::io::Result<()> {
    let mut read_half = WireStream::connect(&cfg.connect)?;
    let write_half = read_half.try_clone()?;
    let _ = write_half.set_write_timeout(Some(Duration::from_secs(5)));
    let link = Arc::new(DistLink::new(write_half));
    link.send(&DistMsg::Hello {
        slot: cfg.slot,
        threads: cfg.threads as u32,
    });

    let rt = OmpRuntime::new(cfg.threads, PolicyKind::PriorityLocal);
    rt.icv.set_nthreads(cfg.threads);
    let stats = Arc::new(WireStats::default());
    let bcfg = BatchCfg::default();
    let coal = Coalescer::new(Arc::new(Engine::new(rt.clone(), bcfg, stats.clone())), bcfg);
    let batcher = {
        let c = coal.clone();
        std::thread::Builder::new()
            .name("hpxmp-dist-batch".into())
            .spawn(move || c.run_batcher())
            .expect("spawn dist batcher")
    };
    let exec_rt = HpxMpRuntime::new(rt);

    let bcast: Mutex<Option<Bcast>> = Mutex::new(None);
    let band_inflight = Arc::new(AtomicUsize::new(0));
    let band_done = Arc::new(AtomicU64::new(0));

    let mut fb = FrameBuf::new();
    let mut tmp = vec![0u8; 64 * 1024];
    'link: loop {
        loop {
            let msg = match fb.next_body() {
                Ok(Some(body)) => match proto::decode(body) {
                    Ok(m) => m,
                    // Addressable decode error: the frame was framed but
                    // invalid — the streams are still in sync, skip it.
                    Err(e) if e.req_id().is_some() => continue,
                    // Desync (oversized/truncated): the byte stream is
                    // unrecoverable, same policy as the serving shards.
                    Err(_) => break 'link,
                },
                Ok(None) => break,
                Err(_) => break 'link,
            };
            match msg {
                DistMsg::Submit {
                    task_id,
                    op,
                    deadline_us,
                    n,
                    payload,
                } => {
                    if cfg.stall_us > 0 {
                        std::thread::sleep(Duration::from_micros(cfg.stall_us));
                    }
                    let sink: Arc<dyn ReplySink> = link.clone();
                    coal.submit(
                        Request {
                            req_id: task_id,
                            op,
                            deadline_us,
                            n,
                            payload,
                        },
                        sink,
                    );
                }
                DistMsg::BroadcastB { n, b } => {
                    let dim = n as usize;
                    let mut b_pack = vec![0.0f64; packed_b_len(dim, dim)];
                    pack_b_band(&b, dim, dim, 0, dim, &mut b_pack);
                    *bcast.lock().expect("bcast poisoned") = Some(Bcast {
                        n,
                        b_pack: Arc::new(b_pack),
                    });
                }
                DistMsg::SubmitBand {
                    task_id,
                    n,
                    row0: _,
                    a_rows,
                } => {
                    if cfg.stall_us > 0 {
                        std::thread::sleep(Duration::from_micros(cfg.stall_us));
                    }
                    let cached = bcast.lock().expect("bcast poisoned").clone();
                    match cached {
                        Some(bc) if bc.n == n => run_band(
                            &exec_rt,
                            &link,
                            &band_inflight,
                            &band_done,
                            bc.b_pack,
                            task_id,
                            n as usize,
                            a_rows,
                        ),
                        // No (or mismatched) broadcast: the band cannot
                        // be computed — fail it addressably so the
                        // coordinator's future resolves instead of
                        // hanging.
                        _ => {
                            link.send(&DistMsg::Complete {
                                task_id,
                                status: Status::Error,
                                deadline_missed: false,
                                n,
                                payload: Vec::new(),
                            });
                        }
                    }
                }
                DistMsg::StatsReq => {
                    let s = &stats;
                    let done = (s.ok.load(Ordering::Relaxed)
                        + s.errors.load(Ordering::Relaxed)
                        + s.expired.load(Ordering::Relaxed)
                        + s.shed.load(Ordering::Relaxed))
                        as u64
                        + band_done.load(Ordering::Relaxed);
                    let pending =
                        (s.pending() + band_inflight.load(Ordering::Acquire)) as u32;
                    link.send(&DistMsg::StatsReply { done, pending });
                }
                DistMsg::Shutdown => break 'link,
                // Worker-bound directions only; anything else is noise.
                DistMsg::Hello { .. } | DistMsg::Complete { .. } | DistMsg::StatsReply { .. } => {}
            }
        }
        match frame::read_into(&mut read_half, &mut fb, &mut tmp) {
            Ok(0) => break,
            Ok(_) => {}
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }

    // Orderly drain: flush the coalescer, then give in-flight batches
    // and band joins a bounded window to write their completions before
    // the process (and its half of the socket) goes away.
    coal.shutdown();
    let _ = batcher.join();
    let deadline = Instant::now() + Duration::from_secs(2);
    while (stats.pending() > 0 || band_inflight.load(Ordering::Acquire) > 0)
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    Ok(())
}

/// Futurize one mmult row band over the local runtime and send its
/// completion from the join continuation.  `a_rows` is this band's
/// `rows × dim` slice of A; the output band of C is `rows × dim`.
#[allow(clippy::too_many_arguments)]
fn run_band(
    exec_rt: &HpxMpRuntime,
    link: &Arc<DistLink>,
    inflight: &Arc<AtomicUsize>,
    band_done: &Arc<AtomicU64>,
    b_pack: Arc<Vec<f64>>,
    task_id: u64,
    dim: usize,
    a_rows: Vec<f64>,
) {
    let rows = a_rows.len() / dim;
    let mut out = vec![0.0f64; rows * dim];
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let a_rows = Arc::new(a_rows);
    let units = rows.div_ceil(PACKED_ROW_BAND) as i64;
    let body: Arc<dyn Fn(std::ops::Range<i64>) + Send + Sync> = {
        let a_rows = a_rows.clone();
        Arc::new(move |r: std::ops::Range<i64>| {
            for g in r {
                let i0 = g as usize * PACKED_ROW_BAND;
                let i1 = (i0 + PACKED_ROW_BAND).min(rows);
                let mut a_pack = vec![0.0f64; packed_a_len(i1 - i0, dim)];
                pack_a_band(&a_rows, dim, i0, i1, &mut a_pack);
                // SAFETY: rows [i0, i1) of the band's output buffer are
                // this unit's exclusive rectangle (unit indices are
                // claimed exactly once), and the buffer outlives the
                // join (moved into `on_ready`, which only fires after
                // every chunk arrived).
                unsafe {
                    kernel::packed_band_mm_ptr(
                        &a_pack, i1 - i0, &b_pack, dim, dim, out_ptr, dim, i0, 0,
                    );
                }
            }
        })
    };
    inflight.fetch_add(1, Ordering::AcqRel);
    let pol = Policy::with_mode(ExecMode::Task).on(exec_rt);
    let join = exec::for_each_async(&pol, 0..units, body);
    let link = link.clone();
    let inflight = inflight.clone();
    let band_done = band_done.clone();
    join.on_ready(move |outcome: &Outcome<()>| {
        let out = out;
        let msg = match outcome {
            Outcome::Value(()) => DistMsg::Complete {
                task_id,
                status: Status::Ok,
                deadline_missed: false,
                n: dim as u32,
                payload: out,
            },
            Outcome::Cancelled => DistMsg::Complete {
                task_id,
                status: Status::Expired,
                deadline_missed: true,
                n: dim as u32,
                payload: Vec::new(),
            },
            Outcome::Panicked => DistMsg::Complete {
                task_id,
                status: Status::Error,
                deadline_missed: false,
                n: dim as u32,
                payload: Vec::new(),
            },
        };
        link.send(&msg);
        band_done.fetch_add(1, Ordering::Relaxed);
        inflight.fetch_sub(1, Ordering::AcqRel);
    });
}
