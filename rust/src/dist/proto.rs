//! Dist message frames: the coordinator↔worker protocol (ISSUE 10).
//!
//! Dist messages ride the exact same wire layout as the serving frames
//! ([`crate::net::frame`]): a little-endian `u32` length prefix, the
//! [`PROTO_VERSION`] byte, a `u64` id, two bytes, two `u32`s, and an f64
//! payload.  Reusing the layout means the dist transport reuses
//! [`FrameBuf`] reassembly, [`read_into`](frame::read_into) /
//! [`write_frame`](frame::write_frame), the [`MAX_FRAME_LEN`] cap, and
//! the fixed-offset version contract (a foreign-version frame still
//! yields an addressable [`FrameError::BadVersion`]) — only the header
//! *interpretation* differs:
//!
//! ```text
//! len:u32 | ver:u8 | id:u64 | kind:u8 | b1:u8 | w0:u32 | n:u32 | payload f64*
//! ```
//!
//! `kind` selects the [`DistMsg`] variant; `id` is a task id for
//! submit/complete frames and reused as a `u64` stats word for
//! [`DistMsg::StatsReply`].  The taxonomy (DESIGN.md §15):
//!
//! | kind | message      | direction | meaning                                 |
//! |------|--------------|-----------|-----------------------------------------|
//! | 0    | `Hello`      | w → c     | worker announces slot + thread count    |
//! | 1    | `Submit`     | c → w     | one serving-kernel task                 |
//! | 2    | `BroadcastB` | c → w     | cache the shared B operand for mmult    |
//! | 3    | `SubmitBand` | c → w     | one A row-band of a distributed mmult   |
//! | 4    | `Complete`   | w → c     | task outcome (status + reply payload)   |
//! | 5    | `StatsReq`   | c → w     | poll worker counters                    |
//! | 6    | `StatsReply` | w → c     | tasks done + pending                    |
//! | 7    | `Shutdown`   | c → w     | drain and exit                          |

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::net::batch::ReplySink;
use crate::net::frame::{
    self, FrameError, Response, Status, WireOp, HDR_LEN, PROTO_VERSION,
};
use crate::net::server::WireStream;

/// Dimension cap for distributed `dmatdmatmult`: one `BroadcastB` frame
/// carries the full n×n B, so n² doubles must fit under
/// [`frame::MAX_FRAME_LEN`] (1000² × 8 B = 8 MB > header room would
/// overflow; 1000 keeps the body at 7.63 MiB, inside the 8 MiB cap).
pub const DIST_MMULT_MAX_N: usize = 1000;

/// One decoded dist message (see the module-level taxonomy table).
#[derive(Clone, Debug)]
pub enum DistMsg {
    /// Worker → coordinator, first frame on a link: which shard slot
    /// this process was spawned for and how many AMT workers it runs.
    Hello {
        /// Shard slot index assigned at spawn (`--slot`).
        slot: u32,
        /// AMT worker threads in the process (`--threads`).
        threads: u32,
    },
    /// Coordinator → worker: one serving-kernel task, same semantics as
    /// a wire [`crate::net::frame::Request`] but addressed by `task_id`.
    Submit {
        /// Coordinator-assigned task id (the remote-future id).
        task_id: u64,
        /// Kernel to run.
        op: WireOp,
        /// Wall-clock budget in µs from worker-side decode; 0 = none.
        deadline_us: u32,
        /// Operand dimension.
        n: u32,
        /// Request payload, `op.payload_len(n)` doubles.
        payload: Vec<f64>,
    },
    /// Coordinator → worker: cache the shared B operand (row-major n×n)
    /// for subsequent [`DistMsg::SubmitBand`] frames.
    BroadcastB {
        /// Matrix edge.
        n: u32,
        /// Row-major B, n² doubles.
        b: Vec<f64>,
    },
    /// Coordinator → worker: compute rows `[row0, row0 + rows)` of
    /// `C = A · B` against the last broadcast B.  `rows` is implied by
    /// the payload length (`payload.len() / n`).
    SubmitBand {
        /// Coordinator-assigned task id (the remote-future id).
        task_id: u64,
        /// Matrix edge (must match the cached broadcast).
        n: u32,
        /// First global row index of this band (for C placement).
        row0: u32,
        /// The band's rows of A, row-major, `rows × n` doubles.
        a_rows: Vec<f64>,
    },
    /// Worker → coordinator: outcome of a `Submit` or `SubmitBand`.
    Complete {
        /// Task id this completion fulfils.
        task_id: u64,
        /// Outcome status (same byte as the serving protocol).
        status: Status,
        /// Completed, but after its deadline (goodput miss).
        deadline_missed: bool,
        /// Dimension echoed from the task.
        n: u32,
        /// Reply payload (empty unless `status == Ok`).
        payload: Vec<f64>,
    },
    /// Coordinator → worker: report counters.
    StatsReq,
    /// Worker → coordinator: counters at poll time.
    StatsReply {
        /// Tasks completed since the process started.
        done: u64,
        /// Tasks admitted but not yet completed.
        pending: u32,
    },
    /// Coordinator → worker: drain in-flight tasks and exit.
    Shutdown,
}

impl DistMsg {
    /// The wire `kind` byte for this message.
    pub fn kind(&self) -> u8 {
        match self {
            DistMsg::Hello { .. } => 0,
            DistMsg::Submit { .. } => 1,
            DistMsg::BroadcastB { .. } => 2,
            DistMsg::SubmitBand { .. } => 3,
            DistMsg::Complete { .. } => 4,
            DistMsg::StatsReq => 5,
            DistMsg::StatsReply { .. } => 6,
            DistMsg::Shutdown => 7,
        }
    }
}

/// Encode one dist message into a fresh frame (length prefix included).
pub fn encode(msg: &DistMsg) -> Vec<u8> {
    let (id, b1, w0, n, payload): (u64, u8, u32, u32, &[f64]) = match msg {
        DistMsg::Hello { slot, threads } => (0, 0, *slot, *threads, &[]),
        DistMsg::Submit {
            task_id,
            op,
            deadline_us,
            n,
            payload,
        } => (*task_id, op.code(), *deadline_us, *n, payload),
        DistMsg::BroadcastB { n, b } => (0, 0, 0, *n, b),
        DistMsg::SubmitBand {
            task_id,
            n,
            row0,
            a_rows,
        } => (*task_id, 0, *row0, *n, a_rows),
        DistMsg::Complete {
            task_id,
            status,
            deadline_missed,
            n,
            payload,
        } => (
            *task_id,
            status.code() | ((*deadline_missed as u8) << 4),
            0,
            *n,
            payload,
        ),
        DistMsg::StatsReq => (0, 0, 0, 0, &[]),
        DistMsg::StatsReply { done, pending } => (*done, 0, *pending, 0, &[]),
        DistMsg::Shutdown => (0, 0, 0, 0, &[]),
    };
    let body_len = HDR_LEN + payload.len() * 8;
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(PROTO_VERSION);
    out.extend_from_slice(&id.to_le_bytes());
    out.push(msg.kind());
    out.push(b1);
    out.extend_from_slice(&w0.to_le_bytes());
    out.extend_from_slice(&n.to_le_bytes());
    frame::put_f64s(&mut out, payload);
    out
}

/// Decode one complete dist frame body (the bytes after the length
/// prefix, as popped by [`FrameBuf::next_body`](frame::FrameBuf)).
pub fn decode(body: &[u8]) -> Result<DistMsg, FrameError> {
    if body.len() < HDR_LEN {
        return Err(FrameError::Truncated);
    }
    // Fixed-offset contract, same as the serving decoder: the id is
    // readable before the version check so mismatches stay addressable.
    let id = u64::from_le_bytes(body[1..9].try_into().expect("8 bytes"));
    if body[0] != PROTO_VERSION {
        return Err(FrameError::BadVersion {
            req_id: id,
            got: body[0],
        });
    }
    let kind = body[9];
    let b1 = body[10];
    let w0 = u32::from_le_bytes(body[11..15].try_into().expect("4 bytes"));
    let n = u32::from_le_bytes(body[15..19].try_into().expect("4 bytes"));
    let payload = &body[HDR_LEN..];
    let length_err = |expect: usize| FrameError::LengthMismatch {
        req_id: id,
        expect,
        got: payload.len(),
    };
    match kind {
        0 => Ok(DistMsg::Hello {
            slot: w0,
            threads: n,
        }),
        1 => {
            let op = WireOp::from_code(b1).ok_or(FrameError::BadOp {
                req_id: id,
                code: b1,
            })?;
            if n == 0 || n > op.max_n() {
                return Err(FrameError::BadDim { req_id: id, n });
            }
            let expect = op.payload_len(n) * 8;
            if payload.len() != expect {
                return Err(length_err(expect));
            }
            Ok(DistMsg::Submit {
                task_id: id,
                op,
                deadline_us: w0,
                n,
                payload: frame::get_f64s(payload),
            })
        }
        2 => {
            if n == 0 || n as usize > DIST_MMULT_MAX_N {
                return Err(FrameError::BadDim { req_id: id, n });
            }
            let expect = n as usize * n as usize * 8;
            if payload.len() != expect {
                return Err(length_err(expect));
            }
            Ok(DistMsg::BroadcastB {
                n,
                b: frame::get_f64s(payload),
            })
        }
        3 => {
            if n == 0 || n as usize > DIST_MMULT_MAX_N {
                return Err(FrameError::BadDim { req_id: id, n });
            }
            if payload.is_empty() || payload.len() % (n as usize * 8) != 0 {
                return Err(length_err(n as usize * 8));
            }
            Ok(DistMsg::SubmitBand {
                task_id: id,
                n,
                row0: w0,
                a_rows: frame::get_f64s(payload),
            })
        }
        4 => {
            let status = Status::from_code(b1 & 0x0F).ok_or(FrameError::BadStatus {
                req_id: id,
                code: b1 & 0x0F,
            })?;
            Ok(DistMsg::Complete {
                task_id: id,
                status,
                deadline_missed: b1 & 0x10 != 0,
                n,
                payload: frame::get_f64s(payload),
            })
        }
        5 => Ok(DistMsg::StatsReq),
        6 => Ok(DistMsg::StatsReply {
            done: id,
            pending: w0,
        }),
        7 => Ok(DistMsg::Shutdown),
        other => Err(FrameError::BadOp {
            req_id: id,
            code: other,
        }),
    }
}

/// One direction of a coordinator↔worker connection: a mutex-serialized
/// write half plus a liveness flag.  Every sender (router forwards,
/// band scatter, worker completions) goes through [`DistLink::send`];
/// the first write error marks the link dead so later sends fail fast
/// instead of blocking on a broken socket.
pub struct DistLink {
    stream: Mutex<WireStream>,
    alive: AtomicBool,
}

impl DistLink {
    /// Wrap a connected write half.
    pub fn new(stream: WireStream) -> Self {
        Self {
            stream: Mutex::new(stream),
            alive: AtomicBool::new(true),
        }
    }

    /// Encode and write one message; returns `false` (and marks the
    /// link dead) if the link is already dead or the write fails.
    pub fn send(&self, msg: &DistMsg) -> bool {
        if !self.alive() {
            return false;
        }
        let bytes = encode(msg);
        let mut stream = self.stream.lock().expect("dist link poisoned");
        if frame::write_frame(&mut *stream, &bytes).is_err() {
            self.kill();
            return false;
        }
        true
    }

    /// Whether the link has seen no write failure and no explicit kill.
    pub fn alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Mark the link dead (reader saw EOF / decode error, or the peer
    /// process was reaped).  Idempotent.
    pub fn kill(&self) {
        self.alive.store(false, Ordering::Release);
    }
}

/// The worker-side engine replies through its link: a serving
/// [`Response`] becomes a [`DistMsg::Complete`] addressed by the task
/// id, so the whole Engine/Coalescer reply path (including shed and
/// expired outcomes) emits completion frames with no dist-specific
/// branches in `net/batch.rs`.
impl ReplySink for DistLink {
    fn send(&self, resp: &Response) {
        DistLink::send(
            self,
            &DistMsg::Complete {
                task_id: resp.req_id,
                status: resp.status,
                deadline_missed: resp.deadline_missed,
                n: resp.n,
                payload: resp.payload.clone(),
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::frame::FrameBuf;

    fn roundtrip(msg: &DistMsg) -> DistMsg {
        let bytes = encode(msg);
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        let body = fb.next_body().expect("frame ok").expect("complete");
        let got = decode(body).expect("decode ok");
        assert!(fb.next_body().expect("clean").is_none());
        got
    }

    #[test]
    fn every_kind_roundtrips() {
        let got = roundtrip(&DistMsg::Hello { slot: 3, threads: 4 });
        assert!(matches!(got, DistMsg::Hello { slot: 3, threads: 4 }));

        let got = roundtrip(&DistMsg::Submit {
            task_id: 42,
            op: WireOp::Daxpy,
            deadline_us: 500,
            n: 4,
            payload: vec![1.0, 2.0, 3.0, 4.0],
        });
        match got {
            DistMsg::Submit {
                task_id,
                op,
                deadline_us,
                n,
                payload,
            } => {
                assert_eq!(task_id, 42);
                assert_eq!(op, WireOp::Daxpy);
                assert_eq!(deadline_us, 500);
                assert_eq!(n, 4);
                assert_eq!(payload, vec![1.0, 2.0, 3.0, 4.0]);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let got = roundtrip(&DistMsg::BroadcastB {
            n: 2,
            b: vec![1.0, 2.0, 3.0, 4.0],
        });
        match got {
            DistMsg::BroadcastB { n, b } => {
                assert_eq!(n, 2);
                assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0]);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let got = roundtrip(&DistMsg::SubmitBand {
            task_id: 9,
            n: 2,
            row0: 6,
            a_rows: vec![0.5; 4],
        });
        match got {
            DistMsg::SubmitBand {
                task_id,
                n,
                row0,
                a_rows,
            } => {
                assert_eq!((task_id, n, row0), (9, 2, 6));
                assert_eq!(a_rows.len(), 4);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let got = roundtrip(&DistMsg::Complete {
            task_id: 42,
            status: Status::Expired,
            deadline_missed: true,
            n: 4,
            payload: vec![],
        });
        match got {
            DistMsg::Complete {
                task_id,
                status,
                deadline_missed,
                n,
                payload,
            } => {
                assert_eq!(task_id, 42);
                assert_eq!(status, Status::Expired);
                assert!(deadline_missed);
                assert_eq!(n, 4);
                assert!(payload.is_empty());
            }
            other => panic!("wrong variant: {other:?}"),
        }

        assert!(matches!(roundtrip(&DistMsg::StatsReq), DistMsg::StatsReq));
        let got = roundtrip(&DistMsg::StatsReply {
            done: u64::MAX - 1,
            pending: 7,
        });
        assert!(matches!(
            got,
            DistMsg::StatsReply { done, pending: 7 } if done == u64::MAX - 1
        ));
        assert!(matches!(roundtrip(&DistMsg::Shutdown), DistMsg::Shutdown));
    }

    #[test]
    fn dist_frames_share_the_version_contract() {
        let mut bytes = encode(&DistMsg::Submit {
            task_id: 77,
            op: WireOp::VAdd,
            deadline_us: 0,
            n: 2,
            payload: vec![1.0, 2.0],
        });
        bytes[4] = PROTO_VERSION + 1;
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        let body = fb.next_body().expect("frame ok").expect("complete");
        let err = decode(body).unwrap_err();
        assert!(matches!(err, FrameError::BadVersion { .. }));
        assert_eq!(err.req_id(), Some(77));
    }

    #[test]
    fn malformed_dist_frames_are_rejected() {
        // Unknown kind byte.
        let mut bytes = encode(&DistMsg::Shutdown);
        bytes[13] = 99; // kind sits at body[9] = frame[4 + 9]
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        let body = fb.next_body().unwrap().unwrap();
        assert!(matches!(decode(body), Err(FrameError::BadOp { code: 99, .. })));

        // Band payload not divisible by the row length.
        let bytes = encode(&DistMsg::SubmitBand {
            task_id: 1,
            n: 3,
            row0: 0,
            a_rows: vec![0.0; 4],
        });
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        let body = fb.next_body().unwrap().unwrap();
        assert!(matches!(
            decode(body),
            Err(FrameError::LengthMismatch { .. })
        ));

        // Broadcast over the dist dimension cap.
        let mut bytes = encode(&DistMsg::BroadcastB {
            n: 2,
            b: vec![0.0; 4],
        });
        let bad_n = (DIST_MMULT_MAX_N as u32 + 1).to_le_bytes();
        bytes[19..23].copy_from_slice(&bad_n); // n sits at body[15..19]
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        let body = fb.next_body().unwrap().unwrap();
        assert!(matches!(decode(body), Err(FrameError::BadDim { .. })));
    }
}
