//! The wire server: TCP/UDS listeners feeding the coalescing engine.
//!
//! Thread model (the "no thread-per-connection" acceptance bar): the
//! server runs a **constant** number of threads regardless of how many
//! connections are open — one acceptor per listener parked on
//! `poll(2)`, a small fixed pool of IO shards (each owning a subset of
//! connections, parked on `poll(2)` across all of them plus a self-pipe
//! for new-connection wakeups), and one batcher draining the coalescing
//! windows.  Compute never happens on these threads: decoded requests
//! become futurized pipelines on the runtime ([`super::batch`]), and
//! responses are written by join continuations through per-connection
//! [`ConnTx`] sinks.
//!
//! Sockets stay in blocking mode; readiness is established by `poll`
//! before every single `read`, so a read returns whatever bytes are
//! there without blocking the shard.  Writes are blocking with a short
//! `SO_SNDTIMEO` so a client that stops reading degrades into a dead
//! connection, not a wedged worker.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::net::batch::{BatchCfg, Coalescer, Engine, ReplySink, WireStats};
use crate::net::frame::{self, encode_response, FrameBuf, Request, Response, Status};
use crate::omp::OmpRuntime;

/// Where decoded requests go: the in-process [`Coalescer`] for a plain
/// server, the dist shard router for `serve --shards` (ISSUE 10) — the
/// IO layer is identical either way (connection reuse for the dist
/// front-end).
pub trait RequestHandler: Send + Sync {
    fn submit(&self, req: Request, sink: Arc<dyn ReplySink>);
    /// Called once from server shutdown, before threads are joined.
    fn on_shutdown(&self) {}
}

impl RequestHandler for Coalescer {
    fn submit(&self, req: Request, sink: Arc<dyn ReplySink>) {
        Coalescer::submit(self, req, sink);
    }

    fn on_shutdown(&self) {
        Coalescer::shutdown(self);
    }
}

/// Listen / connect address: `tcp:host:port`, `uds:/path`, or a bare
/// `host:port` (TCP).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireAddr {
    Tcp(String),
    Uds(PathBuf),
}

impl WireAddr {
    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(rest) = s.strip_prefix("uds:") {
            if rest.is_empty() {
                return Err(format!("empty uds path in {s:?}"));
            }
            return Ok(WireAddr::Uds(PathBuf::from(rest)));
        }
        let hostport = s.strip_prefix("tcp:").unwrap_or(s);
        if hostport.rsplit_once(':').is_none() {
            return Err(format!("expected tcp:host:port or uds:/path, got {s:?}"));
        }
        Ok(WireAddr::Tcp(hostport.to_string()))
    }
}

impl std::fmt::Display for WireAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireAddr::Tcp(hp) => write!(f, "tcp:{hp}"),
            WireAddr::Uds(p) => write!(f, "uds:{}", p.display()),
        }
    }
}

/// A connected stream of either family, unified so shards and the
/// client speak one type.
pub enum WireStream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl WireStream {
    /// Connect to a wire address (TCP with nodelay, or UDS) — the one
    /// dialer behind the blocking client, the load generator, and the
    /// dist worker links.
    pub fn connect(addr: &WireAddr) -> std::io::Result<WireStream> {
        Ok(match addr {
            WireAddr::Tcp(hp) => {
                let s = TcpStream::connect(hp.as_str())?;
                let _ = s.set_nodelay(true);
                WireStream::Tcp(s)
            }
            WireAddr::Uds(p) => WireStream::Uds(UnixStream::connect(p)?),
        })
    }

    fn as_raw_fd(&self) -> RawFd {
        match self {
            WireStream::Tcp(s) => s.as_raw_fd(),
            WireStream::Uds(s) => s.as_raw_fd(),
        }
    }

    pub(crate) fn try_clone(&self) -> std::io::Result<WireStream> {
        Ok(match self {
            WireStream::Tcp(s) => WireStream::Tcp(s.try_clone()?),
            WireStream::Uds(s) => WireStream::Uds(s.try_clone()?),
        })
    }

    pub(crate) fn set_read_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.set_read_timeout(t),
            WireStream::Uds(s) => s.set_read_timeout(t),
        }
    }

    pub(crate) fn set_write_timeout(&self, t: Option<Duration>) -> std::io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.set_write_timeout(t),
            WireStream::Uds(s) => s.set_write_timeout(t),
        }
    }

    fn set_nodelay(&self) {
        if let WireStream::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.read(buf),
            WireStream::Uds(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.write(buf),
            WireStream::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.flush(),
            WireStream::Uds(s) => s.flush(),
        }
    }
}

/// Per-connection reply writer — the [`ReplySink`] handed to every job
/// submitted from this connection.  Join continuations (worker threads)
/// and the shard (BadRequest replies) serialize on the mutex; a failed
/// write marks the sink dead so later responses for a dropped client
/// are discarded instead of wedging anything.
struct ConnTx {
    stream: Mutex<WireStream>,
    alive: AtomicBool,
}

impl ConnTx {
    fn new(stream: WireStream) -> Self {
        Self {
            stream: Mutex::new(stream),
            alive: AtomicBool::new(true),
        }
    }
}

impl ReplySink for ConnTx {
    fn send(&self, resp: &Response) {
        if !self.alive.load(Ordering::Acquire) {
            return;
        }
        let bytes = encode_response(resp);
        let mut s = self.stream.lock().expect("conn writer poisoned");
        if frame::write_frame(&mut *s, &bytes).is_err() {
            self.alive.store(false, Ordering::Release);
        }
    }
}

enum WireListener {
    Tcp(TcpListener),
    Uds(UnixListener),
}

impl WireListener {
    fn as_raw_fd(&self) -> RawFd {
        match self {
            WireListener::Tcp(l) => l.as_raw_fd(),
            WireListener::Uds(l) => l.as_raw_fd(),
        }
    }

    fn accept(&self) -> std::io::Result<WireStream> {
        match self {
            WireListener::Tcp(l) => l.accept().map(|(s, _)| WireStream::Tcp(s)),
            WireListener::Uds(l) => l.accept().map(|(s, _)| WireStream::Uds(s)),
        }
    }
}

/// New connections handed from an acceptor to an IO shard; the self-pipe
/// write interrupts the shard's `poll`.
struct ShardInbox {
    queue: Mutex<Vec<WireStream>>,
    wake_wr: RawFd,
}

impl ShardInbox {
    fn push(&self, s: WireStream) {
        self.queue.lock().expect("shard inbox poisoned").push(s);
        let b = [1u8];
        // SAFETY: wake_wr is a pipe fd owned by the server for its
        // whole lifetime; a failed/partial write only costs a wakeup
        // that the shard's poll timeout covers anyway.
        unsafe {
            libc::write(self.wake_wr, b.as_ptr() as *const libc::c_void, 1);
        }
    }
}

struct Conn {
    stream: WireStream,
    buf: FrameBuf,
    tx: Arc<ConnTx>,
}

/// Running wire server; dropping it shuts everything down and joins all
/// threads.
pub struct WireServer {
    handler: Arc<dyn RequestHandler>,
    stats: Arc<WireStats>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    tcp_addrs: Vec<SocketAddr>,
    uds_paths: Vec<PathBuf>,
    wake_fds: Vec<(RawFd, RawFd)>,
}

/// Fixed IO-shard count: connection parallelism on the read side without
/// scaling threads with connections.
const IO_SHARDS: usize = 2;

impl WireServer {
    /// Bind every address and start the acceptor/IO/batcher threads.
    pub fn start(
        rt: Arc<OmpRuntime>,
        addrs: &[WireAddr],
        cfg: BatchCfg,
    ) -> std::io::Result<WireServer> {
        let stats = Arc::new(WireStats::default());
        let engine = Arc::new(Engine::new(rt, cfg, stats.clone()));
        let coalescer = Coalescer::new(engine, cfg);
        let batcher = {
            let coal = coalescer.clone();
            std::thread::Builder::new()
                .name("hpxmp-wire-batch".into())
                .spawn(move || coal.run_batcher())
                .expect("spawn batcher")
        };
        let mut server = Self::start_with(coalescer, stats, addrs)?;
        server.threads.push(batcher);
        Ok(server)
    }

    /// Bind every address and start the acceptor/IO threads in front of
    /// an arbitrary [`RequestHandler`] — how the dist shard router
    /// reuses the whole connection layer (no batcher thread here; a
    /// handler that needs one owns it).
    pub fn start_with(
        handler: Arc<dyn RequestHandler>,
        stats: Arc<WireStats>,
        addrs: &[WireAddr],
    ) -> std::io::Result<WireServer> {
        let shutdown = Arc::new(AtomicBool::new(false));

        let mut listeners = Vec::new();
        let mut tcp_addrs = Vec::new();
        let mut uds_paths = Vec::new();
        for addr in addrs {
            match addr {
                WireAddr::Tcp(hp) => {
                    let l = TcpListener::bind(hp.as_str())?;
                    tcp_addrs.push(l.local_addr()?);
                    listeners.push(WireListener::Tcp(l));
                }
                WireAddr::Uds(p) => {
                    // A stale socket file from a previous run would make
                    // bind fail; only ever unlink the path we then bind.
                    let _ = std::fs::remove_file(p);
                    listeners.push(WireListener::Uds(UnixListener::bind(p)?));
                    uds_paths.push(p.clone());
                }
            }
        }

        let mut wake_fds = Vec::new();
        let mut shards = Vec::new();
        for _ in 0..IO_SHARDS {
            let mut fds = [0 as RawFd; 2];
            // SAFETY: plain pipe creation; fds are recorded and closed in
            // shutdown().
            let rc = unsafe { libc::pipe(fds.as_mut_ptr()) };
            if rc != 0 {
                return Err(std::io::Error::last_os_error());
            }
            wake_fds.push((fds[0], fds[1]));
            shards.push(Arc::new(ShardInbox {
                queue: Mutex::new(Vec::new()),
                wake_wr: fds[1],
            }));
        }

        let mut threads = Vec::new();
        let next_shard = Arc::new(AtomicUsize::new(0));
        for l in listeners {
            let shards = shards.clone();
            let next = next_shard.clone();
            let stop = shutdown.clone();
            let stats = stats.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("hpxmp-wire-accept".into())
                    .spawn(move || accept_loop(l, &shards, &next, &stop, &stats))
                    .expect("spawn acceptor"),
            );
        }
        for (i, inbox) in shards.into_iter().enumerate() {
            let wake_rd = wake_fds[i].0;
            let handler = handler.clone();
            let stop = shutdown.clone();
            let stats = stats.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("hpxmp-wire-io{i}"))
                    .spawn(move || shard_loop(&inbox, wake_rd, &*handler, &stop, &stats))
                    .expect("spawn io shard"),
            );
        }

        Ok(WireServer {
            handler,
            stats,
            shutdown,
            threads,
            tcp_addrs,
            uds_paths,
            wake_fds,
        })
    }

    /// Convenience: one TCP listener (ephemeral port with `:0`).
    pub fn start_tcp(
        rt: Arc<OmpRuntime>,
        hostport: &str,
        cfg: BatchCfg,
    ) -> std::io::Result<WireServer> {
        Self::start(rt, &[WireAddr::Tcp(hostport.to_string())], cfg)
    }

    /// Bound address of the first TCP listener.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.tcp_addrs.first().copied()
    }

    pub fn stats(&self) -> &Arc<WireStats> {
        &self.stats
    }

    /// Requests queued or in flight right now (0 once drained).
    pub fn pending(&self) -> usize {
        self.stats.pending()
    }

    /// Server threads (constant in the number of connections — the
    /// bound `tests/serve_wire.rs` asserts).
    pub fn thread_count(&self) -> usize {
        self.threads.len()
    }

    /// Block until every admitted request has been answered, up to
    /// `timeout`; returns whether the drain completed.
    pub fn drain(&self, timeout: Duration) -> bool {
        let start = std::time::Instant::now();
        while self.stats.pending() > 0 {
            if start.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }

    fn stop(&mut self) {
        if self.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        self.handler.on_shutdown();
        for &(_, wr) in &self.wake_fds {
            let b = [1u8];
            // SAFETY: pipe write ends are open until the join below.
            unsafe {
                libc::write(wr, b.as_ptr() as *const libc::c_void, 1);
            }
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        for &(rd, wr) in &self.wake_fds {
            // SAFETY: closing fds this server created; threads are joined.
            unsafe {
                libc::close(rd);
                libc::close(wr);
            }
        }
        self.wake_fds.clear();
        for p in &self.uds_paths {
            let _ = std::fs::remove_file(p);
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: WireListener,
    shards: &[Arc<ShardInbox>],
    next: &AtomicUsize,
    stop: &AtomicBool,
    stats: &WireStats,
) {
    let fd = listener.as_raw_fd();
    while !stop.load(Ordering::Acquire) {
        let mut pfd = libc::pollfd {
            fd,
            events: libc::POLLIN,
            revents: 0,
        };
        // SAFETY: polling one valid listener fd with a bounded timeout.
        let rc = unsafe { libc::poll(&mut pfd, 1, 100) };
        if rc <= 0 || pfd.revents & libc::POLLIN == 0 {
            continue;
        }
        match listener.accept() {
            Ok(stream) => {
                stream.set_nodelay();
                stats.accepted.fetch_add(1, Ordering::Relaxed);
                let i = next.fetch_add(1, Ordering::Relaxed) % shards.len();
                shards[i].push(stream);
            }
            Err(_) => continue,
        }
    }
}

fn shard_loop(
    inbox: &ShardInbox,
    wake_rd: RawFd,
    handler: &dyn RequestHandler,
    stop: &AtomicBool,
    stats: &WireStats,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut read_buf = vec![0u8; 64 * 1024];
    loop {
        for stream in inbox.queue.lock().expect("shard inbox poisoned").drain(..) {
            match stream.try_clone() {
                Ok(write_half) => {
                    let _ = write_half.set_write_timeout(Some(Duration::from_secs(1)));
                    conns.push(Conn {
                        stream,
                        buf: FrameBuf::new(),
                        tx: Arc::new(ConnTx::new(write_half)),
                    });
                }
                Err(_) => drop(stream),
            }
        }
        if stop.load(Ordering::Acquire) {
            return;
        }

        let mut pfds = Vec::with_capacity(conns.len() + 1);
        pfds.push(libc::pollfd {
            fd: wake_rd,
            events: libc::POLLIN,
            revents: 0,
        });
        for c in &conns {
            pfds.push(libc::pollfd {
                fd: c.stream.as_raw_fd(),
                events: libc::POLLIN,
                revents: 0,
            });
        }
        // SAFETY: every fd in pfds is owned by this shard (self-pipe +
        // live connections) and the timeout is bounded.
        let rc = unsafe { libc::poll(pfds.as_mut_ptr(), pfds.len() as libc::nfds_t, 100) };
        if rc <= 0 {
            continue;
        }
        if pfds[0].revents & libc::POLLIN != 0 {
            let mut sink = [0u8; 64];
            // SAFETY: draining the self-pipe this shard owns.
            unsafe {
                libc::read(wake_rd, sink.as_mut_ptr() as *mut libc::c_void, sink.len());
            }
        }
        // pfds[idx + 1] stays aligned with conns[idx] for the whole
        // pass; removals are applied afterwards (reverse index order so
        // swap_remove never moves a not-yet-removed entry).
        let mut dead = Vec::new();
        for (idx, conn) in conns.iter_mut().enumerate() {
            let revents = pfds[idx + 1].revents;
            let ready = revents & (libc::POLLIN | libc::POLLHUP | libc::POLLERR) != 0;
            if ready && !conn_readable(conn, handler, stats, &mut read_buf) {
                dead.push(idx);
            }
        }
        for &idx in dead.iter().rev() {
            conns.swap_remove(idx);
        }
    }
}

/// One readiness-gated read plus frame decode; returns `false` when the
/// connection should be dropped (EOF, IO error, or protocol violation).
fn conn_readable(
    conn: &mut Conn,
    handler: &dyn RequestHandler,
    stats: &WireStats,
    scratch: &mut [u8],
) -> bool {
    match frame::read_into(&mut conn.stream, &mut conn.buf, scratch) {
        Ok(0) => false,
        Ok(_) => {
            loop {
                match conn.buf.next_request() {
                    Ok(Some(req)) => {
                        let sink: Arc<dyn ReplySink> = conn.tx.clone();
                        handler.submit(req, sink);
                    }
                    Ok(None) => break true,
                    Err(e) => {
                        stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                        // Tell the client what it did wrong when the
                        // frame still carried an id, then hang up — a
                        // desynced stream cannot be trusted further.
                        if let Some(req_id) = e.req_id() {
                            conn.tx.send(&Response {
                                req_id,
                                status: Status::BadRequest,
                                deadline_missed: false,
                                n: 0,
                                payload: Vec::new(),
                            });
                        }
                        break false;
                    }
                }
            }
        }
        Err(ref e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::Interrupted =>
        {
            true
        }
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_addr_parses_all_forms() {
        assert_eq!(
            WireAddr::parse("tcp:127.0.0.1:8080").unwrap(),
            WireAddr::Tcp("127.0.0.1:8080".into())
        );
        assert_eq!(
            WireAddr::parse("127.0.0.1:0").unwrap(),
            WireAddr::Tcp("127.0.0.1:0".into())
        );
        assert_eq!(
            WireAddr::parse("uds:/tmp/x.sock").unwrap(),
            WireAddr::Uds(PathBuf::from("/tmp/x.sock"))
        );
        assert!(WireAddr::parse("uds:").is_err());
        assert!(WireAddr::parse("nonsense").is_err());
        assert_eq!(WireAddr::parse("uds:/a b").unwrap().to_string(), "uds:/a b");
    }
}
