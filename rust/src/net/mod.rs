//! Socket front-end: serve kernels at wire speed (ISSUE 9).
//!
//! Layering of the wire path, socket to scheduler:
//!
//! - [`frame`] — the length-prefixed request/response protocol and the
//!   incremental [`frame::FrameBuf`] decoder.
//! - [`batch`] — same-kernel request coalescing, fused batch execution
//!   as futurized pipelines on the runtime, and admission-coupled
//!   backpressure.
//! - [`server`] — TCP/UDS listeners, a constant-size acceptor/IO thread
//!   set parked on `poll(2)` (no thread-per-connection), per-connection
//!   reply writers.
//! - [`client`] — blocking [`client::WireClient`] for tests/tools and
//!   the seeded open-loop load generator behind `hpxmp loadgen`.

pub mod batch;
pub mod client;
pub mod frame;
pub mod server;

pub use batch::{expected_reply, BatchCfg, Coalescer, Engine, ReplySink, WireStats};
pub use client::{
    default_wire_n, run_loadgen, Dist, LoadgenCfg, LoadgenReport, WireClient,
};
pub use frame::{Request, Response, Status, WireOp};
pub use server::{WireAddr, WireServer};
