//! Same-kernel request batching/coalescing and backpressure (ISSUE 9).
//!
//! Decoded requests are not executed one-by-one: [`Coalescer::submit`]
//! buckets them by `(op, n)` and a batcher thread flushes each bucket
//! when its **coalescing window** expires (or immediately once it holds
//! [`BatchCfg::max_batch`] requests).  A flushed bucket becomes *one*
//! fused [`for_each_async`] over the batch's concatenated index space —
//! one team fork (hot-team checkout, PR 1) and one cached-operand /
//! packed-B pass (PR 7) amortized over every request in the window,
//! exactly the fork- and pack-amortization the in-process serving
//! scenario gets from streaming, recovered for open-loop arrivals.
//!
//! **Correctness of coalescing** is structural, not numerical luck: each
//! request's output segment is a disjoint slice of the batch response
//! buffer, and every kernel's per-element/per-row/per-band arithmetic is
//! decomposition-independent (elementwise ops trivially; `matvec` row
//! dots; the packed matmul accumulates in ascending k within KC strips —
//! DESIGN.md §12), so a request computes bit-for-bit the same reply
//! whether it shared a batch or ran alone.  `HPXMP_COALESCE=0` (or
//! `BatchCfg::coalesce = false`) degenerates to dispatch-per-request —
//! the unbatched ablation arm.
//!
//! **Backpressure** (the overload path): admission headroom
//! ([`crate::omp::OmpRuntime::admission_headroom`]) plus the pending
//! gauge decide *before* queueing whether a request is accepted, so
//! overload degrades in order — queue into the window, shrink effective
//! team share (admission, PR 3), shed ([`Status::Shed`], PR 6) — instead
//! of collapsing.  A hard [`BatchCfg::max_pending`] cap bounds memory
//! regardless of the shed flag.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use once_cell::sync::OnceCell;

use crate::amt::future::Outcome;
use crate::blaze::kernel::{
    self, pack_a_band, pack_b_band, packed_a_len, packed_b_len, PACKED_ROW_BAND,
};
use crate::blaze::ops::SendPtr;
use crate::blaze::{serial, DynMatrix, DynVector};
use crate::net::frame::{operand_seed, Request, Response, Status, WireOp};
use crate::omp::OmpRuntime;
use crate::par::exec::{self, KernelVariant};
use crate::par::{ExecMode, HpxMpRuntime, Policy};

/// Where a finished (or rejected) request's response goes.  The server's
/// per-connection writer implements this; tests plug in channels.
pub trait ReplySink: Send + Sync {
    fn send(&self, resp: &Response);
}

/// Batching/backpressure knobs for the wire engine.
#[derive(Clone, Copy, Debug)]
pub struct BatchCfg {
    /// Execution mode of the fused batch dispatch.  `Task` (the default)
    /// keeps the batcher thread non-blocking — the batch is a futurized
    /// pipeline whose responses are written by a continuation.
    pub mode: ExecMode,
    /// Team size per batch fork; 0 = the executor's max concurrency.
    pub threads: usize,
    /// Master switch: `false` dispatches every request alone (the
    /// `HPXMP_COALESCE=0` ablation arm).
    pub coalesce: bool,
    /// Coalescing window in µs: how long the first request of a bucket
    /// waits for same-shape company before the batch is flushed.
    pub coalesce_us: u64,
    /// Flush a bucket early once it holds this many requests.
    pub max_batch: usize,
    /// Hard cap on queued + in-flight requests; beyond it every submit is
    /// shed regardless of [`BatchCfg::shed`] (memory bound).
    pub max_pending: usize,
    /// Soft shedding: reject new requests while the admission budget has
    /// no headroom *and* at least a batch worth of work is already
    /// pending — PR 6's deadline/shed machinery applied at the socket
    /// edge.
    pub shed: bool,
    /// Deadline stamped on requests that carry none (µs; 0 = none).
    pub default_deadline_us: u32,
}

impl Default for BatchCfg {
    fn default() -> Self {
        Self {
            mode: ExecMode::Task,
            threads: 0,
            coalesce: coalesce_from_env(),
            coalesce_us: coalesce_window_us_from_env(),
            max_batch: 32,
            max_pending: 1024,
            shed: true,
            default_deadline_us: 0,
        }
    }
}

/// `HPXMP_COALESCE=0` disables batching (the unbatched ablation arm);
/// unset or any other value leaves it on.
pub fn coalesce_from_env() -> bool {
    std::env::var("HPXMP_COALESCE").map_or(true, |v| v != "0")
}

/// `HPXMP_COALESCE_US` overrides the coalescing window (default 150 µs —
/// small against a millisecond-scale SLO, wide against inter-arrival
/// gaps at interesting rates).
pub fn coalesce_window_us_from_env() -> u64 {
    std::env::var("HPXMP_COALESCE_US")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(150)
}

/// Counters the wire front-end exports (`hpxmp serve --listen` prints
/// them; tests assert leak-freedom on `pending`).
#[derive(Default)]
pub struct WireStats {
    /// Connections accepted across all listeners.
    pub accepted: AtomicUsize,
    /// Requests decoded and admitted past backpressure.
    pub requests: AtomicUsize,
    /// Frames rejected at decode (connection dropped after).
    pub bad_frames: AtomicUsize,
    /// Fused dispatches (a batch of one still counts).
    pub batches: AtomicUsize,
    /// Requests carried by those batches.
    pub batched_requests: AtomicUsize,
    /// Largest single batch seen.
    pub max_batch: AtomicUsize,
    /// Requests rejected by backpressure.
    pub shed: AtomicUsize,
    /// Requests abandoned because their deadline expired server-side.
    pub expired: AtomicUsize,
    /// Completed responses that missed their deadline (still served).
    pub deadline_misses: AtomicUsize,
    /// Requests answered `Status::Error` (batch died).
    pub errors: AtomicUsize,
    /// Requests answered `Status::Ok`.
    pub ok: AtomicUsize,
    /// Queued + in-flight requests (gauge; 0 when drained — the
    /// admission-leak check of `tests/serve_wire.rs`).
    pub pending: AtomicUsize,
}

impl WireStats {
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    fn note_batch(&self, len: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(len, Ordering::Relaxed);
        self.max_batch.fetch_max(len, Ordering::Relaxed);
    }
}

/// One admitted request waiting for (or riding) a batch.
pub struct Job {
    pub req: Request,
    pub sink: Arc<dyn ReplySink>,
    /// Absolute deadline derived from the frame's `deadline_us` (or the
    /// configured default) at submit time — queueing in the coalescing
    /// window burns this budget, by design.
    pub deadline: Option<Instant>,
}

/// Generate the cached second operand for `(op, n)` — deterministic in
/// `(op, n)` via [`operand_seed`], shared by the server's operand cache
/// and the client-side oracle so expected replies are computable without
/// a round-trip.  Vector ops get an n-vector; `MatVec` its n×n A;
/// `MMult` its n×n B.
pub fn gen_operand(op: WireOp, n: u32) -> Vec<f64> {
    let seed = operand_seed(op, n);
    let n = n as usize;
    match op {
        WireOp::Daxpy | WireOp::VAdd => DynVector::random(n, seed).as_slice().to_vec(),
        WireOp::MatVec | WireOp::MMult => {
            DynMatrix::random(n, n, seed).as_slice().to_vec()
        }
    }
}

enum CachedOperand {
    /// daxpy/vadd second operand, or matvec A (row-major n×n).
    Plain(Arc<Vec<f64>>),
    /// mmult B together with its packed image — packed once per shape,
    /// the "one packed-operand pass" every batch member shares.
    PackedB(Arc<(Vec<f64>, Vec<f64>)>),
}

impl Clone for CachedOperand {
    fn clone(&self) -> Self {
        match self {
            CachedOperand::Plain(v) => CachedOperand::Plain(v.clone()),
            CachedOperand::PackedB(v) => CachedOperand::PackedB(v.clone()),
        }
    }
}

/// Executes flushed batches on the runtime and writes responses.
pub struct Engine {
    exec: HpxMpRuntime,
    cfg: BatchCfg,
    stats: Arc<WireStats>,
    operands: Mutex<HashMap<(u8, u32), CachedOperand>>,
}

impl Engine {
    pub fn new(rt: Arc<OmpRuntime>, cfg: BatchCfg, stats: Arc<WireStats>) -> Self {
        Self {
            exec: HpxMpRuntime::new(rt),
            cfg,
            stats,
            operands: Mutex::new(HashMap::new()),
        }
    }

    pub fn stats(&self) -> &Arc<WireStats> {
        &self.stats
    }

    /// Worker slots not yet reserved by in-flight regions — the
    /// admission-budget gauge backpressure consults.
    pub fn admission_headroom(&self) -> usize {
        self.exec.rt.admission_headroom()
    }

    fn operand(&self, op: WireOp, n: u32) -> CachedOperand {
        let mut map = self.operands.lock().expect("operand cache poisoned");
        map.entry((op.code(), n))
            .or_insert_with(|| match op {
                WireOp::Daxpy | WireOp::VAdd | WireOp::MatVec => {
                    CachedOperand::Plain(Arc::new(gen_operand(op, n)))
                }
                WireOp::MMult => {
                    let b = gen_operand(op, n);
                    let dim = n as usize;
                    let mut b_pack = vec![0.0f64; packed_b_len(dim, dim)];
                    pack_b_band(&b, dim, dim, 0, dim, &mut b_pack);
                    CachedOperand::PackedB(Arc::new((b, b_pack)))
                }
            })
            .clone()
    }

    /// Execute one flushed bucket: a single fused dispatch over the
    /// batch's concatenated index space, responses written by the join
    /// continuation (Task mode never blocks the calling thread).
    pub fn dispatch(&self, op: WireOp, n: u32, mut jobs: Vec<Job>) {
        self.stats.note_batch(jobs.len());
        // Requests whose whole budget burned in the window are answered
        // Expired without compute when shedding; without shedding they
        // run anyway and are flagged as misses on completion.
        if self.cfg.shed {
            let now = Instant::now();
            let (dead, live): (Vec<Job>, Vec<Job>) = jobs
                .drain(..)
                .partition(|j| j.deadline.is_some_and(|d| d < now));
            for j in &dead {
                respond(&self.stats, j, Status::Expired, true, Vec::new());
            }
            jobs = live;
        }
        if jobs.is_empty() {
            return;
        }
        // The fused batch deadline: the *latest* member deadline (earlier
        // members are flagged individually on completion).  Only armed
        // when every member carries one — an unbounded member must not be
        // cancelled by its neighbors' budgets.
        let batch_deadline = jobs
            .iter()
            .map(|j| j.deadline)
            .collect::<Option<Vec<_>>>()
            .and_then(|ds| ds.into_iter().max());
        let dim = n as usize;
        let reply_len = op.reply_len(n);
        let mut out = vec![0.0f64; jobs.len() * reply_len];
        let out_ptr = SendPtr::new(out.as_mut_ptr());
        let jobs = Arc::new(jobs);
        let body = self.batch_body(op, n, &jobs, out_ptr);
        // Units: elements (vector ops), rows (matvec), or row bands
        // (mmult) across the whole batch.
        let units_per_req = match op {
            WireOp::Daxpy | WireOp::VAdd | WireOp::MatVec => dim,
            WireOp::MMult => dim.div_ceil(PACKED_ROW_BAND),
        };
        let total = (jobs.len() * units_per_req) as i64;
        let mut pol = Policy::with_mode(self.cfg.mode).on(&self.exec);
        if self.cfg.threads > 0 {
            pol = pol.threads(self.cfg.threads);
        }
        if let Some(at) = batch_deadline {
            pol = pol.deadline_at(at);
        }
        let join = exec::for_each_async(&pol, 0..total, body);
        let stats = self.stats.clone();
        // `on_ready` (unlike `then`) runs for every outcome, including
        // Cancelled/Panicked — a wire request must always get *some*
        // response.  The join only fires once every chunk has arrived
        // (run or skipped), so no writer is live when `out` drops.
        join.on_ready(move |outcome: &Outcome<()>| {
            let now = Instant::now();
            let out = out;
            match outcome {
                Outcome::Value(()) => {
                    for (i, job) in jobs.iter().enumerate() {
                        let missed = job.deadline.is_some_and(|d| now > d);
                        let payload = out[i * reply_len..(i + 1) * reply_len].to_vec();
                        respond(&stats, job, Status::Ok, missed, payload);
                    }
                }
                Outcome::Cancelled => {
                    // The batch deadline fired: partial buffers are not
                    // trustworthy — every member expires.
                    for job in jobs.iter() {
                        respond(&stats, job, Status::Expired, true, Vec::new());
                    }
                }
                Outcome::Panicked => {
                    for job in jobs.iter() {
                        respond(&stats, job, Status::Error, false, Vec::new());
                    }
                }
            }
        });
    }

    /// The fused chunk body: maps a global unit range back to (request,
    /// local range) pairs and runs the kernel on each segment.  Output
    /// segments are disjoint per unit, so the raw-pointer stores satisfy
    /// the [`SendPtr`] partition invariant.
    fn batch_body(
        &self,
        op: WireOp,
        n: u32,
        jobs: &Arc<Vec<Job>>,
        out: SendPtr,
    ) -> Arc<dyn Fn(std::ops::Range<i64>) + Send + Sync> {
        let dim = n as usize;
        let jobs = jobs.clone();
        match op {
            WireOp::Daxpy | WireOp::VAdd => {
                let operand = match self.operand(op, n) {
                    CachedOperand::Plain(v) => v,
                    CachedOperand::PackedB(_) => unreachable!("vector op"),
                };
                Arc::new(move |r: std::ops::Range<i64>| {
                    let mut g = r.start as usize;
                    let end = r.end as usize;
                    while g < end {
                        let req = g / dim;
                        let lo = g % dim;
                        let hi = dim.min(lo + (end - g));
                        let x = &jobs[req].req.payload[lo..hi];
                        let b = &operand[lo..hi];
                        // SAFETY: [req*dim+lo, req*dim+hi) is this call's
                        // exclusive slice of the batch buffer (global
                        // unit indices are claimed exactly once).
                        let y = unsafe { out.slice_range(req * dim + lo, req * dim + hi) };
                        match op {
                            WireOp::Daxpy => {
                                y.copy_from_slice(b);
                                kernel::daxpy(KernelVariant::Auto, 3.0, x, y);
                            }
                            _ => kernel::vadd(KernelVariant::Auto, x, b, y),
                        }
                        g = req * dim + hi;
                    }
                })
            }
            WireOp::MatVec => {
                let a = match self.operand(op, n) {
                    CachedOperand::Plain(v) => v,
                    CachedOperand::PackedB(_) => unreachable!("matvec"),
                };
                Arc::new(move |r: std::ops::Range<i64>| {
                    for g in r {
                        let g = g as usize;
                        let req = g / dim;
                        let row = g % dim;
                        let x = &jobs[req].req.payload[..];
                        // SAFETY: one global row index -> one exclusive
                        // output element.
                        let y = unsafe { out.slice_range(g, g + 1) };
                        serial::matvec_rows(&a[row * dim..(row + 1) * dim], x, y);
                    }
                })
            }
            WireOp::MMult => {
                let packed = match self.operand(op, n) {
                    CachedOperand::PackedB(v) => v,
                    CachedOperand::Plain(_) => unreachable!("mmult"),
                };
                let bands = dim.div_ceil(PACKED_ROW_BAND);
                // Per-request A, generated lazily from the request's seed
                // by whichever band task gets there first (OnceCell makes
                // the race benign) — bands of the same request share it.
                let a_cells: Arc<Vec<OnceCell<Vec<f64>>>> =
                    Arc::new((0..jobs.len()).map(|_| OnceCell::new()).collect());
                Arc::new(move |r: std::ops::Range<i64>| {
                    for g in r {
                        let g = g as usize;
                        let req = g / bands;
                        let band = g % bands;
                        let seed = jobs[req].req.payload[0].to_bits();
                        let a = a_cells[req].get_or_init(|| {
                            DynMatrix::random(dim, dim, seed).as_slice().to_vec()
                        });
                        let i0 = band * PACKED_ROW_BAND;
                        let i1 = (i0 + PACKED_ROW_BAND).min(dim);
                        let mut a_pack = vec![0.0f64; packed_a_len(i1 - i0, dim)];
                        pack_a_band(a, dim, i0, i1, &mut a_pack);
                        // SAFETY: rows [i0, i1) of request `req`'s C are
                        // this band's exclusive rectangle of the batch
                        // buffer — addressed from the batch base with
                        // `row_off = req·dim + i0` (row-major squares
                        // laid out back to back share the leading dim).
                        unsafe {
                            kernel::packed_band_mm_ptr(
                                &a_pack,
                                i1 - i0,
                                &packed.1,
                                dim,
                                dim,
                                out,
                                dim,
                                req * dim + i0,
                                0,
                            );
                        }
                    }
                })
            }
        }
    }
}

/// Send the terminal response for an admitted job and settle its
/// accounting — the ONLY place the pending gauge is decremented, so
/// "every admitted job passes through exactly once" is the leak-freedom
/// invariant (`tests/serve_wire.rs` asserts the gauge returns to 0).
fn respond(stats: &WireStats, job: &Job, status: Status, missed: bool, payload: Vec<f64>) {
    match status {
        Status::Ok => {
            stats.ok.fetch_add(1, Ordering::Relaxed);
            if missed {
                stats.deadline_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        Status::Expired => {
            stats.expired.fetch_add(1, Ordering::Relaxed);
        }
        Status::Error => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
        }
        Status::Shed | Status::BadRequest => {}
    }
    job.sink.send(&Response {
        req_id: job.req.req_id,
        status,
        deadline_missed: missed,
        n: job.req.n,
        payload,
    });
    stats.pending.fetch_sub(1, Ordering::AcqRel);
}

/// Reference reply computation (client-side oracle / tests): what the
/// server must answer for `(op, n, payload)` — bit-for-bit, whatever
/// batch the request rode in.
pub fn expected_reply(op: WireOp, n: u32, payload: &[f64]) -> Vec<f64> {
    let dim = n as usize;
    let operand = gen_operand(op, n);
    match op {
        WireOp::Daxpy => {
            let mut y = operand;
            kernel::daxpy(KernelVariant::Auto, 3.0, payload, &mut y);
            y
        }
        WireOp::VAdd => {
            let mut y = vec![0.0f64; dim];
            kernel::vadd(KernelVariant::Auto, payload, &operand, &mut y);
            y
        }
        WireOp::MatVec => {
            let mut y = vec![0.0f64; dim];
            serial::matvec_rows(&operand, payload, &mut y);
            y
        }
        WireOp::MMult => {
            let a = DynMatrix::random(dim, dim, payload[0].to_bits())
                .as_slice()
                .to_vec();
            let mut c = vec![0.0f64; dim * dim];
            kernel::packed_matmul(&a, &operand, dim, dim, dim, &mut c);
            c
        }
    }
}

struct Bucket {
    jobs: Vec<Job>,
    first: Instant,
}

/// Buckets admitted requests by `(op, n)` and flushes them as fused
/// batches; owns the backpressure decision.
pub struct Coalescer {
    engine: Arc<Engine>,
    cfg: BatchCfg,
    buckets: Mutex<HashMap<(u8, u32), Bucket>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

impl Coalescer {
    pub fn new(engine: Arc<Engine>, cfg: BatchCfg) -> Arc<Self> {
        Arc::new(Self {
            engine,
            cfg,
            buckets: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Admit-or-shed, then bucket (or dispatch immediately when
    /// coalescing is off / the bucket filled).  Called from IO threads;
    /// never blocks on compute in Task mode.
    pub fn submit(&self, req: Request, sink: Arc<dyn ReplySink>) {
        let stats = self.engine.stats();
        let pending = stats.pending();
        let hard_cap = pending >= self.cfg.max_pending;
        let soft_shed = self.cfg.shed
            && pending >= self.cfg.max_batch
            && self.engine.admission_headroom() == 0;
        if hard_cap || soft_shed {
            stats.shed.fetch_add(1, Ordering::Relaxed);
            sink.send(&Response {
                req_id: req.req_id,
                status: Status::Shed,
                deadline_missed: false,
                n: req.n,
                payload: Vec::new(),
            });
            return;
        }
        stats.requests.fetch_add(1, Ordering::Relaxed);
        stats.pending.fetch_add(1, Ordering::AcqRel);
        let deadline_us = if req.deadline_us > 0 {
            req.deadline_us
        } else {
            self.cfg.default_deadline_us
        };
        let deadline =
            (deadline_us > 0).then(|| Instant::now() + Duration::from_micros(deadline_us as u64));
        let key = (req.op.code(), req.n);
        let op = req.op;
        let n = req.n;
        let job = Job { req, sink, deadline };
        if !self.cfg.coalesce || self.cfg.coalesce_us == 0 {
            self.engine.dispatch(op, n, vec![job]);
            return;
        }
        let full = {
            let mut map = self.buckets.lock().expect("coalescer poisoned");
            let bucket = map.entry(key).or_insert_with(|| Bucket {
                jobs: Vec::with_capacity(self.cfg.max_batch),
                first: Instant::now(),
            });
            if bucket.jobs.is_empty() {
                bucket.first = Instant::now();
            }
            bucket.jobs.push(job);
            if bucket.jobs.len() >= self.cfg.max_batch {
                map.remove(&key)
            } else {
                None
            }
        };
        match full {
            // A full bucket flushes on the submitting thread — zero
            // added latency, and Task-mode dispatch never blocks it.
            Some(bucket) => self.engine.dispatch(op, n, bucket.jobs),
            None => self.cv.notify_one(),
        }
    }

    /// The batcher loop: park until the oldest bucket's window expires,
    /// flush every due bucket, repeat.  Owned by one server thread.
    pub fn run_batcher(&self) {
        let window = Duration::from_micros(self.cfg.coalesce_us.max(1));
        let mut map = self.buckets.lock().expect("coalescer poisoned");
        loop {
            let now = Instant::now();
            let mut due = Vec::new();
            let mut next: Option<Instant> = None;
            map.retain(|&(opc, n), bucket| {
                let flush_at = bucket.first + window;
                if flush_at <= now || self.shutdown.load(Ordering::Acquire) {
                    due.push((opc, n, std::mem::take(&mut bucket.jobs)));
                    false
                } else {
                    next = Some(next.map_or(flush_at, |t| t.min(flush_at)));
                    true
                }
            });
            if !due.is_empty() {
                drop(map);
                for (opc, n, jobs) in due {
                    let op = WireOp::from_code(opc).expect("bucket key is a valid op");
                    self.engine.dispatch(op, n, jobs);
                }
                map = self.buckets.lock().expect("coalescer poisoned");
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            let timeout = next
                .map(|t| t.saturating_duration_since(now))
                .unwrap_or(Duration::from_millis(50));
            let (guard, _) = self
                .cv
                .wait_timeout(map, timeout)
                .expect("coalescer poisoned");
            map = guard;
        }
    }

    /// Flush everything and stop the batcher.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}
