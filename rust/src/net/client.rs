//! Wire client: blocking request/response for tests and tools, plus the
//! seeded open-loop load generator behind `hpxmp loadgen`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::blaze::DynVector;
use crate::net::frame::{self, encode_request, FrameBuf, Request, Response, REQ_ID_OFFSET, WireOp};
use crate::net::server::{WireAddr, WireStream};
use crate::util::rng::Xoshiro256;
use crate::util::stats::RequestStats;
use crate::util::timing::spin_wait;

/// Default request sizes per op for loadgen / the wire bench: big enough
/// that the kernel dominates framing, small enough that a single request
/// cannot saturate the machine on its own.
pub fn default_wire_n(op: WireOp) -> u32 {
    match op {
        WireOp::Daxpy | WireOp::VAdd => 4096,
        WireOp::MatVec => 256,
        WireOp::MMult => 64,
    }
}

/// Blocking round-trip client (tests, oracles, simple tools).
pub struct WireClient {
    stream: WireStream,
    buf: FrameBuf,
    next_id: u64,
}

fn to_io<E: std::error::Error + Send + Sync + 'static>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e)
}

impl WireClient {
    pub fn connect(addr: &WireAddr) -> std::io::Result<Self> {
        let stream = WireStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        Ok(Self {
            stream,
            buf: FrameBuf::new(),
            next_id: 1,
        })
    }

    /// Send raw bytes on the connection (tests use this to inject
    /// malformed or truncated frames).
    pub fn send_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        frame::write_frame(&mut self.stream, bytes)
    }

    /// Send one request without waiting (pipelining).
    pub fn send(&mut self, req: &Request) -> std::io::Result<()> {
        frame::write_frame(&mut self.stream, &encode_request(req))
    }

    /// Receive the next response frame (blocking, read-timeout bounded).
    pub fn recv(&mut self) -> std::io::Result<Response> {
        let mut tmp = [0u8; 64 * 1024];
        loop {
            if let Some(resp) = self.buf.next_response().map_err(to_io)? {
                return Ok(resp);
            }
            if frame::read_into(&mut self.stream, &mut self.buf, &mut tmp)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed connection",
                ));
            }
        }
    }

    /// One synchronous round-trip.
    pub fn request(
        &mut self,
        op: WireOp,
        n: u32,
        payload: Vec<f64>,
        deadline_us: u32,
    ) -> std::io::Result<Response> {
        let req_id = self.next_id;
        self.next_id += 1;
        self.send(&Request {
            req_id,
            op,
            deadline_us,
            n,
            payload,
        })?;
        loop {
            let resp = self.recv()?;
            if resp.req_id == req_id {
                return Ok(resp);
            }
        }
    }
}

/// Inter-arrival distribution for the open-loop generator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    /// Exponential gaps (Poisson arrivals) — bursty, the realistic case
    /// coalescing exploits.
    Poisson,
    /// Gaps uniform in `[0, 2/λ)` — same mean rate, bounded burstiness.
    Uniform,
}

impl Dist {
    pub const CHOICES: &'static [&'static str] = &["poisson", "uniform"];

    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "poisson" => Ok(Dist::Poisson),
            "uniform" => Ok(Dist::Uniform),
            _ => Err(format!("unknown dist {s:?} (choices: poisson, uniform)")),
        }
    }
}

/// Open-loop load-generator configuration (`hpxmp loadgen`).
#[derive(Clone, Debug)]
pub struct LoadgenCfg {
    pub addr: WireAddr,
    pub op: WireOp,
    pub n: u32,
    /// Total offered load across all connections, requests/second.
    pub rate: f64,
    pub conns: usize,
    pub dist: Dist,
    pub duration: Duration,
    /// Deadline stamped on every request (0 = none).
    pub deadline_us: u32,
    pub seed: u64,
}

/// What a loadgen run measured.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Merged per-connection request accounting (latencies from `Ok`
    /// responses; shed / expired / failed counters).
    pub stats: RequestStats,
    /// Send-window length in seconds (rates are relative to this).
    pub wall_s: f64,
    /// Requests put on the wire.
    pub sent: usize,
    /// Requests never answered (connection died or drain timed out).
    pub lost: usize,
}

impl LoadgenReport {
    pub fn reqs_per_sec(&self) -> f64 {
        self.stats.reqs_per_sec(self.wall_s)
    }

    pub fn goodput_per_sec(&self) -> f64 {
        self.stats.goodput_per_sec(self.wall_s)
    }
}

/// How long after the send window closes the receivers keep draining
/// responses before declaring the remainder lost.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(3);

/// Run the open-loop generator: `conns` connections, each with a sender
/// thread pacing seeded arrivals (send times never wait for responses —
/// that is what makes the load open-loop) and a receiver thread matching
/// responses back to send timestamps.  Client-side threads are fine; the
/// thread-count bound under test is the *server's*.
pub fn run_loadgen(cfg: &LoadgenCfg) -> std::io::Result<LoadgenReport> {
    assert!(cfg.conns > 0, "loadgen needs at least one connection");
    assert!(cfg.rate > 0.0, "loadgen rate must be positive");
    let per_conn_rate = cfg.rate / cfg.conns as f64;
    let mut senders = Vec::new();
    let mut receivers = Vec::new();
    let sent_total = Arc::new(AtomicUsize::new(0));
    for conn_idx in 0..cfg.conns {
        let stream = WireStream::connect(&cfg.addr)?;
        let reader = stream.try_clone()?;
        reader.set_read_timeout(Some(Duration::from_millis(50)))?;
        let outstanding: Arc<Mutex<HashMap<u64, Instant>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let done = Arc::new(AtomicBool::new(false));

        let cfg_s = cfg.clone();
        let out_s = outstanding.clone();
        let done_s = done.clone();
        let sent_s = sent_total.clone();
        senders.push(std::thread::spawn(move || {
            sender_loop(stream, &cfg_s, conn_idx as u64, per_conn_rate, &out_s, &sent_s);
            done_s.store(true, Ordering::Release);
        }));

        receivers.push(std::thread::spawn(move || {
            receiver_loop(reader, &outstanding, &done)
        }));
    }
    for s in senders {
        let _ = s.join();
    }
    let mut report = LoadgenReport {
        wall_s: cfg.duration.as_secs_f64(),
        sent: 0,
        ..Default::default()
    };
    for r in receivers {
        let (stats, unanswered) = r.join().unwrap_or_default();
        report.stats.merge(&stats);
        report.lost += unanswered;
    }
    report.sent = sent_total.load(Ordering::Acquire);
    Ok(report)
}

fn sender_loop(
    mut stream: WireStream,
    cfg: &LoadgenCfg,
    conn_idx: u64,
    per_conn_rate: f64,
    outstanding: &Mutex<HashMap<u64, Instant>>,
    sent_total: &AtomicUsize,
) {
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ (conn_idx.wrapping_mul(0x9E37)));
    // Template frame re-used for every request: only the request id is
    // patched per send, so the hot loop does no re-encoding.
    let payload_len = cfg.op.payload_len(cfg.n);
    let payload = if cfg.op == WireOp::MMult {
        vec![f64::from_bits(cfg.seed ^ conn_idx)]
    } else {
        DynVector::random(payload_len, cfg.seed ^ conn_idx)
            .as_slice()
            .to_vec()
    };
    let mut template = encode_request(&Request {
        req_id: 0,
        op: cfg.op,
        deadline_us: cfg.deadline_us,
        n: cfg.n,
        payload,
    });
    let mean_gap = 1.0 / per_conn_rate;
    let start = Instant::now();
    let mut t_next = 0.0f64;
    let mut seq: u64 = 0;
    loop {
        let elapsed = start.elapsed().as_secs_f64();
        if elapsed >= cfg.duration.as_secs_f64() {
            break;
        }
        if elapsed < t_next {
            let wait = t_next - elapsed;
            // Hybrid pacing: coarse sleep, then spin the last sliver so
            // arrival times track the schedule at µs granularity.
            if wait > 2e-3 {
                std::thread::sleep(Duration::from_secs_f64(wait - 1e-3));
            }
            spin_wait(Duration::from_secs_f64(
                (t_next - start.elapsed().as_secs_f64()).max(0.0),
            ));
        }
        let req_id = (conn_idx << 32) | seq;
        template[REQ_ID_OFFSET..REQ_ID_OFFSET + 8].copy_from_slice(&req_id.to_le_bytes());
        outstanding
            .lock()
            .expect("outstanding map poisoned")
            .insert(req_id, Instant::now());
        if frame::write_frame(&mut stream, &template).is_err() {
            // The send never made it; do not leave it looking lost.
            outstanding
                .lock()
                .expect("outstanding map poisoned")
                .remove(&req_id);
            break;
        }
        sent_total.fetch_add(1, Ordering::Relaxed);
        seq += 1;
        let u = rng.next_f64();
        let gap = match cfg.dist {
            Dist::Poisson => -(1.0 - u).ln() * mean_gap,
            Dist::Uniform => u * 2.0 * mean_gap,
        };
        t_next += gap;
    }
}

fn receiver_loop(
    mut stream: WireStream,
    outstanding: &Mutex<HashMap<u64, Instant>>,
    done: &AtomicBool,
) -> (RequestStats, usize) {
    let mut stats = RequestStats::new();
    let mut buf = FrameBuf::new();
    let mut tmp = vec![0u8; 64 * 1024];
    let mut drain_deadline: Option<Instant> = None;
    loop {
        if done.load(Ordering::Acquire) {
            let dl = *drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_TIMEOUT);
            let empty = outstanding
                .lock()
                .expect("outstanding map poisoned")
                .is_empty();
            if empty || Instant::now() > dl {
                break;
            }
        }
        match frame::read_into(&mut stream, &mut buf, &mut tmp) {
            Ok(0) => break,
            Ok(_) => {
                loop {
                    match buf.next_response() {
                        Ok(Some(resp)) => account(&mut stats, &resp, outstanding),
                        Ok(None) => break,
                        Err(_) => return (stats, drain_outstanding(outstanding)),
                    }
                }
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
    (stats, drain_outstanding(outstanding))
}

fn drain_outstanding(outstanding: &Mutex<HashMap<u64, Instant>>) -> usize {
    let mut map = outstanding.lock().expect("outstanding map poisoned");
    let n = map.len();
    map.clear();
    n
}

fn account(
    stats: &mut RequestStats,
    resp: &Response,
    outstanding: &Mutex<HashMap<u64, Instant>>,
) {
    let sent_at = outstanding
        .lock()
        .expect("outstanding map poisoned")
        .remove(&resp.req_id);
    let Some(sent_at) = sent_at else { return };
    use crate::net::frame::Status;
    match resp.status {
        Status::Ok => stats.record(sent_at.elapsed().as_secs_f64(), resp.deadline_missed),
        Status::Shed => stats.shed += 1,
        Status::Expired => stats.deadline_misses += 1,
        Status::Error | Status::BadRequest => stats.failed += 1,
    }
}
