//! Wire protocol for the kernel-serving front-end (ISSUE 9; version
//! byte and the dist message frames that ride it: ISSUE 10).
//!
//! Every frame is a little-endian `u32` length prefix (bytes *after* the
//! prefix) followed by a 19-byte header and an f64 payload:
//!
//! ```text
//! request:  len:u32 | ver:u8 | req_id:u64 | op:u8 | flags:u8 | deadline_us:u32 | n:u32 | payload f64*
//! response: len:u32 | ver:u8 | req_id:u64 | status:u8 | flags:u8 | reserved:u32 | n:u32 | payload f64*
//! ```
//!
//! * `ver` is the protocol version ([`PROTO_VERSION`]).  The version
//!   byte and `req_id` sit at **fixed offsets in every version** — the
//!   forward-compat contract that lets a server decode enough of a
//!   foreign-version frame to answer [`Status::BadRequest`] (addressed
//!   by `req_id`) instead of silently desyncing on an unknown layout.
//!
//! * `op` selects the kernel ([`WireOp`]); `n` is the operand dimension
//!   (vector length / square-matrix edge).
//! * `deadline_us` is the request's wall-clock budget measured from
//!   server-side *decode* (0 = none): the server charges queueing in the
//!   coalescing window against it ([`crate::par::Policy::deadline_at`]).
//! * Response `flags` bit 0 = the request completed but *after* its
//!   deadline (a goodput miss, still carrying the payload).
//!
//! The second operand of every kernel is a **cached server-side operand**
//! derived deterministically from `(op, n)` via [`operand_seed`], so a
//! client can compute the bitwise-exact expected reply locally (the
//! loopback oracle in `tests/serve_wire.rs`) and the server amortizes one
//! operand (and for `MMult` one packed-B buffer) across every request of
//! that shape — the "one packed-operand pass" half of coalescing.
//!
//! Malformed frames (unknown op, dimension over the per-op cap, length
//! disagreeing with `payload_len(op, n)`, oversized prefix) decode to
//! [`FrameError`]; the server answers [`Status::BadRequest`] when the
//! header was readable and drops the connection either way — a framing
//! error leaves the byte stream unsynchronized.

/// Frame length cap (bytes after the prefix): rejects absurd prefixes
/// before any allocation happens.  Large enough for an `MMult` reply at
/// the dimension cap (512² doubles = 2 MiB) with room to spare.
pub const MAX_FRAME_LEN: u32 = 8 << 20;

/// Wire protocol version, the first body byte of every frame (serving
/// *and* dist).  Bumped on any layout change; a mismatch decodes to
/// [`FrameError::BadVersion`] and is answered `BadRequest`.
pub const PROTO_VERSION: u8 = 1;

/// Bytes in the fixed header after the length prefix (version byte
/// included).
pub const HDR_LEN: usize = 19;

/// The kernels the wire protocol serves — the same four the in-process
/// serving mix cycles through.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WireOp {
    /// `y = b_cached + 3.0 * x` (payload: x, reply: y; n doubles each).
    Daxpy,
    /// `y = x + b_cached` (payload: x, reply: y).
    VAdd,
    /// `y = A_cached · x` (payload: x of n, reply: y of n; A is n×n).
    MatVec,
    /// `C = A · B_cached` (payload: one double carrying the u64 seed A is
    /// generated from, reply: C of n²; packed-kernel path).
    MMult,
}

impl WireOp {
    pub const ALL: [WireOp; 4] = [WireOp::Daxpy, WireOp::VAdd, WireOp::MatVec, WireOp::MMult];

    pub const CHOICES: &[(&str, WireOp)] = &[
        ("daxpy", WireOp::Daxpy),
        ("vadd", WireOp::VAdd),
        ("matvec", WireOp::MatVec),
        ("mmult", WireOp::MMult),
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WireOp::Daxpy => "daxpy",
            WireOp::VAdd => "vadd",
            WireOp::MatVec => "matvec",
            WireOp::MMult => "mmult",
        }
    }

    pub fn code(&self) -> u8 {
        match self {
            WireOp::Daxpy => 0,
            WireOp::VAdd => 1,
            WireOp::MatVec => 2,
            WireOp::MMult => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(WireOp::Daxpy),
            1 => Some(WireOp::VAdd),
            2 => Some(WireOp::MatVec),
            3 => Some(WireOp::MMult),
            _ => None,
        }
    }

    /// Largest accepted dimension, per op: bounds both the decode
    /// allocation and the reply size (`MMult` replies are n²).
    pub fn max_n(&self) -> u32 {
        match self {
            WireOp::Daxpy | WireOp::VAdd => 1 << 20,
            WireOp::MatVec => 1 << 12,
            WireOp::MMult => 512,
        }
    }

    /// Request payload length in f64 elements for dimension `n`.
    pub fn payload_len(&self, n: u32) -> usize {
        match self {
            WireOp::Daxpy | WireOp::VAdd | WireOp::MatVec => n as usize,
            WireOp::MMult => 1,
        }
    }

    /// Reply payload length in f64 elements for dimension `n`.
    pub fn reply_len(&self, n: u32) -> usize {
        match self {
            WireOp::Daxpy | WireOp::VAdd | WireOp::MatVec => n as usize,
            WireOp::MMult => n as usize * n as usize,
        }
    }
}

/// Seed the server derives the cached second operand for `(op, n)` from —
/// shared with the client-side oracle so expected replies are computable
/// without a server round-trip.
pub fn operand_seed(op: WireOp, n: u32) -> u64 {
    0xC0FF_EE00_0000_0000 ^ ((op.code() as u64) << 32) ^ n as u64
}

/// Response status byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Computed; payload attached.
    Ok,
    /// Rejected by backpressure (admission headroom exhausted or the
    /// pending cap hit) — never computed, no payload.
    Shed,
    /// The frame decoded far enough to answer but was invalid.
    BadRequest,
    /// The batch died (injected fault / panic isolation) — no payload.
    Error,
    /// The request's deadline expired before (or while) computing and the
    /// server abandoned it — no payload.
    Expired,
}

impl Status {
    pub fn code(&self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Shed => 1,
            Status::BadRequest => 2,
            Status::Error => 3,
            Status::Expired => 4,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(Status::Ok),
            1 => Some(Status::Shed),
            2 => Some(Status::BadRequest),
            3 => Some(Status::Error),
            4 => Some(Status::Expired),
            _ => None,
        }
    }
}

/// One decoded kernel request.
#[derive(Clone, Debug)]
pub struct Request {
    pub req_id: u64,
    pub op: WireOp,
    /// Wall-clock budget in µs from server-side decode; 0 = none.
    pub deadline_us: u32,
    /// Operand dimension (vector length / matrix edge).
    pub n: u32,
    pub payload: Vec<f64>,
}

/// One response frame.
#[derive(Clone, Debug)]
pub struct Response {
    pub req_id: u64,
    pub status: Status,
    /// Completed, but after its deadline (goodput miss).
    pub deadline_missed: bool,
    pub n: u32,
    pub payload: Vec<f64>,
}

/// Why a frame failed to decode.  `req_id` is attached when the header
/// was readable, so the server can still address a `BadRequest` reply.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Length prefix exceeds [`MAX_FRAME_LEN`].
    Oversized { len: u32 },
    /// Frame shorter than the fixed header.
    Truncated,
    /// Unknown op code.
    BadOp { req_id: u64, code: u8 },
    /// Dimension 0 or over the per-op cap.
    BadDim { req_id: u64, n: u32 },
    /// Frame length disagrees with `payload_len(op, n)`.
    LengthMismatch { req_id: u64, expect: usize, got: usize },
    /// Unknown status code (client-side decode).
    BadStatus { req_id: u64, code: u8 },
    /// Version byte differs from [`PROTO_VERSION`].  `req_id` is still
    /// readable (fixed-offset contract), so the peer gets an addressed
    /// `BadRequest` instead of a silent desync.
    BadVersion { req_id: u64, got: u8 },
}

impl FrameError {
    /// The request id to address a `BadRequest` reply to, if the header
    /// got far enough to carry one.
    pub fn req_id(&self) -> Option<u64> {
        match *self {
            FrameError::Oversized { .. } | FrameError::Truncated => None,
            FrameError::BadOp { req_id, .. }
            | FrameError::BadDim { req_id, .. }
            | FrameError::LengthMismatch { req_id, .. }
            | FrameError::BadStatus { req_id, .. }
            | FrameError::BadVersion { req_id, .. } => Some(req_id),
        }
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversized { len } => write!(f, "frame length {len} over cap"),
            FrameError::Truncated => write!(f, "frame shorter than header"),
            FrameError::BadOp { code, .. } => write!(f, "unknown op code {code}"),
            FrameError::BadDim { n, .. } => write!(f, "dimension {n} out of range"),
            FrameError::LengthMismatch { expect, got, .. } => {
                write!(f, "payload length {got} != expected {expect}")
            }
            FrameError::BadStatus { code, .. } => write!(f, "unknown status code {code}"),
            FrameError::BadVersion { got, .. } => {
                write!(f, "protocol version {got} != {PROTO_VERSION}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Append `vals` little-endian — the payload codec shared by the
/// serving frames and the dist message frames.
pub(crate) fn put_f64s(out: &mut Vec<u8>, vals: &[f64]) {
    out.reserve(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Decode a little-endian f64 payload (trailing partial chunks are a
/// framing bug and are dropped by `chunks_exact`; decoders length-check
/// before calling).
pub(crate) fn get_f64s(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().expect("8-byte chunk")))
        .collect()
}

/// One read into `buf` through `scratch`, returning the byte count (0 =
/// EOF).  The single implementation behind every frame-reassembly read
/// loop (server shards, blocking client, loadgen receivers, dist links)
/// — previously copy-pasted per site.
pub fn read_into<R: std::io::Read>(
    stream: &mut R,
    buf: &mut FrameBuf,
    scratch: &mut [u8],
) -> std::io::Result<usize> {
    let k = stream.read(scratch)?;
    if k > 0 {
        buf.extend(&scratch[..k]);
    }
    Ok(k)
}

/// Write one already-encoded frame and push it to the wire (frames are
/// the flush granularity everywhere: replies, submits, completions).
pub fn write_frame<W: std::io::Write>(stream: &mut W, frame: &[u8]) -> std::io::Result<()> {
    stream.write_all(frame)?;
    stream.flush()
}

/// Encode a request into a fresh byte buffer (prefix included).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let body_len = HDR_LEN + req.payload.len() * 8;
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(PROTO_VERSION);
    out.extend_from_slice(&req.req_id.to_le_bytes());
    out.push(req.op.code());
    out.push(0); // request flags: reserved
    out.extend_from_slice(&req.deadline_us.to_le_bytes());
    out.extend_from_slice(&req.n.to_le_bytes());
    put_f64s(&mut out, &req.payload);
    out
}

/// Encode a response into a fresh byte buffer (prefix included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let body_len = HDR_LEN + resp.payload.len() * 8;
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(PROTO_VERSION);
    out.extend_from_slice(&resp.req_id.to_le_bytes());
    out.push(resp.status.code());
    out.push(resp.deadline_missed as u8);
    out.extend_from_slice(&0u32.to_le_bytes()); // reserved
    out.extend_from_slice(&resp.n.to_le_bytes());
    put_f64s(&mut out, &resp.payload);
    out
}

/// Byte offset of `req_id` within an encoded frame (after the length
/// prefix and version byte) — lets the load generator patch a
/// pre-encoded template per send instead of re-encoding the payload
/// every request.
pub const REQ_ID_OFFSET: usize = 5;

struct Header {
    req_id: u64,
    b0: u8,
    b1: u8,
    w0: u32,
    n: u32,
}

fn split_header(body: &[u8]) -> Result<(Header, &[u8]), FrameError> {
    if body.len() < HDR_LEN {
        return Err(FrameError::Truncated);
    }
    // req_id before the version check: both sit at fixed offsets in
    // every protocol version, so a mismatched frame is still addressable.
    let req_id = u64::from_le_bytes(body[1..9].try_into().expect("8 bytes"));
    if body[0] != PROTO_VERSION {
        return Err(FrameError::BadVersion {
            req_id,
            got: body[0],
        });
    }
    let hdr = Header {
        req_id,
        b0: body[9],
        b1: body[10],
        w0: u32::from_le_bytes(body[11..15].try_into().expect("4 bytes")),
        n: u32::from_le_bytes(body[15..19].try_into().expect("4 bytes")),
    };
    Ok((hdr, &body[HDR_LEN..]))
}

/// Decode one complete request frame body (the bytes after the length
/// prefix).
pub fn decode_request(body: &[u8]) -> Result<Request, FrameError> {
    let (h, payload) = split_header(body)?;
    let op = WireOp::from_code(h.b0).ok_or(FrameError::BadOp {
        req_id: h.req_id,
        code: h.b0,
    })?;
    if h.n == 0 || h.n > op.max_n() {
        return Err(FrameError::BadDim {
            req_id: h.req_id,
            n: h.n,
        });
    }
    let expect = op.payload_len(h.n) * 8;
    if payload.len() != expect {
        return Err(FrameError::LengthMismatch {
            req_id: h.req_id,
            expect,
            got: payload.len(),
        });
    }
    Ok(Request {
        req_id: h.req_id,
        op,
        deadline_us: h.w0,
        n: h.n,
        payload: get_f64s(payload),
    })
}

/// Decode one complete response frame body (client side).
pub fn decode_response(body: &[u8]) -> Result<Response, FrameError> {
    let (h, payload) = split_header(body)?;
    let status = Status::from_code(h.b0).ok_or(FrameError::BadStatus {
        req_id: h.req_id,
        code: h.b0,
    })?;
    Ok(Response {
        req_id: h.req_id,
        status,
        deadline_missed: h.b1 & 1 != 0,
        n: h.n,
        payload: get_f64s(payload),
    })
}

/// Incremental frame reassembly over a byte stream: feed reads in with
/// [`FrameBuf::extend`], pop complete frame bodies with
/// [`FrameBuf::next_body`].  A `FrameError` from the length prefix
/// (oversized) is sticky — the stream has lost sync and the connection
/// must be dropped.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`; compacted once it outgrows the tail.
    pos: usize,
}

impl FrameBuf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        // Compact before growing: keeps the buffer bounded by the frame
        // size rather than the connection's lifetime traffic.
        if self.pos > 0 && self.pos >= self.buf.len().saturating_sub(self.pos) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered but not yet consumed.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Pop the next complete frame body, `Ok(None)` when more bytes are
    /// needed.  The returned slice excludes the length prefix.
    pub fn next_body(&mut self) -> Result<Option<&[u8]>, FrameError> {
        let avail = &self.buf[self.pos..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(avail[0..4].try_into().expect("4 bytes"));
        if len > MAX_FRAME_LEN {
            return Err(FrameError::Oversized { len });
        }
        if (len as usize) < HDR_LEN {
            // Even an empty-payload frame carries the full header.
            return Err(FrameError::Truncated);
        }
        if avail.len() < 4 + len as usize {
            return Ok(None);
        }
        let start = self.pos + 4;
        self.pos = start + len as usize;
        Ok(Some(&self.buf[start..start + len as usize]))
    }

    /// Pop and decode the next complete request frame.
    pub fn next_request(&mut self) -> Result<Option<Request>, FrameError> {
        match self.next_body()? {
            None => Ok(None),
            Some(body) => decode_request(body).map(Some),
        }
    }

    /// Pop and decode the next complete response frame.
    pub fn next_response(&mut self) -> Result<Option<Response>, FrameError> {
        match self.next_body()? {
            None => Ok(None),
            Some(body) => decode_response(body).map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request(op: WireOp, n: u32) -> Request {
        Request {
            req_id: 0xDEAD_BEEF_0000_0001,
            op,
            deadline_us: 1500,
            n,
            payload: (0..op.payload_len(n)).map(|i| i as f64 * 0.5).collect(),
        }
    }

    #[test]
    fn request_roundtrip_every_op() {
        for op in WireOp::ALL {
            let req = sample_request(op, 8);
            let bytes = encode_request(&req);
            let mut fb = FrameBuf::new();
            fb.extend(&bytes);
            let got = fb.next_request().expect("decode").expect("complete");
            assert_eq!(got.req_id, req.req_id);
            assert_eq!(got.op, op);
            assert_eq!(got.deadline_us, 1500);
            assert_eq!(got.n, 8);
            assert_eq!(got.payload, req.payload);
            assert!(fb.next_request().expect("clean").is_none());
        }
    }

    #[test]
    fn response_roundtrip_with_miss_flag() {
        let resp = Response {
            req_id: 7,
            status: Status::Ok,
            deadline_missed: true,
            n: 3,
            payload: vec![1.0, 2.0, 3.0],
        };
        let mut fb = FrameBuf::new();
        fb.extend(&encode_response(&resp));
        let got = fb.next_response().expect("decode").expect("complete");
        assert_eq!(got.req_id, 7);
        assert_eq!(got.status, Status::Ok);
        assert!(got.deadline_missed);
        assert_eq!(got.payload, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn byte_at_a_time_reassembly() {
        let bytes = encode_request(&sample_request(WireOp::Daxpy, 4));
        let mut fb = FrameBuf::new();
        for (i, b) in bytes.iter().enumerate() {
            fb.extend(std::slice::from_ref(b));
            let r = fb.next_request().expect("no error mid-stream");
            assert_eq!(r.is_some(), i == bytes.len() - 1, "byte {i}");
        }
    }

    #[test]
    fn two_pipelined_frames_pop_in_order() {
        let mut a = sample_request(WireOp::VAdd, 4);
        a.req_id = 1;
        let mut b = sample_request(WireOp::MatVec, 4);
        b.req_id = 2;
        let mut fb = FrameBuf::new();
        fb.extend(&encode_request(&a));
        fb.extend(&encode_request(&b));
        assert_eq!(fb.next_request().unwrap().unwrap().req_id, 1);
        assert_eq!(fb.next_request().unwrap().unwrap().req_id, 2);
        assert!(fb.next_request().unwrap().is_none());
        assert_eq!(fb.pending(), 0);
    }

    #[test]
    fn malformed_frames_are_rejected() {
        // Unknown op.
        let mut bytes = encode_request(&sample_request(WireOp::Daxpy, 4));
        bytes[REQ_ID_OFFSET + 8] = 200;
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        assert!(matches!(
            fb.next_request(),
            Err(FrameError::BadOp { code: 200, .. })
        ));

        // Oversized length prefix: rejected before allocation.
        let mut fb = FrameBuf::new();
        fb.extend(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(matches!(fb.next_body(), Err(FrameError::Oversized { .. })));

        // Length prefix shorter than the header.
        let mut fb = FrameBuf::new();
        fb.extend(&4u32.to_le_bytes());
        fb.extend(&[0u8; 4]);
        assert!(matches!(fb.next_body(), Err(FrameError::Truncated)));

        // Dimension over the per-op cap.
        let mut req = sample_request(WireOp::MMult, 4);
        req.n = WireOp::MMult.max_n() + 1;
        let mut fb = FrameBuf::new();
        fb.extend(&encode_request(&req));
        assert!(matches!(fb.next_request(), Err(FrameError::BadDim { .. })));

        // Payload length disagreeing with (op, n).
        let mut req = sample_request(WireOp::Daxpy, 4);
        req.n = 5; // header says 5, payload carries 4
        let mut fb = FrameBuf::new();
        fb.extend(&encode_request(&req));
        let err = fb.next_request().unwrap_err();
        assert!(matches!(err, FrameError::LengthMismatch { .. }));
        assert_eq!(err.req_id(), Some(req.req_id));
    }

    #[test]
    fn version_mismatch_is_addressable_bad_version() {
        let req = sample_request(WireOp::Daxpy, 4);
        let mut bytes = encode_request(&req);
        bytes[4] = PROTO_VERSION + 1; // foreign version byte
        let mut fb = FrameBuf::new();
        fb.extend(&bytes);
        let err = fb.next_request().unwrap_err();
        assert!(matches!(err, FrameError::BadVersion { .. }));
        // The fixed-offset contract: the id survives the mismatch, so a
        // server can answer BadRequest instead of silently desyncing.
        assert_eq!(err.req_id(), Some(req.req_id));
    }

    #[test]
    fn operand_seed_distinguishes_ops_and_sizes() {
        let mut seen = std::collections::HashSet::new();
        for op in WireOp::ALL {
            for n in [4u32, 8, 64] {
                assert!(seen.insert(operand_seed(op, n)), "seed collision");
            }
        }
    }
}
