//! Task Bench pattern grid over the futurized engine (ISSUE 8 /
//! ROADMAP open item 5).
//!
//! *Quantifying Overheads in Charm++ and HPX using Task Bench* (PAPERS.md)
//! measures runtime overhead with one parameterized workload: a `steps ×
//! width` grid of tasks where task `(step, i)` depends on a
//! pattern-defined subset of row `step - 1`, each task doing a fixed
//! amount of busy work (the *grain*).  Sweeping the grain downward until
//! parallel efficiency collapses locates the **minimum effective task
//! granularity** (METG) — the smallest task the runtime can schedule
//! without its own overhead dominating.
//!
//! Here each grid row is a vector of [`Future<()>`]s and each task is a
//! `then` continuation hung off the [`when_all`] join of its
//! dependencies (single-dependency tasks skip the join and chain
//! directly) — so the benchmark exercises exactly the scheduler paths
//! ISSUE 8 optimizes: continuation dispatch (inlining), queue pressure
//! (steal-half batching), and victim choice (locality ordering).
//! Patterns:
//!
//! * `stencil` — `{i-1, i, i+1}` clamped at the edges (1-D halo exchange);
//! * `nearest` — `{i-2, i, i+2}` periodic;
//! * `fft`     — butterfly partner `i ^ (1 << (step mod log2 width))`;
//! * `spread`  — three parents spread `width/3` apart (all-to-all-ish);
//! * `random`  — three parents drawn from a PRNG seeded by `(step, i)`
//!   (deterministic across runs and processes).
//!
//! Wall time includes graph construction (the same convention as the
//! `chain_<len>` bench): METG charges the runtime for task *creation*,
//! dependence resolution, and scheduling, not just execution.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::amt::future::{when_all, Future, Promise};
use crate::amt::{PolicyKind, Scheduler, Tuning};
use crate::util::rng::Xoshiro256;
use crate::util::timing::spin_wait;

/// The five Task Bench dependency patterns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    Stencil,
    Nearest,
    Fft,
    Spread,
    Random,
}

impl Pattern {
    pub const ALL: [Pattern; 5] = [
        Pattern::Stencil,
        Pattern::Nearest,
        Pattern::Fft,
        Pattern::Spread,
        Pattern::Random,
    ];

    pub const CHOICES: &[(&str, Pattern)] = &[
        ("stencil", Pattern::Stencil),
        ("nearest", Pattern::Nearest),
        ("fft", Pattern::Fft),
        ("spread", Pattern::Spread),
        ("random", Pattern::Random),
    ];

    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        crate::util::cli::parse_choice("pattern", s, Self::CHOICES)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Pattern::Stencil => "stencil",
            Pattern::Nearest => "nearest",
            Pattern::Fft => "fft",
            Pattern::Spread => "spread",
            Pattern::Random => "random",
        }
    }

    /// Column indices in row `step - 1` that task `(step, i)` depends on,
    /// written into `out` (sorted, deduplicated; never empty for
    /// `width >= 1`).  Deterministic in all arguments — the `random`
    /// pattern derives its PRNG seed from `(step, i)`.
    pub fn deps(&self, step: usize, i: usize, width: usize, out: &mut Vec<usize>) {
        out.clear();
        match self {
            Pattern::Stencil => {
                if i > 0 {
                    out.push(i - 1);
                }
                out.push(i);
                if i + 1 < width {
                    out.push(i + 1);
                }
            }
            Pattern::Nearest => {
                out.push((i + width.saturating_sub(2 % width)) % width);
                out.push(i);
                out.push((i + 2) % width);
            }
            Pattern::Fft => {
                out.push(i);
                let log2w = width.next_power_of_two().trailing_zeros().max(1);
                let partner = (i ^ (1usize << (step as u32 % log2w))) % width;
                out.push(partner);
            }
            Pattern::Spread => {
                let stride = (width / 3).max(1);
                for j in 0..3 {
                    out.push((i + j * stride) % width);
                }
            }
            Pattern::Random => {
                let seed = 0x5eed_7a5c_b000_0000u64
                    ^ ((step as u64) << 24)
                    ^ (i as u64);
                let mut rng = Xoshiro256::seed_from_u64(seed);
                for _ in 0..3 {
                    out.push(rng.next_below(width));
                }
            }
        }
        out.sort_unstable();
        out.dedup();
    }
}

/// One Task Bench cell: a `steps × width` grid under one pattern, each
/// task spinning for `grain_us` of busy work.
#[derive(Clone, Copy, Debug)]
pub struct GraphCfg {
    pub pattern: Pattern,
    pub width: usize,
    pub steps: usize,
    pub grain_us: u64,
}

impl GraphCfg {
    pub fn tasks(&self) -> usize {
        self.width * self.steps
    }
}

/// Build and execute one dependency graph; returns end-to-end wall time
/// (construction + execution, the METG convention).
pub fn run_graph(sched: &Arc<Scheduler>, cfg: &GraphCfg) -> Duration {
    let grain = Duration::from_micros(cfg.grain_us);
    let work = move || {
        if !grain.is_zero() {
            spin_wait(grain);
        }
    };
    let t0 = Instant::now();
    let head = Promise::new();
    let mut row: Vec<Future<()>> = {
        let h = head.get_future();
        (0..cfg.width)
            .map(|_| h.then_named(sched, "taskbench", move |_| work()))
            .collect()
    };
    let mut deps = Vec::new();
    let mut joined = Vec::new();
    for step in 1..cfg.steps {
        let mut next: Vec<Future<()>> = Vec::with_capacity(cfg.width);
        for i in 0..cfg.width {
            cfg.pattern.deps(step, i, cfg.width, &mut deps);
            let f = if deps.len() == 1 {
                // Single dependency: chain directly, no join object.
                row[deps[0]].then_named(sched, "taskbench", move |_| work())
            } else {
                joined.clear();
                joined.extend(deps.iter().map(|&d| row[d].clone()));
                when_all(&joined).then_named(sched, "taskbench", move |_| work())
            };
            next.push(f);
        }
        row = next;
    }
    head.set_value(());
    when_all(&row).wait();
    t0.elapsed()
}

/// One measured sweep cell.
#[derive(Clone, Debug)]
pub struct TbRow {
    pub pattern: &'static str,
    pub policy: &'static str,
    pub threads: usize,
    pub grain_us: u64,
    /// Tuning label: `"steal-half"` (batching + inlining on) or
    /// `"steal-one"` (the classic single-steal, no-inline ablation arm).
    pub mode: &'static str,
    /// Wall microseconds per task — the METG-style overhead row (at
    /// grain 0 this is pure runtime overhead per task).
    pub us_per_task: f64,
    /// Parallel efficiency: useful work (`tasks × grain`) over burned
    /// core-time (`wall × min(threads, width)`).  0 at grain 0 by
    /// construction; METG is the smallest grain keeping this above 0.5.
    pub eff: f64,
    /// Solved minimum effective task granularity for this row's
    /// (pattern, policy, threads, tuning) combination — the smallest
    /// grain with `eff >=` [`METG_EFF_TARGET`], found by [`solve_metg`].
    /// `None` when the solver was skipped ([`SweepCfg::metg`] off) or
    /// efficiency never reached the target within the search ceiling.
    pub metg_us: Option<f64>,
}

/// Efficiency threshold defining METG (the Task Bench convention: the
/// smallest grain sustaining at least 50% parallel efficiency).
pub const METG_EFF_TARGET: f64 = 0.5;

/// Grain-axis search ceiling for [`solve_metg`], in microseconds.  A
/// runtime whose overhead still eats half of 1 ms tasks has no useful
/// METG to report.
pub const METG_MAX_GRAIN_US: u64 = 1024;

/// Measured parallel efficiency at one grain (best of `reps` runs).
fn eff_at(
    sched: &Arc<Scheduler>,
    pattern: Pattern,
    width: usize,
    steps: usize,
    threads: usize,
    grain_us: u64,
    reps: usize,
) -> f64 {
    if grain_us == 0 {
        return 0.0;
    }
    let g = GraphCfg { pattern, width, steps, grain_us };
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        best = best.min(run_graph(sched, &g).as_secs_f64());
    }
    let tasks = g.tasks() as f64;
    let cores = threads.min(width).max(1) as f64;
    (tasks * grain_us as f64) / (best * 1e6 * cores)
}

/// Automated METG solver (ISSUE 9): binary-search the grain axis for the
/// smallest integer grain whose parallel efficiency reaches
/// [`METG_EFF_TARGET`] on an already-constructed scheduler.
///
/// Strategy: probe upward by doubling from 1 us until the target is met
/// (giving a bracketing interval `(lo fails, hi passes]`), then bisect.
/// Efficiency is only statistically monotone in grain, so each probe
/// takes the best of `reps` runs to suppress noise; the result is a
/// measurement, not an exact root.  Returns `None` when even
/// `max_grain_us` cannot reach the target — overhead dominates the whole
/// searched axis.
pub fn solve_metg(
    sched: &Arc<Scheduler>,
    pattern: Pattern,
    width: usize,
    steps: usize,
    threads: usize,
    reps: usize,
    max_grain_us: u64,
) -> Option<f64> {
    let passes =
        |g: u64| eff_at(sched, pattern, width, steps, threads, g, reps) >= METG_EFF_TARGET;
    let mut lo = 0u64; // grain 0 has eff 0 by construction
    let mut hi = 1u64;
    if hi > max_grain_us {
        return None;
    }
    while !passes(hi) {
        lo = hi;
        hi = hi.saturating_mul(2);
        if hi > max_grain_us {
            return None;
        }
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if passes(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi as f64)
}

/// Full sweep grid for [`sweep`].
#[derive(Clone, Debug)]
pub struct SweepCfg {
    pub patterns: Vec<Pattern>,
    pub policies: Vec<PolicyKind>,
    pub threads: Vec<usize>,
    pub grains_us: Vec<u64>,
    pub width: usize,
    pub steps: usize,
    /// Timed repetitions per cell (one extra warm-up run is not counted);
    /// the best rep is reported, Blazemark-style.
    pub reps: usize,
    /// Tuning arms, each `(mode label, knobs)` — one scheduler per
    /// (threads, policy, arm), all cells of the pattern × grain grid
    /// reuse it.
    pub tunings: Vec<(&'static str, Tuning)>,
    /// Run [`solve_metg`] once per (threads, policy, tuning, pattern)
    /// combination and stamp the result on every row of that
    /// combination's grain sweep.
    pub metg: bool,
}

/// Run the whole pattern × policy × tuning × grain × threads grid.
pub fn sweep(cfg: &SweepCfg) -> Vec<TbRow> {
    let mut rows = Vec::new();
    for &t in &cfg.threads {
        for &policy in &cfg.policies {
            for &(mode, tuning) in &cfg.tunings {
                let sched = Scheduler::with_tuning(t, policy, tuning);
                for &pattern in &cfg.patterns {
                    let metg_us = if cfg.metg {
                        solve_metg(
                            &sched,
                            pattern,
                            cfg.width,
                            cfg.steps,
                            t,
                            cfg.reps,
                            METG_MAX_GRAIN_US,
                        )
                    } else {
                        None
                    };
                    for &grain_us in &cfg.grains_us {
                        let g = GraphCfg {
                            pattern,
                            width: cfg.width,
                            steps: cfg.steps,
                            grain_us,
                        };
                        run_graph(&sched, &g); // warm-up
                        let mut best = f64::INFINITY;
                        for _ in 0..cfg.reps.max(1) {
                            best = best.min(run_graph(&sched, &g).as_secs_f64());
                        }
                        let tasks = g.tasks() as f64;
                        let cores = t.min(cfg.width).max(1) as f64;
                        rows.push(TbRow {
                            pattern: pattern.name(),
                            policy: policy.name(),
                            threads: t,
                            grain_us,
                            mode,
                            us_per_task: best / tasks * 1e6,
                            eff: if grain_us == 0 {
                                0.0
                            } else {
                                (tasks * grain_us as f64) / (best * 1e6 * cores)
                            },
                            metg_us,
                        });
                    }
                }
                sched.shutdown();
            }
        }
    }
    rows
}

/// Render sweep rows as the aligned table both the CLI subcommand and the
/// ablation bench print.
pub fn render(rows: &[TbRow]) -> String {
    let mut out = format!(
        "{:<8} {:<18} {:>7} {:>8} {:<10} {:>12} {:>6} {:>8}\n",
        "pattern", "policy", "threads", "grain_us", "mode", "us/task", "eff", "metg_us"
    );
    for r in rows {
        let metg = match r.metg_us {
            Some(m) => format!("{m:.0}"),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<8} {:<18} {:>7} {:>8} {:<10} {:>12.3} {:>6.2} {:>8}\n",
            r.pattern, r.policy, r.threads, r.grain_us, r.mode, r.us_per_task, r.eff, metg
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deps_are_deterministic_sorted_and_in_range() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        for pattern in Pattern::ALL {
            for width in [1usize, 2, 3, 8, 64] {
                for step in 1..6 {
                    for i in 0..width {
                        pattern.deps(step, i, width, &mut a);
                        pattern.deps(step, i, width, &mut b);
                        assert_eq!(a, b, "{} must be deterministic", pattern.name());
                        assert!(!a.is_empty(), "{} empty deps", pattern.name());
                        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted+dedup: {a:?}");
                        assert!(a.iter().all(|&d| d < width), "range: {a:?} width {width}");
                    }
                }
            }
        }
    }

    #[test]
    fn stencil_clamps_at_edges() {
        let mut d = Vec::new();
        Pattern::Stencil.deps(1, 0, 8, &mut d);
        assert_eq!(d, vec![0, 1]);
        Pattern::Stencil.deps(1, 7, 8, &mut d);
        assert_eq!(d, vec![6, 7]);
        Pattern::Stencil.deps(1, 3, 8, &mut d);
        assert_eq!(d, vec![2, 3, 4]);
    }

    #[test]
    fn fft_partner_is_a_butterfly() {
        let mut d = Vec::new();
        Pattern::Fft.deps(1, 0, 8, &mut d); // step 1 -> bit 1 -> partner 2
        assert_eq!(d, vec![0, 2]);
        Pattern::Fft.deps(3, 0, 8, &mut d); // step 3 -> bit 0 -> partner 1
        assert_eq!(d, vec![0, 1]);
    }

    #[test]
    fn parse_names_roundtrip() {
        for p in Pattern::ALL {
            assert_eq!(Pattern::parse_or_list(p.name()), Ok(p));
        }
        assert!(Pattern::parse_or_list("nope").is_err());
    }

    #[test]
    fn metg_solver_brackets_and_bisects() {
        let sched = Scheduler::with_tuning(2, PolicyKind::PriorityLocal, Tuning::default());
        // A generous ceiling must find *some* grain on a tiny grid: at
        // 1 ms tasks the spin work dwarfs scheduling overhead.
        let m = solve_metg(&sched, Pattern::Stencil, 4, 3, 2, 1, METG_MAX_GRAIN_US);
        if let Some(m) = m {
            assert!(m >= 1.0 && m <= METG_MAX_GRAIN_US as f64, "metg {m}");
        }
        // A ceiling of 0 can never pass and must report None, not spin.
        assert_eq!(solve_metg(&sched, Pattern::Stencil, 4, 3, 2, 1, 0), None);
        sched.shutdown();
    }

    #[test]
    fn tiny_graph_runs_every_pattern() {
        let sched = Scheduler::with_tuning(2, PolicyKind::PriorityLocal, Tuning::default());
        for pattern in Pattern::ALL {
            let d = run_graph(
                &sched,
                &GraphCfg { pattern, width: 4, steps: 3, grain_us: 0 },
            );
            assert!(d > Duration::ZERO);
        }
        sched.shutdown();
    }
}
