//! Single-cell measurement: MFLOP/s of one (op, runtime, threads, size).
//!
//! Methodology mirrors Blazemark: operands initialized once, the operation
//! repeated in a steady-state loop, per-iteration median → MFLOP/s.

use crate::blaze::{self, DynMatrix, DynVector};
use crate::par::Policy;
use crate::util::cli;
use crate::util::timing::{bench, mflops, BenchCfg};

/// The Blazemark kernels: the paper's four figures plus the dense
/// matrix-vector product (`dmatdvecmult`, ISSUE 3) the suite was missing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    DVecDVecAdd,
    Daxpy,
    DMatDMatAdd,
    DMatDMatMult,
    DMatDVecMult,
}

impl Op {
    pub const ALL: [Op; 5] = [
        Op::DVecDVecAdd,
        Op::Daxpy,
        Op::DMatDMatAdd,
        Op::DMatDMatMult,
        Op::DMatDVecMult,
    ];

    /// Accepted spellings (canonical names first), resolved through the
    /// shared [`cli::lookup_choice`] selector helper.
    pub const CHOICES: &[(&str, Op)] = &[
        ("dvecdvecadd", Op::DVecDVecAdd),
        ("daxpy", Op::Daxpy),
        ("dmatdmatadd", Op::DMatDMatAdd),
        ("dmatdmatmult", Op::DMatDMatMult),
        ("dmatdvecmult", Op::DMatDVecMult),
        ("vadd", Op::DVecDVecAdd),
        ("madd", Op::DMatDMatAdd),
        ("matmul", Op::DMatDMatMult),
        ("mmult", Op::DMatDMatMult),
        ("matvec", Op::DMatDVecMult),
        ("mvmult", Op::DMatDVecMult),
    ];

    pub fn parse(s: &str) -> Option<Self> {
        cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for `--op`: unknown values report the valid set.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        cli::parse_choice("op", s, Self::CHOICES)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Op::DVecDVecAdd => "dvecdvecadd",
            Op::Daxpy => "daxpy",
            Op::DMatDMatAdd => "dmatdmatadd",
            Op::DMatDMatMult => "dmatdmatmult",
            Op::DMatDVecMult => "dmatdvecmult",
        }
    }

    /// Is `n` a vector length (true) or a square-matrix dimension (false)?
    pub fn is_vector(&self) -> bool {
        matches!(self, Op::DVecDVecAdd | Op::Daxpy)
    }

    /// Figure ids for this op: (heatmap, scaling).  Figs 2–9 are the
    /// paper's; `fig10`/`fig11` are this repo's extension ids for the
    /// matrix-vector kernel the paper omits.
    pub fn figures(&self) -> (&'static str, &'static str) {
        match self {
            Op::DVecDVecAdd => ("fig2", "fig6"),
            Op::Daxpy => ("fig3", "fig7"),
            Op::DMatDMatAdd => ("fig4", "fig8"),
            Op::DMatDMatMult => ("fig5", "fig9"),
            Op::DMatDVecMult => ("fig10", "fig11"),
        }
    }

    /// FLOPs of one invocation at size `n`.
    pub fn flops(&self, n: usize) -> f64 {
        match self {
            Op::DVecDVecAdd => blaze::ops::flops::dvecdvecadd(n),
            Op::Daxpy => blaze::ops::flops::daxpy(n),
            Op::DMatDMatAdd => blaze::ops::flops::dmatdmatadd(n),
            Op::DMatDMatMult => blaze::ops::flops::dmatdmatmult(n),
            Op::DMatDVecMult => blaze::ops::flops::dmatdvecmult(n),
        }
    }

    /// Default size grid for the heatmap sweep (geometric subset of the
    /// paper's arithmetic 1..10M progression, capped per op so a full
    /// 16-thread sweep stays tractable on the 1-core testbed).
    pub fn heatmap_sizes(&self) -> Vec<usize> {
        match self {
            Op::DVecDVecAdd | Op::Daxpy => {
                vec![10_000, 38_000, 65_536, 131_072, 262_144, 524_288, 1_048_576, 2_097_152]
            }
            Op::DMatDMatAdd => vec![64, 128, 190, 230, 300, 455, 700, 1000],
            Op::DMatDMatMult => vec![32, 55, 74, 113, 150, 230, 300, 400],
            Op::DMatDVecMult => vec![64, 128, 230, 330, 455, 700, 1000, 1400],
        }
    }

    /// Size grid for the scaling plots (Figs 6–9 x-axis).
    pub fn scaling_sizes(&self) -> Vec<usize> {
        match self {
            Op::DVecDVecAdd | Op::Daxpy => vec![
                1_000, 4_000, 10_000, 38_000, 100_000, 262_144, 524_288, 1_048_576, 2_097_152,
                4_194_304,
            ],
            Op::DMatDMatAdd => vec![16, 32, 64, 128, 190, 230, 300, 455, 700, 1000],
            Op::DMatDMatMult => vec![8, 16, 32, 55, 74, 113, 150, 230, 300, 400],
            Op::DMatDVecMult => vec![16, 64, 128, 230, 330, 455, 700, 1000, 1400, 2000],
        }
    }
}

/// Measure MFLOP/s of `op` at size `n` under execution policy `pol` —
/// the one measurement cell behind every figure.  The policy selects the
/// runtime *and* the execution model: `par().on(&hpx)` is the paper's
/// fork-join hpxMP cell, `par().on(&base)` its libomp comparator, and
/// `task().on(&hpx)` the futurized dataflow path (for a fair
/// execution-model comparison build the runtime with exactly
/// `pol.num_threads()` workers — the task graph parallelizes over every
/// scheduler worker, as `hpxmp dataflow` and `ablation_exec` both do).
pub fn measure(pol: &Policy<'_>, op: Op, n: usize, cfg: &BenchCfg) -> f64 {
    let summary = match op {
        Op::DVecDVecAdd => {
            let a = DynVector::random(n, 11);
            let b = DynVector::random(n, 12);
            let mut c = DynVector::zeros(n);
            bench(cfg, || blaze::dvecdvecadd(pol, &a, &b, &mut c))
        }
        Op::Daxpy => {
            let a = DynVector::random(n, 13);
            let mut b = DynVector::random(n, 14);
            bench(cfg, || blaze::daxpy(pol, 3.0, &a, &mut b))
        }
        Op::DMatDMatAdd => {
            let a = DynMatrix::random(n, n, 15);
            let b = DynMatrix::random(n, n, 16);
            let mut c = DynMatrix::zeros(n, n);
            bench(cfg, || blaze::dmatdmatadd(pol, &a, &b, &mut c))
        }
        Op::DMatDMatMult => {
            let a = DynMatrix::random(n, n, 17);
            let b = DynMatrix::random(n, n, 18);
            let mut c = DynMatrix::zeros(n, n);
            bench(cfg, || blaze::dmatdmatmult(pol, &a, &b, &mut c))
        }
        Op::DMatDVecMult => {
            let a = DynMatrix::random(n, n, 19);
            let x = DynVector::random(n, 20);
            let mut y = DynVector::zeros(n);
            bench(cfg, || blaze::dmatdvecmult(pol, &a, &x, &mut y))
        }
    };
    mflops(&summary, op.flops(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::exec::{seq, task};
    use crate::par::HpxMpRuntime;

    #[test]
    fn op_parse_roundtrip() {
        for op in Op::ALL {
            assert_eq!(Op::parse(op.name()), Some(op));
            assert_eq!(Op::parse_or_list(op.name()), Ok(op));
        }
        assert_eq!(Op::parse("matmul"), Some(Op::DMatDMatMult));
        assert_eq!(Op::parse("matvec"), Some(Op::DMatDVecMult));
        assert_eq!(Op::parse("nope"), None);
        let err = Op::parse_or_list("nope").unwrap_err();
        assert!(err.contains("dvecdvecadd"), "{err}");
    }

    #[test]
    fn measure_returns_positive_mflops() {
        let cfg = BenchCfg {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: std::time::Duration::from_micros(1),
        };
        for op in Op::ALL {
            let n = if op.is_vector() { 1024 } else { 32 };
            let m = measure(&seq(), op, n, &cfg);
            assert!(m > 0.0, "{}: {m}", op.name());
        }
    }

    #[test]
    fn measure_task_policy_returns_positive_mflops() {
        let cfg = BenchCfg {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: std::time::Duration::from_micros(1),
        };
        let hpx = HpxMpRuntime::new(crate::omp::OmpRuntime::for_tests(2));
        let pol = task().on(&hpx).threads(2);
        for op in Op::ALL {
            let n = if op.is_vector() { 65_536 } else { 64 };
            let m = measure(&pol, op, n, &cfg);
            assert!(m > 0.0, "{} under task(): {m}", op.name());
        }
    }

    #[test]
    fn size_grids_are_sorted_and_nonempty() {
        for op in Op::ALL {
            for grid in [op.heatmap_sizes(), op.scaling_sizes()] {
                assert!(!grid.is_empty());
                assert!(grid.windows(2).all(|w| w[0] < w[1]), "{}", op.name());
            }
        }
    }
}
