//! Sweeps: the paper's figures as data.
//!
//! * [`heatmap_sweep`] — Figs 2–5: ratio r = MFLOP/s(hpxMP)/MFLOP/s(OpenMP)
//!   over a (threads × size) grid.
//! * [`scaling_sweep`] — Figs 6–9: MFLOP/s vs size for both runtimes at a
//!   fixed thread count.

use crate::par::Policy;
use crate::util::heatmap::Heatmap;
use crate::util::timing::BenchCfg;

use super::blazemark::{measure, Op};

/// The full grid of one heatmap figure.
pub struct HeatmapResult {
    pub op: Op,
    pub threads: Vec<usize>,
    pub sizes: Vec<usize>,
    /// `ratio[t][s]` = hpxMP / baseline MFLOP/s.
    pub ratio: Vec<Vec<f64>>,
    pub hpx_mflops: Vec<Vec<f64>>,
    pub base_mflops: Vec<Vec<f64>>,
}

impl HeatmapResult {
    pub fn to_heatmap(&self) -> Heatmap {
        let mut h = Heatmap::new(
            self.threads.iter().map(|t| format!("{t}T")).collect(),
            self.sizes.iter().map(|s| s.to_string()).collect(),
        );
        for (ti, row) in self.ratio.iter().enumerate() {
            for (si, &v) in row.iter().enumerate() {
                h.set(ti, si, v);
            }
        }
        h
    }

    /// Mean ratio over cells at/above the parallelization threshold — the
    /// quantity the paper's prose summarizes ("between 0% and 30-40%
    /// slower").
    pub fn mean_ratio(&self) -> f64 {
        self.to_heatmap().mean()
    }
}

/// Run the (threads × sizes) ratio grid for `op`.  `hpx`/`base` are the
/// two execution policies being compared (per-cell the thread count is
/// overridden with [`Policy::threads`] — policies are `Copy`, so a grid
/// is just stamped-out copies of the same policy value).
pub fn heatmap_sweep(
    hpx: &Policy<'_>,
    base: &Policy<'_>,
    op: Op,
    threads: &[usize],
    sizes: &[usize],
    cfg: &BenchCfg,
    progress: bool,
) -> HeatmapResult {
    let mut ratio = vec![vec![f64::NAN; sizes.len()]; threads.len()];
    let mut hpx_m = vec![vec![f64::NAN; sizes.len()]; threads.len()];
    let mut base_m = vec![vec![f64::NAN; sizes.len()]; threads.len()];
    for (ti, &t) in threads.iter().enumerate() {
        for (si, &n) in sizes.iter().enumerate() {
            let h = measure(&hpx.threads(t), op, n, cfg);
            let b = measure(&base.threads(t), op, n, cfg);
            hpx_m[ti][si] = h;
            base_m[ti][si] = b;
            ratio[ti][si] = h / b;
            if progress {
                eprintln!(
                    "  {} threads={t:<2} n={n:<9} hpxMP={h:>10.1} base={b:>10.1} r={:.3}",
                    op.name(),
                    h / b
                );
            }
        }
    }
    HeatmapResult {
        op,
        threads: threads.to_vec(),
        sizes: sizes.to_vec(),
        ratio,
        hpx_mflops: hpx_m,
        base_mflops: base_m,
    }
}

/// One scaling series (Figs 6–9): MFLOP/s vs size at fixed thread count.
pub struct ScalingResult {
    pub op: Op,
    pub threads: usize,
    pub sizes: Vec<usize>,
    pub hpx_mflops: Vec<f64>,
    pub base_mflops: Vec<f64>,
}

pub fn scaling_sweep(
    hpx: &Policy<'_>,
    base: &Policy<'_>,
    op: Op,
    threads: usize,
    sizes: &[usize],
    cfg: &BenchCfg,
    progress: bool,
) -> ScalingResult {
    let mut hpx_m = Vec::with_capacity(sizes.len());
    let mut base_m = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let h = measure(&hpx.threads(threads), op, n, cfg);
        let b = measure(&base.threads(threads), op, n, cfg);
        if progress {
            eprintln!(
                "  {} threads={threads} n={n:<9} hpxMP={h:>10.1} base={b:>10.1}",
                op.name()
            );
        }
        hpx_m.push(h);
        base_m.push(b);
    }
    ScalingResult {
        op,
        threads,
        sizes: sizes.to_vec(),
        hpx_mflops: hpx_m,
        base_mflops: base_m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::seq;

    fn tiny_cfg() -> BenchCfg {
        BenchCfg {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 2,
            min_time: std::time::Duration::from_micros(1),
        }
    }

    #[test]
    fn heatmap_sweep_fills_grid() {
        let r = heatmap_sweep(
            &seq(),
            &seq(),
            Op::DVecDVecAdd,
            &[1, 2],
            &[512, 1024],
            &tiny_cfg(),
            false,
        );
        assert_eq!(r.ratio.len(), 2);
        assert_eq!(r.ratio[0].len(), 2);
        assert!(r.ratio.iter().flatten().all(|v| v.is_finite() && *v > 0.0));
        assert!(r.mean_ratio() > 0.0);
    }

    #[test]
    fn scaling_sweep_lengths_match() {
        let r = scaling_sweep(
            &seq(),
            &seq(),
            Op::Daxpy,
            1,
            &[256, 512, 1024],
            &tiny_cfg(),
            false,
        );
        assert_eq!(r.hpx_mflops.len(), 3);
        assert_eq!(r.base_mflops.len(), 3);
    }
}
