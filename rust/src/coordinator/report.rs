//! Report emission: CSV files + ASCII rendering under `results/`.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::csv::CsvWriter;

use super::sweep::{HeatmapResult, ScalingResult};

/// Write one heatmap figure: `results/<fig>_<op>_heatmap.csv` with rows
/// (threads, size, hpx_mflops, base_mflops, ratio), plus the ASCII render.
pub fn write_heatmap(dir: impl AsRef<Path>, r: &HeatmapResult) -> Result<String> {
    let (fig, _) = r.op.figures();
    let path = dir
        .as_ref()
        .join(format!("{fig}_{}_heatmap.csv", r.op.name()));
    let mut w = CsvWriter::create(&path)?;
    w.row(&["threads", "size", "hpx_mflops", "base_mflops", "ratio"])?;
    for (ti, &t) in r.threads.iter().enumerate() {
        for (si, &n) in r.sizes.iter().enumerate() {
            w.row(&[
                t.to_string(),
                n.to_string(),
                format!("{:.3}", r.hpx_mflops[ti][si]),
                format!("{:.3}", r.base_mflops[ti][si]),
                format!("{:.4}", r.ratio[ti][si]),
            ])?;
        }
    }
    w.flush()?;
    let title = format!(
        "{} — performance ratio hpxMP/OpenMP (paper {} analog); mean r = {:.3}",
        r.op.name(),
        fig,
        r.mean_ratio()
    );
    let art = r.to_heatmap().render(&title);
    Ok(format!("{art}\nwrote {}\n", path.display()))
}

/// Write one scaling series: `results/<fig>_<op>_scaling_<T>.csv` with rows
/// (size, hpx_mflops, base_mflops), plus a console summary.
pub fn write_scaling(dir: impl AsRef<Path>, r: &ScalingResult) -> Result<String> {
    let (_, fig) = r.op.figures();
    let path = dir.as_ref().join(format!(
        "{fig}_{}_scaling_{}t.csv",
        r.op.name(),
        r.threads
    ));
    let mut w = CsvWriter::create(&path)?;
    w.row(&["size", "hpx_mflops", "base_mflops"])?;
    let mut out = String::new();
    out.push_str(&format!(
        "{} scaling @{} threads (paper {} analog)\n{:>10} {:>14} {:>14} {:>8}\n",
        r.op.name(),
        r.threads,
        fig,
        "size",
        "hpxMP",
        "OpenMP",
        "ratio"
    ));
    for (i, &n) in r.sizes.iter().enumerate() {
        w.row(&[
            n.to_string(),
            format!("{:.3}", r.hpx_mflops[i]),
            format!("{:.3}", r.base_mflops[i]),
        ])?;
        out.push_str(&format!(
            "{:>10} {:>14.1} {:>14.1} {:>8.3}\n",
            n,
            r.hpx_mflops[i],
            r.base_mflops[i],
            r.hpx_mflops[i] / r.base_mflops[i]
        ));
    }
    w.flush()?;
    out.push_str(&format!("wrote {}\n", path.display()));
    Ok(out)
}

/// Render a per-slot count vector as a compact bracketed list, e.g.
/// `[12, 9, 14]`.  Used by `hpxmp serve --shards` to print per-shard
/// routing totals on its status line.
pub fn render_counts(counts: &[usize]) -> String {
    let mut s = String::from("[");
    for (i, c) in counts.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&c.to_string());
    }
    s.push(']');
    s
}

/// Append a named summary line to `results/summary.txt` (used by benches
/// so `cargo bench` leaves a machine-readable trail).
pub fn append_summary(dir: impl AsRef<Path>, line: &str) -> Result<()> {
    std::fs::create_dir_all(dir.as_ref())?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.as_ref().join("summary.txt"))?;
    writeln!(f, "{line}")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::blazemark::Op;

    #[test]
    fn heatmap_report_writes_csv_and_renders() {
        let dir = std::env::temp_dir().join("hpxmp_report_test");
        let r = HeatmapResult {
            op: Op::Daxpy,
            threads: vec![1, 2],
            sizes: vec![100, 200],
            ratio: vec![vec![1.0, 0.9], vec![0.8, 1.1]],
            hpx_mflops: vec![vec![10.0, 9.0], vec![8.0, 11.0]],
            base_mflops: vec![vec![10.0, 10.0], vec![10.0, 10.0]],
        };
        let out = write_heatmap(&dir, &r).unwrap();
        assert!(out.contains("daxpy"));
        let csv = std::fs::read_to_string(dir.join("fig3_daxpy_heatmap.csv")).unwrap();
        assert!(csv.starts_with("threads,size,"));
        assert_eq!(csv.lines().count(), 5); // header + 4 cells
    }

    #[test]
    fn counts_render_bracketed() {
        assert_eq!(render_counts(&[]), "[]");
        assert_eq!(render_counts(&[7]), "[7]");
        assert_eq!(render_counts(&[12, 9, 14]), "[12, 9, 14]");
    }

    #[test]
    fn scaling_report_writes_csv() {
        let dir = std::env::temp_dir().join("hpxmp_report_test2");
        let r = ScalingResult {
            op: Op::DMatDMatMult,
            threads: 8,
            sizes: vec![10, 20],
            hpx_mflops: vec![1.0, 2.0],
            base_mflops: vec![2.0, 2.0],
        };
        let out = write_scaling(&dir, &r).unwrap();
        assert!(out.contains("dmatdmatmult"));
        assert!(dir.join("fig9_dmatdmatmult_scaling_8t.csv").exists());
    }
}
