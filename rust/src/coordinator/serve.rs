//! The serving scenario (ISSUE 3): M client threads issuing streams of
//! mixed Blaze kernels against one runtime configuration.
//!
//! This is the paper's composition story made measurable: an application
//! with many concurrently-requesting threads calls into an
//! OpenMP-parallelized library.  With a **shared** hpxMP runtime every
//! client's `parallel` regions land on one AMT scheduler (the multi-tenant
//! team pool + admission of DESIGN.md §8 arbitrate); with the
//! **pool-per-client** baseline each client owns a private warm OS-thread
//! pool — the abstract's "competing threading systems", K·n OS threads
//! fighting over the same cores.
//!
//! [`serve_shared`] and [`serve_per_client`] drive the identical request
//! stream through both shapes and report requests/sec plus p50/p99
//! request latency; `hpxmp serve` and `benches/ablation_concurrent.rs`
//! are thin front-ends over this module.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::baseline::BaselineRuntime;
use crate::blaze::{self, DynMatrix, DynVector};
use crate::omp::OmpRuntime;
use crate::par::{ExecMode, Executor, HpxMpRuntime, Policy};
use crate::util::stats::percentile;

/// Which kernels a client's request stream cycles through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMix {
    /// daxpy + dvecdvecadd: short memory-bound requests.
    Vector,
    /// All four: daxpy, dvecdvecadd, dmatdvecmult, dmatdmatmult.
    Mixed,
}

#[derive(Clone, Copy)]
enum Kernel {
    Daxpy,
    VAdd,
    MatVec,
    MMult,
}

impl KernelMix {
    pub const ALL: [KernelMix; 2] = [KernelMix::Vector, KernelMix::Mixed];

    /// Accepted spellings, resolved through the shared
    /// [`crate::util::cli::lookup_choice`] selector helper.
    pub const CHOICES: &[(&str, KernelMix)] = &[
        ("vec", KernelMix::Vector),
        ("mixed", KernelMix::Mixed),
        ("vector", KernelMix::Vector),
        ("all", KernelMix::Mixed),
    ];

    pub fn parse(s: &str) -> Option<Self> {
        crate::util::cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for `--mix`: unknown values report the valid set.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        crate::util::cli::parse_choice("mix", s, Self::CHOICES)
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelMix::Vector => "vec",
            KernelMix::Mixed => "mixed",
        }
    }

    fn kernels(&self) -> &'static [Kernel] {
        match self {
            KernelMix::Vector => &[Kernel::Daxpy, Kernel::VAdd],
            KernelMix::Mixed => &[Kernel::Daxpy, Kernel::VAdd, Kernel::MatVec, Kernel::MMult],
        }
    }
}

/// One serving-run configuration.  Operand sizes default to just above
/// each kernel's Blaze parallelization threshold, so every request
/// actually exercises the fork/join path instead of the serial fallback.
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Concurrent client (application) threads.
    pub clients: usize,
    /// Requested team size per `parallel` region.
    pub threads: usize,
    /// Requests each client issues back-to-back.
    pub requests_per_client: usize,
    pub mix: KernelMix,
    /// Execution model every request runs under (the `--exec` selector):
    /// `Par` forks a team per request, `Task` runs each request as a
    /// futurized chunk/tile graph, `Seq` serializes (the degenerate
    /// floor).  Defaults to `Par` — the paper's serving regime.
    pub mode: ExecMode,
    /// daxpy / dvecdvecadd operand length (threshold 38 000).
    pub vec_len: usize,
    /// dmatdvecmult square dimension (row threshold 330).
    pub matvec_dim: usize,
    /// dmatdmatmult square dimension (element threshold 3 025 ≈ 55×55).
    pub mmult_dim: usize,
}

impl ServeCfg {
    pub fn new(clients: usize, threads: usize, requests_per_client: usize, mix: KernelMix) -> Self {
        Self {
            clients: clients.max(1),
            threads: threads.max(1),
            requests_per_client: requests_per_client.max(1),
            mix,
            mode: ExecMode::Par,
            vec_len: 50_000,
            matvec_dim: 400,
            mmult_dim: 64,
        }
    }
}

/// Aggregate result of one serving run.
#[derive(Clone, Copy, Debug)]
pub struct ServeStats {
    pub runtime: &'static str,
    pub mix: KernelMix,
    pub clients: usize,
    pub threads: usize,
    pub total_requests: usize,
    pub reqs_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

/// Serve the stream on **one shared hpxMP runtime**: every client's
/// regions contend for (and share) the same scheduler, team pool and
/// admission budget.
pub fn serve_shared(rt: &Arc<OmpRuntime>, cfg: &ServeCfg) -> ServeStats {
    let rts: Vec<Arc<dyn Executor>> = (0..cfg.clients)
        .map(|_| Arc::new(HpxMpRuntime::new(rt.clone())) as Arc<dyn Executor>)
        .collect();
    drive(cfg, "hpxmp-shared", rts)
}

/// Serve the stream with a **private warm OS-thread pool per client** —
/// the libomp-style configuration where K clients × n pool threads
/// oversubscribe the machine (the paper's competing-runtimes regime).
/// (`ExecMode::Task` degrades to eager execution here: the pool exposes
/// no AMT substrate.)
pub fn serve_per_client(cfg: &ServeCfg) -> ServeStats {
    let rts: Vec<Arc<dyn Executor>> = (0..cfg.clients)
        .map(|_| Arc::new(BaselineRuntime::new(cfg.threads)) as Arc<dyn Executor>)
        .collect();
    drive(cfg, "baseline-per-client", rts)
}

fn drive(cfg: &ServeCfg, runtime: &'static str, rts: Vec<Arc<dyn Executor>>) -> ServeStats {
    assert_eq!(rts.len(), cfg.clients);
    // clients + 1: the coordinator passes the barrier with the clients so
    // the wall clock starts when every client is warmed up and ready.
    let start = Arc::new(Barrier::new(cfg.clients + 1));
    let cfg = *cfg;
    let handles: Vec<_> = rts
        .into_iter()
        .enumerate()
        .map(|(ci, rt)| {
            let start = start.clone();
            std::thread::Builder::new()
                .name(format!("serve-client-{ci}"))
                .spawn(move || client_loop(ci, rt, &cfg, &start))
                .expect("spawn serve client")
        })
        .collect();
    start.wait();
    // Wall time spans the clients' own clocks (earliest start to latest
    // stop), not the coordinator's post-barrier wakeup — a descheduled
    // coordinator must not inflate reqs/sec.
    let mut latencies = Vec::with_capacity(cfg.clients * cfg.requests_per_client);
    let mut first_start: Option<Instant> = None;
    let mut last_stop: Option<Instant> = None;
    for h in handles {
        let (t_start, t_stop, lat) = h.join().expect("serve client panicked");
        first_start = Some(first_start.map_or(t_start, |f| f.min(t_start)));
        last_stop = Some(last_stop.map_or(t_stop, |l| l.max(t_stop)));
        latencies.extend(lat);
    }
    let wall = last_stop
        .unwrap()
        .duration_since(first_start.unwrap())
        .as_secs_f64()
        .max(1e-9);
    ServeStats {
        runtime,
        mix: cfg.mix,
        clients: cfg.clients,
        threads: cfg.threads,
        total_requests: latencies.len(),
        reqs_per_sec: latencies.len() as f64 / wall,
        p50_us: percentile(&latencies, 50.0) * 1e6,
        p99_us: percentile(&latencies, 99.0) * 1e6,
    }
}

/// One client: allocate operands once (outside the clock), then issue the
/// request stream, timing each request individually.  Returns this
/// client's (stream start, stream stop, per-request latencies).
fn client_loop(
    ci: usize,
    rt: Arc<dyn Executor>,
    cfg: &ServeCfg,
    start: &Barrier,
) -> (Instant, Instant, Vec<f64>) {
    let pol = Policy::with_mode(cfg.mode)
        .on(rt.as_ref())
        .threads(cfg.threads);
    let kernels = cfg.mix.kernels();
    let seed = ci as u64;
    let a = DynVector::random(cfg.vec_len, 100 + seed);
    let mut b = DynVector::random(cfg.vec_len, 200 + seed);
    let mut c = DynVector::zeros(cfg.vec_len);
    let mv_a = DynMatrix::random(cfg.matvec_dim, cfg.matvec_dim, 300 + seed);
    let mv_x = DynVector::random(cfg.matvec_dim, 400 + seed);
    let mut mv_y = DynVector::zeros(cfg.matvec_dim);
    let mm_a = DynMatrix::random(cfg.mmult_dim, cfg.mmult_dim, 500 + seed);
    let mm_b = DynMatrix::random(cfg.mmult_dim, cfg.mmult_dim, 600 + seed);
    let mut mm_c = DynMatrix::zeros(cfg.mmult_dim, cfg.mmult_dim);

    start.wait();
    let stream_start = Instant::now();
    let mut latencies = Vec::with_capacity(cfg.requests_per_client);
    for r in 0..cfg.requests_per_client {
        let kernel = kernels[(ci + r) % kernels.len()];
        let t0 = Instant::now();
        match kernel {
            Kernel::Daxpy => blaze::daxpy(&pol, 3.0, &a, &mut b),
            Kernel::VAdd => blaze::dvecdvecadd(&pol, &a, &b, &mut c),
            Kernel::MatVec => blaze::dmatdvecmult(&pol, &mv_a, &mv_x, &mut mv_y),
            Kernel::MMult => blaze::dmatdmatmult(&pol, &mm_a, &mm_b, &mut mm_c),
        }
        latencies.push(t0.elapsed().as_secs_f64());
    }
    (stream_start, Instant::now(), latencies)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(mix: KernelMix) -> ServeCfg {
        // Shrunken operands (below every threshold — serial bodies) keep
        // the functional test fast; the real benches use over-threshold
        // sizes.
        let mut cfg = ServeCfg::new(2, 2, 4, mix);
        cfg.vec_len = 1_000;
        cfg.matvec_dim = 32;
        cfg.mmult_dim = 16;
        cfg
    }

    #[test]
    fn shared_serving_counts_every_request() {
        let rt = OmpRuntime::for_tests(2);
        for mix in KernelMix::ALL {
            let stats = serve_shared(&rt, &tiny(mix));
            assert_eq!(stats.total_requests, 2 * 4, "mix {}", mix.name());
            assert!(stats.reqs_per_sec > 0.0);
            assert!(stats.p50_us > 0.0 && stats.p50_us <= stats.p99_us);
        }
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    }

    #[test]
    fn shared_serving_exercises_the_team_pool() {
        // Over-threshold vectors: every request forks a real region on the
        // shared runtime, so the team pool must see checkouts — and the
        // admission budget must read zero once all clients drained.
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = tiny(KernelMix::Vector);
        cfg.vec_len = 50_000;
        let stats = serve_shared(&rt, &cfg);
        assert_eq!(stats.total_requests, 2 * 4);
        assert!(
            rt.pool_hits() + rt.pool_misses() > 0,
            "no request reached the team pool"
        );
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    }

    #[test]
    fn per_client_serving_counts_every_request() {
        let stats = serve_per_client(&tiny(KernelMix::Mixed));
        assert_eq!(stats.total_requests, 2 * 4);
        assert!(stats.reqs_per_sec > 0.0);
        assert_eq!(stats.runtime, "baseline-per-client");
    }

    #[test]
    fn task_mode_serving_works_on_both_shapes() {
        // The --exec selector threaded into serving: every request runs
        // as a futurized chunk graph on the shared runtime, and degrades
        // to eager execution on the AMT-less per-client pools.
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = tiny(KernelMix::Mixed);
        cfg.mode = ExecMode::Task;
        cfg.vec_len = 50_000; // over-threshold: the task path actually runs
        let shared = serve_shared(&rt, &cfg);
        assert_eq!(shared.total_requests, 2 * 4);
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
        let per = serve_per_client(&cfg);
        assert_eq!(per.total_requests, 2 * 4);
    }

    #[test]
    fn mix_parse_roundtrip() {
        for mix in KernelMix::ALL {
            assert_eq!(KernelMix::parse(mix.name()), Some(mix));
        }
        assert_eq!(KernelMix::parse("all"), Some(KernelMix::Mixed));
        assert_eq!(KernelMix::parse("nope"), None);
    }
}
