//! The serving scenario (ISSUE 3): M client threads issuing streams of
//! mixed Blaze kernels against one runtime configuration.
//!
//! This is the paper's composition story made measurable: an application
//! with many concurrently-requesting threads calls into an
//! OpenMP-parallelized library.  With a **shared** hpxMP runtime every
//! client's `parallel` regions land on one AMT scheduler (the multi-tenant
//! team pool + admission of DESIGN.md §8 arbitrate); with the
//! **pool-per-client** baseline each client owns a private warm OS-thread
//! pool — the abstract's "competing threading systems", K·n OS threads
//! fighting over the same cores.
//!
//! [`serve_shared`] and [`serve_per_client`] drive the identical request
//! stream through both shapes and report requests/sec plus p50/p99
//! request latency; `hpxmp serve` and `benches/ablation_concurrent.rs`
//! are thin front-ends over this module.

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::baseline::BaselineRuntime;
use crate::blaze::{self, DynMatrix, DynVector};
use crate::omp::OmpRuntime;
use crate::par::{ExecMode, Executor, HpxMpRuntime, Policy};
use crate::util::stats::RequestStats;
use crate::util::timing::spin_wait;

/// Which kernels a client's request stream cycles through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMix {
    /// daxpy + dvecdvecadd: short memory-bound requests.
    Vector,
    /// All four: daxpy, dvecdvecadd, dmatdvecmult, dmatdmatmult.
    Mixed,
}

#[derive(Clone, Copy)]
enum Kernel {
    Daxpy,
    VAdd,
    MatVec,
    MMult,
}

impl KernelMix {
    pub const ALL: [KernelMix; 2] = [KernelMix::Vector, KernelMix::Mixed];

    /// Accepted spellings, resolved through the shared
    /// [`crate::util::cli::lookup_choice`] selector helper.
    pub const CHOICES: &[(&str, KernelMix)] = &[
        ("vec", KernelMix::Vector),
        ("mixed", KernelMix::Mixed),
        ("vector", KernelMix::Vector),
        ("all", KernelMix::Mixed),
    ];

    pub fn parse(s: &str) -> Option<Self> {
        crate::util::cli::lookup_choice(s, Self::CHOICES)
    }

    /// Strict parse for `--mix`: unknown values report the valid set.
    pub fn parse_or_list(s: &str) -> Result<Self, String> {
        crate::util::cli::parse_choice("mix", s, Self::CHOICES)
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelMix::Vector => "vec",
            KernelMix::Mixed => "mixed",
        }
    }

    fn kernels(&self) -> &'static [Kernel] {
        match self {
            KernelMix::Vector => &[Kernel::Daxpy, Kernel::VAdd],
            KernelMix::Mixed => &[Kernel::Daxpy, Kernel::VAdd, Kernel::MatVec, Kernel::MMult],
        }
    }
}

/// One serving-run configuration.  Operand sizes default to just above
/// each kernel's Blaze parallelization threshold, so every request
/// actually exercises the fork/join path instead of the serial fallback.
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Concurrent client (application) threads.
    pub clients: usize,
    /// Requested team size per `parallel` region.
    pub threads: usize,
    /// Requests each client issues back-to-back.
    pub requests_per_client: usize,
    pub mix: KernelMix,
    /// Execution model every request runs under (the `--exec` selector):
    /// `Par` forks a team per request, `Task` runs each request as a
    /// futurized chunk/tile graph, `Seq` serializes (the degenerate
    /// floor).  Defaults to `Par` — the paper's serving regime.
    pub mode: ExecMode,
    /// daxpy / dvecdvecadd operand length (threshold 38 000).
    pub vec_len: usize,
    /// dmatdvecmult square dimension (row threshold 330).
    pub matvec_dim: usize,
    /// dmatdmatmult square dimension (element threshold 3 025 ≈ 55×55).
    pub mmult_dim: usize,
    /// Per-request wall-clock deadline in microseconds (ISSUE 6).  When
    /// set, every request's [`Policy`] carries `.deadline(..)` — requests
    /// that blow the budget abandon their un-started chunks — and
    /// requests finishing late count as `deadline_misses` (excluded from
    /// goodput).  `None` disables deadline accounting entirely.
    pub deadline_us: Option<u64>,
    /// Deadline-aware load shedding: before submitting, a client consults
    /// [`Executor::overloaded`] (the admission budget's saturation gauge)
    /// and — after `retries` bounded backoff attempts — *rejects* the
    /// request outright instead of queueing it into certain deadline
    /// death.  Shed requests are counted, never timed.
    pub shed: bool,
    /// Backoff attempts before a shed (exponential spin: 50 µs, 100 µs,
    /// 200 µs, ... capped at 3.2 ms per attempt).
    pub retries: usize,
}

impl ServeCfg {
    pub fn new(clients: usize, threads: usize, requests_per_client: usize, mix: KernelMix) -> Self {
        Self {
            clients: clients.max(1),
            threads: threads.max(1),
            requests_per_client: requests_per_client.max(1),
            mix,
            mode: ExecMode::Par,
            vec_len: 50_000,
            matvec_dim: 400,
            mmult_dim: 64,
            deadline_us: None,
            shed: false,
            retries: 2,
        }
    }
}

/// Aggregate result of one serving run.
#[derive(Clone, Copy, Debug)]
pub struct ServeStats {
    pub runtime: &'static str,
    pub mix: KernelMix,
    pub clients: usize,
    pub threads: usize,
    /// Requests that actually executed (shed and crashed requests are
    /// accounted separately — a run where nothing completed reports 0).
    pub total_requests: usize,
    pub reqs_per_sec: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Client threads that panicked; their streams are charged to
    /// `failed_requests` and the survivors' results still aggregate
    /// (ISSUE 6 fault containment — one crashed client must not take the
    /// run down with it).
    pub failed_clients: usize,
    /// Requests lost to crashed clients (`requests_per_client` each).
    pub failed_requests: usize,
    /// Requests rejected by the load shedder (overloaded after retries).
    pub shed: usize,
    /// Backoff attempts taken across all clients before submit/shed.
    pub retries: usize,
    /// Completed requests that finished after their deadline.
    pub deadline_misses: usize,
    /// Requests completed *within* their deadline per wall second — the
    /// serving metric shedding is supposed to protect.  Equals
    /// `reqs_per_sec` when no deadline is configured.
    pub goodput_per_sec: f64,
}

/// What one client thread brings home (drive() aggregates these).  The
/// request accounting itself is the shared [`RequestStats`] accumulator —
/// the same one the wire front-end's load generator fills — so the
/// in-process and socket serving paths report identical row schemas.
struct ClientReport {
    start: Instant,
    stop: Instant,
    stats: RequestStats,
}

/// Serve the stream on **one shared hpxMP runtime**: every client's
/// regions contend for (and share) the same scheduler, team pool and
/// admission budget.
pub fn serve_shared(rt: &Arc<OmpRuntime>, cfg: &ServeCfg) -> ServeStats {
    let rts: Vec<Arc<dyn Executor>> = (0..cfg.clients)
        .map(|_| Arc::new(HpxMpRuntime::new(rt.clone())) as Arc<dyn Executor>)
        .collect();
    drive(cfg, "hpxmp-shared", rts)
}

/// Serve the stream with a **private warm OS-thread pool per client** —
/// the libomp-style configuration where K clients × n pool threads
/// oversubscribe the machine (the paper's competing-runtimes regime).
/// (`ExecMode::Task` degrades to eager execution here: the pool exposes
/// no AMT substrate.)
pub fn serve_per_client(cfg: &ServeCfg) -> ServeStats {
    let rts: Vec<Arc<dyn Executor>> = (0..cfg.clients)
        .map(|_| Arc::new(BaselineRuntime::new(cfg.threads)) as Arc<dyn Executor>)
        .collect();
    drive(cfg, "baseline-per-client", rts)
}

fn drive(cfg: &ServeCfg, runtime: &'static str, rts: Vec<Arc<dyn Executor>>) -> ServeStats {
    assert_eq!(rts.len(), cfg.clients);
    // clients + 1: the coordinator passes the barrier with the clients so
    // the wall clock starts when every client is warmed up and ready.
    let start = Arc::new(Barrier::new(cfg.clients + 1));
    let cfg = *cfg;
    let handles: Vec<_> = rts
        .into_iter()
        .enumerate()
        .map(|(ci, rt)| {
            let start = start.clone();
            std::thread::Builder::new()
                .name(format!("serve-client-{ci}"))
                .spawn(move || client_loop(ci, rt, &cfg, &start))
                .expect("spawn serve client")
        })
        .collect();
    start.wait();
    // Coordinator-side fallback clock: when *every* client crashed there
    // are no client-side timestamps, but the run still has a duration.
    let t_origin = Instant::now();
    // Wall time spans the clients' own clocks (earliest start to latest
    // stop), not the coordinator's post-barrier wakeup — a descheduled
    // coordinator must not inflate reqs/sec.
    let mut total = RequestStats::with_capacity(cfg.clients * cfg.requests_per_client);
    let mut first_start: Option<Instant> = None;
    let mut last_stop: Option<Instant> = None;
    let (mut failed_clients, mut failed_requests) = (0, 0);
    for h in handles {
        match h.join() {
            Ok(rep) => {
                first_start = Some(first_start.map_or(rep.start, |f| f.min(rep.start)));
                last_stop = Some(last_stop.map_or(rep.stop, |l| l.max(rep.stop)));
                total.merge(&rep.stats);
            }
            Err(_) => {
                // The client thread panicked mid-stream.  Its requests
                // are lost, but the run survives: charge the whole stream
                // as failed and keep aggregating the other clients.
                failed_clients += 1;
                failed_requests += cfg.requests_per_client;
            }
        }
    }
    let wall = match (first_start, last_stop) {
        (Some(f), Some(l)) => l.duration_since(f),
        _ => t_origin.elapsed(),
    }
    .as_secs_f64();
    ServeStats {
        runtime,
        mix: cfg.mix,
        clients: cfg.clients,
        threads: cfg.threads,
        total_requests: total.completed(),
        reqs_per_sec: total.reqs_per_sec(wall),
        p50_us: total.p50_us(),
        p99_us: total.p99_us(),
        failed_clients,
        failed_requests,
        shed: total.shed,
        retries: total.retries,
        deadline_misses: total.deadline_misses,
        goodput_per_sec: total.goodput_per_sec(wall),
    }
}

/// One client: allocate operands once (outside the clock), then issue the
/// request stream, timing each request individually.
///
/// With a deadline configured every request's policy carries it (late
/// requests abandon un-started chunks and count as misses); with `shed`
/// on, requests arriving while the executor is saturated back off
/// (bounded exponential spin) and are finally *rejected* rather than
/// queued — overload turns into explicit `Rejected` outcomes instead of
/// a latency collapse.
fn client_loop(ci: usize, rt: Arc<dyn Executor>, cfg: &ServeCfg, start: &Barrier) -> ClientReport {
    let mut pol = Policy::with_mode(cfg.mode)
        .on(rt.as_ref())
        .threads(cfg.threads);
    if let Some(d) = cfg.deadline_us {
        pol = pol.deadline(Duration::from_micros(d));
    }
    let kernels = cfg.mix.kernels();
    let seed = ci as u64;
    let a = DynVector::random(cfg.vec_len, 100 + seed);
    let mut b = DynVector::random(cfg.vec_len, 200 + seed);
    let mut c = DynVector::zeros(cfg.vec_len);
    let mv_a = DynMatrix::random(cfg.matvec_dim, cfg.matvec_dim, 300 + seed);
    let mv_x = DynVector::random(cfg.matvec_dim, 400 + seed);
    let mut mv_y = DynVector::zeros(cfg.matvec_dim);
    let mm_a = DynMatrix::random(cfg.mmult_dim, cfg.mmult_dim, 500 + seed);
    let mm_b = DynMatrix::random(cfg.mmult_dim, cfg.mmult_dim, 600 + seed);
    let mut mm_c = DynMatrix::zeros(cfg.mmult_dim, cfg.mmult_dim);

    start.wait();
    let stream_start = Instant::now();
    let mut rep = ClientReport {
        start: stream_start,
        stop: stream_start,
        stats: RequestStats::with_capacity(cfg.requests_per_client),
    };
    for r in 0..cfg.requests_per_client {
        if cfg.shed && rt.overloaded() {
            // Bounded backoff: give in-flight regions a chance to retire
            // before giving up on this request.
            let mut admitted = false;
            for attempt in 0..cfg.retries {
                spin_wait(Duration::from_micros(50 << attempt.min(6)));
                rep.stats.retries += 1;
                if !rt.overloaded() {
                    admitted = true;
                    break;
                }
            }
            if !admitted {
                rep.stats.shed += 1;
                continue;
            }
        }
        let kernel = kernels[(ci + r) % kernels.len()];
        let t0 = Instant::now();
        match kernel {
            Kernel::Daxpy => blaze::daxpy(&pol, 3.0, &a, &mut b),
            Kernel::VAdd => blaze::dvecdvecadd(&pol, &a, &b, &mut c),
            Kernel::MatVec => blaze::dmatdvecmult(&pol, &mv_a, &mv_x, &mut mv_y),
            Kernel::MMult => blaze::dmatdmatmult(&pol, &mm_a, &mm_b, &mut mm_c),
        }
        let elapsed = t0.elapsed();
        let missed = matches!(cfg.deadline_us, Some(d) if elapsed > Duration::from_micros(d));
        rep.stats.record(elapsed.as_secs_f64(), missed);
    }
    rep.stop = Instant::now();
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(mix: KernelMix) -> ServeCfg {
        // Shrunken operands (below every threshold — serial bodies) keep
        // the functional test fast; the real benches use over-threshold
        // sizes.
        let mut cfg = ServeCfg::new(2, 2, 4, mix);
        cfg.vec_len = 1_000;
        cfg.matvec_dim = 32;
        cfg.mmult_dim = 16;
        cfg
    }

    #[test]
    fn shared_serving_counts_every_request() {
        let rt = OmpRuntime::for_tests(2);
        for mix in KernelMix::ALL {
            let stats = serve_shared(&rt, &tiny(mix));
            assert_eq!(stats.total_requests, 2 * 4, "mix {}", mix.name());
            assert!(stats.reqs_per_sec > 0.0);
            assert!(stats.p50_us > 0.0 && stats.p50_us <= stats.p99_us);
        }
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    }

    #[test]
    fn shared_serving_exercises_the_team_pool() {
        // Over-threshold vectors: every request forks a real region on the
        // shared runtime, so the team pool must see checkouts — and the
        // admission budget must read zero once all clients drained.
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = tiny(KernelMix::Vector);
        cfg.vec_len = 50_000;
        let stats = serve_shared(&rt, &cfg);
        assert_eq!(stats.total_requests, 2 * 4);
        assert!(
            rt.pool_hits() + rt.pool_misses() > 0,
            "no request reached the team pool"
        );
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    }

    #[test]
    fn per_client_serving_counts_every_request() {
        let stats = serve_per_client(&tiny(KernelMix::Mixed));
        assert_eq!(stats.total_requests, 2 * 4);
        assert!(stats.reqs_per_sec > 0.0);
        assert_eq!(stats.runtime, "baseline-per-client");
    }

    #[test]
    fn task_mode_serving_works_on_both_shapes() {
        // The --exec selector threaded into serving: every request runs
        // as a futurized chunk graph on the shared runtime, and degrades
        // to eager execution on the AMT-less per-client pools.
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = tiny(KernelMix::Mixed);
        cfg.mode = ExecMode::Task;
        cfg.vec_len = 50_000; // over-threshold: the task path actually runs
        let shared = serve_shared(&rt, &cfg);
        assert_eq!(shared.total_requests, 2 * 4);
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
        let per = serve_per_client(&cfg);
        assert_eq!(per.total_requests, 2 * 4);
    }

    /// Executor whose every fork crashes — the hostile tenant the
    /// fault-containment satellite hardens `drive` against.
    struct PanickingExec;

    impl Executor for PanickingExec {
        fn name(&self) -> &'static str {
            "boom"
        }

        fn max_concurrency(&self) -> usize {
            4
        }

        fn bulk_sync(
            &self,
            _threads: usize,
            _range: std::ops::Range<i64>,
            _sched: crate::par::LoopSched,
            _body: &(dyn Fn(std::ops::Range<i64>) + Sync),
        ) {
            panic!("injected executor fault");
        }
    }

    /// Executor that reports permanent saturation (the admission budget
    /// pinned at its ceiling) but executes fine — isolates the shedder.
    struct SaturatedExec;

    impl Executor for SaturatedExec {
        fn name(&self) -> &'static str {
            "saturated"
        }

        fn max_concurrency(&self) -> usize {
            2
        }

        fn bulk_sync(
            &self,
            _threads: usize,
            range: std::ops::Range<i64>,
            _sched: crate::par::LoopSched,
            body: &(dyn Fn(std::ops::Range<i64>) + Sync),
        ) {
            body(range);
        }

        fn overloaded(&self) -> bool {
            true
        }
    }

    #[test]
    fn panicking_client_is_contained_and_survivors_aggregate() {
        // Client 0's executor blows up on its first over-threshold fork;
        // client 1 must still finish and the run must still report.
        let cfg = ServeCfg::new(2, 2, 4, KernelMix::Vector); // vec_len 50 000 > threshold
        let rts: Vec<Arc<dyn Executor>> = vec![
            Arc::new(PanickingExec),
            Arc::new(BaselineRuntime::new(2)) as Arc<dyn Executor>,
        ];
        let stats = drive(&cfg, "mixed-fates", rts);
        assert_eq!(stats.failed_clients, 1);
        assert_eq!(stats.failed_requests, 4, "crashed stream charged whole");
        assert_eq!(stats.total_requests, 4, "survivor's stream aggregated");
        assert!(stats.reqs_per_sec > 0.0);
    }

    #[test]
    fn all_clients_crashed_still_reports_without_hanging() {
        // Zero successful clients: no client-side clocks, no latencies —
        // the coordinator's fallback clock and empty-percentile guards
        // must carry the report.
        let cfg = ServeCfg::new(2, 2, 3, KernelMix::Vector);
        let rts: Vec<Arc<dyn Executor>> =
            vec![Arc::new(PanickingExec), Arc::new(PanickingExec)];
        let stats = drive(&cfg, "all-dead", rts);
        assert_eq!(stats.failed_clients, 2);
        assert_eq!(stats.failed_requests, 6);
        assert_eq!(stats.total_requests, 0);
        assert_eq!(stats.reqs_per_sec, 0.0);
        assert_eq!(stats.p50_us, 0.0);
        assert_eq!(stats.goodput_per_sec, 0.0);
    }

    #[test]
    fn overloaded_executor_sheds_after_bounded_retries() {
        // Permanently saturated + shedding on: every request backs off
        // `retries` times, then is rejected — never queued, never timed.
        let mut cfg = tiny(KernelMix::Vector);
        cfg.shed = true;
        cfg.retries = 1;
        let rts: Vec<Arc<dyn Executor>> =
            vec![Arc::new(SaturatedExec), Arc::new(SaturatedExec)];
        let stats = drive(&cfg, "shed-all", rts);
        assert_eq!(stats.shed, 2 * 4, "every request rejected");
        assert_eq!(stats.retries, 2 * 4, "one backoff attempt per request");
        assert_eq!(stats.total_requests, 0);
        assert_eq!(stats.goodput_per_sec, 0.0);
        assert_eq!(stats.failed_clients, 0, "shedding is not failure");
    }

    #[test]
    fn zero_deadline_counts_every_completion_as_miss() {
        // deadline_us = 0: nothing can finish in time, so goodput must
        // read zero while throughput still counts the completions.
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = tiny(KernelMix::Vector);
        cfg.deadline_us = Some(0);
        let stats = serve_shared(&rt, &cfg);
        assert_eq!(stats.total_requests, 2 * 4);
        assert_eq!(stats.deadline_misses, 2 * 4);
        assert_eq!(stats.goodput_per_sec, 0.0);
        assert!(stats.reqs_per_sec > 0.0);
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    }

    #[test]
    fn expired_deadline_abandons_chunks_in_real_serving() {
        // Over-threshold requests on the shared runtime with an already-
        // expired deadline: the policy's token fires at algorithm entry,
        // chunks are abandoned, and the stream still completes cleanly.
        let rt = OmpRuntime::for_tests(2);
        let mut cfg = tiny(KernelMix::Vector);
        cfg.vec_len = 50_000;
        cfg.deadline_us = Some(0);
        let stats = serve_shared(&rt, &cfg);
        assert_eq!(stats.total_requests, 2 * 4);
        assert_eq!(stats.deadline_misses, 2 * 4);
        assert_eq!(rt.reserved_workers(), 0, "admission budget leaked");
    }

    #[test]
    fn mix_parse_roundtrip() {
        for mix in KernelMix::ALL {
            assert_eq!(KernelMix::parse(mix.name()), Some(mix));
        }
        assert_eq!(KernelMix::parse("all"), Some(KernelMix::Mixed));
        assert_eq!(KernelMix::parse("nope"), None);
    }
}
