//! The Blazemark-style benchmark coordinator.
//!
//! Regenerates every figure of the paper's evaluation (§6):
//!
//! * [`blazemark`] — one-operation measurement (MFLOP/s under a runtime);
//! * [`sweep`] — threads×size ratio heatmaps (Figs 2–5) and per-thread
//!   scaling series (Figs 6–9);
//! * [`conformance`] — the Tables 1–3 feature inventory, verified live;
//! * [`serve`] — the multi-tenant serving scenario (ISSUE 3): M client
//!   threads × mixed kernels, shared runtime vs pool-per-client;
//! * [`taskbench`] — the Task Bench dependency-pattern grid (ISSUE 8):
//!   METG-style per-task overhead under stencil/nearest/fft/spread/random
//!   future graphs, the proof layer for the scheduler fast paths;
//! * [`report`] — CSV + ASCII emission under `results/`.

pub mod blazemark;
pub mod conformance;
pub mod report;
pub mod serve;
pub mod sweep;
pub mod taskbench;

pub use blazemark::{measure, Op};
pub use sweep::{heatmap_sweep, scaling_sweep, HeatmapResult, ScalingResult};
