//! Live conformance report for the paper's Tables 1–3: every directive,
//! runtime-library function, and OMPT callback the paper lists is
//! exercised against the hpxMP runtime and reported pass/fail.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::omp::api::*;
use crate::omp::sync::{critical, AtomicF64};
use crate::omp::team::{current_ctx, fork_call};
use crate::omp::{ompt, OmpRuntime};

/// One checked feature.
pub struct Check {
    pub table: &'static str,
    pub feature: &'static str,
    pub passed: bool,
    pub detail: String,
}

/// Run the full Tables 1–3 conformance suite against `rt`.
pub fn run_all(rt: &Arc<OmpRuntime>) -> Vec<Check> {
    let mut checks = Vec::new();
    let mut add = |table, feature, result: Result<(), String>| {
        checks.push(Check {
            table,
            feature,
            passed: result.is_ok(),
            detail: result.err().unwrap_or_default(),
        });
    };

    // --- Table 1: directives -------------------------------------------------
    add("T1", "#pragma omp parallel", check_parallel(rt));
    add("T1", "#pragma omp for", check_for(rt));
    add("T1", "#pragma omp barrier", check_barrier(rt));
    add("T1", "#pragma omp critical", check_critical(rt));
    add("T1", "#pragma omp atomic", check_atomic(rt));
    add("T1", "#pragma omp master", check_master(rt));
    add("T1", "#pragma omp single", check_single(rt));
    add("T1", "#pragma omp section", check_sections(rt));
    add("T1", "#pragma omp ordered", check_ordered(rt));
    add("T1", "#pragma omp task depend", check_task_depend(rt));

    // --- Table 2: runtime library functions ----------------------------------
    add("T2", "omp_get_thread_num/num_threads", check_thread_ids(rt));
    add("T2", "omp_get_max_threads/set_num_threads", {
        let saved = omp_get_max_threads();
        omp_set_num_threads(3);
        let r = if omp_get_max_threads() == 3 {
            Ok(())
        } else {
            Err("set/get mismatch".into())
        };
        omp_set_num_threads(saved);
        r
    });
    add("T2", "omp_in_parallel", check_in_parallel(rt));
    add(
        "T2",
        "omp_get_ancestor_thread_num/team_size",
        check_ancestors(rt),
    );
    add("T2", "omp_get_num_procs", ok_if(omp_get_num_procs() >= 1, "procs < 1"));
    add(
        "T2",
        "omp_get_wtime/wtick",
        ok_if(
            omp_get_wtime() >= 0.0 && omp_get_wtick() > 0.0,
            "non-positive timer",
        ),
    );
    add("T2", "omp_get_dynamic/set_dynamic", {
        let saved = omp_get_dynamic();
        omp_set_dynamic(true);
        let r = ok_if(omp_get_dynamic(), "set_dynamic(true) not visible");
        omp_set_dynamic(saved);
        r
    });
    add("T2", "omp_init/set/unset/test_lock", {
        let l = omp_init_lock();
        omp_set_lock(&l);
        let t1 = omp_test_lock(&l);
        omp_unset_lock(&l);
        let t2 = omp_test_lock(&l);
        if t2 {
            omp_unset_lock(&l);
        }
        ok_if(!t1 && t2, "lock test semantics wrong")
    });
    add("T2", "omp_init/set/unset/test_nest_lock", {
        let l = omp_init_nest_lock();
        omp_set_nest_lock(&l);
        let d = omp_test_nest_lock(&l);
        omp_unset_nest_lock(&l);
        omp_unset_nest_lock(&l);
        ok_if(d == 2, format!("nest depth {d} != 2"))
    });

    // --- Table 3: OMPT callbacks ----------------------------------------------
    add("T3", "ompt_callback_parallel_begin/end", check_ompt_parallel(rt));
    add("T3", "ompt_callback_implicit_task", check_ompt_implicit(rt));
    add("T3", "ompt_callback_task_create/schedule", check_ompt_task(rt));

    checks
}

/// Render the checks as the conformance report table.
pub fn render(checks: &[Check]) -> String {
    let mut out = String::new();
    out.push_str("conformance report (paper Tables 1-3)\n");
    let mut last = "";
    let mut pass = 0;
    for c in checks {
        if c.table != last {
            out.push_str(&format!("-- {} --\n", c.table));
            last = c.table;
        }
        out.push_str(&format!(
            "  [{}] {}{}\n",
            if c.passed { "PASS" } else { "FAIL" },
            c.feature,
            if c.detail.is_empty() {
                String::new()
            } else {
                format!("  ({})", c.detail)
            }
        ));
        pass += c.passed as usize;
    }
    out.push_str(&format!("{pass}/{} features pass\n", checks.len()));
    out
}

fn ok_if(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

fn check_parallel(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let n = Arc::new(AtomicUsize::new(0));
    let n2 = n.clone();
    fork_call(rt, Some(4), move |_| {
        n2.fetch_add(1, Ordering::SeqCst);
    });
    ok_if(n.load(Ordering::SeqCst) == 4, "wrong team size")
}

fn check_for(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let seen = Arc::new(Mutex::new(vec![0u32; 128]));
    let s = seen.clone();
    fork_call(rt, Some(4), move |ctx| {
        ctx.for_static(0..128, None, |i| {
            s.lock().unwrap()[i as usize] += 1;
        });
    });
    let ok = seen.lock().unwrap().iter().all(|&c| c == 1);
    ok_if(ok, "loop partition broken")
}

fn check_barrier(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let phase = Arc::new(AtomicUsize::new(0));
    let bad = Arc::new(AtomicUsize::new(0));
    let (p, b) = (phase.clone(), bad.clone());
    fork_call(rt, Some(4), move |ctx| {
        p.fetch_add(1, Ordering::SeqCst);
        ctx.barrier();
        if p.load(Ordering::SeqCst) != 4 {
            b.fetch_add(1, Ordering::SeqCst);
        }
    });
    ok_if(bad.load(Ordering::SeqCst) == 0, "barrier leaked")
}

fn check_critical(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let v = Arc::new(Mutex::new(0i64));
    let v2 = v.clone();
    fork_call(rt, Some(4), move |_| {
        for _ in 0..100 {
            critical("conf", || {
                *v2.lock().unwrap() += 1;
            });
        }
    });
    let ok = *v.lock().unwrap() == 400;
    ok_if(ok, "lost updates")
}

fn check_atomic(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let cell = Arc::new(AtomicF64::new(0.0));
    let c = cell.clone();
    fork_call(rt, Some(4), move |_| {
        for _ in 0..1000 {
            c.fetch_add(1.0);
        }
    });
    ok_if(cell.load() == 4000.0, format!("sum {}", cell.load()))
}

fn check_master(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let hits = Arc::new(AtomicUsize::new(0));
    let h = hits.clone();
    fork_call(rt, Some(4), move |ctx| {
        ctx.master(|| {
            h.fetch_add(1, Ordering::SeqCst);
        });
    });
    ok_if(hits.load(Ordering::SeqCst) == 1, "master ran != 1 times")
}

fn check_single(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let hits = Arc::new(AtomicUsize::new(0));
    let h = hits.clone();
    fork_call(rt, Some(4), move |ctx| {
        ctx.single(|| {
            h.fetch_add(1, Ordering::SeqCst);
        });
    });
    ok_if(hits.load(Ordering::SeqCst) == 1, "single ran != 1 times")
}

fn check_sections(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let hits = Arc::new(AtomicUsize::new(0));
    let h = hits.clone();
    fork_call(rt, Some(3), move |ctx| {
        let mut secs: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
        for _ in 0..5 {
            let h = h.clone();
            secs.push(Box::new(move || {
                h.fetch_add(1, Ordering::SeqCst);
            }));
        }
        ctx.sections(secs);
    });
    ok_if(hits.load(Ordering::SeqCst) == 5, "sections ran != 5")
}

fn check_ordered(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let order = Arc::new(Mutex::new(Vec::new()));
    let o = order.clone();
    fork_call(rt, Some(4), move |ctx| {
        let o = o.clone();
        ctx.for_ordered(0..32, |_| {}, move |i| o.lock().unwrap().push(i));
    });
    let ok = *order.lock().unwrap() == (0..32).collect::<Vec<_>>();
    ok_if(ok, "ordered out of order")
}

fn check_task_depend(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    use crate::omp::{Dep, DepKind};
    let trace = Arc::new(Mutex::new(Vec::new()));
    let t = trace.clone();
    fork_call(rt, Some(2), move |c| {
        if c.tid == 0 {
            let ctx = current_ctx().unwrap();
            for step in 0..6 {
                let t = t.clone();
                ctx.task_with_deps(&[Dep { addr: 0xA11CE, kind: DepKind::InOut }], move || {
                    t.lock().unwrap().push(step);
                });
            }
            ctx.taskwait();
        }
    });
    let ok = *trace.lock().unwrap() == (0..6).collect::<Vec<_>>();
    ok_if(ok, "dependence chain violated")
}

fn check_thread_ids(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let ids = Arc::new(Mutex::new(Vec::new()));
    let i2 = ids.clone();
    fork_call(rt, Some(4), move |_| {
        i2.lock()
            .unwrap()
            .push((omp_get_thread_num(), omp_get_num_threads()));
    });
    let mut got = ids.lock().unwrap().clone();
    got.sort();
    ok_if(
        got == (0..4).map(|i| (i, 4)).collect::<Vec<_>>(),
        format!("{got:?}"),
    )
}

fn check_in_parallel(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    if omp_in_parallel() {
        return Err("true outside region".into());
    }
    let ok = Arc::new(AtomicUsize::new(0));
    let o = ok.clone();
    fork_call(rt, Some(2), move |_| {
        if omp_in_parallel() {
            o.fetch_add(1, Ordering::SeqCst);
        }
    });
    ok_if(ok.load(Ordering::SeqCst) == 2, "false inside region")
}

fn check_ancestors(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let bad = Arc::new(AtomicUsize::new(0));
    let b = bad.clone();
    fork_call(rt, Some(2), move |ctx| {
        let ok = omp_get_ancestor_thread_num(0) == 0
            && omp_get_team_size(0) == 1
            && omp_get_ancestor_thread_num(1) == ctx.tid as isize
            && omp_get_team_size(1) == 2
            && omp_get_ancestor_thread_num(2) == -1
            && omp_get_team_size(2) == -1;
        if !ok {
            b.fetch_add(1, Ordering::SeqCst);
        }
    });
    ok_if(bad.load(Ordering::SeqCst) == 0, "ancestor introspection wrong")
}

fn check_ompt_parallel(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let begins = Arc::new(AtomicUsize::new(0));
    let ends = Arc::new(AtomicUsize::new(0));
    let (b, e) = (begins.clone(), ends.clone());
    rt.ompt
        .set_parallel_begin(Box::new(move |_pid, _size| {
            b.fetch_add(1, Ordering::SeqCst);
        }));
    rt.ompt.set_parallel_end(Box::new(move |_pid| {
        e.fetch_add(1, Ordering::SeqCst);
    }));
    fork_call(rt, Some(2), |_| {});
    ok_if(
        begins.load(Ordering::SeqCst) == 1 && ends.load(Ordering::SeqCst) == 1,
        "parallel callbacks not fired",
    )
}

fn check_ompt_implicit(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let begins = Arc::new(AtomicUsize::new(0));
    let b = begins.clone();
    rt.ompt
        .set_implicit_task(Box::new(move |ep, _pid, _size, _tid| {
            if ep == ompt::Endpoint::Begin {
                b.fetch_add(1, Ordering::SeqCst);
            }
        }));
    fork_call(rt, Some(3), |_| {});
    ok_if(
        begins.load(Ordering::SeqCst) == 3,
        format!("implicit begins {}", begins.load(Ordering::SeqCst)),
    )
}

fn check_ompt_task(rt: &Arc<OmpRuntime>) -> Result<(), String> {
    let created = Arc::new(AtomicUsize::new(0));
    let scheduled = Arc::new(AtomicUsize::new(0));
    let (c, s) = (created.clone(), scheduled.clone());
    rt.ompt.set_task_create(Box::new(move |_p, _c| {
        c.fetch_add(1, Ordering::SeqCst);
    }));
    rt.ompt.set_task_schedule(Box::new(move |_p, _st, _n| {
        s.fetch_add(1, Ordering::SeqCst);
    }));
    fork_call(rt, Some(2), |c| {
        if c.tid == 0 {
            let ctx = current_ctx().unwrap();
            for _ in 0..4 {
                ctx.task(|| {});
            }
            ctx.taskwait();
        }
    });
    ok_if(
        created.load(Ordering::SeqCst) == 4 && scheduled.load(Ordering::SeqCst) >= 4,
        "task callbacks not fired",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_conformance_suite_passes() {
        let rt = OmpRuntime::for_tests(4);
        let checks = run_all(&rt);
        let failed: Vec<_> = checks.iter().filter(|c| !c.passed).collect();
        assert!(
            failed.is_empty(),
            "failures: {:?}",
            failed
                .iter()
                .map(|c| format!("{}: {}", c.feature, c.detail))
                .collect::<Vec<_>>()
        );
        // All three tables represented.
        for t in ["T1", "T2", "T3"] {
            assert!(checks.iter().any(|c| c.table == t));
        }
    }

    #[test]
    fn render_contains_counts() {
        let rt = OmpRuntime::for_tests(2);
        let checks = run_all(&rt);
        let s = render(&checks);
        assert!(s.contains("T1"));
        assert!(s.contains("features pass"));
    }
}
