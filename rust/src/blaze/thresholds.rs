//! Blaze's parallelization thresholds (paper §6, per benchmark).
//!
//! Blaze gates parallel execution on the element count of the target:
//! below the threshold the operation runs single-threaded.  The paper
//! quotes, and we reproduce:
//!
//! * dvecdvecadd — 38 000 elements
//! * daxpy       — 38 000 elements
//! * dmatdmatadd — 36 100 elements (≈ 190 × 190)
//! * dmatdmatmult —  3 025 elements (≈ 55 × 55)

/// `BLAZE_DVECDVECADD_THRESHOLD`
pub const DVECDVECADD_THRESHOLD: usize = 38_000;

/// daxpy uses the same assignment threshold as dense vector addition.
pub const DAXPY_THRESHOLD: usize = 38_000;

/// `BLAZE_DMATDMATADD_THRESHOLD` (element count of the target matrix).
pub const DMATDMATADD_THRESHOLD: usize = 36_100;

/// `BLAZE_DMATDMATMULT_THRESHOLD` (element count of the target matrix).
pub const DMATDMATMULT_THRESHOLD: usize = 3_025;

/// `BLAZE_DMATDVECMULT_THRESHOLD` — Blaze 3.4 gates the dense
/// matrix/vector multiplication on the *row count* of the matrix (the
/// target vector's length), default 330.
pub const DMATDVECMULT_THRESHOLD: usize = 330;

/// Minimum dimension (all of m, k, n) at which [`crate::par::exec::KernelVariant::Auto`]
/// selects the packed cache-blocked `dmatdmatmult` kernel (ISSUE 7).
///
/// Below this floor Auto keeps the scalar row kernel, so every existing
/// bitwise oracle (which tests dimensions ≤ 130) is untouched by the
/// packed path's reassociated summation; above it the packing cost is
/// amortized and per-element accumulation happens in registers.
/// Explicitly requesting `KernelVariant::Packed` bypasses the floor.
pub const PACKED_MIN_DIM: usize = 256;

/// Serial→parallel crossover (element count of the target matrix) for
/// the **packed** `dmatdmatmult` path.  Higher than
/// [`DMATDMATMULT_THRESHOLD`]: the packed kernel's per-call fixed cost
/// (packing A/B panels into contiguous buffers) shifts the point where a
/// parallel tile graph beats one serial packed pass — below ≈128×128
/// the pack traffic dominates and the serial packed kernel wins.
pub const PACKED_DMATDMATMULT_THRESHOLD: usize = 16_384;

/// Would Blaze parallelize an operation on `elements` under `threshold`?
#[inline]
pub fn parallelize(elements: usize, threshold: usize) -> bool {
    elements >= threshold
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_match_paper_values() {
        assert_eq!(DVECDVECADD_THRESHOLD, 38_000);
        assert_eq!(DAXPY_THRESHOLD, 38_000);
        assert_eq!(DMATDMATADD_THRESHOLD, 36_100);
        assert_eq!(DMATDMATMULT_THRESHOLD, 3_025);
    }

    #[test]
    fn matrix_thresholds_correspond_to_paper_sizes() {
        // dmatdmatadd: 190x190 = 36100 is the first parallel size.
        assert!(parallelize(190 * 190, DMATDMATADD_THRESHOLD));
        assert!(!parallelize(189 * 189, DMATDMATADD_THRESHOLD));
        // dmatdmatmult: 55x55 = 3025.
        assert!(parallelize(55 * 55, DMATDMATMULT_THRESHOLD));
        assert!(!parallelize(54 * 54, DMATDMATMULT_THRESHOLD));
    }

    #[test]
    fn matvec_threshold_matches_blaze_default() {
        assert_eq!(DMATDVECMULT_THRESHOLD, 330);
        assert!(parallelize(330, DMATDVECMULT_THRESHOLD));
        assert!(!parallelize(329, DMATDVECMULT_THRESHOLD));
    }

    #[test]
    fn boundary_is_inclusive() {
        assert!(parallelize(38_000, DVECDVECADD_THRESHOLD));
        assert!(!parallelize(37_999, DVECDVECADD_THRESHOLD));
    }

    #[test]
    fn packed_floor_clears_every_bitwise_oracle_size() {
        // The repo's bitwise matmul oracles test dimensions up to 230
        // (BENCH_exec's largest mm size); the Auto→packed floor must sit
        // strictly above them so Auto never changes their numerics.
        assert!(PACKED_MIN_DIM > 230);
        // And the packed parallel crossover is above the scalar one —
        // packing adds per-call fixed cost.
        assert!(PACKED_DMATDMATMULT_THRESHOLD > DMATDMATMULT_THRESHOLD);
    }
}
