//! `DynVector` — Blaze's `DynamicVector<double>` analog.

use crate::par::exec::Policy;
use crate::util::rng::Xoshiro256;

/// A heap-allocated dense f64 vector.
#[derive(Clone, Debug, PartialEq)]
pub struct DynVector {
    data: Vec<f64>,
}

impl DynVector {
    pub fn zeros(n: usize) -> Self {
        Self { data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f64>) -> Self {
        Self { data }
    }

    /// Uniform random in [-1, 1) — Blazemark-style operand init.
    pub fn random(n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = vec![0.0; n];
        rng.fill_f64(&mut data);
        Self { data }
    }

    /// Zero vector with **first-touch placement** (ISSUE 7): pages are
    /// written block-by-block under `pol`, so each page lands on the
    /// node of the worker that first wrote it.  Contents are identical
    /// to [`Self::zeros`].
    pub fn zeros_first_touch(pol: &Policy<'_>, n: usize) -> Self {
        let mut data = vec![0.0; n];
        super::first_touch_fill(pol, &mut data, |_, block| block.fill(0.0));
        Self { data }
    }

    /// Seeded random vector with first-touch placement.  Each
    /// [`super::INIT_BLOCK`]-element block reseeds from `(seed, block)`
    /// — contents are a pure function of `(n, seed)`, bitwise identical
    /// across policies and thread counts (but a *different* stream than
    /// [`Self::random`]).
    pub fn random_first_touch(pol: &Policy<'_>, n: usize, seed: u64) -> Self {
        let mut data = vec![0.0; n];
        super::first_touch_fill(pol, &mut data, |b, block| {
            let mut rng =
                Xoshiro256::seed_from_u64(seed ^ (b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            rng.fill_f64(block);
        });
        Self { data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Max |a-b| against another vector (test comparisons).
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<usize> for DynVector {
    type Output = f64;

    fn index(&self, i: usize) -> &f64 {
        &self.data[i]
    }
}

impl std::ops::IndexMut<usize> for DynVector {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.data[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_index() {
        let mut v = DynVector::zeros(4);
        assert_eq!(v.len(), 4);
        assert_eq!(v[2], 0.0);
        v[2] = 5.0;
        assert_eq!(v[2], 5.0);
    }

    #[test]
    fn random_is_seeded_and_bounded() {
        let a = DynVector::random(100, 7);
        let b = DynVector::random(100, 7);
        assert_eq!(a, b);
        assert!(a.as_slice().iter().all(|&x| (-1.0..1.0).contains(&x)));
        let c = DynVector::random(100, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn first_touch_is_policy_independent() {
        use crate::baseline::BaselineRuntime;
        use crate::par::exec::{par, seq};
        let rt = BaselineRuntime::new(4);
        let n = 3 * super::super::INIT_BLOCK + 17; // several blocks, ragged tail
        let serial = DynVector::random_first_touch(&seq(), n, 5);
        let parallel = DynVector::random_first_touch(&par().on(&rt).threads(4), n, 5);
        assert_eq!(serial, parallel);
        assert!(serial.as_slice().iter().all(|&x| (-1.0..1.0).contains(&x)));
        let z = DynVector::zeros_first_touch(&par().on(&rt).threads(4), n);
        assert_eq!(z, DynVector::zeros(n));
    }

    #[test]
    fn max_abs_diff_works() {
        let a = DynVector::from_vec(vec![1.0, 2.0, 3.0]);
        let b = DynVector::from_vec(vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
