//! `DynVector` — Blaze's `DynamicVector<double>` analog.

use crate::util::rng::Xoshiro256;

/// A heap-allocated dense f64 vector.
#[derive(Clone, Debug, PartialEq)]
pub struct DynVector {
    data: Vec<f64>,
}

impl DynVector {
    pub fn zeros(n: usize) -> Self {
        Self { data: vec![0.0; n] }
    }

    pub fn from_vec(data: Vec<f64>) -> Self {
        Self { data }
    }

    /// Uniform random in [-1, 1) — Blazemark-style operand init.
    pub fn random(n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = vec![0.0; n];
        rng.fill_f64(&mut data);
        Self { data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Max |a-b| against another vector (test comparisons).
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<usize> for DynVector {
    type Output = f64;

    fn index(&self, i: usize) -> &f64 {
        &self.data[i]
    }
}

impl std::ops::IndexMut<usize> for DynVector {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.data[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_index() {
        let mut v = DynVector::zeros(4);
        assert_eq!(v.len(), 4);
        assert_eq!(v[2], 0.0);
        v[2] = 5.0;
        assert_eq!(v[2], 5.0);
    }

    #[test]
    fn random_is_seeded_and_bounded() {
        let a = DynVector::random(100, 7);
        let b = DynVector::random(100, 7);
        assert_eq!(a, b);
        assert!(a.as_slice().iter().all(|&x| (-1.0..1.0).contains(&x)));
        let c = DynVector::random(100, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = DynVector::from_vec(vec![1.0, 2.0, 3.0]);
        let b = DynVector::from_vec(vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
