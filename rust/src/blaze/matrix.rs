//! `DynMatrix` — Blaze's row-major `DynamicMatrix<double>` analog.

use crate::util::rng::Xoshiro256;

/// A heap-allocated dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DynMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DynMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = vec![0.0; rows * cols];
        rng.fill_f64(&mut data);
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn elements(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rowmajor_layout() {
        let m = DynMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn identity_diagonal() {
        let m = DynMatrix::identity(3);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m.at(i, j), if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn random_seeded() {
        let a = DynMatrix::random(4, 5, 1);
        let b = DynMatrix::random(4, 5, 1);
        assert_eq!(a, b);
        assert_eq!(a.elements(), 20);
    }
}
