//! `DynMatrix` — Blaze's row-major `DynamicMatrix<double>` analog.

use crate::par::exec::Policy;
use crate::util::rng::Xoshiro256;

/// A heap-allocated dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DynMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DynMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut data = vec![0.0; rows * cols];
        rng.fill_f64(&mut data);
        Self { rows, cols, data }
    }

    /// Zero matrix with **first-touch placement** (ISSUE 7): the
    /// backing pages are written block-by-block under `pol`, so on a
    /// NUMA system each page lands on the node of the worker that
    /// first wrote it — the same workers that will read it in a
    /// parallel kernel.  Contents are identical to [`Self::zeros`].
    pub fn zeros_first_touch(pol: &Policy<'_>, rows: usize, cols: usize) -> Self {
        let mut data = vec![0.0; rows * cols];
        super::first_touch_fill(pol, &mut data, |_, block| block.fill(0.0));
        Self { rows, cols, data }
    }

    /// Seeded random matrix with first-touch placement.  Each
    /// [`super::INIT_BLOCK`]-element block reseeds from `(seed, block)`,
    /// so the contents are a pure function of `(rows, cols, seed)` —
    /// bitwise identical across policies and thread counts (but a
    /// *different* stream than [`Self::random`], which draws one
    /// sequential stream).
    pub fn random_first_touch(pol: &Policy<'_>, rows: usize, cols: usize, seed: u64) -> Self {
        let mut data = vec![0.0; rows * cols];
        super::first_touch_fill(pol, &mut data, |b, block| {
            let mut rng =
                Xoshiro256::seed_from_u64(seed ^ (b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            rng.fill_f64(block);
        });
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn elements(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rowmajor_layout() {
        let m = DynMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn identity_diagonal() {
        let m = DynMatrix::identity(3);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m.at(i, j), if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn random_seeded() {
        let a = DynMatrix::random(4, 5, 1);
        let b = DynMatrix::random(4, 5, 1);
        assert_eq!(a, b);
        assert_eq!(a.elements(), 20);
    }

    #[test]
    fn first_touch_is_policy_independent() {
        use crate::baseline::BaselineRuntime;
        use crate::par::exec::{par, seq};
        let rt = BaselineRuntime::new(4);
        // Large enough for several INIT_BLOCK blocks, ragged tail.
        let (r, c) = (130usize, 101usize);
        let serial = DynMatrix::random_first_touch(&seq(), r, c, 9);
        let parallel = DynMatrix::random_first_touch(&par().on(&rt).threads(4), r, c, 9);
        assert_eq!(serial, parallel);
        assert!(serial
            .as_slice()
            .iter()
            .all(|&x| (-1.0..1.0).contains(&x)));
        let other = DynMatrix::random_first_touch(&seq(), r, c, 10);
        assert_ne!(serial, other);
        let z = DynMatrix::zeros_first_touch(&par().on(&rt).threads(4), r, c);
        assert_eq!(z, DynMatrix::zeros(r, c));
    }
}
