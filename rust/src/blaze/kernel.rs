//! The compute layer (ISSUE 7): explicitly unrolled micro-kernels and
//! the packed cache-blocked matmul behind [`KernelVariant`] dispatch.
//!
//! `serial.rs` keeps the straightforward scalar loops — they are the
//! oracle every test compares against.  This module adds the fast paths:
//!
//! * **Unrolled elementwise kernels** ([`vadd_unrolled`],
//!   [`daxpy_unrolled`]) — 4-wide via `chunks_exact` so bounds checks
//!   vanish from the inner loop.  Elementwise operations have one
//!   independent sum per output element, so these are **bitwise equal**
//!   to the scalar loops.
//! * **Accumulator-split matvec** ([`matvec_unrolled`]) — four partial
//!   dot-product accumulators folded as `(s0+s1)+(s2+s3)+tail`; this
//!   *reassociates* the sum, so it only runs when explicitly requested.
//! * **Packed cache-blocked matmul** ([`packed_matmul`],
//!   [`packed_band_mm`]) — a BLIS-style [`MR`]×[`NR`] register-blocked
//!   micro-kernel over panels packed into contiguous buffers
//!   ([`pack_a_band`] / [`pack_b_band`]), stepping the depth in [`KC`]
//!   strips.  Per output element the contributions accumulate in one
//!   register in strictly ascending `k`, so the packed result is a pure
//!   function of the operands — **bitwise identical across policies,
//!   tile sizes, and thread counts** (only *different from the scalar
//!   row kernel*, which streams C through memory per `k`).
//! * **FMA paths** behind the `simd` cargo feature
//!   (`#[target_feature(enable = "avx2,fma")]` + runtime CPUID
//!   detection, surfaced by [`simd_label`] in `hpxmp info`).  Fused
//!   multiply-add changes rounding, so FMA engages only for explicitly
//!   requested variants — never under [`KernelVariant::Auto`].
//!
//! Dispatch contract (the reason every pre-existing bitwise test stays
//! green): [`KernelVariant::Auto`] is numerics-preserving.  It unrolls
//! elementwise kernels (bitwise-equal), keeps the scalar matvec (the
//! split accumulator would reassociate), and selects the packed matmul
//! only when `min(m, k, n) ≥` [`PACKED_MIN_DIM`] — above every
//! dimension the repo's bitwise oracles exercise.  Resolution depends
//! only on `(variant, dimensions)`, never on the execution mode or
//! thread count.

use super::ops::SendPtr;
use super::serial;
use super::thresholds::PACKED_MIN_DIM;
use crate::par::exec::KernelVariant;

/// Rows of the register-blocked micro-tile.  4×4 f64 accumulators fit
/// the SSE2 register file (8 of 16 xmm) and map to four `__m256d` rows
/// under AVX2.
pub const MR: usize = 4;

/// Columns of the register-blocked micro-tile (one `__m256d` wide).
pub const NR: usize = 4;

/// Depth-strip length of the packed matmul: one A-sliver strip
/// (`MR·KC·8` = 8 KiB) plus one B-sliver strip stay L1-resident while
/// the micro-kernel sweeps them.
pub const KC: usize = 256;

/// Row-band height of the serial [`packed_matmul`] driver (and the
/// natural `.tile()` for the parallel paths): packs
/// `PACKED_ROW_BAND·k` doubles of A at a time.
pub const PACKED_ROW_BAND: usize = 64;

/// Was the `simd` cargo feature compiled into this build (on x86-64)?
pub fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Are the FMA fast paths usable *right now* — compiled in **and** the
/// CPU reports AVX2+FMA?  Detection runs once and is cached.
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        use once_cell::sync::Lazy;
        static AVX2_FMA: Lazy<bool> = Lazy::new(|| {
            std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
        });
        *AVX2_FMA
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// One-line SIMD status for `hpxmp info` and bench metadata.
pub fn simd_label() -> &'static str {
    if !simd_compiled() {
        "portable (simd feature not compiled)"
    } else if simd_active() {
        "avx2+fma (runtime-detected)"
    } else {
        "portable (simd compiled, cpu lacks avx2+fma)"
    }
}

/// `c[i] = a[i] + b[i]`, explicitly 4-wide.  Bitwise equal to
/// [`serial::vadd_slice`] (independent per-element sums).
pub fn vadd_unrolled(a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    let mut cc = c.chunks_exact_mut(4);
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for ((cv, av), bv) in (&mut cc).zip(&mut ca).zip(&mut cb) {
        cv[0] = av[0] + bv[0];
        cv[1] = av[1] + bv[1];
        cv[2] = av[2] + bv[2];
        cv[3] = av[3] + bv[3];
    }
    for ((ci, ai), bi) in cc
        .into_remainder()
        .iter_mut()
        .zip(ca.remainder())
        .zip(cb.remainder())
    {
        *ci = *ai + *bi;
    }
}

/// `b[i] += beta * a[i]`, explicitly 4-wide.  Bitwise equal to
/// [`serial::daxpy_slice`] (separate multiply and add per element — the
/// FMA variant lives in the feature-gated module).
pub fn daxpy_unrolled(beta: f64, a: &[f64], b: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    let mut cb = b.chunks_exact_mut(4);
    let mut ca = a.chunks_exact(4);
    for (bv, av) in (&mut cb).zip(&mut ca) {
        bv[0] += beta * av[0];
        bv[1] += beta * av[1];
        bv[2] += beta * av[2];
        bv[3] += beta * av[3];
    }
    for (bi, ai) in cb.into_remainder().iter_mut().zip(ca.remainder()) {
        *bi += beta * *ai;
    }
}

/// Row band of `y = A * x` with 4-way accumulator splitting: four
/// partial sums folded as `(s0+s1)+(s2+s3)+tail`.  **Reassociates** the
/// dot product relative to [`serial::matvec_rows`] — tolerance-checked
/// against the oracle, never selected by `Auto`.
pub fn matvec_unrolled(a: &[f64], x: &[f64], y: &mut [f64]) {
    let n = x.len();
    debug_assert_eq!(a.len(), y.len() * n);
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * n..(i + 1) * n];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
        let mut cr = row.chunks_exact(4);
        let mut cx = x.chunks_exact(4);
        for (rv, xv) in (&mut cr).zip(&mut cx) {
            s0 += rv[0] * xv[0];
            s1 += rv[1] * xv[1];
            s2 += rv[2] * xv[2];
            s3 += rv[3] * xv[3];
        }
        let mut tail = 0.0;
        for (aij, xj) in cr.remainder().iter().zip(cx.remainder()) {
            tail += *aij * *xj;
        }
        *yi = (s0 + s1) + (s2 + s3) + tail;
    }
}

/// The FMA fast paths — compiled only with the `simd` cargo feature on
/// x86-64, and only *called* after [`simd_active`] confirmed AVX2+FMA
/// at runtime.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::{MR, NR};
    use std::arch::x86_64::*;

    /// `b[i] = fma(beta, a[i], b[i])` — fused rounding, so numerically
    /// different from the scalar loop.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA via [`super::simd_active`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn daxpy_fma(beta: f64, a: &[f64], b: &mut [f64]) {
        let vb = _mm256_set1_pd(beta);
        let mut ca = a.chunks_exact(4);
        let mut cb = b.chunks_exact_mut(4);
        for (av, bv) in (&mut ca).zip(&mut cb) {
            let r = _mm256_fmadd_pd(vb, _mm256_loadu_pd(av.as_ptr()), _mm256_loadu_pd(bv.as_ptr()));
            _mm256_storeu_pd(bv.as_mut_ptr(), r);
        }
        for (ai, bi) in ca.remainder().iter().zip(cb.into_remainder()) {
            *bi = beta.mul_add(*ai, *bi);
        }
    }

    /// Row band of `y = A * x` with one `__m256d` accumulator per row
    /// (4-way lane split + horizontal fold) and fused multiply-adds.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA via [`super::simd_active`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn matvec_fma(a: &[f64], x: &[f64], y: &mut [f64]) {
        let n = x.len();
        debug_assert_eq!(a.len(), y.len() * n);
        for (i, yi) in y.iter_mut().enumerate() {
            let row = &a[i * n..(i + 1) * n];
            let mut acc = _mm256_setzero_pd();
            let mut cr = row.chunks_exact(4);
            let mut cx = x.chunks_exact(4);
            for (rv, xv) in (&mut cr).zip(&mut cx) {
                acc = _mm256_fmadd_pd(
                    _mm256_loadu_pd(rv.as_ptr()),
                    _mm256_loadu_pd(xv.as_ptr()),
                    acc,
                );
            }
            let lo = _mm256_castpd256_pd128(acc);
            let hi = _mm256_extractf128_pd(acc, 1);
            let pair = _mm_add_pd(lo, hi);
            let mut sum = _mm_cvtsd_f64(_mm_add_sd(pair, _mm_unpackhi_pd(pair, pair)));
            for (aij, xj) in cr.remainder().iter().zip(cx.remainder()) {
                sum = aij.mul_add(*xj, sum);
            }
            *yi = sum;
        }
    }

    /// The [`MR`]×[`NR`] micro-kernel over one depth strip, four
    /// `__m256d` row accumulators: `acc[r] = fma(broadcast(a[r]), b, acc[r])`
    /// per `kk`.  Same ascending-`kk` per-lane accumulation as the
    /// scalar micro-kernel (decomposition-independent), fused rounding.
    ///
    /// # Safety
    /// Caller must have verified AVX2+FMA via [`super::simd_active`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn microkernel_fma(ap: &[f64], bp: &[f64], acc: &mut [[f64; NR]; MR]) {
        let mut c0 = _mm256_loadu_pd(acc[0].as_ptr());
        let mut c1 = _mm256_loadu_pd(acc[1].as_ptr());
        let mut c2 = _mm256_loadu_pd(acc[2].as_ptr());
        let mut c3 = _mm256_loadu_pd(acc[3].as_ptr());
        for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
            let b = _mm256_loadu_pd(bv.as_ptr());
            c0 = _mm256_fmadd_pd(_mm256_set1_pd(av[0]), b, c0);
            c1 = _mm256_fmadd_pd(_mm256_set1_pd(av[1]), b, c1);
            c2 = _mm256_fmadd_pd(_mm256_set1_pd(av[2]), b, c2);
            c3 = _mm256_fmadd_pd(_mm256_set1_pd(av[3]), b, c3);
        }
        _mm256_storeu_pd(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_pd(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_pd(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_pd(acc[3].as_mut_ptr(), c3);
    }
}

/// `c = a + b` under `variant` — the elementwise dispatch behind
/// `dvecdvecadd` and `dmatdmatadd`.  Every variant is bitwise equal
/// (independent per-element sums); `Scalar` pins the oracle loop.
pub fn vadd(variant: KernelVariant, a: &[f64], b: &[f64], c: &mut [f64]) {
    match variant {
        KernelVariant::Scalar => serial::vadd_slice(a, b, c),
        _ => vadd_unrolled(a, b, c),
    }
}

/// `b += beta * a` under `variant`.  `Auto` unrolls without FMA
/// (bitwise equal to scalar); `Unrolled`/`Packed` opt into the fused
/// FMA path when compiled and detected.
pub fn daxpy(variant: KernelVariant, beta: f64, a: &[f64], b: &mut [f64]) {
    match variant {
        KernelVariant::Scalar => serial::daxpy_slice(beta, a, b),
        KernelVariant::Auto => daxpy_unrolled(beta, a, b),
        KernelVariant::Unrolled | KernelVariant::Packed => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            {
                if simd_active() {
                    // SAFETY: AVX2+FMA confirmed by simd_active().
                    unsafe { x86::daxpy_fma(beta, a, b) };
                    return;
                }
            }
            daxpy_unrolled(beta, a, b)
        }
    }
}

/// Row band of `C = A + B` under `variant` (flat slices — elementwise,
/// same dispatch as [`vadd`]).
pub fn madd(variant: KernelVariant, a: &[f64], b: &[f64], c: &mut [f64]) {
    vadd(variant, a, b, c);
}

/// Row band of `y = A * x` under `variant`.  `Auto` keeps the scalar
/// single-accumulator loop (splitting would reassociate the dot
/// product); `Unrolled`/`Packed` opt into the split/FMA paths.
pub fn matvec(variant: KernelVariant, a: &[f64], x: &[f64], y: &mut [f64]) {
    match variant {
        KernelVariant::Scalar | KernelVariant::Auto => serial::matvec_rows(a, x, y),
        KernelVariant::Unrolled | KernelVariant::Packed => {
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            {
                if simd_active() {
                    // SAFETY: AVX2+FMA confirmed by simd_active().
                    unsafe { x86::matvec_fma(a, x, y) };
                    return;
                }
            }
            matvec_unrolled(a, x, y)
        }
    }
}

/// Does `variant` select the packed matmul at these dimensions?
/// `Packed` always; `Auto` only when every dimension clears
/// [`PACKED_MIN_DIM`] (the numerics-preserving floor — see the module
/// doc); `Scalar`/`Unrolled` keep the row kernel.
pub fn matmul_uses_packed(variant: KernelVariant, m: usize, k: usize, n: usize) -> bool {
    match variant {
        KernelVariant::Packed => true,
        KernelVariant::Auto => m.min(k).min(n) >= PACKED_MIN_DIM,
        KernelVariant::Scalar | KernelVariant::Unrolled => false,
    }
}

/// Packed-buffer length for a band of `rows` rows at depth `k`: row
/// panels are padded up to a multiple of [`MR`].
pub fn packed_a_len(rows: usize, k: usize) -> usize {
    rows.div_ceil(MR) * MR * k
}

/// Packed-buffer length for a band of `cols` columns at depth `k`:
/// column panels are padded up to a multiple of [`NR`].
pub fn packed_b_len(k: usize, cols: usize) -> usize {
    cols.div_ceil(NR) * NR * k
}

/// Pack rows `i0..i1` of row-major `a` (`lda = k`) into `buf`:
/// panel-major, each panel [`MR`] rows stored as ascending-`kk` slivers
/// (`buf[p·MR·k + kk·MR + r]`), rows past `i1` zero-padded.  A depth
/// strip of a panel is then the contiguous range `kk0·MR..kk1·MR`.
pub fn pack_a_band(a: &[f64], k: usize, i0: usize, i1: usize, buf: &mut [f64]) {
    let rows = i1 - i0;
    let panels = rows.div_ceil(MR);
    debug_assert!(a.len() >= i1 * k);
    debug_assert_eq!(buf.len(), panels * MR * k);
    for p in 0..panels {
        let pbuf = &mut buf[p * MR * k..(p + 1) * MR * k];
        for r in 0..MR {
            let i = i0 + p * MR + r;
            if i < i1 {
                for (kk, &v) in a[i * k..(i + 1) * k].iter().enumerate() {
                    pbuf[kk * MR + r] = v;
                }
            } else {
                for kk in 0..k {
                    pbuf[kk * MR + r] = 0.0;
                }
            }
        }
    }
}

/// Pack columns `j0..j1` of row-major `b` (`k × n`) into `buf`:
/// panel-major, each panel [`NR`] columns stored as ascending-`kk`
/// slivers (`buf[q·NR·k + kk·NR + c]`), columns past `j1` zero-padded.
pub fn pack_b_band(b: &[f64], k: usize, n: usize, j0: usize, j1: usize, buf: &mut [f64]) {
    let cols = j1 - j0;
    let panels = cols.div_ceil(NR);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(buf.len(), panels * NR * k);
    for q in 0..panels {
        let qbuf = &mut buf[q * NR * k..(q + 1) * NR * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            for c in 0..NR {
                let j = j0 + q * NR + c;
                qbuf[kk * NR + c] = if j < j1 { brow[j] } else { 0.0 };
            }
        }
    }
}

/// Scalar [`MR`]×[`NR`] micro-kernel over one depth strip: 16
/// independent register accumulators, `kk` ascending.  `chunks_exact`
/// keeps bounds checks out of the loop.
#[inline]
fn microkernel_scalar(ap: &[f64], bp: &[f64], acc: &mut [[f64; NR]; MR]) {
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for r in 0..MR {
            let ar = av[r];
            for c in 0..NR {
                acc[r][c] += ar * bv[c];
            }
        }
    }
}

/// Micro-kernel dispatch: FMA when compiled + detected, scalar
/// otherwise.  Both accumulate per output lane in ascending `kk`, so
/// either way the packed product is decomposition-independent.
#[inline]
fn microkernel(ap: &[f64], bp: &[f64], acc: &mut [[f64; NR]; MR]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if simd_active() {
            // SAFETY: AVX2+FMA confirmed by simd_active().
            unsafe { x86::microkernel_fma(ap, bp, acc) };
            return;
        }
    }
    microkernel_scalar(ap, bp, acc);
}

/// The panel sweep shared by [`packed_band_mm`] and
/// [`packed_band_mm_ptr`]: identical arithmetic, store abstracted so
/// the two entry points differ only in how a finished accumulator row
/// reaches C.  `store(row_in_band, col_in_band, values)` receives the
/// valid (unpadded) corner of each accumulator row.
#[inline]
fn packed_band_mm_core(
    a_pack: &[f64],
    band_rows: usize,
    b_pack: &[f64],
    band_cols: usize,
    k: usize,
    mut store: impl FnMut(usize, usize, &[f64]),
) {
    let a_panels = band_rows.div_ceil(MR);
    let b_panels = band_cols.div_ceil(NR);
    debug_assert_eq!(a_pack.len(), a_panels * MR * k);
    debug_assert_eq!(b_pack.len(), b_panels * NR * k);
    for p in 0..a_panels {
        let ap_full = &a_pack[p * MR * k..(p + 1) * MR * k];
        let rmax = (band_rows - p * MR).min(MR);
        for q in 0..b_panels {
            let bq_full = &b_pack[q * NR * k..(q + 1) * NR * k];
            let cmax = (band_cols - q * NR).min(NR);
            let mut acc = [[0.0f64; NR]; MR];
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + KC).min(k);
                microkernel(
                    &ap_full[k0 * MR..k1 * MR],
                    &bq_full[k0 * NR..k1 * NR],
                    &mut acc,
                );
                k0 = k1;
            }
            for (r, acc_row) in acc.iter().enumerate().take(rmax) {
                store(p * MR + r, q * NR, &acc_row[..cmax]);
            }
        }
    }
}

/// Multiply one packed A band (`band_rows × k`, [`pack_a_band`] layout)
/// by one packed B band (`k × band_cols`, [`pack_b_band`] layout) into
/// the C rectangle at column offset `j_off` of the `band_rows × ldc`
/// row-major slice `c` (overwrite, not accumulate — matching the `=`
/// semantics of every Blaze kernel here).
///
/// Per (row panel, column panel) pair the [`MR`]×[`NR`] accumulator
/// block is register-resident across the whole depth, stepped in [`KC`]
/// strips; only the valid `rmax × cmax` corner is stored for edge
/// panels, so zero-padding never leaks into C.
#[allow(clippy::too_many_arguments)]
pub fn packed_band_mm(
    a_pack: &[f64],
    band_rows: usize,
    b_pack: &[f64],
    band_cols: usize,
    k: usize,
    c: &mut [f64],
    ldc: usize,
    j_off: usize,
) {
    debug_assert!(band_rows == 0 || c.len() >= (band_rows - 1) * ldc + j_off + band_cols);
    packed_band_mm_core(a_pack, band_rows, b_pack, band_cols, k, |row, col, vals| {
        let base = row * ldc + j_off + col;
        c[base..base + vals.len()].copy_from_slice(vals);
    });
}

/// [`packed_band_mm`] storing through a raw [`SendPtr`] base instead of
/// a borrowed C band: the C rectangle starts at row `row_off`, column
/// `j_off` of the `ldc`-pitch row-major matrix behind `c`.  Only the
/// disjoint per-row segments actually written are ever materialized as
/// `&mut` — so concurrent tile tasks whose rectangles partition C can
/// each call this against the same base pointer without two overlapping
/// exclusive slices ever being live at once (unlike slicing out the
/// whole row band, which aliases across the band's column tiles).
/// Arithmetic is [`packed_band_mm_core`], i.e. bitwise identical to
/// [`packed_band_mm`].
///
/// # Safety
/// For every `r in 0..band_rows`, the segment
/// `(row_off + r) * ldc + j_off .. + band_cols` must lie within the
/// allocation behind `c`, and no other thread may access any of those
/// segments concurrently (callers partition C into disjoint rectangles
/// and order reads after this write via their task graph / join).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn packed_band_mm_ptr(
    a_pack: &[f64],
    band_rows: usize,
    b_pack: &[f64],
    band_cols: usize,
    k: usize,
    c: SendPtr,
    ldc: usize,
    row_off: usize,
    j_off: usize,
) {
    packed_band_mm_core(a_pack, band_rows, b_pack, band_cols, k, |row, col, vals| {
        let base = (row_off + row) * ldc + j_off + col;
        // SAFETY: in-bounds and exclusive per the function contract;
        // this `&mut` covers only this tile's `vals.len()`-element row
        // segment and dies before the next store.
        let seg = unsafe { c.slice_range(base, base + vals.len()) };
        seg.copy_from_slice(vals);
    });
}

/// Serial whole-matrix packed product `C = A·B` (`m × k` times
/// `k × n`): B is packed once, A in [`PACKED_ROW_BAND`]-row bands, each
/// band driven through [`packed_band_mm`].  The serial spelling of the
/// same arithmetic the parallel paths decompose — bitwise identical to
/// them for any decomposition.
pub fn packed_matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let mut b_pack = vec![0.0f64; packed_b_len(k, n)];
    pack_b_band(b, k, n, 0, n, &mut b_pack);
    let band = PACKED_ROW_BAND.min(m);
    let mut a_pack = vec![0.0f64; packed_a_len(band, k)];
    for i0 in (0..m).step_by(PACKED_ROW_BAND) {
        let i1 = (i0 + PACKED_ROW_BAND).min(m);
        let len = packed_a_len(i1 - i0, k);
        pack_a_band(a, k, i0, i1, &mut a_pack[..len]);
        packed_band_mm(
            &a_pack[..len],
            i1 - i0,
            &b_pack,
            n,
            k,
            &mut c[i0 * n..i1 * n],
            n,
            0,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut v = vec![0.0; n];
        rng.fill_f64(&mut v);
        v
    }

    fn naive_mm(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn unrolled_elementwise_kernels_are_bitwise_equal_to_scalar() {
        // Lengths straddling the 4-wide chunk boundary.
        for n in [0usize, 1, 3, 4, 5, 17, 1024, 1027] {
            let a = rand_vec(n, 1);
            let b = rand_vec(n, 2);
            let mut c_ref = vec![0.0; n];
            serial::vadd_slice(&a, &b, &mut c_ref);
            let mut c = vec![0.0; n];
            vadd_unrolled(&a, &b, &mut c);
            assert_eq!(c, c_ref, "vadd n={n}");

            let mut b_ref = b.clone();
            serial::daxpy_slice(3.0, &a, &mut b_ref);
            let mut b_un = b.clone();
            daxpy_unrolled(3.0, &a, &mut b_un);
            assert_eq!(b_un, b_ref, "daxpy n={n}");
        }
    }

    #[test]
    fn matvec_unrolled_matches_oracle_within_tolerance() {
        for (m, n) in [(1usize, 1usize), (7, 5), (40, 37), (13, 128), (33, 301)] {
            let a = rand_vec(m * n, 3);
            let x = rand_vec(n, 4);
            let mut y_ref = vec![0.0; m];
            serial::matvec_rows(&a, &x, &mut y_ref);
            let mut y = vec![0.0; m];
            matvec_unrolled(&a, &x, &mut y);
            assert!(
                max_abs_diff(&y, &y_ref) < 1e-12 * n as f64,
                "matvec {m}x{n}"
            );
        }
    }

    #[test]
    fn pack_a_band_layout_and_padding() {
        // 3 rows (one ragged panel), k=2.
        let a = [1., 2., 3., 4., 5., 6.];
        let mut buf = vec![f64::NAN; packed_a_len(3, 2)];
        pack_a_band(&a, 2, 0, 3, &mut buf);
        // Panel 0, kk=0 sliver: rows 0..3 col 0, pad 0.
        assert_eq!(&buf[0..4], &[1., 3., 5., 0.]);
        // kk=1 sliver: col 1, pad 0.
        assert_eq!(&buf[4..8], &[2., 4., 6., 0.]);
    }

    #[test]
    fn pack_b_band_layout_and_padding() {
        // B 2x3, pack cols 0..3 (one ragged panel).
        let b = [1., 2., 3., 4., 5., 6.];
        let mut buf = vec![f64::NAN; packed_b_len(2, 3)];
        pack_b_band(&b, 2, 3, 0, 3, &mut buf);
        // kk=0 sliver: row 0 cols 0..3, pad 0.
        assert_eq!(&buf[0..4], &[1., 2., 3., 0.]);
        assert_eq!(&buf[4..8], &[4., 5., 6., 0.]);
    }

    #[test]
    fn packed_matmul_identity() {
        let n = 37;
        let a = rand_vec(n * n, 5);
        let mut eye = vec![0.0; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut c = vec![f64::NAN; n * n];
        packed_matmul(&a, &eye, n, n, n, &mut c);
        assert_eq!(max_abs_diff(&c, &a), 0.0);
    }

    #[test]
    fn packed_matmul_matches_naive_oracle_on_ragged_shapes() {
        for (m, k, n) in [
            (1usize, 1usize, 1usize),
            (4, 4, 4),
            (5, 3, 7),
            (64, 64, 64),
            (57, 119, 83),
            (70, 300, 9),
            (130, 37, 65),
        ] {
            let a = rand_vec(m * k, 6);
            let b = rand_vec(k * n, 7);
            let mut c = vec![f64::NAN; m * n];
            packed_matmul(&a, &b, m, k, n, &mut c);
            let c_ref = naive_mm(&a, &b, m, k, n);
            assert!(
                max_abs_diff(&c, &c_ref) < 1e-12 * k as f64,
                "packed {m}x{k}x{n} diverged from naive oracle"
            );
        }
    }

    #[test]
    fn packed_product_is_decomposition_independent() {
        // The same product computed band-by-band at several band/tile
        // shapes must agree *bitwise* — per C element the accumulation
        // is one register in ascending k regardless of decomposition.
        let (m, k, n) = (90usize, 70usize, 110usize);
        let a = rand_vec(m * k, 8);
        let b = rand_vec(k * n, 9);
        let mut c_full = vec![0.0; m * n];
        packed_matmul(&a, &b, m, k, n, &mut c_full);
        for tile in [8usize, 10, 16, 33, 64, 128] {
            let mut c = vec![0.0; m * n];
            let mut b_pack = vec![0.0; packed_b_len(k, tile.min(n))];
            let mut a_pack = vec![0.0; packed_a_len(tile.min(m), k)];
            for i0 in (0..m).step_by(tile) {
                let i1 = (i0 + tile).min(m);
                let alen = packed_a_len(i1 - i0, k);
                pack_a_band(&a, k, i0, i1, &mut a_pack[..alen]);
                for j0 in (0..n).step_by(tile) {
                    let j1 = (j0 + tile).min(n);
                    let blen = packed_b_len(k, j1 - j0);
                    pack_b_band(&b, k, n, j0, j1, &mut b_pack[..blen]);
                    packed_band_mm(
                        &a_pack[..alen],
                        i1 - i0,
                        &b_pack[..blen],
                        j1 - j0,
                        k,
                        &mut c[i0 * n..i1 * n],
                        n,
                        j0,
                    );
                }
            }
            assert_eq!(
                max_abs_diff(&c, &c_full),
                0.0,
                "tile={tile} decomposition changed packed numerics"
            );
        }
    }

    #[test]
    fn packed_band_mm_ptr_matches_slice_store_bitwise() {
        // The ptr-store entry point (task-mode tiles) is the same core
        // as the slice-store one — tile-by-tile results must be
        // bit-identical, including ragged edge tiles.
        let (m, k, n) = (53usize, 41usize, 67usize);
        let a = rand_vec(m * k, 14);
        let b = rand_vec(k * n, 15);
        let tile = 16usize;
        let mut c_slice = vec![0.0; m * n];
        let mut c_ptr = vec![0.0; m * n];
        let cp = SendPtr::new(c_ptr.as_mut_ptr());
        for i0 in (0..m).step_by(tile) {
            let i1 = (i0 + tile).min(m);
            let alen = packed_a_len(i1 - i0, k);
            let mut a_pack = vec![0.0; alen];
            pack_a_band(&a, k, i0, i1, &mut a_pack);
            for j0 in (0..n).step_by(tile) {
                let j1 = (j0 + tile).min(n);
                let blen = packed_b_len(k, j1 - j0);
                let mut b_pack = vec![0.0; blen];
                pack_b_band(&b, k, n, j0, j1, &mut b_pack);
                packed_band_mm(
                    &a_pack,
                    i1 - i0,
                    &b_pack,
                    j1 - j0,
                    k,
                    &mut c_slice[i0 * n..i1 * n],
                    n,
                    j0,
                );
                // SAFETY: single-threaded; tile rectangles are
                // in-bounds and visited once each.
                unsafe {
                    packed_band_mm_ptr(&a_pack, i1 - i0, &b_pack, j1 - j0, k, cp, n, i0, j0)
                };
            }
        }
        assert_eq!(c_ptr, c_slice, "ptr-store diverged from slice-store");
    }

    #[test]
    fn packed_matmul_degenerate_dims() {
        // k = 0: C is all zeros.  m/n = 0: no-op.
        let mut c = vec![f64::NAN; 6];
        packed_matmul(&[], &[], 2, 0, 3, &mut c);
        assert!(c.iter().all(|&v| v == 0.0));
        let mut empty: Vec<f64> = vec![];
        packed_matmul(&[], &[1.0], 0, 1, 0, &mut empty);
    }

    #[test]
    fn auto_resolution_is_numerics_preserving() {
        // Auto engages packing only above the floor, in every dimension.
        assert!(!matmul_uses_packed(KernelVariant::Auto, 255, 300, 300));
        assert!(!matmul_uses_packed(KernelVariant::Auto, 300, 300, 130));
        assert!(matmul_uses_packed(KernelVariant::Auto, 256, 256, 256));
        // Explicit requests bypass the floor / never pack.
        assert!(matmul_uses_packed(KernelVariant::Packed, 8, 8, 8));
        assert!(!matmul_uses_packed(KernelVariant::Scalar, 4096, 4096, 4096));
        assert!(!matmul_uses_packed(KernelVariant::Unrolled, 4096, 4096, 4096));
    }

    #[test]
    fn dispatchers_agree_with_oracles() {
        let n = 1029usize;
        let a = rand_vec(n, 10);
        let b = rand_vec(n, 11);
        for v in KernelVariant::ALL {
            let mut c = vec![0.0; n];
            vadd(v, &a, &b, &mut c);
            let mut c_ref = vec![0.0; n];
            serial::vadd_slice(&a, &b, &mut c_ref);
            assert_eq!(c, c_ref, "vadd bitwise under {v:?}");

            let mut bb = b.clone();
            daxpy(v, 3.0, &a, &mut bb);
            let mut bb_ref = b.clone();
            serial::daxpy_slice(3.0, &a, &mut bb_ref);
            // FMA (explicit variants with the feature active) fuses
            // rounding; everything else stays bitwise.
            let fma_possible =
                simd_active() && matches!(v, KernelVariant::Unrolled | KernelVariant::Packed);
            if fma_possible {
                assert!(max_abs_diff(&bb, &bb_ref) < 1e-12, "daxpy under {v:?}");
            } else {
                assert_eq!(bb, bb_ref, "daxpy bitwise under {v:?}");
            }
        }
        // matvec: Scalar/Auto bitwise, explicit variants within tolerance.
        let (m, cols) = (31usize, 301usize);
        let a = rand_vec(m * cols, 12);
        let x = rand_vec(cols, 13);
        let mut y_ref = vec![0.0; m];
        serial::matvec_rows(&a, &x, &mut y_ref);
        for v in [KernelVariant::Scalar, KernelVariant::Auto] {
            let mut y = vec![0.0; m];
            matvec(v, &a, &x, &mut y);
            assert_eq!(y, y_ref, "matvec bitwise under {v:?}");
        }
        for v in [KernelVariant::Unrolled, KernelVariant::Packed] {
            let mut y = vec![0.0; m];
            matvec(v, &a, &x, &mut y);
            assert!(
                max_abs_diff(&y, &y_ref) < 1e-12 * cols as f64,
                "matvec under {v:?}"
            );
        }
    }

    #[test]
    fn simd_introspection_is_consistent() {
        // Feature off → never active; label always classifies the build.
        if !simd_compiled() {
            assert!(!simd_active());
            assert_eq!(simd_label(), "portable (simd feature not compiled)");
        } else {
            assert!(simd_label().contains("avx2") || simd_label().contains("portable"));
        }
        if simd_active() {
            assert!(simd_compiled());
        }
    }
}
