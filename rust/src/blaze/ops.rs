//! The four Blazemark operations, parallelized over `ParallelRuntime`
//! with Blaze's threshold gating (paper §6.1–§6.4).
//!
//! Each op partitions its index space into OpenMP loop chunks; each chunk
//! runs the serial kernel on a disjoint slice of the output.  Below the
//! per-op threshold the whole op runs single-threaded — exactly Blaze's
//! behaviour, and the cause of the flat region in every paper figure.

use std::ops::Range;

use super::matrix::DynMatrix;
use super::serial;
use super::thresholds::*;
use super::vector::DynVector;
use crate::amt::future::{when_all, Future};
use crate::par::{HpxMpRuntime, LoopSched, ParallelRuntime};

/// Execution configuration for one operation invocation.
#[derive(Clone, Copy, Debug)]
pub struct BlazeConfig {
    pub threads: usize,
    pub sched: LoopSched,
}

impl BlazeConfig {
    pub fn new(threads: usize) -> Self {
        Self {
            threads,
            sched: LoopSched::default(),
        }
    }
}

/// Covariant raw-pointer smuggle for disjoint parallel writes.  Soundness
/// rests on the loop-partition invariant (each index claimed exactly once)
/// which `prop_invariants.rs` checks for every schedule.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// # Safety
    /// `r` must be within the allocation and disjoint across callers.
    unsafe fn slice(&self, r: &Range<i64>) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.0.add(r.start as usize), (r.end - r.start) as usize)
    }
}

/// dvecdvecadd (paper §6.1): `c = a + b`; threshold 38 000 elements.
pub fn dvecdvecadd(
    rt: &dyn ParallelRuntime,
    cfg: &BlazeConfig,
    a: &DynVector,
    b: &DynVector,
    c: &mut DynVector,
) {
    let n = a.len();
    assert_eq!(n, b.len());
    assert_eq!(n, c.len());
    if !parallelize(n, DVECDVECADD_THRESHOLD) || cfg.threads <= 1 {
        serial::vadd_slice(a.as_slice(), b.as_slice(), c.as_mut_slice());
        return;
    }
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    rt.parallel_for(cfg.threads, 0..n as i64, cfg.sched, &|r| {
        let (s, e) = (r.start as usize, r.end as usize);
        // SAFETY: chunks partition 0..n disjointly.
        let c_sub = unsafe { cp.slice(&r) };
        serial::vadd_slice(&a.as_slice()[s..e], &b.as_slice()[s..e], c_sub);
    });
}

/// daxpy (paper §6.2): `b += beta * a`; threshold 38 000 elements.
/// Blazemark uses `beta = 3.0`.
pub fn daxpy(
    rt: &dyn ParallelRuntime,
    cfg: &BlazeConfig,
    beta: f64,
    a: &DynVector,
    b: &mut DynVector,
) {
    let n = a.len();
    assert_eq!(n, b.len());
    if !parallelize(n, DAXPY_THRESHOLD) || cfg.threads <= 1 {
        serial::daxpy_slice(beta, a.as_slice(), b.as_mut_slice());
        return;
    }
    let bp = SendPtr(b.as_mut_slice().as_mut_ptr());
    rt.parallel_for(cfg.threads, 0..n as i64, cfg.sched, &|r| {
        let (s, e) = (r.start as usize, r.end as usize);
        // SAFETY: chunks partition 0..n disjointly.
        let b_sub = unsafe { bp.slice(&r) };
        serial::daxpy_slice(beta, &a.as_slice()[s..e], b_sub);
    });
}

/// dmatdmatadd (paper §6.3): `C = A + B`, parallel over rows; threshold
/// 36 100 elements of the target (≈190×190).
pub fn dmatdmatadd(
    rt: &dyn ParallelRuntime,
    cfg: &BlazeConfig,
    a: &DynMatrix,
    b: &DynMatrix,
    c: &mut DynMatrix,
) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!((m, n), (b.rows(), b.cols()));
    assert_eq!((m, n), (c.rows(), c.cols()));
    if !parallelize(m * n, DMATDMATADD_THRESHOLD) || cfg.threads <= 1 {
        serial::madd_rows(a.as_slice(), b.as_slice(), c.as_mut_slice());
        return;
    }
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    rt.parallel_for(cfg.threads, 0..m as i64, cfg.sched, &|r| {
        let (rs, re) = (r.start as usize, r.end as usize);
        let flat = (rs * n) as i64..(re * n) as i64;
        // SAFETY: row bands are disjoint.
        let c_sub = unsafe { cp.slice(&flat) };
        serial::madd_rows(
            &a.as_slice()[rs * n..re * n],
            &b.as_slice()[rs * n..re * n],
            c_sub,
        );
    });
}

/// dmatdmatmult (paper §6.4): `C = A * B`, rows of C distributed across
/// the team (Blaze's row-wise decomposition); threshold 3 025 elements of
/// the target (≈55×55).
pub fn dmatdmatmult(
    rt: &dyn ParallelRuntime,
    cfg: &BlazeConfig,
    a: &DynMatrix,
    b: &DynMatrix,
    c: &mut DynMatrix,
) {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    assert_eq!((m, n), (c.rows(), c.cols()));
    let run_serial = !parallelize(m * n, DMATDMATMULT_THRESHOLD) || cfg.threads <= 1;
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let row_body = |r: Range<i64>| {
        for i in r.start as usize..r.end as usize {
            let flat = (i * n) as i64..((i + 1) * n) as i64;
            // SAFETY: each row of C is written by exactly one claimant.
            let c_row = unsafe { cp.slice(&flat) };
            serial::matmul_row(a.row(i), b.as_slice(), n, c_row);
        }
    };
    if run_serial {
        row_body(0..m as i64);
        return;
    }
    rt.parallel_for(cfg.threads, 0..m as i64, cfg.sched, &row_body);
}

/// dmatdvecmult (ISSUE 3 — the suite's dense matrix-vector product, the
/// missing fourth Blazemark kernel): `y = A * x`, rows of `y` distributed
/// across the team; Blaze gates on the matrix's **row count** (threshold
/// 330).  Supports non-square `A` (m × n times length-n).
pub fn dmatdvecmult(
    rt: &dyn ParallelRuntime,
    cfg: &BlazeConfig,
    a: &DynMatrix,
    x: &DynVector,
    y: &mut DynVector,
) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(n, x.len());
    assert_eq!(m, y.len());
    if !parallelize(m, DMATDVECMULT_THRESHOLD) || cfg.threads <= 1 {
        serial::matvec_rows(a.as_slice(), x.as_slice(), y.as_mut_slice());
        return;
    }
    let yp = SendPtr(y.as_mut_slice().as_mut_ptr());
    rt.parallel_for(cfg.threads, 0..m as i64, cfg.sched, &|r| {
        let (rs, re) = (r.start as usize, r.end as usize);
        // SAFETY: row bands partition 0..m disjointly.
        let y_sub = unsafe { yp.slice(&r) };
        serial::matvec_rows(&a.as_slice()[rs * n..re * n], x.as_slice(), y_sub);
    });
}

/// Covariant const-pointer smuggle for shared parallel reads from
/// dataflow tasks (the read-side sibling of [`SendPtr`]).
#[derive(Clone, Copy)]
struct ConstPtr(*const f64);

unsafe impl Send for ConstPtr {}
unsafe impl Sync for ConstPtr {}

/// Default tile edge of the dataflow dmatdmatmult decomposition: large
/// enough that one tile amortizes task scheduling, small enough that a
/// 150×150 product still yields a stealable graph.
pub const DATAFLOW_TILE: usize = 64;

/// dmatdmatmult as a dependence-driven tiled task graph (ISSUE 2) with
/// the default tile size — see [`dmatdmatmult_dataflow_tiled`].
pub fn dmatdmatmult_dataflow(
    rt: &HpxMpRuntime,
    cfg: &BlazeConfig,
    a: &DynMatrix,
    b: &DynMatrix,
    c: &mut DynMatrix,
) {
    dmatdmatmult_dataflow_tiled(rt, cfg, a, b, c, DATAFLOW_TILE)
}

/// `C = A * B` as a **futurized dataflow graph** (ISSUE 2; DESIGN.md §7):
/// C is blocked into `tile × tile` tiles; each tile task is a `then`
/// continuation on `when_all` of its *input-band futures* (the A row band
/// and B column band it consumes), and the product completes at one final
/// `when_all` join — no fork/join barriers anywhere, the first
/// non-fork-join workload of this repo.
///
/// The input bands here are materialized as already-ready futures (the
/// operands exist), but the graph shape is exactly what lets an upstream
/// producer chain products without joins: hang the band futures off
/// producer tasks instead and nothing else changes.
///
/// Same threshold gating and summation order as the fork-join
/// [`dmatdmatmult`] (tile tasks accumulate over the full depth in
/// increasing k), so results agree with the serial oracle bit-for-bit.
pub fn dmatdmatmult_dataflow_tiled(
    rt: &HpxMpRuntime,
    cfg: &BlazeConfig,
    a: &DynMatrix,
    b: &DynMatrix,
    c: &mut DynMatrix,
    tile: usize,
) {
    let (m, k_dim) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k_dim, k2);
    assert_eq!((m, n), (c.rows(), c.cols()));
    if !parallelize(m * n, DMATDMATMULT_THRESHOLD) || cfg.threads <= 1 {
        for i in 0..m {
            serial::matmul_row(a.row(i), b.as_slice(), n, c.row_mut(i));
        }
        return;
    }

    let tile = tile.max(8);
    let row_tiles = m / tile + usize::from(m % tile != 0);
    let col_tiles = n / tile + usize::from(n % tile != 0);

    // The input tiles of the graph: A banded by tile rows, B by tile
    // columns, one future each.
    let a_bands: Vec<Future<()>> = (0..row_tiles).map(|_| Future::ready(())).collect();
    let b_bands: Vec<Future<()>> = (0..col_tiles).map(|_| Future::ready(())).collect();

    let ap = ConstPtr(a.as_slice().as_ptr());
    let bp = ConstPtr(b.as_slice().as_ptr());
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let sched = &rt.rt.sched;

    let mut tiles: Vec<Future<()>> = Vec::with_capacity(row_tiles * col_tiles);
    for bi in 0..row_tiles {
        let (i0, i1) = (bi * tile, ((bi + 1) * tile).min(m));
        for bj in 0..col_tiles {
            let (j0, j1) = (bj * tile, ((bj + 1) * tile).min(n));
            let inputs = [a_bands[bi].clone(), b_bands[bj].clone()];
            let tile_task = when_all(&inputs).then_named(sched, "blaze_tile_mult", move |_| {
                // SAFETY: the final `when_all(..).wait()` below blocks this
                // function until every tile task retired, so the operand
                // borrows outlive all uses; tile (row × column) ranges
                // partition C disjointly, so each segment has exactly one
                // writer.
                let a_all = unsafe { std::slice::from_raw_parts(ap.0, m * k_dim) };
                let b_all = unsafe { std::slice::from_raw_parts(bp.0, k_dim * n) };
                for i in i0..i1 {
                    let flat = (i * n + j0) as i64..(i * n + j1) as i64;
                    let c_seg = unsafe { cp.slice(&flat) };
                    serial::matmul_row_seg(
                        &a_all[i * k_dim..(i + 1) * k_dim],
                        b_all,
                        n,
                        j0,
                        c_seg,
                    );
                }
            });
            tiles.push(tile_task);
        }
    }
    when_all(&tiles).wait();
}

/// Blazemark FLOP counts per operation (what MFLOP/s is computed from).
pub mod flops {
    /// dvecdvecadd: one add per element.
    pub fn dvecdvecadd(n: usize) -> f64 {
        n as f64
    }

    /// daxpy: multiply + add per element.
    pub fn daxpy(n: usize) -> f64 {
        2.0 * n as f64
    }

    /// dmatdmatadd: one add per element.
    pub fn dmatdmatadd(n: usize) -> f64 {
        (n * n) as f64
    }

    /// dmatdmatmult: 2·n³ (multiply-add per inner element).
    pub fn dmatdmatmult(n: usize) -> f64 {
        2.0 * (n as f64).powi(3)
    }

    /// dmatdvecmult: 2·n² for a square n×n matrix (multiply-add per
    /// matrix element).
    pub fn dmatdvecmult(n: usize) -> f64 {
        2.0 * (n as f64).powi(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::BaselineRuntime;
    use crate::par::SerialRuntime;

    fn vec_ref_add(a: &DynVector, b: &DynVector) -> DynVector {
        DynVector::from_vec(
            a.as_slice()
                .iter()
                .zip(b.as_slice())
                .map(|(x, y)| x + y)
                .collect(),
        )
    }

    #[test]
    fn dvecdvecadd_below_threshold_is_serial_and_correct() {
        let rt = SerialRuntime;
        let a = DynVector::random(1000, 1);
        let b = DynVector::random(1000, 2);
        let mut c = DynVector::zeros(1000);
        dvecdvecadd(&rt, &BlazeConfig::new(4), &a, &b, &mut c);
        assert_eq!(c, vec_ref_add(&a, &b));
    }

    #[test]
    fn dvecdvecadd_parallel_matches_serial() {
        let rt = BaselineRuntime::new(4);
        let n = 50_000; // above threshold
        let a = DynVector::random(n, 3);
        let b = DynVector::random(n, 4);
        let mut c = DynVector::zeros(n);
        dvecdvecadd(&rt, &BlazeConfig::new(4), &a, &b, &mut c);
        assert_eq!(c.max_abs_diff(&vec_ref_add(&a, &b)), 0.0);
    }

    #[test]
    fn daxpy_parallel_matches_serial() {
        let rt = BaselineRuntime::new(4);
        let n = 60_000;
        let a = DynVector::random(n, 5);
        let b0 = DynVector::random(n, 6);
        let mut b_par = b0.clone();
        daxpy(&rt, &BlazeConfig::new(4), 3.0, &a, &mut b_par);
        let mut b_ser = b0.clone();
        serial::daxpy_slice(3.0, a.as_slice(), b_ser.as_mut_slice());
        assert_eq!(b_par.max_abs_diff(&b_ser), 0.0);
    }

    #[test]
    fn dmatdmatadd_parallel_matches_serial() {
        let rt = BaselineRuntime::new(4);
        let n = 200; // 40000 elements > 36100
        let a = DynMatrix::random(n, n, 7);
        let b = DynMatrix::random(n, n, 8);
        let mut c = DynMatrix::zeros(n, n);
        dmatdmatadd(&rt, &BlazeConfig::new(4), &a, &b, &mut c);
        let mut c_ref = DynMatrix::zeros(n, n);
        serial::madd_rows(a.as_slice(), b.as_slice(), c_ref.as_mut_slice());
        assert_eq!(c.max_abs_diff(&c_ref), 0.0);
    }

    #[test]
    fn dmatdmatmult_identity_and_parallel_consistency() {
        let rt = BaselineRuntime::new(4);
        let n = 64; // 4096 elements > 3025: parallel path
        let a = DynMatrix::random(n, n, 9);
        let eye = DynMatrix::identity(n);
        let mut c = DynMatrix::zeros(n, n);
        dmatdmatmult(&rt, &BlazeConfig::new(4), &a, &eye, &mut c);
        assert!(c.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn dmatdmatmult_small_uses_serial_path() {
        // 10x10 < 3025 threshold: must still be correct.
        let rt = BaselineRuntime::new(4);
        let a = DynMatrix::random(10, 10, 10);
        let b = DynMatrix::random(10, 10, 11);
        let mut c = DynMatrix::zeros(10, 10);
        dmatdmatmult(&rt, &BlazeConfig::new(4), &a, &b, &mut c);
        // Oracle: naive triple loop.
        let mut c_ref = DynMatrix::zeros(10, 10);
        for i in 0..10 {
            for j in 0..10 {
                let mut s = 0.0;
                for k in 0..10 {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c_ref.at_mut(i, j) = s;
            }
        }
        assert!(c.max_abs_diff(&c_ref) < 1e-12);
    }

    /// Naive dot-product oracle for `y = A * x`.
    fn matvec_oracle(a: &DynMatrix, x: &DynVector) -> DynVector {
        let (m, n) = (a.rows(), a.cols());
        let mut y = DynVector::zeros(m);
        for i in 0..m {
            let mut s = 0.0;
            for j in 0..n {
                s += a.at(i, j) * x.as_slice()[j];
            }
            y.as_mut_slice()[i] = s;
        }
        y
    }

    #[test]
    fn dmatdvecmult_small_uses_serial_path_and_is_correct() {
        // 100 rows < 330 threshold: serial fallback must still be exact.
        let rt = BaselineRuntime::new(4);
        let a = DynMatrix::random(100, 100, 21);
        let x = DynVector::random(100, 22);
        let mut y = DynVector::zeros(100);
        dmatdvecmult(&rt, &BlazeConfig::new(4), &a, &x, &mut y);
        assert!(y.max_abs_diff(&matvec_oracle(&a, &x)) < 1e-12);
    }

    #[test]
    fn dmatdvecmult_parallel_matches_serial_oracle() {
        let rt = BaselineRuntime::new(4);
        let n = 400; // above the 330-row threshold: parallel path
        let a = DynMatrix::random(n, n, 23);
        let x = DynVector::random(n, 24);
        let mut y = DynVector::zeros(n);
        dmatdvecmult(&rt, &BlazeConfig::new(4), &a, &x, &mut y);
        assert_eq!(y.max_abs_diff(&matvec_oracle(&a, &x)), 0.0);
    }

    #[test]
    fn dmatdvecmult_non_square_shapes() {
        let rt = BaselineRuntime::new(4);
        // (m, n) pairs straddling the row threshold, wide and tall.
        for (m, n) in [(400usize, 37usize), (350, 700), (64, 512)] {
            let a = DynMatrix::random(m, n, 25);
            let x = DynVector::random(n, 26);
            let mut y = DynVector::zeros(m);
            dmatdvecmult(&rt, &BlazeConfig::new(4), &a, &x, &mut y);
            assert_eq!(
                y.max_abs_diff(&matvec_oracle(&a, &x)),
                0.0,
                "shape {m}x{n} diverged from the dot-product oracle"
            );
        }
    }

    #[test]
    fn dmatdvecmult_hpxmp_matches_baseline() {
        use crate::omp::OmpRuntime;
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let n = 512;
        let a = DynMatrix::random(n, n, 27);
        let x = DynVector::random(n, 28);
        let mut y = DynVector::zeros(n);
        dmatdvecmult(&hpx, &BlazeConfig::new(4), &a, &x, &mut y);
        assert_eq!(y.max_abs_diff(&matvec_oracle(&a, &x)), 0.0);
    }

    #[test]
    fn dmatdmatmult_dataflow_matches_forkjoin_oracle_exactly() {
        use crate::omp::OmpRuntime;
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        // 30: below threshold (serial path); 64: parallel, even tiles;
        // 130: parallel, ragged edge tiles.
        for n in [30usize, 64, 130] {
            let a = DynMatrix::random(n, n, 31);
            let b = DynMatrix::random(n, n, 32);
            let mut c_df = DynMatrix::zeros(n, n);
            dmatdmatmult_dataflow_tiled(&hpx, &BlazeConfig::new(4), &a, &b, &mut c_df, 16);
            let mut c_ref = DynMatrix::zeros(n, n);
            dmatdmatmult(&SerialRuntime, &BlazeConfig::new(1), &a, &b, &mut c_ref);
            assert_eq!(
                c_df.max_abs_diff(&c_ref),
                0.0,
                "dataflow diverged from serial oracle at n={n}"
            );
        }
    }

    #[test]
    fn flop_counts() {
        assert_eq!(flops::dvecdvecadd(100), 100.0);
        assert_eq!(flops::daxpy(100), 200.0);
        assert_eq!(flops::dmatdmatadd(10), 100.0);
        assert_eq!(flops::dmatdmatmult(10), 2000.0);
        assert_eq!(flops::dmatdvecmult(10), 200.0);
    }
}
