//! The five Blazemark operations, generic over [`exec::Policy`]
//! (paper §6.1–§6.4 plus the dmatdvecmult extension), with Blaze's
//! threshold gating.
//!
//! Since PR 5 every kernel takes one execution policy instead of a
//! `(runtime, config)` pair: `seq()` runs the serial kernel, `par()`
//! partitions the index space into OpenMP loop chunks, and `task()`
//! executes the same decomposition as a futurized task graph — so all
//! five kernels gained a dataflow execution for free (the bespoke
//! `dmatdmatmult_dataflow_tiled` entry point is gone; its tiled graph
//! lives in [`exec::for_each_tile_async`]).  Below the per-op threshold
//! the whole op runs single-threaded regardless of policy — exactly
//! Blaze's behaviour, and the cause of the flat region in every paper
//! figure.
//!
//! Since ISSUE 7 the inner loops dispatch through
//! [`super::kernel`] on [`Policy::kernel`]'s [`exec::KernelVariant`]:
//! `Auto` is numerics-preserving (unrolled elementwise loops are
//! bitwise-equal; matvec keeps its single accumulator; matmul packs only
//! above [`PACKED_MIN_DIM`]), while explicit `Unrolled`/`Packed` opt into
//! accumulator splitting, FMA (with the `simd` feature), and the packed
//! cache-blocked product.  Thresholds honour [`Policy::threshold`] via
//! [`Policy::par_threshold`].

use std::ops::Range;

use super::kernel;
use super::matrix::DynMatrix;
use super::serial;
use super::thresholds::*;
use super::vector::DynVector;
use crate::par::exec::{self, ExecMode, Policy};
use std::sync::Arc;

/// Covariant raw-pointer smuggle for disjoint parallel writes.  Soundness
/// rests on the loop-partition invariant (each index claimed exactly once)
/// which `prop_invariants.rs` checks for every schedule.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(*mut f64);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    pub(crate) fn new(p: *mut f64) -> Self {
        Self(p)
    }

    /// # Safety
    /// `r` must be within the allocation and disjoint across callers.
    unsafe fn slice(&self, r: &Range<i64>) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.0.add(r.start as usize), (r.end - r.start) as usize)
    }

    /// # Safety
    /// `lo..hi` must be within the allocation and disjoint across callers.
    pub(crate) unsafe fn slice_range(&self, lo: usize, hi: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.0.add(lo), hi - lo)
    }
}

/// Covariant const-pointer smuggle for shared parallel reads from
/// dataflow tasks (the read-side sibling of [`SendPtr`]).
#[derive(Clone, Copy)]
pub(crate) struct ConstPtr(*const f64);

unsafe impl Send for ConstPtr {}
unsafe impl Sync for ConstPtr {}

impl ConstPtr {
    pub(crate) fn new(p: *const f64) -> Self {
        Self(p)
    }

    /// # Safety
    /// `lo..hi` must be within the allocation, and no `&mut` to the
    /// range may be live concurrently (writes must be ordered before
    /// via the task graph / join).
    pub(crate) unsafe fn slice(&self, lo: usize, hi: usize) -> &[f64] {
        std::slice::from_raw_parts(self.0.add(lo), hi - lo)
    }
}

/// dvecdvecadd (paper §6.1): `c = a + b`; threshold 38 000 elements.
pub fn dvecdvecadd(pol: &Policy<'_>, a: &DynVector, b: &DynVector, c: &mut DynVector) {
    let n = a.len();
    assert_eq!(n, b.len());
    assert_eq!(n, c.len());
    let v = pol.kernel_variant();
    if !parallelize(n, pol.par_threshold(DVECDVECADD_THRESHOLD)) || pol.is_serial() {
        kernel::vadd(v, a.as_slice(), b.as_slice(), c.as_mut_slice());
        return;
    }
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    exec::for_each(pol, 0..n as i64, |r| {
        let (s, e) = (r.start as usize, r.end as usize);
        // SAFETY: chunks partition 0..n disjointly.
        let c_sub = unsafe { cp.slice(&r) };
        kernel::vadd(v, &a.as_slice()[s..e], &b.as_slice()[s..e], c_sub);
    });
}

/// daxpy (paper §6.2): `b += beta * a`; threshold 38 000 elements.
/// Blazemark uses `beta = 3.0`.
pub fn daxpy(pol: &Policy<'_>, beta: f64, a: &DynVector, b: &mut DynVector) {
    let n = a.len();
    assert_eq!(n, b.len());
    let v = pol.kernel_variant();
    if !parallelize(n, pol.par_threshold(DAXPY_THRESHOLD)) || pol.is_serial() {
        kernel::daxpy(v, beta, a.as_slice(), b.as_mut_slice());
        return;
    }
    let bp = SendPtr(b.as_mut_slice().as_mut_ptr());
    exec::for_each(pol, 0..n as i64, |r| {
        let (s, e) = (r.start as usize, r.end as usize);
        // SAFETY: chunks partition 0..n disjointly.
        let b_sub = unsafe { bp.slice(&r) };
        kernel::daxpy(v, beta, &a.as_slice()[s..e], b_sub);
    });
}

/// dmatdmatadd (paper §6.3): `C = A + B`, parallel over rows; threshold
/// 36 100 elements of the target (≈190×190).
pub fn dmatdmatadd(pol: &Policy<'_>, a: &DynMatrix, b: &DynMatrix, c: &mut DynMatrix) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!((m, n), (b.rows(), b.cols()));
    assert_eq!((m, n), (c.rows(), c.cols()));
    let v = pol.kernel_variant();
    if !parallelize(m * n, pol.par_threshold(DMATDMATADD_THRESHOLD)) || pol.is_serial() {
        kernel::madd(v, a.as_slice(), b.as_slice(), c.as_mut_slice());
        return;
    }
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    exec::for_each(pol, 0..m as i64, |r| {
        let (rs, re) = (r.start as usize, r.end as usize);
        let flat = (rs * n) as i64..(re * n) as i64;
        // SAFETY: row bands are disjoint.
        let c_sub = unsafe { cp.slice(&flat) };
        kernel::madd(
            v,
            &a.as_slice()[rs * n..re * n],
            &b.as_slice()[rs * n..re * n],
            c_sub,
        );
    });
}

/// dmatdmatmult (paper §6.4): `C = A * B`; threshold 3 025 elements of
/// the target (≈55×55).
///
/// Under `seq()`/`par()` the rows of C are distributed across the team
/// (Blaze's row-wise decomposition).  Under `task()` the product runs as
/// a **futurized dataflow graph** (ISSUE 2 → generalized in ISSUE 5;
/// DESIGN.md §7/§10): C is blocked into [`Policy::tile`]-edged tiles,
/// each tile a continuation on `when_all` of its input-band futures,
/// joined once at the end — no fork/join barriers anywhere.  Same
/// summation order on every path (tile tasks accumulate over the full
/// depth in increasing k), so all policies agree with the serial oracle
/// bit-for-bit.
///
/// When [`kernel::matmul_uses_packed`] selects the packed cache-blocked
/// kernel (explicit `Packed`, or `Auto` with every dimension ≥
/// [`PACKED_MIN_DIM`]), the product runs through
/// [`dmatdmatmult_packed`] instead: register-resident accumulation over
/// packed panels — bitwise identical across policies and tile sizes,
/// but *reassociated* relative to the scalar row kernel (tolerance-
/// checked against it, never selected by `Auto` at bitwise-oracle
/// sizes).
pub fn dmatdmatmult(pol: &Policy<'_>, a: &DynMatrix, b: &DynMatrix, c: &mut DynMatrix) {
    let (m, k_dim) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k_dim, k2);
    assert_eq!((m, n), (c.rows(), c.cols()));
    if kernel::matmul_uses_packed(pol.kernel_variant(), m, k_dim, n) {
        dmatdmatmult_packed(pol, a, b, c);
        return;
    }
    let cp = SendPtr(c.as_mut_slice().as_mut_ptr());
    let row_body = |r: Range<i64>| {
        for i in r.start as usize..r.end as usize {
            let flat = (i * n) as i64..((i + 1) * n) as i64;
            // SAFETY: each row of C is written by exactly one claimant.
            let c_row = unsafe { cp.slice(&flat) };
            serial::matmul_row(a.row(i), b.as_slice(), n, c_row);
        }
    };
    if !parallelize(m * n, pol.par_threshold(DMATDMATMULT_THRESHOLD)) || pol.is_serial() {
        row_body(0..m as i64);
        return;
    }
    if pol.mode() == ExecMode::Task {
        let ap = ConstPtr(a.as_slice().as_ptr());
        let bp = ConstPtr(b.as_slice().as_ptr());
        let tile_body: Arc<dyn Fn(Range<usize>, Range<usize>) + Send + Sync> =
            Arc::new(move |ri, rj| {
                // SAFETY: the `wait()` below blocks this function until
                // every tile task retired, so the operand borrows outlive
                // all uses; tile (row × column) ranges partition C
                // disjointly, so each segment has exactly one writer.
                let a_all = unsafe { ap.slice(0, m * k_dim) };
                let b_all = unsafe { bp.slice(0, k_dim * n) };
                let (j0, j1) = (rj.start, rj.end);
                for i in ri {
                    let flat = (i * n + j0) as i64..(i * n + j1) as i64;
                    let c_seg = unsafe { cp.slice(&flat) };
                    serial::matmul_row_seg(&a_all[i * k_dim..(i + 1) * k_dim], b_all, n, j0, c_seg);
                }
            });
        exec::for_each_tile_async(pol, m, n, tile_body).wait();
        return;
    }
    exec::for_each(pol, 0..m as i64, row_body);
}

/// The packed cache-blocked `C = A * B` (ISSUE 7; DESIGN.md §12).
///
/// Serial (or below [`PACKED_DMATDMATMULT_THRESHOLD`]): one
/// [`kernel::packed_matmul`] pass.  `par()`: B column-bands are packed
/// in parallel, then C row-bands are computed in parallel, each chunk
/// packing its own A band into a thread-local buffer.  `task()`: the
/// prepped tile graph ([`exec::for_each_tile_async_prepped`]) — each
/// row/column band's *packing* runs as a real task (the band future),
/// every tile is a continuation on its two bands' pack futures, so
/// packing overlaps compute and each band is packed exactly once and
/// shared by all its tiles.
///
/// All three paths drive the same [`kernel::packed_band_mm`] arithmetic
/// (one register accumulator per C element, depth ascending; the task
/// tiles store through [`kernel::packed_band_mm_ptr`], which shares the
/// core and materializes only each tile's disjoint per-row C segments),
/// so their results are **bitwise identical** to each other for any
/// tile size or thread count.
fn dmatdmatmult_packed(pol: &Policy<'_>, a: &DynMatrix, b: &DynMatrix, c: &mut DynMatrix) {
    let (m, k_dim) = (a.rows(), a.cols());
    let n = b.cols();
    if !parallelize(m * n, pol.par_threshold(PACKED_DMATDMATMULT_THRESHOLD)) || pol.is_serial() {
        kernel::packed_matmul(a.as_slice(), b.as_slice(), m, k_dim, n, c.as_mut_slice());
        return;
    }
    let tile = pol.tile_size();
    let row_tiles = m.div_ceil(tile);
    let col_tiles = n.div_ceil(tile);
    // Uniform per-band strides so prep tasks can address their band's
    // pack buffer without coordination; ragged edge bands use a prefix.
    let a_stride = kernel::packed_a_len(tile.min(m), k_dim);
    let b_stride = kernel::packed_b_len(k_dim, tile.min(n));
    let mut b_pack = vec![0.0f64; col_tiles * b_stride];
    let bpk_w = SendPtr::new(b_pack.as_mut_ptr());
    let bpk_r = ConstPtr::new(b_pack.as_ptr());
    let ap = ConstPtr::new(a.as_slice().as_ptr());
    let bp = ConstPtr::new(b.as_slice().as_ptr());
    let cp = SendPtr::new(c.as_mut_slice().as_mut_ptr());

    if pol.mode() == ExecMode::Task {
        let mut a_pack = vec![0.0f64; row_tiles * a_stride];
        let apk_w = SendPtr::new(a_pack.as_mut_ptr());
        let apk_r = ConstPtr::new(a_pack.as_ptr());
        // SAFETY (all closures): the `wait()` below blocks until the
        // whole graph retired, so the operand/pack-buffer borrows
        // outlive every use.  Each band is packed by exactly one prep
        // task (disjoint writes); tiles read a band's pack only after
        // its prep future completed (a graph edge), so no read races a
        // write; tile ranges partition C disjointly.
        let row_prep: exec::BandPrep = Arc::new(move |bi, ri| {
            let a_all = unsafe { ap.slice(0, m * k_dim) };
            let len = kernel::packed_a_len(ri.end - ri.start, k_dim);
            let buf = unsafe { apk_w.slice_range(bi * a_stride, bi * a_stride + len) };
            kernel::pack_a_band(a_all, k_dim, ri.start, ri.end, buf);
        });
        let col_prep: exec::BandPrep = Arc::new(move |bj, rj| {
            let b_all = unsafe { bp.slice(0, k_dim * n) };
            let len = kernel::packed_b_len(k_dim, rj.end - rj.start);
            let buf = unsafe { bpk_w.slice_range(bj * b_stride, bj * b_stride + len) };
            kernel::pack_b_band(b_all, k_dim, n, rj.start, rj.end, buf);
        });
        let tile_body: Arc<dyn Fn(Range<usize>, Range<usize>) + Send + Sync> =
            Arc::new(move |ri, rj| {
                let (bi, bj) = (ri.start / tile, rj.start / tile);
                let (br, bc) = (ri.end - ri.start, rj.end - rj.start);
                let alen = kernel::packed_a_len(br, k_dim);
                let blen = kernel::packed_b_len(k_dim, bc);
                let a_band = unsafe { apk_r.slice(bi * a_stride, bi * a_stride + alen) };
                let b_band = unsafe { bpk_r.slice(bj * b_stride, bj * b_stride + blen) };
                // Column tiles of one row band run concurrently, so the
                // tile must NOT slice out the whole row band of C — the
                // ptr-store kernel materializes only this tile's
                // per-row `(i*n + rj.start)..(i*n + rj.end)` segments,
                // which are disjoint across all live tiles.
                unsafe {
                    kernel::packed_band_mm_ptr(
                        a_band, br, b_band, bc, k_dim, cp, n, ri.start, rj.start,
                    )
                };
            });
        exec::for_each_tile_async_prepped(pol, m, n, row_prep, col_prep, tile_body).wait();
        return;
    }

    // par(): two fork-join phases — pack B bands, then sweep C row
    // bands (each chunk packs its A bands into a local buffer so A pack
    // pages are first-touched by their consumer).
    exec::for_each(pol, 0..col_tiles as i64, |r| {
        for bj in r.start as usize..r.end as usize {
            let j0 = bj * tile;
            let j1 = (j0 + tile).min(n);
            let len = kernel::packed_b_len(k_dim, j1 - j0);
            // SAFETY: band buffers are disjoint; joined before any read.
            let buf = unsafe { bpk_w.slice_range(bj * b_stride, bj * b_stride + len) };
            kernel::pack_b_band(b.as_slice(), k_dim, n, j0, j1, buf);
        }
    });
    exec::for_each(pol, 0..row_tiles as i64, |r| {
        let mut a_buf = vec![0.0f64; a_stride];
        for bi in r.start as usize..r.end as usize {
            let i0 = bi * tile;
            let i1 = (i0 + tile).min(m);
            let alen = kernel::packed_a_len(i1 - i0, k_dim);
            kernel::pack_a_band(a.as_slice(), k_dim, i0, i1, &mut a_buf[..alen]);
            // SAFETY: row bands of C are disjoint; B packs were joined
            // above so the const reads race nothing.
            let c_band = unsafe { cp.slice_range(i0 * n, i1 * n) };
            for bj in 0..col_tiles {
                let j0 = bj * tile;
                let j1 = (j0 + tile).min(n);
                let blen = kernel::packed_b_len(k_dim, j1 - j0);
                let b_band = unsafe { bpk_r.slice(bj * b_stride, bj * b_stride + blen) };
                kernel::packed_band_mm(
                    &a_buf[..alen],
                    i1 - i0,
                    b_band,
                    j1 - j0,
                    k_dim,
                    c_band,
                    n,
                    j0,
                );
            }
        }
    });
}

/// dmatdvecmult (ISSUE 3 — the suite's dense matrix-vector product, the
/// missing fourth Blazemark kernel): `y = A * x`, rows of `y` distributed
/// across the team; Blaze gates on the matrix's **row count** (threshold
/// 330).  Supports non-square `A` (m × n times length-n).
pub fn dmatdvecmult(pol: &Policy<'_>, a: &DynMatrix, x: &DynVector, y: &mut DynVector) {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(n, x.len());
    assert_eq!(m, y.len());
    let v = pol.kernel_variant();
    if !parallelize(m, pol.par_threshold(DMATDVECMULT_THRESHOLD)) || pol.is_serial() {
        kernel::matvec(v, a.as_slice(), x.as_slice(), y.as_mut_slice());
        return;
    }
    let yp = SendPtr(y.as_mut_slice().as_mut_ptr());
    exec::for_each(pol, 0..m as i64, |r| {
        let (rs, re) = (r.start as usize, r.end as usize);
        // SAFETY: row bands partition 0..m disjointly.
        let y_sub = unsafe { yp.slice(&r) };
        kernel::matvec(v, &a.as_slice()[rs * n..re * n], x.as_slice(), y_sub);
    });
}

/// Blazemark FLOP counts per operation (what MFLOP/s is computed from).
pub mod flops {
    /// dvecdvecadd: one add per element.
    pub fn dvecdvecadd(n: usize) -> f64 {
        n as f64
    }

    /// daxpy: multiply + add per element.
    pub fn daxpy(n: usize) -> f64 {
        2.0 * n as f64
    }

    /// dmatdmatadd: one add per element.
    pub fn dmatdmatadd(n: usize) -> f64 {
        (n * n) as f64
    }

    /// dmatdmatmult: 2·n³ (multiply-add per inner element).
    pub fn dmatdmatmult(n: usize) -> f64 {
        2.0 * (n as f64).powi(3)
    }

    /// dmatdvecmult: 2·n² for a square n×n matrix (multiply-add per
    /// matrix element).
    pub fn dmatdvecmult(n: usize) -> f64 {
        2.0 * (n as f64).powi(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::BaselineRuntime;
    use crate::omp::OmpRuntime;
    use crate::par::exec::{par, seq, task, KernelVariant};
    use crate::par::HpxMpRuntime;

    fn vec_ref_add(a: &DynVector, b: &DynVector) -> DynVector {
        DynVector::from_vec(
            a.as_slice()
                .iter()
                .zip(b.as_slice())
                .map(|(x, y)| x + y)
                .collect(),
        )
    }

    #[test]
    fn dvecdvecadd_below_threshold_is_serial_and_correct() {
        let a = DynVector::random(1000, 1);
        let b = DynVector::random(1000, 2);
        let mut c = DynVector::zeros(1000);
        dvecdvecadd(&seq(), &a, &b, &mut c);
        assert_eq!(c, vec_ref_add(&a, &b));
    }

    #[test]
    fn dvecdvecadd_parallel_matches_serial() {
        let rt = BaselineRuntime::new(4);
        let n = 50_000; // above threshold
        let a = DynVector::random(n, 3);
        let b = DynVector::random(n, 4);
        let mut c = DynVector::zeros(n);
        dvecdvecadd(&par().on(&rt).threads(4), &a, &b, &mut c);
        assert_eq!(c.max_abs_diff(&vec_ref_add(&a, &b)), 0.0);
    }

    #[test]
    fn daxpy_parallel_matches_serial() {
        let rt = BaselineRuntime::new(4);
        let n = 60_000;
        let a = DynVector::random(n, 5);
        let b0 = DynVector::random(n, 6);
        let mut b_par = b0.clone();
        daxpy(&par().on(&rt).threads(4), 3.0, &a, &mut b_par);
        let mut b_ser = b0.clone();
        serial::daxpy_slice(3.0, a.as_slice(), b_ser.as_mut_slice());
        assert_eq!(b_par.max_abs_diff(&b_ser), 0.0);
    }

    #[test]
    fn dmatdmatadd_parallel_matches_serial() {
        let rt = BaselineRuntime::new(4);
        let n = 200; // 40000 elements > 36100
        let a = DynMatrix::random(n, n, 7);
        let b = DynMatrix::random(n, n, 8);
        let mut c = DynMatrix::zeros(n, n);
        dmatdmatadd(&par().on(&rt).threads(4), &a, &b, &mut c);
        let mut c_ref = DynMatrix::zeros(n, n);
        serial::madd_rows(a.as_slice(), b.as_slice(), c_ref.as_mut_slice());
        assert_eq!(c.max_abs_diff(&c_ref), 0.0);
    }

    #[test]
    fn dmatdmatmult_identity_and_parallel_consistency() {
        let rt = BaselineRuntime::new(4);
        let n = 64; // 4096 elements > 3025: parallel path
        let a = DynMatrix::random(n, n, 9);
        let eye = DynMatrix::identity(n);
        let mut c = DynMatrix::zeros(n, n);
        dmatdmatmult(&par().on(&rt).threads(4), &a, &eye, &mut c);
        assert!(c.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn dmatdmatmult_small_uses_serial_path() {
        // 10x10 < 3025 threshold: must still be correct under any policy.
        let rt = BaselineRuntime::new(4);
        let a = DynMatrix::random(10, 10, 10);
        let b = DynMatrix::random(10, 10, 11);
        let mut c = DynMatrix::zeros(10, 10);
        dmatdmatmult(&par().on(&rt).threads(4), &a, &b, &mut c);
        // Oracle: naive triple loop.
        let mut c_ref = DynMatrix::zeros(10, 10);
        for i in 0..10 {
            for j in 0..10 {
                let mut s = 0.0;
                for k in 0..10 {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c_ref.at_mut(i, j) = s;
            }
        }
        assert!(c.max_abs_diff(&c_ref) < 1e-12);
    }

    /// Naive dot-product oracle for `y = A * x`.
    fn matvec_oracle(a: &DynMatrix, x: &DynVector) -> DynVector {
        let (m, n) = (a.rows(), a.cols());
        let mut y = DynVector::zeros(m);
        for i in 0..m {
            let mut s = 0.0;
            for j in 0..n {
                s += a.at(i, j) * x.as_slice()[j];
            }
            y.as_mut_slice()[i] = s;
        }
        y
    }

    #[test]
    fn dmatdvecmult_small_uses_serial_path_and_is_correct() {
        // 100 rows < 330 threshold: serial fallback must still be exact.
        let rt = BaselineRuntime::new(4);
        let a = DynMatrix::random(100, 100, 21);
        let x = DynVector::random(100, 22);
        let mut y = DynVector::zeros(100);
        dmatdvecmult(&par().on(&rt).threads(4), &a, &x, &mut y);
        assert!(y.max_abs_diff(&matvec_oracle(&a, &x)) < 1e-12);
    }

    #[test]
    fn dmatdvecmult_parallel_matches_serial_oracle() {
        let rt = BaselineRuntime::new(4);
        let n = 400; // above the 330-row threshold: parallel path
        let a = DynMatrix::random(n, n, 23);
        let x = DynVector::random(n, 24);
        let mut y = DynVector::zeros(n);
        dmatdvecmult(&par().on(&rt).threads(4), &a, &x, &mut y);
        assert_eq!(y.max_abs_diff(&matvec_oracle(&a, &x)), 0.0);
    }

    #[test]
    fn dmatdvecmult_non_square_shapes() {
        let rt = BaselineRuntime::new(4);
        // (m, n) pairs straddling the row threshold, wide and tall.
        for (m, n) in [(400usize, 37usize), (350, 700), (64, 512)] {
            let a = DynMatrix::random(m, n, 25);
            let x = DynVector::random(n, 26);
            let mut y = DynVector::zeros(m);
            dmatdvecmult(&par().on(&rt).threads(4), &a, &x, &mut y);
            assert_eq!(
                y.max_abs_diff(&matvec_oracle(&a, &x)),
                0.0,
                "shape {m}x{n} diverged from the dot-product oracle"
            );
        }
    }

    #[test]
    fn dmatdvecmult_hpxmp_matches_baseline() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let n = 512;
        let a = DynMatrix::random(n, n, 27);
        let x = DynVector::random(n, 28);
        let mut y = DynVector::zeros(n);
        dmatdvecmult(&par().on(&hpx).threads(4), &a, &x, &mut y);
        assert_eq!(y.max_abs_diff(&matvec_oracle(&a, &x)), 0.0);
    }

    #[test]
    fn dmatdmatmult_task_policy_matches_serial_oracle_exactly() {
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        // 30: below threshold (serial path); 64: parallel, even tiles;
        // 130: parallel, ragged edge tiles.
        for n in [30usize, 64, 130] {
            let a = DynMatrix::random(n, n, 31);
            let b = DynMatrix::random(n, n, 32);
            let mut c_df = DynMatrix::zeros(n, n);
            dmatdmatmult(&task().on(&hpx).threads(4).tile(16), &a, &b, &mut c_df);
            let mut c_ref = DynMatrix::zeros(n, n);
            dmatdmatmult(&seq(), &a, &b, &mut c_ref);
            assert_eq!(
                c_df.max_abs_diff(&c_ref),
                0.0,
                "task-policy dataflow diverged from serial oracle at n={n}"
            );
        }
    }

    #[test]
    fn dmatdmatmult_packed_matches_scalar_within_tolerance() {
        // Explicit Packed at a below-floor size, every policy: agrees
        // with the scalar oracle to accumulation tolerance.
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let n = 96;
        let a = DynMatrix::random(n, n, 41);
        let b = DynMatrix::random(n, n, 42);
        let mut c_ref = DynMatrix::zeros(n, n);
        dmatdmatmult(&seq(), &a, &b, &mut c_ref);
        for pol in [
            seq().kernel(KernelVariant::Packed),
            par().on(&hpx).threads(4).kernel(KernelVariant::Packed),
            task()
                .on(&hpx)
                .threads(4)
                .tile(32)
                .kernel(KernelVariant::Packed),
        ] {
            let mut c = DynMatrix::zeros(n, n);
            dmatdmatmult(&pol, &a, &b, &mut c);
            assert!(
                c.max_abs_diff(&c_ref) < 1e-11,
                "packed under {:?} diverged from scalar oracle",
                pol.mode()
            );
        }
    }

    #[test]
    fn dmatdmatmult_packed_is_bitwise_stable_across_policies_and_tiles() {
        // The packed kernel's accumulation is decomposition-independent:
        // serial, par, and task at several tile sizes agree bit-for-bit.
        // Force the parallel packed path with a low threshold knob.
        let hpx = HpxMpRuntime::new(OmpRuntime::for_tests(4));
        let (m, k, n) = (70usize, 90, 110);
        let a = DynMatrix::random(m, k, 43);
        let b = DynMatrix::random(k, n, 44);
        let mut c_ref = DynMatrix::zeros(m, n);
        dmatdmatmult(&seq().kernel(KernelVariant::Packed), &a, &b, &mut c_ref);
        for tile in [16usize, 24, 64] {
            for pol in [
                par().on(&hpx).threads(4).kernel(KernelVariant::Packed),
                task().on(&hpx).threads(4).kernel(KernelVariant::Packed),
            ] {
                let pol = pol.tile(tile).threshold(1);
                let mut c = DynMatrix::zeros(m, n);
                dmatdmatmult(&pol, &a, &b, &mut c);
                assert_eq!(
                    c.max_abs_diff(&c_ref),
                    0.0,
                    "packed {:?} tile={tile} changed numerics",
                    pol.mode()
                );
            }
        }
    }

    #[test]
    fn threshold_knob_moves_the_crossover() {
        // With .threshold(1) a tiny daxpy takes the parallel path and
        // still matches; with a huge threshold a large one stays serial.
        let rt = BaselineRuntime::new(2);
        let a = DynVector::random(100, 45);
        let b0 = DynVector::random(100, 46);
        let mut b_par = b0.clone();
        daxpy(&par().on(&rt).threads(2).threshold(1), 3.0, &a, &mut b_par);
        let mut b_ser = b0.clone();
        serial::daxpy_slice(3.0, a.as_slice(), b_ser.as_mut_slice());
        assert_eq!(b_par.max_abs_diff(&b_ser), 0.0);

        let n = 60_000;
        let a = DynVector::random(n, 47);
        let b0 = DynVector::random(n, 48);
        let mut b_hi = b0.clone();
        daxpy(
            &par().on(&rt).threads(2).threshold(usize::MAX),
            3.0,
            &a,
            &mut b_hi,
        );
        let mut b_ser = b0.clone();
        serial::daxpy_slice(3.0, a.as_slice(), b_ser.as_mut_slice());
        assert_eq!(b_hi.max_abs_diff(&b_ser), 0.0);
    }

    #[test]
    fn flop_counts() {
        assert_eq!(flops::dvecdvecadd(100), 100.0);
        assert_eq!(flops::daxpy(100), 200.0);
        assert_eq!(flops::dmatdmatadd(10), 100.0);
        assert_eq!(flops::dmatdmatmult(10), 2000.0);
        assert_eq!(flops::dmatdvecmult(10), 200.0);
    }
}
