//! Blaze-lite: the dense linear-algebra substrate the paper benchmarks.
//!
//! The paper runs Blazemark (Blaze 3.4's benchmark suite) on top of either
//! OpenMP runtime.  This module rebuilds the relevant slice of Blaze:
//! dynamic vectors/matrices ([`vector`], [`matrix`]), serial kernels
//! ([`serial`]), the tuned micro-kernels and packed cache-blocked matmul
//! ([`kernel`]), the five benchmark operations generic over the
//! [`crate::par::exec::Policy`] seam ([`ops`]), and — crucially for the
//! figures — Blaze's **parallelization thresholds** ([`thresholds`]):
//! below the per-op element-count threshold the operation is executed
//! single-threaded, which is why every paper plot is flat until the
//! threshold and why the heatmaps only show structure to its right.

use crate::par::exec::{self, Policy};

pub mod kernel;
pub mod matrix;
pub mod ops;
pub mod serial;
pub mod thresholds;
pub mod vector;

pub use matrix::DynMatrix;
pub use ops::{daxpy, dmatdmatadd, dmatdmatmult, dmatdvecmult, dvecdvecadd};
pub use vector::DynVector;

/// Block granularity (elements) of first-touch initialization: each
/// block is filled by whichever worker claims it, so under a parallel
/// policy its pages are faulted in — first-touched — on that worker's
/// node.  4096 f64 = two 16 KiB half-pages per block keeps the claim
/// traffic negligible against the page-zeroing cost.
pub(crate) const INIT_BLOCK: usize = 4096;

/// First-touch fill: partition `data` into [`INIT_BLOCK`]-element blocks
/// and run `fill(block_index, block)` on each under `pol`, so the pages
/// of each block are first touched by the worker that executes it.
///
/// `fill` receives the *global* block index, letting callers derive
/// per-block deterministic state (e.g. a reseeded RNG) — the resulting
/// contents are a pure function of `(len, fill)` and therefore bitwise
/// identical across policies and thread counts.
pub(crate) fn first_touch_fill<F>(pol: &Policy<'_>, data: &mut [f64], fill: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    let len = data.len();
    let blocks = len.div_ceil(INIT_BLOCK);
    if pol.is_serial() || blocks < 2 {
        for b in 0..blocks {
            let lo = b * INIT_BLOCK;
            let hi = (lo + INIT_BLOCK).min(len);
            fill(b, &mut data[lo..hi]);
        }
        return;
    }
    let base = ops::SendPtr::new(data.as_mut_ptr());
    let fill_ref = &fill;
    exec::for_each(pol, 0..blocks as i64, move |r| {
        for b in r {
            let lo = b as usize * INIT_BLOCK;
            let hi = (lo + INIT_BLOCK).min(len);
            // SAFETY: blocks partition `data` disjointly and for_each
            // joins before returning, so no aliasing or escape.
            fill_ref(b as usize, unsafe { base.slice_range(lo, hi) });
        }
    });
}
