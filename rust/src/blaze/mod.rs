//! Blaze-lite: the dense linear-algebra substrate the paper benchmarks.
//!
//! The paper runs Blazemark (Blaze 3.4's benchmark suite) on top of either
//! OpenMP runtime.  This module rebuilds the relevant slice of Blaze:
//! dynamic vectors/matrices ([`vector`], [`matrix`]), serial kernels
//! ([`serial`]), the five benchmark operations generic over the
//! [`crate::par::exec::Policy`] seam ([`ops`]), and — crucially for the
//! figures — Blaze's **parallelization thresholds** ([`thresholds`]):
//! below the per-op element-count threshold the operation is executed
//! single-threaded, which is why every paper plot is flat until the
//! threshold and why the heatmaps only show structure to its right.

pub mod matrix;
pub mod ops;
pub mod serial;
pub mod thresholds;
pub mod vector;

pub use matrix::DynMatrix;
pub use ops::{daxpy, dmatdmatadd, dmatdmatmult, dmatdvecmult, dvecdvecadd};
pub use vector::DynVector;
