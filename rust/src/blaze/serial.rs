//! Serial kernels: the single-threaded code both runtimes parallelize.
//!
//! Shared by the serial fallback (below threshold), the parallel chunk
//! bodies (each chunk calls these on its sub-range) and the test oracles.
//! Hot loops are written so LLVM auto-vectorizes them (no bounds checks in
//! the inner loop, slice-zip form).

/// `b[i] += beta * a[i]` — daxpy (paper §6.2, beta = 3.0 in Blazemark).
#[inline]
pub fn daxpy_slice(beta: f64, a: &[f64], b: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    for (bi, ai) in b.iter_mut().zip(a.iter()) {
        *bi += beta * *ai;
    }
}

/// `c[i] = a[i] + b[i]` — dense vector addition (paper §6.1).
#[inline]
pub fn vadd_slice(a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    for ((ci, ai), bi) in c.iter_mut().zip(a.iter()).zip(b.iter()) {
        *ci = *ai + *bi;
    }
}

/// One row-band of `C = A + B` (paper §6.3): slices are whole rows.
#[inline]
pub fn madd_rows(a: &[f64], b: &[f64], c: &mut [f64]) {
    vadd_slice(a, b, c);
}

/// One row of `C = A * B` (paper §6.4): `c_row = a_row * B`, ikj order so
/// the inner loop streams B and C rows (cache-friendly, vectorizable).
#[inline]
pub fn matmul_row(a_row: &[f64], b: &[f64], n: usize, c_row: &mut [f64]) {
    let k_dim = a_row.len();
    debug_assert_eq!(b.len(), k_dim * n);
    debug_assert_eq!(c_row.len(), n);
    c_row.fill(0.0);
    for (k, &aik) in a_row.iter().enumerate().take(k_dim) {
        let b_row = &b[k * n..(k + 1) * n];
        for (cj, bj) in c_row.iter_mut().zip(b_row.iter()) {
            *cj += aik * *bj;
        }
    }
}

/// A row band of `y = A * x` (dmatdvecmult, the paper suite's dense
/// matrix-vector product): `a` holds `y.len()` consecutive rows of A
/// (row-major, `x.len()` columns each), and `y[i]` receives the dot
/// product of row `i` with `x`.  Plain accumulate-in-register form so the
/// inner loop vectorizes (slice-zip, no bounds checks).
#[inline]
pub fn matvec_rows(a: &[f64], x: &[f64], y: &mut [f64]) {
    let n = x.len();
    debug_assert_eq!(a.len(), y.len() * n);
    for (i, yi) in y.iter_mut().enumerate() {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for (aij, xj) in row.iter().zip(x.iter()) {
            acc += *aij * *xj;
        }
        *yi = acc;
    }
}

/// One row *segment* of `C = A * B` (the tiled dataflow decomposition's
/// inner kernel): `c_seg = C[i, j0..j0+c_seg.len()]`, full-depth k
/// accumulation in increasing k — the same summation order as
/// [`matmul_row`], so tiled and row-wise products agree bit-for-bit.
#[inline]
pub fn matmul_row_seg(a_row: &[f64], b: &[f64], n: usize, j0: usize, c_seg: &mut [f64]) {
    let k_dim = a_row.len();
    let w = c_seg.len();
    debug_assert_eq!(b.len(), k_dim * n);
    debug_assert!(j0 + w <= n);
    c_seg.fill(0.0);
    for (k, &aik) in a_row.iter().enumerate().take(k_dim) {
        let b_seg = &b[k * n + j0..k * n + j0 + w];
        for (cj, bj) in c_seg.iter_mut().zip(b_seg.iter()) {
            *cj += aik * *bj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn daxpy_matches_definition() {
        let a = [1.0, 2.0, 3.0];
        let mut b = [10.0, 20.0, 30.0];
        daxpy_slice(3.0, &a, &mut b);
        assert_eq!(b, [13.0, 26.0, 39.0]);
    }

    #[test]
    fn vadd_matches_definition() {
        let a = [1.0, 2.0];
        let b = [0.5, 0.25];
        let mut c = [0.0; 2];
        vadd_slice(&a, &b, &mut c);
        assert_eq!(c, [1.5, 2.25]);
    }

    #[test]
    fn matmul_row_identity() {
        // B = I(3): c_row == a_row.
        let b = [1., 0., 0., 0., 1., 0., 0., 0., 1.];
        let a_row = [3.0, 4.0, 5.0];
        let mut c_row = [0.0; 3];
        matmul_row(&a_row, &b, 3, &mut c_row);
        assert_eq!(c_row, a_row);
    }

    #[test]
    fn matmul_row_known_product() {
        // A row [1,2] times B=[[1,2],[3,4]] = [7,10].
        let b = [1., 2., 3., 4.];
        let a_row = [1.0, 2.0];
        let mut c_row = [0.0; 2];
        matmul_row(&a_row, &b, 2, &mut c_row);
        assert_eq!(c_row, [7.0, 10.0]);
    }

    #[test]
    fn matvec_rows_identity_and_known_product() {
        // A = I(3): y == x.
        let a = [1., 0., 0., 0., 1., 0., 0., 0., 1.];
        let x = [3.0, 4.0, 5.0];
        let mut y = [0.0; 3];
        matvec_rows(&a, &x, &mut y);
        assert_eq!(y, x);
        // A = [[1,2],[3,4]], x = [1,2] => y = [5, 11].
        let a = [1., 2., 3., 4.];
        let x = [1.0, 2.0];
        let mut y = [0.0; 2];
        matvec_rows(&a, &x, &mut y);
        assert_eq!(y, [5.0, 11.0]);
    }

    #[test]
    fn matvec_rows_non_square_band() {
        // 3x2 matrix (non-square): each row dotted with a length-2 x.
        let a = [1., 2., 3., 4., 5., 6.];
        let x = [10.0, 100.0];
        let mut y = [0.0; 3];
        matvec_rows(&a, &x, &mut y);
        assert_eq!(y, [210.0, 430.0, 650.0]);
    }

    #[test]
    fn matmul_row_seg_matches_full_row() {
        let b = [1., 2., 3., 4., 5., 6., 7., 8., 9.];
        let a_row = [0.5, -1.0, 2.0];
        let mut full = [0.0; 3];
        matmul_row(&a_row, &b, 3, &mut full);
        for (j0, w) in [(0usize, 3usize), (0, 2), (1, 2), (2, 1)] {
            let mut seg = vec![0.0; w];
            matmul_row_seg(&a_row, &b, 3, j0, &mut seg);
            assert_eq!(&seg[..], &full[j0..j0 + w], "segment ({j0},{w})");
        }
    }
}
